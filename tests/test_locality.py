"""Section IV locality-optimization tests (Table II reproduction), plus
exact-parity checks of the vectorized locality kernels against the original
pure-Python loop implementations (kept here as references)."""
import numpy as np
import pytest

from repro.core.params import SchemeParams
from repro.core.assignment import (hybrid_assignment, hybrid_slots,
                                   check_hybrid_constraints, rack_subsets)
from repro.core.locality import (
    greedy_perm, group_servers, locality_matrix, locality_of_perm,
    optimal_perm, place_replicas, random_perm, table2_experiment,
)


def _params(K, P, rf, N):
    return SchemeParams(K, P, Q=K, N=N, r=2, r_f=rf)


# ---------------------------------------------------------------------------
# Reference loop implementations (the pre-vectorization code, verbatim):
# the incidence-matmul versions must match them EXACTLY.
# ---------------------------------------------------------------------------

def _locality_matrix_loops(p, replicas, lam=0.8):
    groups = group_servers(p)
    C = np.zeros((p.N, len(groups)))
    replica_racks = [set(p.rack_of(int(s)) for s in replicas[i])
                     for i in range(p.N)]
    replica_servers = [set(int(s) for s in replicas[i]) for i in range(p.N)]
    for g, servers in enumerate(groups):
        racks = [p.rack_of(s) for s in servers]
        for i in range(p.N):
            node = sum(1 for s in servers if s in replica_servers[i])
            rack = sum(1 for rk in racks if rk in replica_racks[i])
            C[i, g] = lam * node + (1.0 - lam) * rack
    return C


def _locality_of_perm_loops(p, replicas, perm):
    groups = group_servers(p)
    slots = hybrid_slots(p)
    subsets = rack_subsets(p.P, p.r)
    node_hits = rack_hits = 0
    for slot_index, (layer, t_idx, _w) in enumerate(slots):
        i = perm[slot_index]
        servers = groups[layer * len(subsets) + t_idx]
        rset = set(int(s) for s in replicas[i])
        rracks = set(p.rack_of(int(s)) for s in replicas[i])
        node_hits += sum(1 for s in servers if s in rset)
        rack_hits += sum(1 for s in servers if p.rack_of(s) in rracks)
    return node_hits / (p.N * p.r), rack_hits / (p.N * p.r)


@pytest.mark.parametrize("K,P,rf,N", [
    (8, 2, 2, 160), (9, 3, 3, 90), (16, 4, 2, 192), (10, 5, 2, 100),
    (21, 3, 2, 84),
])
def test_vectorized_locality_matches_loops_exactly(K, P, rf, N):
    p = _params(K, P, rf, N)
    rng = np.random.default_rng(K * N)
    reps = place_replicas(p, rng)
    np.testing.assert_array_equal(locality_matrix(p, reps),
                                  _locality_matrix_loops(p, reps))
    perm = rng.permutation(p.N)
    assert locality_of_perm(p, reps, perm) == \
        _locality_of_perm_loops(p, reps, perm)


def test_replica_placement_distinct():
    p = _params(8, 2, 3, 32)
    rng = np.random.default_rng(0)
    for policy in ("uniform", "hdfs"):
        reps = place_replicas(p, rng, policy)
        assert reps.shape == (p.N, p.r_f)
        for row in reps:
            assert len(set(row.tolist())) == p.r_f


def test_hdfs_policy_spans_two_racks():
    p = _params(8, 2, 3, 32)
    rng = np.random.default_rng(1)
    reps = place_replicas(p, rng, "hdfs")
    for row in reps:
        racks = {p.rack_of(int(s)) for s in row}
        assert len(racks) == 2  # replica 2 in another rack, replica 3 with it


def test_locality_matrix_range():
    p = _params(9, 3, 2, 36)
    rng = np.random.default_rng(2)
    reps = place_replicas(p, rng)
    C = locality_matrix(p, reps, lam=0.8)
    assert C.min() >= 0.0
    # max possible: lam*r + (1-lam)*r with r=2
    assert C.max() <= 2.0 + 1e-9


def test_lambda_validation():
    p = _params(8, 2, 2, 32)
    reps = place_replicas(p, np.random.default_rng(0))
    with pytest.raises(ValueError):
        locality_matrix(p, reps, lam=0.5)   # paper requires lam in (0.5, 1]


def test_optimal_perm_is_valid_assignment():
    p = _params(9, 3, 2, 36)
    rng = np.random.default_rng(3)
    reps = place_replicas(p, rng)
    C = locality_matrix(p, reps)
    perm = optimal_perm(p, C)
    assert sorted(perm.tolist()) == list(range(p.N))
    check_hybrid_constraints(hybrid_assignment(p, perm))


def test_optimal_beats_random_and_greedy_le_optimal():
    p = _params(16, 4, 2, 96)
    rng = np.random.default_rng(4)
    reps = place_replicas(p, rng)
    C = locality_matrix(p, reps)
    rp, gp, op = random_perm(p, rng), greedy_perm(p, C), optimal_perm(p, C)

    def score(perm):
        n, r = locality_of_perm(p, reps, perm)
        return n, r

    def objective(perm):
        # the Theorem IV.1 objective value of a permutation
        from repro.core.assignment import hybrid_slots, rack_subsets
        subsets = rack_subsets(p.P, p.r)
        tot = 0.0
        for slot_index, (layer, t_idx, _w) in enumerate(hybrid_slots(p)):
            tot += C[perm[slot_index], layer * len(subsets) + t_idx]
        return tot

    assert objective(op) >= objective(gp) - 1e-9
    assert objective(op) >= objective(rp) - 1e-9
    assert score(op)[0] > score(rp)[0]  # node locality strictly improves


@pytest.mark.parametrize("K,P,rf,N,node_ran,node_opt,rack_ran,rack_opt", [
    (8, 2, 2, 160, 0.25, 0.60, 0.80, 0.80),    # Table II row 1
    (9, 3, 2, 144, 0.17, 0.64, 0.57, 0.86),    # Table II row 3
    (16, 4, 2, 192, 0.10, 0.64, 0.45, 0.90),   # Table II row 6
])
def test_table2_reproduction(K, P, rf, N, node_ran, node_opt, rack_ran,
                             rack_opt):
    """Reproduce Table II within tolerance (paper used unspecified seeds)."""
    p = _params(K, P, rf, N)
    res = table2_experiment(p, trials=4, seed=0)
    assert res.node_random == pytest.approx(node_ran, abs=0.09)
    assert res.node_opt == pytest.approx(node_opt, abs=0.10)
    assert res.rack_random == pytest.approx(rack_ran, abs=0.09)
    assert res.rack_opt == pytest.approx(rack_opt, abs=0.10)
    # the qualitative claim: optimization improves node locality a lot
    assert res.node_opt > res.node_random + 0.2


def test_table2_experiment_reports_std():
    """n_trials averaging upgrade: LocalityResult now carries per-metric
    std; multiple random-placement instances have nonzero spread while a
    single trial has exactly zero."""
    p = _params(9, 3, 2, 144)
    multi = table2_experiment(p, trials=4, seed=0)
    assert multi.node_random_std > 0.0
    assert multi.node_opt_std >= 0.0
    single = table2_experiment(p, trials=1, seed=0)
    assert single.node_opt_std == single.node_random_std == 0.0


def test_table2_trials_full_suite_beats_random_on_paper_row():
    """Registry-wide Table II check on row (9,3,2,144): every non-random
    solver's mean node locality beats the random baseline."""
    from repro.placement import table2_trials
    p = _params(9, 3, 2, 144)
    res = table2_trials(p, seed=0, n_trials=2,
                        solvers=("random", "greedy", "flow", "local_search",
                                 "anneal_jax"),
                        per_solver_kwargs={"anneal_jax": {"n_chains": 8,
                                                          "n_steps": 150}})
    base = res.stats["random"].node_mean
    for name, s in res.stats.items():
        if name != "random":
            assert s.node_mean > base, name
    assert res.stats["flow"].objective_mean >= \
        res.stats["greedy"].objective_mean - 1e-9


def test_rf3_improves_locality_over_rf2():
    p2 = _params(9, 3, 2, 90)
    p3 = _params(9, 3, 3, 90)
    r2 = table2_experiment(p2, trials=3, seed=1)
    r3 = table2_experiment(p3, trials=3, seed=1)
    assert r3.node_opt > r2.node_opt   # more replicas => easier locality
