"""Hypothesis property tests on the system's invariants.

The paper's scheme is combinatorial — exactly what property testing is
for: for RANDOM valid (K, P, Q, N, r) the structural constraints of
Theorem IV.1 must hold, the closed forms must equal the enumerated
schedules, and the coded encode/decode must round-trip for random shapes
and coefficients.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.assignment import (check_hybrid_constraints,
                                   coded_assignment, hybrid_assignment,
                                   uncoded_assignment)
from repro.core.costs import coded_cost, hybrid_cost, uncoded_cost
from repro.core.params import SchemeParams
from repro.core.shuffle_plan import count_plan, make_plan


@st.composite
def hybrid_params(draw):
    P_ = draw(st.integers(2, 5))
    Kr = draw(st.integers(1, 4))
    K = P_ * Kr
    r = draw(st.integers(2, min(P_, 3)))
    # the enumerated schedule additionally needs r | M (each of the r
    # replica servers sources M/r subfiles of a coded exchange)
    M = r * draw(st.integers(1, 2))
    N = math.comb(P_, r) * M * Kr
    q_mult = draw(st.integers(1, 3))
    return SchemeParams(K=K, P=P_, Q=K * q_mult, N=N, r=r)


@settings(max_examples=25, deadline=None)
@given(hybrid_params())
def test_hybrid_structure_invariants(p):
    """Theorem IV.1's four constraints hold for every valid hybrid
    assignment AND for random permutations of it (the Sec. IV degree of
    freedom)."""
    a = hybrid_assignment(p)
    check_hybrid_constraints(a)
    rng = np.random.default_rng(abs(hash((p.K, p.P, p.N, p.r))) % 2 ** 31)
    a2 = hybrid_assignment(p, perm=rng.permutation(p.N).tolist())
    check_hybrid_constraints(a2)
    # every subfile mapped at exactly r servers, one per rack in its subset
    for servers in a2.servers_of_subfile:
        assert len(servers) == p.r
        assert len({p.rack_of(s) for s in servers}) == p.r


@settings(max_examples=25, deadline=None)
@given(hybrid_params())
def test_hybrid_cost_formula_equals_schedule(p):
    """Thm III.1 closed form == enumerated message schedule, exactly."""
    a = hybrid_assignment(p)
    counts = count_plan(make_plan(a), p)
    c = hybrid_cost(p)
    assert counts.cross == int(round(c.cross)), (counts.cross, c.cross)
    assert counts.intra == int(round(c.intra)), (counts.intra, c.intra)


@settings(max_examples=25, deadline=None)
@given(hybrid_params())
def test_uncoded_cost_formula_equals_schedule(p):
    if p.N % p.K:
        return
    a = uncoded_assignment(p)
    counts = count_plan(make_plan(a), p)
    c = uncoded_cost(p)
    assert counts.cross == int(round(c.cross))
    assert counts.intra == int(round(c.intra))


@settings(max_examples=20, deadline=None)
@given(hybrid_params())
def test_hybrid_beats_uncoded_cross_rack(p):
    """The paper's headline claim: L_cro^Hyb <= L_cro^Unc always (with
    equality only in degenerate corners)."""
    hy = hybrid_cost(p)
    un = uncoded_cost(p, check=False)
    assert hy.cross <= un.cross + 1e-9
    if p.r >= 2 and p.P > p.r:
        assert hy.cross < un.cross


@st.composite
def family_plan_params(draw):
    """(family, params) valid for that plan-compiler family — binomial via
    the C(P,r)-subset sizing above, resolvable via q = P/r parallel
    classes with q^{r-1} batches and (r-1) shares per missing block."""
    family = draw(st.sampled_from(["binomial", "resolvable"]))
    if family == "binomial":
        return family, draw(hybrid_params())
    r = draw(st.integers(2, 3))
    q = draw(st.integers(2, 4 if r == 2 else 3))
    P_ = q * r
    Kr = draw(st.integers(1, 2))
    K = P_ * Kr
    M = (r - 1) * draw(st.integers(1, 2))
    N = q ** (r - 1) * M * Kr * K // P_
    return family, SchemeParams(K=K, P=P_, Q=K * draw(st.integers(1, 2)),
                                N=N, r=r)


@settings(max_examples=25, deadline=None)
@given(family_plan_params(), st.sampled_from(["unicast", "coded"]))
def test_any_registered_compiler_passes_shuffle_oracle(fp, multicast):
    """EVERY registered plan-compiler family (the tentpole registry) emits
    plans whose NumPy re-execution — multicast packets decoded against
    side information — reproduces the dense all-to-all reference
    bit-exactly, in both wire formats."""
    from repro.core.coded_collectives import (compile_hybrid_plan,
                                              plan_shuffle_reference,
                                              simulate_plan_shuffle)
    family, p = fp
    plan = compile_hybrid_plan(p, family=family)
    rng = np.random.default_rng(abs(hash((family, p.K, p.N, p.r))) % 2 ** 31)
    V = rng.integers(-50, 50, size=(p.N, p.Q, 2)).astype(np.float32)
    ref = plan_shuffle_reference(V, p, family=family)
    got = simulate_plan_shuffle(V, plan, multicast=multicast)
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=25, deadline=None)
@given(family_plan_params())
def test_resolvable_cost_formula_equals_schedule(fp):
    """Closed-form resolvable costs == enumerated message schedule, and
    the strict execute_plan decodability proof holds for random params."""
    family, p = fp
    if family != "resolvable":
        return
    from repro.core.costs import hybrid_resolvable_cost
    from repro.core.resolvable import resolvable_assignment
    from repro.core.shuffle_plan import execute_plan
    a = resolvable_assignment(p)
    counts = count_plan(make_plan(a), p)
    c = hybrid_resolvable_cost(p)
    assert counts.cross == int(round(c.cross))
    assert counts.intra == int(round(c.intra))
    rng = np.random.default_rng(p.N % 2 ** 16)
    V = rng.integers(0, 100, size=(p.N, p.Q))
    execute_plan(a, V, strict=True)


@st.composite
def coded_params(draw):
    K = draw(st.integers(3, 6))
    r = draw(st.integers(2, K - 1))
    J = r * draw(st.integers(1, 2))     # schedule needs r | J
    N = math.comb(K, r) * J
    P_ = draw(st.sampled_from([d for d in range(2, K + 1) if K % d == 0]))
    return SchemeParams(K=K, P=P_, Q=K, N=N, r=r)


@settings(max_examples=20, deadline=None)
@given(coded_params())
def test_coded_total_cost_formula(p):
    """Prop 2 total == (QN/r)(1 - r/K) == enumerated schedule total."""
    c = coded_cost(p)
    want = p.Q * p.N / p.r * (1 - p.r / p.K)
    assert abs(c.total - want) < 1e-6
    a = coded_assignment(p)
    counts = count_plan(make_plan(a), p)
    assert counts.intra + counts.cross == int(round(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 4), st.integers(1, 5), st.integers(1, 4),
       st.data())
def test_coded_combine_roundtrip(r, t_mult, d_mult, data):
    """f(.) encode -> decode recovers any missing stream exactly, for any
    nonzero coefficients (the property eq. (1) relies on)."""
    from repro.kernels.coded_combine import ops
    T, d = 32 * t_mult, 32 * d_mult
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2 ** 20)))
    streams = [jax.random.normal(jax.random.fold_in(key, i), (T, d))
               for i in range(r)]
    coeffs = jnp.asarray(
        data.draw(st.lists(st.floats(0.5, 4.0), min_size=r, max_size=r)),
        jnp.float32)
    f = ops.coded_encode(streams, coeffs)
    miss = data.draw(st.integers(0, r - 1))
    known = [s for i, s in enumerate(streams) if i != miss]
    cs = jnp.concatenate([coeffs[miss:miss + 1],
                          jnp.delete(coeffs, miss, assume_unique_indices=True)])
    dec = ops.coded_decode(f, known, cs)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(streams[miss]),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 16))
def test_pipeline_determinism(seed):
    """batch_at(step) is a pure function — the checkpoint/restart
    contract of the data pipeline."""
    from repro.configs import ARCHS
    from repro.data.pipeline import SyntheticPipeline
    pipe = SyntheticPipeline(ARCHS["granite-3-2b"].reduced(), 2, 16,
                             seed=seed)
    a = pipe.batch_at(7)
    b = pipe.batch_at(7)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6))
def test_chunk_table_covers_pairs(P_):
    """Every C(P,2) chunk is owned by exactly its 2 member pods (the r=2
    replication structure the coded gradient sync relies on)."""
    from repro.core.gradient_sync import chunk_index_table
    table = chunk_index_table(P_)
    n_chunks = P_ * (P_ - 1) // 2
    counts = np.zeros(n_chunks, int)
    for row in table:
        for c in row:
            counts[c] += 1
    assert (counts == 2).all()


@st.composite
def recoverable_failures(draw):
    """(family, r, failure set) with at most r-1 failed servers per layer
    replica-group — the regime the degraded compiler must decode around
    with ZERO re-mapped subfiles (Theorem IV.1's replication read as an
    erasure code)."""
    family, r = draw(st.sampled_from(
        [("binomial", 2), ("binomial", 3), ("resolvable", 2)]))
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=r)
    failed = []
    for j in range(p.Kr):                    # per layer-group j
        racks = draw(st.lists(st.integers(0, p.P - 1), unique=True,
                              max_size=r - 1))
        failed += [z * p.Kr + j for z in racks]
    return family, p, tuple(sorted(failed))


@settings(max_examples=40, deadline=None)
@given(recoverable_failures())
def test_degraded_plan_decodes_around_every_recoverable_failure(case):
    """PROPERTY: for every family and every <= r-1-per-group failure set,
    the degraded plan re-maps nothing and the recovered shuffle is
    bit-identical to the failure-free oracle."""
    from repro.core.coded_collectives import (plan_shuffle_reference,
                                              simulate_plan_shuffle)
    from repro.core.degraded import compile_degraded_plan
    family, p, failed = case
    dplan = compile_degraded_plan(p, failed, family=family)
    assert dplan.decode_around
    rng = np.random.default_rng(len(failed))
    V = rng.integers(-50, 50, size=(p.N, p.Q, 2)).astype(np.float32)
    out = simulate_plan_shuffle(V, dplan.plan, failed=dplan.failed)
    np.testing.assert_array_equal(
        out, plan_shuffle_reference(V, p, family=family))
