"""Multi-device collective tests run in a subprocess so the main pytest
process keeps its single-device view (jax locks device count at init)."""
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_driver(name: str, needle: str) -> None:
    import os
    full_env = dict(os.environ)
    full_env.update({"PYTHONPATH": str(ROOT / "src")})
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "multidevice" / name)],
        capture_output=True, text=True, env=full_env, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert needle in proc.stdout


@pytest.mark.slow
def test_multidevice_shuffle_and_collectives():
    _run_driver("driver_shuffle.py", "ALL MULTIDEVICE TESTS PASSED")


@pytest.mark.slow
def test_multidevice_trainer_paths():
    _run_driver("driver_trainer.py", "ALL TRAINER MULTIDEVICE TESTS PASSED")
