"""Subprocess driver: trainer-level multi-pod paths — coded_r2 training
steps on a (pod, data) mesh, hierarchical collectives, and the dry-run
machinery on a miniature mesh.  Spawned by tests/test_multidevice.py."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
from jax.sharding import PartitionSpec as P                    # noqa: E402

from repro.configs import ARCHS                                # noqa: E402
from repro.data.pipeline import SyntheticPipeline              # noqa: E402
from repro.distributed.collectives import (                    # noqa: E402
    flat_all_to_all, hierarchical_all_to_all)
from repro.distributed.meshes import make_mesh, shard_map      # noqa: E402
from repro.train.optimizer import OptimizerConfig              # noqa: E402
from repro.train.trainer import (TrainConfig,                  # noqa: E402
                                 init_train_state,
                                 make_coded_batch_r2, make_train_step)

CFG = ARCHS["qwen2-1.5b"].reduced()


def test_coded_r2_training_descends():
    mesh = make_mesh((4, 2), ("pod", "data"))
    tc = TrainConfig(remat=False, dense_moe=True, dp_mode="coded_r2",
                     opt=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                         decay_steps=30))
    state = init_train_state(jax.random.PRNGKey(0), CFG, tc)
    pipe = SyntheticPipeline(CFG, global_batch=12, seq_len=24)
    step = jax.jit(make_train_step(CFG, tc, mesh=mesh, donate=False))
    losses = []
    for i in range(6):
        cb = make_coded_batch_r2(pipe.batch_at(i), 4)
        state, m = step(state, cb)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    print("coded_r2 training descends:", [f"{l:.3f}" for l in losses])


def test_hierarchical_a2a_equals_flat():
    mesh = make_mesh((2, 4), ("pod", "data"))
    x = jnp.arange(8 * 8 * 6, dtype=jnp.float32).reshape(8, 8, 6)

    def run(fn):
        f = shard_map(lambda a: fn(a[0])[None], mesh=mesh,
                      in_specs=(P(("pod", "data")),),
                      out_specs=P(("pod", "data")))
        return np.asarray(f(x))
    h = run(lambda a: hierarchical_all_to_all(a, "data", "pod"))
    fl = run(lambda a: flat_all_to_all(a, "data", "pod"))
    np.testing.assert_array_equal(h, fl)
    print("hierarchical a2a == flat a2a")


def test_sequence_tp_loss_unchanged():
    """Megatron-SP sharding must not change the math."""
    from repro.distributed import sharding as shlib
    from repro.models import lm
    from repro.models.frontends import make_train_batch
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = ARCHS["granite-3-2b"].reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=16)
    l_ref, _ = lm.lm_loss(params, cfg, batch)
    rules = shlib.with_sequence_tp(shlib.default_rules(multi_pod=False))
    pol = shlib.ShardingPolicy(mesh, rules)
    with mesh:
        with shlib.use_policy(pol):
            l_sp, _ = jax.jit(lambda p, b: lm.lm_loss(p, cfg, b))(params,
                                                                  batch)
    assert abs(float(l_ref) - float(l_sp)) < 1e-4, (l_ref, l_sp)
    print(f"sequence-TP loss identical: {float(l_ref):.5f}")


if __name__ == "__main__":
    test_coded_r2_training_descends()
    test_hierarchical_a2a_equals_flat()
    test_sequence_tp_loss_unchanged()
    print("ALL TRAINER MULTIDEVICE TESTS PASSED")
