"""Subprocess driver: validates the DISTRIBUTED shard_map shuffle and the
coded gradient collectives on a multi-device host mesh.

Run as:  python tests/multidevice/driver_shuffle.py
(spawned by tests/test_multidevice.py so the main pytest process keeps its
single-device view).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
from jax.sharding import PartitionSpec as P                    # noqa: E402

from repro.core.params import SchemeParams                     # noqa: E402
from repro.core.coded_collectives import (                     # noqa: E402
    compile_hybrid_plan, compile_hybrid_plan_r2, hybrid_shuffle,
    hybrid_shuffle_r2, pack_local_values, plan_shuffle_reference)
from repro.core.gradient_sync import (                         # noqa: E402
    chunk_index_table, coded_reduce_scatter_r2, hierarchical_allreduce,
    uncoded_reduce_scatter)
from repro.distributed.meshes import make_mesh, shard_map      # noqa: E402
from repro.mapreduce.engine import run_job, run_job_distributed  # noqa: E402
from repro.mapreduce.jobs import histogram_job, groupby_mean_job  # noqa: E402


def test_distributed_hybrid_shuffle():
    # P=4 racks x Kr=2 servers = 8 devices; N=48 satisfies C(4,r) | NP/K
    # and r | M for every r in {1, 2, 3} — the paper's tradeoff sweep
    mesh = make_mesh((4, 2), ("rack", "server"))
    for r in (1, 2, 3):
        p = SchemeParams(K=8, P=4, Q=16, N=48, r=r)
        plan = compile_hybrid_plan(p)
        rng = np.random.default_rng(r)
        V = rng.integers(-100, 100, size=(p.N, p.Q, 3)).astype(np.float32)
        local = pack_local_values(V, plan)
        out = np.asarray(hybrid_shuffle(jnp.asarray(local), plan, mesh))
        ref = plan_shuffle_reference(V, p)
        np.testing.assert_array_equal(out, ref)
        print(f"distributed hybrid shuffle r={r}: OK (bit-exact vs oracle)")

    # r=2 back-compat aliases: identical program, identical output
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    plan = compile_hybrid_plan_r2(p)
    rng = np.random.default_rng(2)
    V = rng.integers(-100, 100, size=(p.N, p.Q, 3)).astype(np.float32)
    out = np.asarray(hybrid_shuffle_r2(
        jnp.asarray(pack_local_values(V, plan)), plan, mesh))
    np.testing.assert_array_equal(out, plan_shuffle_reference(V, p))
    print("hybrid_shuffle_r2 alias: OK (unchanged behavior)")


def test_coded_multicast_shuffle():
    """Stage-1 coded multicast wire format (the paper's f(.) with receiver-
    side decode from replicated-map side information): bit-exact vs the
    oracle for r in {2, 3}, under both the XLA and the Pallas coded_combine
    implementations, in sum and GF(2)/XOR codecs."""
    mesh = make_mesh((4, 2), ("rack", "server"))
    for r in (2, 3):
        p = SchemeParams(K=8, P=4, Q=16, N=48, r=r)
        plan = compile_hybrid_plan(p)
        rng = np.random.default_rng(10 + r)
        V = rng.integers(-100, 100, size=(p.N, p.Q, 3)).astype(np.float32)
        local = jnp.asarray(pack_local_values(V, plan))
        ref = plan_shuffle_reference(V, p)
        for impl in ("xla", "pallas"):
            out = np.asarray(hybrid_shuffle(local, plan, mesh,
                                            multicast="coded",
                                            combine_impl=impl))
            np.testing.assert_array_equal(out, ref)
        Vi = rng.integers(0, 2 ** 30, size=(p.N, p.Q, 3)).astype(np.int32)
        li = jnp.asarray(pack_local_values(Vi, plan))
        refi = plan_shuffle_reference(Vi, p)
        for impl in ("xla", "pallas"):
            out = np.asarray(hybrid_shuffle(li, plan, mesh,
                                            multicast="coded_xor",
                                            combine_impl=impl))
            np.testing.assert_array_equal(out, refi)
        print(f"coded multicast shuffle r={r}: OK "
              "(sum+xor, xla+pallas, bit-exact)")


def test_fused_pipeline_parity():
    """The single jitted device-resident map->pack->shuffle->reduce program
    is bit-exact vs the run_job oracle for r in {1, 2, 3}, including under
    coded multicast and the Pallas combine kernels."""
    mesh = make_mesh((4, 2), ("rack", "server"))
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    rng = np.random.default_rng(20)
    job = histogram_job()
    subs = np.asarray(rng.integers(0, 1 << 16, size=(p.N, 256)), np.int32)
    ref = run_job(job, jnp.asarray(subs), p, "hybrid")
    for r in (1, 2, 3):
        got = run_job_distributed(job, subs, p, mesh, r=r, fused=True)
        np.testing.assert_array_equal(np.asarray(got.outputs),
                                      np.asarray(ref.outputs))
        print(f"fused pipeline r={r}: OK (bit-exact vs run_job)")
    got = run_job_distributed(job, subs, p, mesh, fused=True,
                              multicast="coded", combine_impl="pallas")
    np.testing.assert_array_equal(np.asarray(got.outputs),
                                  np.asarray(ref.outputs))
    print("fused pipeline coded/pallas: OK (bit-exact)")

    job2 = groupby_mean_job()
    rows = jnp.asarray(rng.normal(size=(p.N, 128, 2)) * 100, jnp.float32)
    ref2 = run_job(job2, rows, p, "hybrid")
    got2 = run_job_distributed(job2, np.asarray(rows), p, mesh, fused=True)
    np.testing.assert_allclose(np.asarray(got2.outputs),
                               np.asarray(ref2.outputs), rtol=1e-5)
    print("fused groupby job: OK")


def test_distributed_mapreduce_jobs():
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    mesh = make_mesh((4, 2), ("rack", "server"))
    rng = np.random.default_rng(1)

    job = histogram_job()
    subfiles = jnp.asarray(rng.integers(0, 1 << 16, size=(p.N, 256)),
                           dtype=jnp.int32)
    ref = run_job(job, subfiles, p, "hybrid")
    # legacy host-round-trip path (the fused default has its own test)
    got = run_job_distributed(job, np.asarray(subfiles), p, mesh, fused=False)
    np.testing.assert_allclose(np.asarray(got.outputs),
                               np.asarray(ref.outputs), rtol=0, atol=0)
    assert got.cross_cost == ref.cross_cost
    print("distributed histogram job (legacy path): OK")

    # the r knob: same job, r=3 replication — same bit-exact outputs,
    # lower cross-rack cost
    got3 = run_job_distributed(job, np.asarray(subfiles), p, mesh, r=3,
                               fused=False)
    np.testing.assert_allclose(np.asarray(got3.outputs),
                               np.asarray(ref.outputs), rtol=0, atol=0)
    assert got3.cross_cost < got.cross_cost
    print("distributed histogram job r=3 knob: OK")

    job = groupby_mean_job()
    rows = jnp.asarray(rng.normal(size=(p.N, 128, 2)) * 100, jnp.float32)
    ref = run_job(job, rows, p, "hybrid")
    got = run_job_distributed(job, np.asarray(rows), p, mesh, fused=False)
    np.testing.assert_allclose(np.asarray(got.outputs),
                               np.asarray(ref.outputs), rtol=1e-5)
    print("distributed groupby job (legacy path): OK")


def test_faulted_recovery_ladder():
    """Crash recovery on the 8-device mesh: for both plan families and
    every r, a mid-shuffle crash recovers to BIT-IDENTICAL outputs via the
    correct ladder rung — decode-around (f <= r-1 per group, nothing
    re-mapped), partial re-map (r=1 orphans), or bounded restart."""
    from repro.resilience import FaultInjector, FaultSpec

    mesh = make_mesh((4, 2), ("rack", "server"))
    rng = np.random.default_rng(7)
    job = histogram_job()

    for family in ("binomial", "resolvable"):
        for r in (1, 2, 3):
            if family == "resolvable" and r != 2:
                continue
            p = SchemeParams(K=8, P=4, Q=16, N=48, r=r)
            subs = np.asarray(rng.integers(0, 1 << 16, size=(p.N, 256)),
                              dtype=np.int32)
            ref = run_job_distributed(job, subs, p, mesh,
                                      scheme_family=family)
            for failed in [(3,), (0, 5)]:
                faults = FaultSpec(FaultInjector.crash(failed))
                got = run_job_distributed(job, subs, p, mesh, faults=faults,
                                          scheme_family=family)
                np.testing.assert_array_equal(np.asarray(got.outputs),
                                              np.asarray(ref.outputs))
                rep = got.recovery
                if r == 1:
                    assert rep.rung == "partial_remap" and rep.n_remapped > 0
                else:
                    assert rep.rung == "decode_around"
                    assert rep.n_remapped == 0
            print(f"faulted recovery {family} r={r}: OK (bit-identical)")

    # unrecoverable first attempt (every server dead) escalates to the
    # restart rung and succeeds on the clean re-run
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    subs = np.asarray(rng.integers(0, 1 << 16, size=(p.N, 256)),
                      dtype=np.int32)
    ref = run_job_distributed(job, subs, p, mesh)
    faults = FaultSpec(FaultInjector.crash(tuple(range(8))), max_restarts=2)
    got = run_job_distributed(job, subs, p, mesh, faults=faults)
    np.testing.assert_array_equal(np.asarray(got.outputs),
                                  np.asarray(ref.outputs))
    assert got.recovery.rung == "restart" and got.recovery.restarts == 1
    assert len(got.recovery.backoff_delays) == 1
    print("faulted recovery restart rung: OK (bit-identical)")

    # mesh validation fails fast with a legible error
    try:
        bad = make_mesh((2, 4), ("rack", "server"))
        run_job_distributed(job, subs, p, bad)
    except ValueError as e:
        assert "rack=P=4" in str(e)
        print("mesh validation: OK (clear error)")
    else:
        raise AssertionError("mismatched mesh must raise ValueError")


def test_coded_reduce_scatter():
    P_ = 4
    mesh = make_mesh((4, 2), ("rack", "server"))
    G = 64
    rng = np.random.default_rng(2)
    pairs = [(a, b) for a in range(P_) for b in range(a + 1, P_)]
    chunk_grads = rng.normal(size=(len(pairs), G)).astype(np.float32)
    total = chunk_grads.sum(axis=0)

    idx = chunk_index_table(P_)                       # [P, P-1]
    per_rack = chunk_grads[idx]                       # [P, P-1, G]
    # replicate over 'server' axis for the test
    inp = jnp.asarray(np.repeat(per_rack[:, None], 2, axis=1)
                      .reshape(8, P_ - 1, G))

    def body(x):
        return coded_reduce_scatter_r2(x[0], "rack", P_)[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(("rack", "server")),),
                   out_specs=P(("rack", "server")))
    out = np.asarray(fn(inp))                          # [8, G/P]
    for rack in range(P_):
        for srv in range(2):
            shard = total.reshape(P_, G // P_)[rack]
            np.testing.assert_allclose(out[rack * 2 + srv], shard, rtol=1e-5)
    print("coded reduce-scatter r=2: OK (== full-batch sum)")

    # the Pallas combine path builds identical send blocks (f(.) as the
    # fused coded_combine encode kernel, interpret mode on CPU)
    def body_pl(x):
        return coded_reduce_scatter_r2(x[0], "rack", P_,
                                       combine_impl="pallas")[None]

    fn_pl = shard_map(body_pl, mesh=mesh,
                      in_specs=(P(("rack", "server")),),
                      out_specs=P(("rack", "server")), check=False)
    np.testing.assert_allclose(np.asarray(fn_pl(inp)), out, rtol=1e-6)
    print("coded reduce-scatter combine_impl=pallas: OK (== xla path)")

    # straggler tolerance: rack 3's data lost; survivors still exact
    def body_f(x):
        return coded_reduce_scatter_r2(x[0], "rack", P_, failed=3)[None]

    fn_f = shard_map(body_f, mesh=mesh,
                     in_specs=(P(("rack", "server")),),
                     out_specs=P(("rack", "server")))
    out_f = np.asarray(fn_f(inp))
    for rack in range(P_ - 1):                         # survivors only
        shard = total.reshape(P_, G // P_)[rack]
        np.testing.assert_allclose(out_f[rack * 2], shard, rtol=1e-5)
    print("coded reduce-scatter with failed rack: OK (erasure-tolerant)")


def test_hierarchical_allreduce():
    mesh = make_mesh((4, 2), ("rack", "server"))
    rng = np.random.default_rng(3)
    x = rng.normal(size=(8, 16)).astype(np.float32)

    def body(v):
        return hierarchical_allreduce(v[0], "server", "rack")[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(("rack", "server")),),
                   out_specs=P(("rack", "server")))
    out = np.asarray(fn(jnp.asarray(x)))
    for d in range(8):
        np.testing.assert_allclose(out[d], x.sum(axis=0), rtol=1e-5)
    print("hierarchical all-reduce: OK (== psum)")


if __name__ == "__main__":
    test_distributed_hybrid_shuffle()
    test_coded_multicast_shuffle()
    test_fused_pipeline_parity()
    test_distributed_mapreduce_jobs()
    test_faulted_recovery_ladder()
    test_coded_reduce_scatter()
    test_hierarchical_allreduce()
    print("ALL MULTIDEVICE TESTS PASSED")
