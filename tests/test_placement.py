"""repro.placement tests: solver registry + ordering invariants, structured
replica placements, joint optimization, the plan-compiler perm threading,
the sim bridge (Table II in time units) and the scheduler placement knob.
Hypothesis property tests (guarded) cover random feasible SchemeParams."""
import math

import numpy as np
import pytest

from repro.core.assignment import (check_hybrid_constraints,
                                   hybrid_assignment, hybrid_group_of_slot)
from repro.core.coded_collectives import compile_hybrid_plan
from repro.core.params import SchemeParams
from repro.placement import (PlacementResult, anneal_perm, flow_perm,
                             get_solver, greedy_perm, joint_optimize,
                             local_search_perm, locality_matrix,
                             locality_of_perm, map_load_imbalance,
                             map_work_factors, n_groups,
                             nonlocal_load, perm_objective, place_replicas,
                             placement_traffic, random_perm, register_solver,
                             replica_load, simulate_placement, solve,
                             solve_all, storage_balance, structured_replicas,
                             table2_trials, traffic_for_result)
from repro.sim import (ClusterSim, CostModel, JobSpec, PhaseCoeffs,
                       PoissonWorkload, RackTopology, SchemeChooser,
                       default_catalog, run_scheduled)

P16 = SchemeParams(16, 4, 16, 96, 2, r_f=2)
FAST_ANNEAL = {"n_chains": 8, "n_steps": 150}


def _instance(p=P16, seed=0):
    rng = np.random.default_rng(seed)
    replicas = place_replicas(p, rng)
    return replicas, locality_matrix(p, replicas), rng


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_resolves_and_rejects():
    for name in ("random", "greedy", "flow", "local_search", "anneal_jax"):
        assert callable(get_solver(name))
    with pytest.raises(ValueError, match="unknown solver"):
        get_solver("simplex_of_doom")


def test_register_solver_plugs_in():
    @register_solver("_test_identity")
    def _ident(p, C, rng, **kw):
        return np.arange(p.N)
    try:
        res = solve(P16, _instance()[0], "_test_identity")
        assert res.solver == "_test_identity"
        assert (res.perm == np.arange(P16.N)).all()
    finally:
        from repro.placement.solvers import SOLVERS
        del SOLVERS["_test_identity"]


# ---------------------------------------------------------------------------
# Solver validity + ordering invariants
# ---------------------------------------------------------------------------

def test_every_solver_emits_valid_hybrid_assignment():
    results = solve_all(P16, _instance()[0],
                        per_solver_kwargs={"anneal_jax": FAST_ANNEAL})
    for name, res in results.items():
        assert sorted(res.perm.tolist()) == list(range(P16.N)), name
        check_hybrid_constraints(hybrid_assignment(P16, res.perm.tolist()))
        assert 0.0 <= res.node_locality <= 1.0
        assert 0.0 <= res.rack_locality <= 1.0
        assert res.node_locality <= res.rack_locality + 1e-12  # node => rack


def test_solver_objective_ordering():
    replicas, C, rng = _instance()
    rp = random_perm(P16, rng)
    obj = lambda perm: perm_objective(P16, C, perm)          # noqa: E731
    gp, fp = greedy_perm(P16, C), flow_perm(P16, C)
    lp = local_search_perm(P16, C, np.random.default_rng(1))
    ap = anneal_perm(P16, C, np.random.default_rng(2), **FAST_ANNEAL)
    assert obj(fp) >= obj(gp) - 1e-9 >= 0                    # flow exact
    assert obj(fp) >= obj(rp) - 1e-9
    assert obj(lp) >= obj(gp) - 1e-9                         # warm-started
    assert obj(ap) >= obj(gp) - 1e-9                         # warm-started
    # node locality: optimization beats the random baseline decisively
    node_rand = locality_of_perm(P16, replicas, rp)[0]
    for perm in (gp, fp, lp, ap):
        assert locality_of_perm(P16, replicas, perm)[0] > node_rand


def test_anneal_flow_warm_start_matches_flow_exactly():
    """Flow is the exact optimum, so a flow-warm-started annealer can never
    strictly improve — it must return the flow permutation itself (ties
    resolve to the first warm start)."""
    _, C, _ = _instance(seed=3)
    fp = flow_perm(P16, C)
    ap = anneal_perm(P16, C, np.random.default_rng(0), n_chains=8,
                     n_steps=100, init_solvers=("flow", "greedy"))
    assert (ap == fp).all()


def test_swap_moves_preserve_hybrid_constraints():
    """The local-search/anneal neighborhood: ANY sequence of slot swaps of a
    valid permutation is another valid hybrid assignment."""
    rng = np.random.default_rng(4)
    perm = rng.permutation(P16.N)
    for _ in range(5):
        a, b = rng.integers(P16.N, size=2)
        perm[a], perm[b] = perm[b], perm[a]
        check_hybrid_constraints(hybrid_assignment(P16, perm.tolist()))


# ---------------------------------------------------------------------------
# Structured placements
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,r_f", [
    ("resolvable", 3), ("aligned", 2), ("aligned", 3)])
def test_structured_replicas_distinct_and_balanced(policy, r_f):
    p = SchemeParams(12, 3, 12, 96, 2, r_f=r_f)
    reps = structured_replicas(p, policy)
    assert reps.shape == (p.N, p.r_f)
    for row in reps:
        assert len(set(row.tolist())) == p.r_f
    lo, hi = storage_balance(reps, p.K)
    assert lo + hi == 2 * p.N * p.r_f // p.K             # mean load exact
    if policy == "resolvable" or r_f <= p.r:
        assert lo == hi                                  # K | N: perfect


def test_resolvable_spreads_racks():
    p = SchemeParams(12, 3, 12, 96, 2, r_f=3)
    reps = structured_replicas(p, "resolvable")
    racks = reps // p.Kr
    # first min(r_f, P) replicas occupy distinct racks (exact HDFS goal)
    assert all(len(set(r.tolist())) == min(p.r_f, p.P) for r in racks)


def test_aligned_reaches_full_locality_with_flow():
    p = SchemeParams(12, 3, 12, 96, 2, r_f=2)            # r_f >= r
    res = solve(p, structured_replicas(p, "aligned"), "flow")
    assert res.node_locality == 1.0 and res.rack_locality == 1.0


def test_structured_beats_random_after_optimization():
    p = P16
    res_struct = solve(p, structured_replicas(p, "resolvable"), "flow")
    res_rand = solve(p, place_replicas(p, np.random.default_rng(0)), "flow")
    assert res_struct.node_locality >= res_rand.node_locality


def test_structured_rejects_unknown_policy_and_overfull_rf():
    with pytest.raises(ValueError, match="policy"):
        structured_replicas(P16, "voodoo")
    with pytest.raises(ValueError, match="r_f"):
        structured_replicas(SchemeParams(4, 2, 4, 8, 2, r_f=5))


# ---------------------------------------------------------------------------
# Joint optimization
# ---------------------------------------------------------------------------

def test_joint_monotone_and_beats_fixed_placement():
    j = joint_optimize(P16, seed=0, rounds=3)
    objs = [h.objective for h in j.history]
    assert objs == sorted(objs)                          # monotone history
    single = solve(P16, place_replicas(P16, np.random.default_rng(0)),
                   "flow")
    assert j.best.objective >= single.objective - 1e-9
    # closing the replica-placement loop reaches full node locality here
    assert j.best.node_locality == 1.0
    check_hybrid_constraints(
        hybrid_assignment(P16, j.best.perm.tolist()))
    # the co-designed replicas stay storage-balanced within the cap
    cap = -(-P16.N * P16.r_f // P16.K)
    assert replica_load(j.best.replicas, P16.K).max() <= cap


# ---------------------------------------------------------------------------
# Perm threading into the executable plan
# ---------------------------------------------------------------------------

def test_plan_perm_threading_permutes_only_subfile_tables():
    p = SchemeParams(8, 4, 16, 48, 2)
    res = solve(p, place_replicas(p, np.random.default_rng(0)), "greedy")
    base = compile_hybrid_plan(p)
    opt = compile_hybrid_plan(p, perm=res.perm)
    assert opt is compile_hybrid_plan(p, perm=res.perm)   # cached
    assert opt is not base
    # positional tables are perm-invariant
    np.testing.assert_array_equal(base.cross_send_pos, opt.cross_send_pos)
    np.testing.assert_array_equal(base.cross_recv_pos, opt.cross_recv_pos)
    np.testing.assert_array_equal(base.local_pos, opt.local_pos)
    # each device maps exactly the subfiles of the permuted assignment
    a = hybrid_assignment(p, res.perm.tolist())
    for srv in range(p.K):
        got = sorted(opt.local_subfiles.reshape(p.K, -1)[srv].tolist())
        assert got == sorted(a.subfiles_of_server[srv])


# ---------------------------------------------------------------------------
# Non-local load accounting + sim bridge
# ---------------------------------------------------------------------------

def test_map_load_imbalance_bounds():
    """map_load_imbalance is 1.0 exactly for a fully local placement and
    > 1.0 whenever locality misses are unevenly spread; structural map
    LOAD (task counts per rack) is always perfectly balanced regardless."""
    p = SchemeParams(12, 3, 12, 96, 2, r_f=2)
    full = solve(p, structured_replicas(p, "aligned"), "flow")
    assert map_load_imbalance(p, full.replicas, full.perm) == 1.0
    replicas, C, rng = _instance(p, seed=6)
    ran = random_perm(p, rng)
    imb = map_load_imbalance(p, replicas, ran)
    assert imb >= 1.0
    # task counts per rack are structurally equal for ANY perm — only the
    # locality-driven effective work (the imbalance above) can differ
    for perm in (full.perm, ran):
        rl = hybrid_assignment(p, list(perm)).rack_load()
        assert len(set(rl.tolist())) == 1


def test_nonlocal_load_totals_match_localities():
    replicas, C, rng = _instance(seed=5)
    perm = flow_perm(P16, C)
    node, rack = locality_of_perm(P16, replicas, perm)
    load = nonlocal_load(P16, replicas, perm)
    total = P16.N * P16.r
    assert load.node_miss.sum() == round(total * (1 - node))
    assert load.rack_miss.sum() == round(total * (1 - rack))
    assert (load.rack_miss <= load.node_miss).all()
    assert load.node_miss.sum() == load.n_loc * P16.K - round(total * node)


def test_fully_local_placement_is_a_noop_bridge():
    p = SchemeParams(12, 3, 12, 96, 2, r_f=2)
    res = solve(p, structured_replicas(p, "aligned"), "flow")
    tr = traffic_for_result(res, d=4)
    assert tr.cross_units == 0.0 and tr.total_units == 0.0
    assert tr.map_factors == (1.0,) * p.K
    topo = RackTopology(P=p.P, cross_bw=1e4, intra_bw=1e5)
    stats = simulate_placement(res, topo)
    assert "fetch" not in stats.phase_times               # no fetch stage


def test_placement_traffic_shape_validation():
    p = SchemeParams(8, 4, 16, 48, 2)
    res = solve(p, place_replicas(p, np.random.default_rng(0)), "random")
    tr = traffic_for_result(res)
    sim = ClusterSim(RackTopology(P=2, cross_bw=1e4, intra_bw=1e5), K=8)
    with pytest.raises(ValueError, match="intra_units_per_rack"):
        sim.submit(JobSpec("histogram", 48, 16, 1), "hybrid", 2,
                   placement=tr, check=False)
    sim2 = ClusterSim(RackTopology(P=4, cross_bw=1e4, intra_bw=1e5), K=12)
    with pytest.raises(ValueError, match="map_factors"):
        sim2.submit(JobSpec("histogram", 48, 24, 1), "hybrid", 2,
                    placement=tr, check=False)


TABLE2_TIME_ROWS = [(8, 2, 3, 100), (16, 4, 2, 192), (20, 5, 2, 200)]


@pytest.mark.parametrize("K,P,rf,N", TABLE2_TIME_ROWS)
def test_optimized_placement_strictly_lowers_jct(K, P, rf, N):
    """Acceptance pin: on straggler-free Table II rows, the flow placement's
    simulated JCT is STRICTLY below the random placement's (same replicas,
    same cluster, same seed) — Table II in time units."""
    p = SchemeParams(K, P, Q=K, N=N, r=2, r_f=rf)
    replicas, C, rng = _instance(p, seed=0)
    opt = solve(p, replicas, "flow")
    ran = solve(p, replicas, "random", seed=1)
    topo = RackTopology(P=P, cross_bw=1e4, intra_bw=1e5)
    cost = CostModel(map=PhaseCoeffs(0.0, 1e-8))
    j_opt = simulate_placement(opt, topo, cost_model=cost).jct
    j_ran = simulate_placement(ran, topo, cost_model=cost).jct
    assert j_opt < j_ran
    assert opt.node_locality > ran.node_locality


def test_map_factors_shift_map_phase():
    """Per-server locality imbalance stretches the simulated map barrier by
    exactly max(factor) (straggler-free, zero fetch bandwidth impact)."""
    p = SchemeParams(8, 4, 16, 48, 2, r_f=2)
    replicas, C, _ = _instance(p, seed=2)
    res = solve(p, replicas, "random", seed=3)
    tr = traffic_for_result(res, d=1, remote_penalty=0.5)
    cost = CostModel(map=PhaseCoeffs(0.0, 1e-8))
    topo = RackTopology(P=4, cross_bw=1e12, intra_bw=1e12)  # free network
    base = simulate_placement(
        solve(p, structured_replicas(p, "aligned"), "flow"),
        topo, cost_model=cost).phase_times["map"]
    skewed = simulate_placement(res, topo, cost_model=cost,
                                remote_penalty=0.5).phase_times["map"]
    assert skewed == pytest.approx(base * max(tr.map_factors))


# ---------------------------------------------------------------------------
# Scheduler placement knob
# ---------------------------------------------------------------------------

def _placement_stream(placement_solver, seed=9):
    jobs = PoissonWorkload(default_catalog(8, 4), n_jobs=12,
                           rate=4.0).generate(seed=seed)
    topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e6)
    cluster = ClusterSim(topo, K=8, cost_model=CostModel(
        map=PhaseCoeffs(1e-4, 1e-8)), seed=seed)
    # rs=(1, 2): the fetch-AWARE estimate (PR 5) correctly prices random
    # r=3 placements (~70% node locality) out of hybrid admissions, so the
    # stream keeps r <= 2 where hybrid genuinely wins with its fetch
    chooser = SchemeChooser(8, cost_model=cluster.cost_model, rs=(1, 2),
                            placement_solver=placement_solver)
    stats, sched = run_scheduled(jobs, cluster, chooser, policy="fifo",
                                 max_concurrent=3)
    return stats, sched


def test_scheduler_placement_knob_attaches_traffic_deterministically():
    stats1, sched1 = _placement_stream("greedy")
    stats2, sched2 = _placement_stream("greedy")
    assert [s.jct for s in stats1] == [s.jct for s in stats2]
    hybrid_decisions = [d for d in sched1.decisions.values()
                        if d.scheme == "hybrid"]
    assert hybrid_decisions, "stream should admit some hybrid jobs"
    for d in hybrid_decisions:
        assert d.placement is not None
        assert 0.0 <= d.placement.node_locality <= 1.0
    for d in sched1.decisions.values():
        if d.scheme != "hybrid":
            assert d.placement is None


def test_scheduler_placement_off_by_default_matches_legacy():
    stats_off, sched_off = _placement_stream(None)
    assert all(d.placement is None for d in sched_off.decisions.values())
    assert all("fetch" not in s.phase_times for s in stats_off)


# ---------------------------------------------------------------------------
# Multi-trial Table II driver
# ---------------------------------------------------------------------------

def test_table2_trials_reports_stats_and_legacy_parity():
    p = SchemeParams(9, 3, 9, 144, 2, r_f=2)
    res = table2_trials(p, seed=0, n_trials=3,
                        solvers=("random", "greedy", "flow"))
    from repro.core.locality import table2_experiment
    legacy = table2_experiment(p, seed=0, trials=3)
    assert res.stats["flow"].node_mean == legacy.node_opt
    assert res.stats["random"].rack_mean == legacy.rack_random
    assert legacy.node_opt_std == res.stats["flow"].node_std >= 0.0
    assert len(res.trials) == 3
    assert all(isinstance(r, PlacementResult)
               for t in res.trials for r in t.values())


# ---------------------------------------------------------------------------
# Hypothesis property tests (random feasible SchemeParams)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def placement_params(draw):
        P_ = draw(st.integers(2, 4))
        Kr = draw(st.integers(1, 3))
        K = P_ * Kr
        r = draw(st.integers(2, min(P_, 3)))
        M = draw(st.integers(1, 3))
        N = math.comb(P_, r) * M * Kr
        r_f = draw(st.integers(1, min(3, K)))
        return SchemeParams(K=K, P=P_, Q=K, N=N, r=r, r_f=r_f)

    @settings(max_examples=15, deadline=None, derandomize=True)
    @given(placement_params(), st.integers(0, 2 ** 16))
    def test_solver_invariants_on_random_instances(p, seed):
        """For random feasible SchemeParams: every solver's locality lies in
        [0, 1] and is >= the random baseline's (flow exactly optimal; the
        heuristics by warm-start monotonicity), and anneal >= greedy."""
        rng = np.random.default_rng(seed)
        replicas = place_replicas(p, rng)
        C = locality_matrix(p, replicas)
        rp = random_perm(p, rng)
        obj_r = perm_objective(p, C, rp)
        gp = greedy_perm(p, C)
        fp = flow_perm(p, C)
        lp = local_search_perm(p, C, rng, init=rp, max_sweeps=4,
                               batch=256)
        ap = anneal_perm(p, C, rng, n_chains=4, n_steps=50,
                         init=[gp, rp])
        for perm in (rp, gp, fp, lp, ap):
            node, rack = locality_of_perm(p, replicas, perm)
            assert 0.0 <= node <= 1.0 and 0.0 <= rack <= 1.0
            assert node <= rack + 1e-12
        assert perm_objective(p, C, fp) >= obj_r - 1e-9   # exact optimum
        assert perm_objective(p, C, fp) >= perm_objective(p, C, gp) - 1e-9
        assert perm_objective(p, C, lp) >= obj_r - 1e-9   # warm start: rp
        assert perm_objective(p, C, ap) >= \
            max(perm_objective(p, C, gp), obj_r) - 1e-6   # warm: {gp, rp}

    @settings(max_examples=15, deadline=None, derandomize=True)
    @given(placement_params(), st.integers(0, 2 ** 16),
           st.integers(1, 16))
    def test_swap_neighborhood_never_leaves_feasible_set(p, seed, n_swaps):
        """Any sequence of swap moves from any valid permutation satisfies
        Theorem IV.1's constraints — the invariant local_search/anneal rely
        on to skip per-move feasibility checks."""
        rng = np.random.default_rng(seed)
        perm = rng.permutation(p.N)
        for _ in range(n_swaps):
            a, b = rng.integers(p.N, size=2)
            perm[a], perm[b] = perm[b], perm[a]
        check_hybrid_constraints(hybrid_assignment(p, perm.tolist()))

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(placement_params(), st.integers(0, 2 ** 16))
    def test_miss_accounting_consistent(p, seed):
        rng = np.random.default_rng(seed)
        replicas = place_replicas(p, rng)
        perm = rng.permutation(p.N)
        node, rack = locality_of_perm(p, replicas, perm)
        load = nonlocal_load(p, replicas, perm)
        assert load.node_miss.sum() == round(p.N * p.r * (1 - node))
        assert load.rack_miss.sum() == round(p.N * p.r * (1 - rack))
        f = map_work_factors(p, replicas, perm)
        assert (f >= 1.0).all()

else:                                                  # pragma: no cover
    def test_placement_property_tests_need_hypothesis():
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (pip install .[test])")
