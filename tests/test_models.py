"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced same-family config runs one forward + one train step on CPU with
correct output shapes and no NaNs; decode path matches the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import lm
from repro.models.frontends import make_train_batch
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch, key):
    cfg = ARCHS[arch].reduced()
    params = lm.init_params(key, cfg, jnp.float32)
    batch = make_train_batch(key, cfg, batch=2, seq=24)
    logits, _, aux = lm.forward(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_frames=batch.get("enc_frames"), dense_moe=True, mixer_chunk=8)
    n_front = (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, batch["tokens"].shape[1] + n_front,
                            cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, key):
    cfg = ARCHS[arch].reduced()
    tc = TrainConfig(n_microbatches=2, remat=True, dense_moe=True,
                     mixer_chunk=8,
                     opt=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                         decay_steps=10))
    state = init_train_state(key, cfg, tc)
    batch = make_train_batch(key, cfg, batch=4, seq=16)
    step = make_train_step(cfg, tc, donate=False)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert moved


@pytest.mark.parametrize("arch", ["qwen2-72b", "llama3-405b", "rwkv6-3b",
                                  "hymba-1.5b", "whisper-large-v3",
                                  "deepseek-v2-lite-16b", "grok-1-314b",
                                  "granite-3-2b", "qwen2-1.5b",
                                  "llava-next-34b"])
def test_decode_matches_forward(arch, key):
    cfg = ARCHS[arch].reduced()
    params = lm.init_params(key, cfg, jnp.float32)
    B, S = 2, 12
    batch = make_train_batch(
        key, cfg, batch=B,
        seq=S + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0))
    toks = batch["tokens"]
    kw = {k: batch[k] for k in ("enc_frames", "prefix_embeds") if k in batch}
    logits_full, _, _ = lm.forward(params, cfg, toks, dense_moe=True,
                                   mixer_chunk=4, **kw)
    n_pre = toks.shape[1] - 2
    cache = lm.init_cache(cfg, B, logits_full.shape[1] + 4, jnp.float32)
    pe = kw.get("prefix_embeds")
    n_front = pe.shape[1] if pe is not None else 0
    lg, cache = lm.prefill(params, cfg, toks[:, :n_pre], cache,
                           prefix_embeds=pe,
                           enc_frames=kw.get("enc_frames"),
                           dense_moe=True, mixer_chunk=4)
    errs = [float(jnp.abs(lg - logits_full[:, n_front + n_pre - 1]).max())]
    pos = n_front + n_pre
    lg, cache = lm.decode_step(params, cfg, toks[:, n_pre], cache,
                               jnp.asarray(pos, jnp.int32), dense_moe=True)
    errs.append(float(jnp.abs(lg - logits_full[:, n_front + n_pre]).max()))
    assert max(errs) < 2e-3, errs


def test_count_params_matches_published():
    """Param counts within tolerance of the published model sizes."""
    expected = {"qwen2-1.5b": 1.54e9, "qwen2-72b": 72.7e9,
                "llama3-405b": 405.8e9, "granite-3-2b": 2.5e9,
                "grok-1-314b": 314e9, "deepseek-v2-lite-16b": 15.7e9,
                "rwkv6-3b": 3.1e9, "llava-next-34b": 34.4e9,
                "hymba-1.5b": 1.5e9, "whisper-large-v3": 1.6e9}
    for arch, want in expected.items():
        got = lm.count_params(ARCHS[arch])
        assert abs(got - want) / want < 0.08, (arch, got, want)


def test_moe_active_params():
    ds = ARCHS["deepseek-v2-lite-16b"]
    assert lm.count_params(ds, active_only=True) < 0.25 * lm.count_params(ds)


def test_sorted_moe_matches_dense_when_no_drop(key):
    """Sort-based dispatch == dense one-hot when capacity is unconstrained."""
    import dataclasses
    from repro.models.moe import init_moe_params, moe_ffn_dense, \
        moe_ffn_sorted
    cfg = ARCHS["deepseek-v2-lite-16b"].reduced()
    m = dataclasses.replace(cfg.moe, capacity_factor=100.0)
    p = init_moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, cfg.d_model))
    dense = moe_ffn_dense(p, m, x)
    srt = moe_ffn_sorted(p, m, x, n_groups=2)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(srt),
                               rtol=2e-4, atol=2e-5)


def test_ring_cache_sliding_window(key):
    """Hymba ring cache decode == stateless windowed attention."""
    cfg = ARCHS["hymba-1.5b"].reduced()    # window 16
    params = lm.init_params(key, cfg, jnp.float32)
    B, S = 1, 40                            # S > 2*window forces wraparound
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = lm.forward(params, cfg, toks, dense_moe=True,
                                   mixer_chunk=4)
    cache = lm.init_cache(cfg, B, S + 4, jnp.float32)
    lg, cache = lm.prefill(params, cfg, toks[:, :S - 4], cache,
                           dense_moe=True, mixer_chunk=4)
    errs = [float(jnp.abs(lg - logits_full[:, S - 5]).max())]
    for t in range(4):
        pos = S - 4 + t
        lg, cache = lm.decode_step(params, cfg, toks[:, pos], cache,
                                   jnp.asarray(pos, jnp.int32),
                                   dense_moe=True)
        if t < 3:
            errs.append(float(jnp.abs(lg - logits_full[:, pos]).max()))
    assert max(errs) < 2e-3, errs


def test_remat_blocks_same_loss(key):
    """2-level remat is numerically identical to plain remat."""
    cfg = ARCHS["granite-3-2b"].reduced()   # 2 layers
    import dataclasses as dc
    cfg = dc.replace(cfg, n_layers=4)
    params = lm.init_params(key, cfg, jnp.float32)
    batch = make_train_batch(key, cfg, batch=2, seq=16)
    l1, _ = lm.lm_loss(params, cfg, batch, remat=True, remat_blocks=1)
    l2, _ = lm.lm_loss(params, cfg, batch, remat=True, remat_blocks=2)
    g1 = jax.grad(lambda p: lm.lm_loss(p, cfg, batch, remat=True,
                                       remat_blocks=1)[0])(params)
    g2 = jax.grad(lambda p: lm.lm_loss(p, cfg, batch, remat=True,
                                       remat_blocks=2)[0])(params)
    assert float(jnp.abs(l1 - l2)) < 1e-6
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_unroll_scans_identical(key):
    """unroll_scans (dry-run cost mode) must not change results."""
    for arch in ("qwen2-1.5b", "rwkv6-3b", "hymba-1.5b"):
        cfg = ARCHS[arch].reduced()
        params = lm.init_params(key, cfg, jnp.float32)
        batch = make_train_batch(key, cfg, batch=2, seq=16)
        l1, _ = lm.lm_loss(params, cfg, batch, dense_moe=True, mixer_chunk=4)
        l2, _ = lm.lm_loss(params, cfg, batch, dense_moe=True, mixer_chunk=4,
                           unroll_scans=True)
        assert float(jnp.abs(l1 - l2)) < 1e-5, arch
