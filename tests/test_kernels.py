"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref.py
pure-jnp oracles (Pallas executed in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.coded_combine import ops as cc_ops, ref as cc_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rwkv_scan import ops as rw_ops, ref as rw_ref

KEY = jax.random.PRNGKey(0)


def _k(i):
    return jax.random.fold_in(KEY, i)


# ---------------------------------------------------------------------------
# coded_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r", [2, 3, 4])
@pytest.mark.parametrize("T,d", [(64, 128), (100, 96), (257, 40),
                                 (1, 7), (300, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coded_encode_decode(r, T, d, dtype):
    streams = [jax.random.normal(_k(i), (T, d), jnp.float32).astype(dtype)
               for i in range(r)]
    coeffs = jnp.arange(1.0, r + 1.0)
    f = cc_ops.coded_encode(streams, coeffs)
    ref = cc_ref.encode_ref(jnp.stack(streams), coeffs)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(f, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
    # decode stream 0 from f + streams[1:]
    dec = cc_ops.coded_decode(f, streams[1:], coeffs)
    # bf16 round-trip: decode subtracts large partial sums, so near-zero
    # elements see catastrophic cancellation — absolute tolerance scaled
    # to the bf16 ulp of the SUM magnitude, not the value
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(streams[0], np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=0.15 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("r", [2, 3])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint32])
def test_xor_roundtrip(r, dtype):
    T, d = 80, 64
    streams = [jax.random.randint(_k(10 + i), (T, d), 0, 2 ** 30
                                  ).astype(dtype) for i in range(r)]
    f = cc_ops.xor_encode(streams)
    np.testing.assert_array_equal(
        np.asarray(f), np.asarray(cc_ref.xor_encode_ref(jnp.stack(streams))))
    dec = cc_ops.xor_decode(f, streams[1:])
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(streams[0]))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def _ref_model_layout(q, k, v, **kw):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV, G, Sq, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, -1, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, -1, hd)
    o = fa_ref.flash_attention_ref(qg, kg, vg, **kw)
    return o.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sq, H, hd)


@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd", [
    (2, 128, 128, 4, 4, 64),      # MHA
    (1, 200, 200, 8, 2, 64),      # GQA, ragged seq
    (2, 64, 256, 4, 1, 128),      # MQA, cross-length (decode-ish)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_ref(B, Sq, Sk, H, KV, hd, dtype, causal):
    q = jax.random.normal(_k(1), (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(_k(2), (B, Sk, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(_k(3), (B, Sk, KV, hd), jnp.float32).astype(dtype)
    q_off = Sk - Sq if causal else 0
    out = fa_ops.flash_attention(q, k, v, causal=causal, q_offset=q_off,
                                 block_q=64, block_k=64)
    ref = _ref_model_layout(q, k, v, causal=causal, q_offset=q_off)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_window():
    B, S, H, KV, hd = 1, 160, 4, 2, 64
    q = jax.random.normal(_k(4), (B, S, H, hd))
    k = jax.random.normal(_k(5), (B, S, KV, hd))
    v = jax.random.normal(_k(6), (B, S, KV, hd))
    out = fa_ops.flash_attention(q, k, v, causal=True, window=32,
                                 block_q=32, block_k=32)
    ref = _ref_model_layout(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_kv_valid():
    """Decode-style masking: only the first kv_valid keys count."""
    B, Sq, Sk, H, KV, hd = 2, 8, 128, 4, 4, 64
    q = jax.random.normal(_k(7), (B, Sq, H, hd))
    k = jax.random.normal(_k(8), (B, Sk, KV, hd))
    v = jax.random.normal(_k(9), (B, Sk, KV, hd))
    out = fa_ops.flash_attention(q, k, v, causal=False, kv_valid=57,
                                 block_q=8, block_k=32)
    ref = _ref_model_layout(q, k, v, causal=False, kv_valid=57)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention():
    """The kernel is the oracle-equal fast path of models.attention."""
    from repro.models.attention import dense_attention
    B, S, H, KV, hd = 2, 96, 8, 2, 64
    q = jax.random.normal(_k(11), (B, S, H, hd))
    k = jax.random.normal(_k(12), (B, S, KV, hd))
    v = jax.random.normal(_k(13), (B, S, KV, hd))
    out = fa_ops.flash_attention(q, k, v, causal=True, block_q=32,
                                 block_k=32)
    ref = dense_attention(q, k, v, jnp.arange(S), causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,h,Nk,Nv,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 100, 3, 32, 32, 32),      # ragged: S % chunk != 0
    (1, 128, 1, 64, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv_scan_vs_ref(B, S, h, Nk, Nv, chunk, dtype):
    r = jax.random.normal(_k(20), (B, S, h, Nk), jnp.float32).astype(dtype)
    k = jax.random.normal(_k(21), (B, S, h, Nk), jnp.float32).astype(dtype)
    v = jax.random.normal(_k(22), (B, S, h, Nv), jnp.float32).astype(dtype)
    w = -jnp.exp(jax.random.normal(_k(23), (B, S, h, Nk)))
    u = 0.1 * jax.random.normal(_k(24), (h, Nk))
    s0 = jax.random.normal(_k(25), (B, h, Nk, Nv)) * 0.1
    out, sT = rw_ops.wkv_scan(r, k, v, w.astype(dtype), u, s0, chunk=chunk)
    from repro.models.linrec import chunked_linear_recurrence
    oref, sref = chunked_linear_recurrence(
        r, k, v, w.astype(dtype), u=u, initial_state=s0, mode="rwkv",
        chunk=chunk, return_state=True)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sref),
                               rtol=tol, atol=tol)


def test_wkv_scan_vs_naive_steps():
    """Kernel == step-by-step recurrence (the ground-truth semantics)."""
    from repro.models.linrec import naive_linear_recurrence
    B, S, h, N = 1, 48, 2, 16
    r = jax.random.normal(_k(30), (B, S, h, N))
    k = jax.random.normal(_k(31), (B, S, h, N))
    v = jax.random.normal(_k(32), (B, S, h, N))
    w = -jnp.exp(jax.random.normal(_k(33), (B, S, h, N)))
    u = 0.1 * jax.random.normal(_k(34), (h, N))
    out, sT = rw_ops.wkv_scan(r, k, v, w, u, chunk=16)
    oref, sref = naive_linear_recurrence(r, k, v, w, u=u, mode="rwkv")
    np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sref),
                               rtol=3e-4, atol=3e-4)
