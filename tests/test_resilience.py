"""repro.resilience tests: speculation policy registry, task-granular map
engine semantics (no-op under NoStragglers, first-finisher-wins, backup
fetch contention, per-wave straggler resampling), straggler-model fitting,
the hedged r-policy, and the fetch-aware chooser flip."""
import numpy as np
import pytest

from repro.resilience import (HedgedRPolicy, SPECULATION_POLICIES,
                              StragglerFit, check_frontier_invariants,
                              cloning_vs_coding_frontier,
                              fit_straggler_model, get_policy,
                              hedged_vs_static_stream, straggler_regimes)
from repro.sim import (ClusterSim, CostModel, DeterministicSlowdown,
                       ExponentialTail, JobSpec, NoStragglers, PhaseCoeffs,
                       RackCorrelated, RackTopology, SchemeChooser,
                       simulate_single_job)

TOPO = RackTopology(P=4, cross_bw=1e4, intra_bw=1e5)
COST = CostModel(map=PhaseCoeffs(0.0, 1e-6))
SPEC = JobSpec("histogram", 48, 16, 1)


def _single(policy=None, stragglers=None, seed=0, cost=COST, scheme="hybrid",
            r=2, spec=SPEC, topo=TOPO, K=8, **pol_kwargs):
    pol = get_policy(policy, **pol_kwargs) if policy is not None else None
    return simulate_single_job(spec, topo, K, scheme, r, cost_model=cost,
                               stragglers=stragglers, seed=seed,
                               speculation=pol)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_has_all_policies():
    assert set(SPECULATION_POLICIES) >= {"none", "clone", "late", "mantri"}
    for name in SPECULATION_POLICIES:
        assert get_policy(name).name == name


def test_registry_rejects_unknown():
    with pytest.raises(ValueError, match="unknown speculation policy"):
        get_policy("dolly++")


# ---------------------------------------------------------------------------
# Task-granular engine semantics
# ---------------------------------------------------------------------------

def test_task_map_matches_barrier_map_with_zero_alpha():
    """With alpha=0 and balanced loads the task-granular map phase sums to
    exactly the barrier phase (per-task seconds are additive in work)."""
    task = _single("none")
    barrier = _single(None)
    assert task.jct == pytest.approx(barrier.jct, rel=1e-12)
    assert task.phase_times["map"] == pytest.approx(
        barrier.phase_times["map"], rel=1e-12)
    assert task.speculation == "none" and barrier.speculation is None


@pytest.mark.parametrize("policy", ["clone", "late", "mantri"])
def test_speculation_is_noop_under_no_stragglers(policy):
    """Acceptance pin: under NoStragglers every policy's JCT is
    bit-identical to the none policy's (backups never pay off, so either
    none launch or all are cancelled at zero cost)."""
    base = _single("none")
    st = _single(policy)
    assert st.jct == base.jct
    assert st.n_backup_wins == 0
    assert st.phase_times["map"] == base.phase_times["map"]


@pytest.mark.parametrize("policy", ["clone", "late", "mantri"])
def test_speculation_beats_none_under_deterministic_straggler(policy):
    """One 6x-slow server: every speculation policy must strictly shorten
    the map phase via winning backups."""
    slow = DeterministicSlowdown((6.0,) + (1.0,) * 7)
    base = _single("none", stragglers=slow)
    st = _single(policy, stragglers=slow)
    assert st.n_backups > 0
    assert st.n_backup_wins > 0
    assert st.phase_times["map"] < base.phase_times["map"]
    assert st.jct < base.jct


def test_first_finisher_wins_no_duplicate_completions():
    """Each task completes exactly once even with aggressive cloning: the
    trace's task_done events are unique per task index."""
    slow = DeterministicSlowdown((6.0,) + (1.0,) * 7)
    sim = ClusterSim(TOPO, 8, COST, slow, 0,
                     speculation=get_policy("clone", n_clones=2))
    sim.submit(SPEC, "hybrid", 2)
    (stats,) = sim.run()
    done = [t[2][1] for t in sim.trace if t[1] == "task_done"]
    assert len(done) == len(set(done)) == 96          # N * r tasks, once
    assert stats.n_backup_wins > 0


def test_backup_fetch_contends_on_network():
    """A backup on a server without the input replica must move the input
    through the fluid network: with the home server catastrophically slow,
    replica-less clones win only AFTER their spec_fetch flow drains — the
    completions appear in the trace."""
    slow = DeterministicSlowdown((1000.0,) + (1.0,) * 7)
    sim = ClusterSim(TOPO, 8, COST, slow, 0,
                     speculation=get_policy("clone", n_clones=1))
    sim.submit(SPEC, "hybrid", 2)
    (stats,) = sim.run()
    fetches = [t for t in sim.trace
               if t[1] == "flow_done" and t[2][1] == "spec_fetch"]
    assert fetches, "replica-less clones should fetch inputs over the net"
    assert stats.n_backup_wins > 0


def test_map_waves_resample_per_backup_batch():
    """Satellite pin: backup launches draw FRESH straggler factors (a new
    wave) — map_waves counts them, and the draws consume the sim rng, so a
    straggling run's factor sequence differs from the no-backup run's."""
    st = _single("late", stragglers=ExponentialTail(2.0), seed=3)
    assert st.map_waves >= 2
    base = _single("none", stragglers=ExponentialTail(2.0), seed=3)
    assert base.map_waves == 1


def test_tasks_per_server_coalescing_preserves_totals():
    st = _single("none", tasks_per_server=3)
    base = _single("none")
    assert st.phase_times["map"] == pytest.approx(base.phase_times["map"])
    assert st.jct == pytest.approx(base.jct)


def test_speculation_on_scheduler_decisions():
    """The chooser's speculation knob rides into every admission."""
    from repro.sim import PoissonWorkload, default_catalog, run_scheduled
    jobs = PoissonWorkload(default_catalog(8, 4), n_jobs=8,
                           rate=4.0).generate(seed=2)
    cluster = ClusterSim(TOPO, 8, COST, ExponentialTail(1.0), seed=2)
    chooser = SchemeChooser(8, cost_model=COST,
                            speculation=get_policy("late"))
    stats, sched = run_scheduled(jobs, cluster, chooser)
    assert len(stats) == 8
    assert all(s.speculation == "late" for s in stats)


# ---------------------------------------------------------------------------
# Determinism with speculation enabled (satellite: per-wave resampling must
# keep per-seed traces bit-identical)
# ---------------------------------------------------------------------------

def _spec_run(seed, policy, scale=1.5):
    sim = ClusterSim(TOPO, 8, COST, ExponentialTail(scale), seed,
                     speculation=get_policy(policy))
    sim.submit(SPEC, "hybrid", 2)
    sim.submit(JobSpec("histogram", 48, 16, 2), "hybrid", 2, time=0.001)
    stats = sim.run()
    return [s.jct for s in stats], list(sim.trace)


@pytest.mark.parametrize("policy", ["none", "clone", "late", "mantri"])
def test_speculative_traces_bit_identical_per_seed(policy):
    j1, t1 = _spec_run(11, policy)
    j2, t2 = _spec_run(11, policy)
    assert j1 == j2
    assert t1 == t2


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10 ** 6),
           scale=st.floats(0.1, 3.0),
           policy=st.sampled_from(["clone", "late", "mantri"]))
    def test_speculative_traces_deterministic_property(seed, scale, policy):
        """Any (seed, tail, policy): rerunning reproduces the event trace
        bit-for-bit — wave resampling stays on the seeded rng."""
        assert _spec_run(seed, policy, scale) == _spec_run(seed, policy,
                                                           scale)
else:                                                  # pragma: no cover
    def test_speculative_traces_deterministic_property():
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (pip install .[test])")


# ---------------------------------------------------------------------------
# Straggler-model fitting
# ---------------------------------------------------------------------------

def test_fit_classifies_none():
    fit = fit_straggler_model([1.0, 1.01, 1.02] * 10, K=9, P=3)
    assert fit.kind == "none"
    assert fit.expected_barrier_factor(9, 3) == 1.0


def test_fit_recovers_exponential_scale():
    rng = np.random.default_rng(0)
    scale, K = 0.5, 16
    # observed slowdowns ~ max of K iid (1 + Exp(scale)) draws
    obs = 1.0 + rng.exponential(scale, size=(500, K)).max(axis=1)
    fit = fit_straggler_model(obs.tolist(), K=K, P=4)
    assert fit.kind == "exp_tail"
    assert fit.scale == pytest.approx(scale, rel=0.25)
    assert fit.expected_barrier_factor(K, 4) > 1.5


def test_fit_recovers_rack_correlated():
    rng = np.random.default_rng(1)
    p_slow, factor, P = 0.2, 4.0, 4
    hit = rng.random(400) < 1 - (1 - p_slow) ** P
    obs = np.where(hit, factor, 1.0)
    fit = fit_straggler_model(obs.tolist(), K=16, P=P)
    assert fit.kind == "rack"
    assert fit.factor == pytest.approx(factor, rel=0.05)
    assert fit.p_slow == pytest.approx(p_slow, abs=0.07)


def test_fit_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fit kind"):
        StragglerFit("bimodal")


def test_hedged_policy_observe_refits_online():
    rp = HedgedRPolicy(8, 4, refit_every=4, hedge_placement=False)
    assert rp.fit.kind == "none"

    class FakeStats:
        def __init__(self, t):
            self.phase_times = {"map": t}
    for t in (4.0, 1.0, 4.0, 1.0, 4.0, 1.0, 4.0, 1.0):
        rp.observe(FakeStats(t), expected_map_s=1.0)
    assert rp.fit.kind == "rack"
    assert rp.fit.factor == pytest.approx(4.0)


def test_hedged_placement_is_deterministic_and_local():
    from repro.core.params import SchemeParams
    rp = HedgedRPolicy(8, 4, placement_solver="flow")
    p = SchemeParams(8, 4, 16, 48, 2, r_f=3)
    tr1, tr2 = rp.placement_for(p), rp.placement_for(p)
    assert tr1 is tr2                       # cached
    assert tr1.node_locality >= 0.9         # rack-hedged structured + flow


def test_hedged_inflation_prices_rack_tail():
    rp = HedgedRPolicy(8, 4, fit=StragglerFit("rack", p_slow=0.25,
                                              factor=4.0),
                       hedge_placement=False)
    infl = rp.compute_inflation("hybrid", 3)
    assert infl == pytest.approx(1 + (1 - 0.75 ** 4) * 3.0)


# ---------------------------------------------------------------------------
# Frontier + hedged stream (small, fast versions of the bench assertions)
# ---------------------------------------------------------------------------

FRONTIER_COST = CostModel(map=PhaseCoeffs(1e-4, 2e-8),
                          pack=PhaseCoeffs(5e-5, 1e-8),
                          reduce=PhaseCoeffs(1e-4, 2e-8))


def test_frontier_invariants_small_grid():
    cells = cloning_vs_coding_frontier(rows=[(9, 3, 18, 72, 2)], n_seeds=4,
                                       cost=FRONTIER_COST)
    inv = check_frontier_invariants(cells)
    assert inv["noop_under_none"]
    assert inv["late_improves_p99"]
    assert inv["clone_improves_p99"]
    assert inv["mantri_improves_p99_rack"]


def test_hedged_beats_static_under_rack_correlated():
    out = hedged_vs_static_stream(stragglers=RackCorrelated(0.25, 4.0),
                                  cost=FRONTIER_COST, n_jobs=30, n_probe=15,
                                  seed=0)
    assert out["fit"]["kind"] == "rack"
    assert out["hedged_beats_static_p99"]


# ---------------------------------------------------------------------------
# Fetch-aware chooser (satellite): the flip pin
# ---------------------------------------------------------------------------

def test_fetch_aware_estimate_flips_decision():
    """Pin: histogram (N=168, d=1) on a 100x-skewed fabric.  Blind to
    fetch, the chooser picks hybrid r=3 (least shuffle traffic); pricing
    the solved random placement's fetch flips it to coded r=3 — and the
    flip is CORRECT: the simulated JCT of the fetch-aware choice is lower.
    """
    topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e6)
    cost = CostModel(map=PhaseCoeffs(1e-4, 1e-8))
    spec = JobSpec("histogram", 168, 16, 1)

    blind = SchemeChooser(8, cost_model=cost)
    aware = SchemeChooser(8, cost_model=cost, placement_solver="greedy")
    cluster = ClusterSim(topo, 8, cost)
    d_blind = blind.choose(spec, cluster)
    d_aware = aware.choose(spec, cluster)
    assert (d_blind.scheme, d_blind.r) == ("hybrid", 3)
    assert (d_aware.scheme, d_aware.r) != ("hybrid", 3)
    assert d_aware.placement is None        # the winner needs no fetch

    # ground truth: simulate both decisions (the blind hybrid pays its
    # placement's fetch in the sim — that is exactly what PR 4 wired up)
    tr = aware._candidate_placement(spec, "hybrid", 3, cluster)
    sim = ClusterSim(topo, 8, cost)
    blind_id = sim.submit(spec, "hybrid", 3, placement=tr)
    jct_blind = {s.job_id: s for s in sim.run()}[blind_id].jct
    sim2 = ClusterSim(topo, 8, cost)
    aware_id = sim2.submit(spec, d_aware.scheme, d_aware.r)
    jct_aware = {s.job_id: s for s in sim2.run()}[aware_id].jct
    assert jct_aware < jct_blind


def test_fetch_aware_estimate_includes_backlog():
    """Fetch pricing sees current network load: the same candidate's
    estimate grows when the root switch is backlogged."""
    topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e6)
    chooser = SchemeChooser(8, placement_solver="greedy")
    spec = JobSpec("histogram", 168, 16, 1)
    quiet = ClusterSim(topo, 8)
    tr = chooser._candidate_placement(spec, "hybrid", 3, quiet)
    assert tr is not None and tr.cross_units > 0
    e_quiet = chooser.estimate(spec, "hybrid", 3, quiet, placement=tr)
    busy = ClusterSim(topo, 8)
    busy.network.start_flow("root", 5e4, (99, "bg"))
    e_busy = chooser.estimate(spec, "hybrid", 3, busy, placement=tr)
    assert e_busy > e_quiet
