"""Serving engine + data pipeline integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import SyntheticPipeline, shuffled_epoch_order
from repro.models import lm
from repro.serve.engine import Request, ServeEngine, sample_token


@pytest.fixture(scope="module")
def engine():
    cfg = ARCHS["granite-3-2b"].reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(cfg, params, batch_slots=2, max_seq=64)


def test_generate_greedy_deterministic(engine):
    prompts = np.random.default_rng(0).integers(
        0, engine.cfg.vocab_size, (2, 8)).astype(np.int32)
    a = engine.generate(prompts, 6)
    b = engine.generate(prompts, 6)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 6)
    assert (a >= 0).all() and (a < engine.cfg.vocab_size).all()


def test_greedy_matches_argmax_forward(engine):
    """First generated token == argmax of the full-forward logits."""
    cfg = engine.cfg
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    toks = engine.generate(prompts, 1)
    logits, _, _ = lm.forward(engine.params, cfg, jnp.asarray(prompts))
    want = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
    np.testing.assert_array_equal(toks[:, 0], want)


def test_serve_queue_slots(engine):
    rng = np.random.default_rng(2)
    reqs = [Request(rng.integers(0, engine.cfg.vocab_size,
                                 rng.integers(3, 9)).astype(np.int32), 4)
            for _ in range(5)]
    done = engine.serve(reqs)
    assert all(r.done and len(r.out_tokens) == 4 for r in done)


def test_sample_token_temperature():
    logits = jnp.asarray([[0.0, 10.0, 0.0]])
    greedy = sample_token(logits, jax.random.PRNGKey(0), 0.0)
    assert int(np.asarray(greedy)[0]) == 1
    # high temperature still returns a valid token id
    t = int(np.asarray(sample_token(logits, jax.random.PRNGKey(0), 5.0))[0])
    assert 0 <= t < 3


def test_pipeline_shapes_per_family():
    for arch in ("whisper-large-v3", "llava-next-34b", "qwen2-1.5b"):
        cfg = ARCHS[arch].reduced()
        pipe = SyntheticPipeline(cfg, 2, 32)
        b = pipe.batch_at(0)
        n_front = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
        assert b["tokens"].shape == (2, 32 - n_front)
        if cfg.family == "encdec":
            assert b["enc_frames"].shape == (2, cfg.encoder_seq, cfg.d_model)
        if cfg.frontend == "vision":
            assert b["prefix_embeds"].shape == (2, n_front, cfg.d_model)
        assert int(b["tokens"].max()) < cfg.vocab_size


def test_epoch_shuffle_through_mapreduce():
    from repro.core.params import SchemeParams
    p = SchemeParams(K=6, P=3, Q=6, N=12, r=2)
    order = shuffled_epoch_order(120, epoch=1, scheme_params=p)
    assert sorted(order.tolist()) == list(range(120))
    # deterministic per epoch, different across epochs
    np.testing.assert_array_equal(order,
                                  shuffled_epoch_order(120, 1,
                                                       scheme_params=None))
    assert (order != shuffled_epoch_order(120, 2)).any()
