"""Telemetry-layer tests (repro.obs): metrics snapshot/reset semantics and
label-cardinality bounds, structured tracing + exporters (incl. the
committed Perfetto golden file), and rack-level byte accounting reconciled
against the closed forms for every registered plan family."""
import json
import pathlib

import numpy as np
import pytest

from repro.core.coded_collectives import (compile_hybrid_plan,
                                          plan_cache_clear, plan_cache_info,
                                          plan_transfer_matrices)
from repro.core.costs import cost_table, hybrid_cost, hybrid_resolvable_cost
from repro.core.degraded import compile_degraded_plan
from repro.core.params import SchemeParams
from repro.core.plan_registry import plan_families, scheme_of_family
from repro.obs import bytes as obytes
from repro.obs import metrics, tracing
from repro.sim import (ClusterSim, CostModel, JobSpec, PhaseCoeffs,
                       RackTopology, simulate_single_job)

GOLDEN = pathlib.Path(__file__).parent / "golden_obs_trace.json"

P9 = SchemeParams(9, 3, 18, 72, 2)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_accumulates_per_label_set():
    reg = metrics.MetricsRegistry()
    c = reg.counter("decisions")
    c.inc(scheme="hybrid", r=2)
    c.inc(scheme="hybrid", r=2)
    c.inc(2.5, scheme="coded", r=3)
    assert c.value(scheme="hybrid", r=2) == 2.0
    assert c.value(r=2, scheme="hybrid") == 2.0     # label order irrelevant
    assert c.value(scheme="coded", r=3) == 2.5
    assert c.value(scheme="uncoded", r=1) == 0.0    # unobserved reads zero


def test_counter_rejects_negative_increments():
    reg = metrics.MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1.0)


def test_redeclare_same_name_returns_same_object_and_kind_mismatch_raises():
    reg = metrics.MetricsRegistry()
    a = reg.counter("x")
    assert reg.counter("x") is a
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_reset_zeroes_values_but_keeps_declarations():
    reg = metrics.MetricsRegistry()
    reg.counter("c").inc(5)
    reg.gauge("g").set(7)
    reg.reset()
    assert reg.names() == ["c", "g"]                # declarations survive
    assert reg.counter("c").value() == 0.0
    assert reg.gauge("g").value() == 0.0
    reg.clear()
    assert reg.names() == []                        # clear drops them too


def test_label_cardinality_bound_enforced():
    reg = metrics.MetricsRegistry()
    c = reg.counter("bounded", max_label_sets=3)
    for i in range(3):
        c.inc(job=i)
    with pytest.raises(metrics.LabelCardinalityError):
        c.inc(job=99)
    c.inc(job=1)                    # existing series still writable


def test_histogram_buckets_are_cumulative_with_inf_tail():
    reg = metrics.MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 100.0):
        h.observe(v)
    (sample,) = reg.snapshot()["lat"]["samples"].values()
    assert sample["buckets"] == [0.1, 1.0, "inf"]
    assert sample["counts"] == [1, 3, 4]            # cumulative
    assert sample["count"] == 4
    assert sample["sum"] == pytest.approx(101.05)


def test_snapshot_json_is_deterministic():
    def build():
        reg = metrics.MetricsRegistry()
        reg.counter("z").inc(scheme="hybrid")
        reg.counter("a").inc(3, r=2, scheme="coded")
        reg.gauge("m").set(1.5, kind="x")
        return reg.snapshot_json()
    assert build() == build()


def test_collect_cache_metrics_mirrors_plan_cache_info():
    plan_cache_clear()
    compile_hybrid_plan(P9)
    compile_hybrid_plan(P9)                          # one hit
    reg = metrics.MetricsRegistry()
    metrics.collect_cache_metrics(reg)
    info = plan_cache_info()
    pc = reg.gauge("plan_cache")
    assert pc.value(event="hit", family="all") == info.hits
    assert pc.value(event="miss", family="all") == info.misses
    assert reg.gauge("plan_cache_size").value(kind="current") == info.currsize
    assert "degraded_cache" in reg.names()


# ---------------------------------------------------------------------------
# Tracing
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_nothing():
    tr = tracing.Tracer(enabled=False)
    tr.event("x")
    with tr.span("map"):
        pass
    assert tr.events == []


def test_span_uses_injected_clock():
    t = [0.0]
    tr = tracing.Tracer(clock=lambda: t[0])
    with tr.span("map", job_id=3, scheme="hybrid"):
        t[0] = 2.5
    (ev,) = tr.events
    assert (ev.ts, ev.dur, ev.phase, ev.job_id) == (0.0, 2.5, "map", 3)
    assert dict(ev.labels) == {"scheme": "hybrid"}


def test_jsonl_rounds_only_on_export():
    tr = tracing.Tracer(clock=lambda: 1.0 / 3.0)
    tr.event("tick")
    assert tr.events[0].ts == 1.0 / 3.0              # producer stays exact
    line = json.loads(tracing.to_jsonl(tr.events).strip())
    assert line["ts"] == round(1.0 / 3.0, tracing.TS_NDIGITS)


def test_chrome_trace_schema_and_validation():
    tr = tracing.Tracer(clock=lambda: 0.0)
    tr.span_at(0.0, 0.001, "phase_span", job_id=1, phase="map")
    tr.event("job_done", job_id=1, ts=0.002)
    doc = tracing.to_chrome_trace(tr.events)
    assert tracing.validate_chrome_trace(doc) == 2
    span, instant = doc["traceEvents"]
    assert span["ph"] == "X" and span["dur"] == 1000.0 and span["pid"] == 1
    assert instant["ph"] == "i"
    with pytest.raises(ValueError):
        tracing.validate_chrome_trace({"traceEvents": [{"name": "x"}]})
    with pytest.raises(ValueError):
        tracing.validate_chrome_trace({})


def test_spans_from_phase_timings_lays_phases_end_to_end():
    row = {"work": {}, "seconds": {"plan_compile": 0.5, "map": 1.0,
                                   "pack": 0.25, "reduce": 0.125},
           "meta": {"job": "j", "backend": "cpu", "shuffle_s": 2.0}}
    tr = tracing.Tracer(clock=lambda: 0.0)
    spans = tracing.spans_from_phase_timings(row, tr)
    assert [s.phase for s in spans] == ["plan_compile", "map", "pack",
                                       "shuffle", "reduce"]
    for a, b in zip(spans, spans[1:]):
        assert b.ts == pytest.approx(a.ts + a.dur)
    assert tr.events == spans


# ---------------------------------------------------------------------------
# Sim trace: structured schema behind the legacy shim
# ---------------------------------------------------------------------------

def _golden_sim() -> ClusterSim:
    """Canonical deterministic run for the committed Perfetto golden: two
    hybrid jobs contending, non-trivial compute costs, no stragglers."""
    topo = RackTopology(P=3, cross_bw=1e3, intra_bw=1e4)
    sim = ClusterSim(topo, K=9, cost_model=CostModel(
        map=PhaseCoeffs(1e-3, 1e-8)), seed=0)
    sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2, time=0.0)
    sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2, time=0.05)
    sim.run()
    return sim


def test_legacy_trace_shim_is_instants_with_exact_timestamps():
    sim = _golden_sim()
    instants = [e for e in sim.tracer.events if e.dur is None]
    spans = [e for e in sim.tracer.events if e.dur is not None]
    assert sim.trace == [(e.ts, e.kind, e.data) for e in instants]
    assert spans, "phase spans must be recorded"
    assert all(e.kind == "phase_span" for e in spans)
    # span [start, start+dur] windows stay within the run
    for e in spans:
        assert 0.0 <= e.ts <= e.ts + e.dur <= sim.now + 1e-12
    # the legacy view stays monotone precisely because spans are excluded
    times = [t for t, _, _ in sim.trace]
    assert times == sorted(times)


def test_sim_trace_events_bit_identical_across_reruns():
    e1 = _golden_sim().tracer.events
    e2 = _golden_sim().tracer.events
    assert e1 == e2                  # frozen dataclasses: exact equality


def test_perfetto_export_matches_golden_file():
    """The committed golden pins BOTH the exporter schema and the sim's
    event stream — regenerate with
    ``python -m tests.test_obs`` only when a deliberate schema/sim change
    is being made, and review the diff."""
    doc = tracing.to_chrome_trace(_golden_sim().tracer.events)
    golden = json.loads(GOLDEN.read_text())
    assert doc == golden


def test_phase_spans_cover_reported_phase_times():
    sim = _golden_sim()
    for stats in sim.stats:
        spans = [e for e in sim.tracer.events
                 if e.kind == "phase_span" and e.job_id == stats.job_id]
        by_phase = {}
        for e in spans:
            by_phase[e.phase] = by_phase.get(e.phase, 0.0) + e.dur
        for phase, secs in stats.phase_times.items():
            assert by_phase[phase] == pytest.approx(secs)


# ---------------------------------------------------------------------------
# Byte accounting: plans, degraded plans, sim, reconciliation property
# ---------------------------------------------------------------------------

def test_plan_rack_bytes_reconcile_for_every_registered_family():
    cases = {"binomial": SchemeParams(8, 4, 16, 48, 2),
             "resolvable": SchemeParams(16, 4, 16, 240, 2)}
    for family in plan_families():
        p = cases[family]
        plan = compile_hybrid_plan(p, family=family)
        scheme = scheme_of_family(family)
        for d in (1, 4):
            rb = obytes.plan_rack_bytes(plan, "coded", d=d)
            obytes.reconcile(rb.intra_total, rb.cross_total, p, scheme, d=d)
            # recorded == plan_transfer_matrices totals (the property)
            tm = plan_transfer_matrices(plan, "coded")
            assert rb.cross_total == pytest.approx(
                float(tm["cross_rack_matrix"].sum()) * d)
            assert rb.intra_total == pytest.approx(
                float(tm["intra_per_rack"].sum()) * d)


def test_reconcile_raises_on_mismatch():
    with pytest.raises(obytes.ByteReconciliationError):
        obytes.reconcile(0.0, 1.0, P9, "hybrid")


def test_degraded_plan_transfer_matrices_dispatch_on_schema():
    dp = compile_degraded_plan(P9, (0,))
    tm = plan_transfer_matrices(dp.plan)            # 4-dim cross_valid path
    loads = dp.transfer_loads()
    assert np.allclose(tm["cross_rack_matrix"], loads["cross_rack_matrix"])
    assert np.allclose(tm["intra_per_rack"], loads["intra_per_rack"])
    # decode-around of one failure moves MORE cross traffic than the coded
    # failure-free schedule (the forfeited multicast gain) but stays unicast
    assert tm["cross_rack_matrix"].sum() > hybrid_cost(P9).cross


def test_degraded_rack_bytes_add_orphan_redistribution():
    dp = compile_degraded_plan(P9, (0,))
    rb = obytes.degraded_rack_bytes(dp, d=2)
    base = float(dp.transfer_loads()["cross_rack_matrix"].sum()) * 2
    extra = dp.orphan_subfiles.size * P9.Q * 2
    assert rb.cross_total == pytest.approx(base + extra)
    assert np.diag(rb.cross_matrix).sum() == 0.0


def test_record_rack_bytes_increments_registry():
    reg = metrics.MetricsRegistry()
    plan = compile_hybrid_plan(P9)
    rb = obytes.plan_rack_bytes(plan, "coded", d=1)
    obytes.record_rack_bytes(rb, "hybrid", "binomial", reg=reg)
    obytes.record_rack_bytes(rb, "hybrid", "binomial", reg=reg)
    tot = reg.counter("shuffle_bytes_total")
    assert tot.value(tier="cross", scheme="hybrid", family="binomial",
                     layer="engine") == pytest.approx(2 * rb.cross_total)
    pair = reg.counter("rack_pair_bytes_total")
    assert pair.value(src=0, dst=1, layer="engine") == pytest.approx(
        2 * float(rb.cross_matrix[0, 1]))


@pytest.mark.parametrize("scheme", ["uncoded", "coded", "hybrid"])
def test_sim_job_stats_bytes_reconcile_with_closed_form(scheme):
    d = 4
    spec = JobSpec("histogram", 72, 18, d)
    topo = RackTopology(P=3)
    stats = simulate_single_job(spec, topo, 9, scheme, 2 if scheme != "uncoded"
                                else 1)
    p = SchemeParams(9, 3, 18, 72, 2 if scheme != "uncoded" else 1)
    obytes.reconcile(stats.intra_rack_bytes, stats.cross_rack_bytes,
                     p, scheme, d=d)
    c = cost_table(p, check=False)[scheme]
    assert stats.cross_rack_bytes == pytest.approx(c.cross * d)


def test_sim_crash_recovery_records_bytes_and_metrics():
    metrics.reset()
    topo = RackTopology(P=3, cross_bw=1e3, intra_bw=1e4)
    sim = ClusterSim(topo, K=9, cost_model=CostModel(
        map=PhaseCoeffs(1e-3, 1e-8)), seed=0)
    sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2, time=0.0)
    # crash mid-shuffle: recovery replaces the schedule with the degraded one
    sim.inject_crash(0.002, (0,))
    (stats,) = sim.run()
    assert stats.crashes == 1 and stats.recoveries >= 1
    assert metrics.counter("sim_crashes_total").value(
        scheme="hybrid", phase="shuffle") + metrics.counter(
        "sim_crashes_total").value(scheme="hybrid", phase="map") >= 1
    # completed bytes include the degraded re-shuffle, so cross exceeds the
    # failure-free closed form (unicast repair forfeits the multicast gain)
    assert stats.cross_rack_bytes > hybrid_cost(P9).cross


def test_chooser_decisions_counter_increments():
    from repro.sim import SchemeChooser, default_catalog, run_scheduled
    from repro.sim.workload import PoissonWorkload
    metrics.reset()
    jobs = PoissonWorkload(default_catalog(8, 4), n_jobs=5,
                           rate=3.0).generate(seed=4)
    topo = RackTopology(P=4, cross_bw=1e5, intra_bw=1e6)
    cluster = ClusterSim(topo, K=8)
    chooser = SchemeChooser(8)
    stats, sched = run_scheduled(jobs, cluster, chooser)
    snap = metrics.snapshot()["chooser_decisions_total"]["samples"]
    assert sum(v for v in snap.values()) == 5
    kinds = {e.kind for e in cluster.tracer.events}
    assert {"sched_arrival", "sched_admit", "sched_drain"} <= kinds


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _parse_prom(text):
    """Minimal exposition-format parser for round-trip checks: returns
    {metric_name: [(labels_dict, value), ...]} for sample lines."""
    import re
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        name_labels, value = line.rsplit(" ", 1)
        if "{" in name_labels:
            name, rest = name_labels.split("{", 1)
            labels = {k: v.replace(r'\"', '"').replace(r'\n', '\n')
                      .replace(r'\\', '\\')
                      for k, v in re.findall(
                          r'([A-Za-z_][A-Za-z0-9_]*)="((?:[^"\\]|\\.)*)"',
                          rest[:-1])}
        else:
            name, labels = name_labels, {}
        out.setdefault(name, []).append((labels, float(value)))
    return out


def test_prometheus_counters_and_gauges_round_trip():
    reg = metrics.MetricsRegistry()
    c = reg.counter("decisions_total", "admission decisions")
    c.inc(2, scheme="hybrid", r=2)
    c.inc(3.5, scheme="coded", r=3)
    reg.gauge("queue_depth", "jobs waiting").set(7.0, policy="fifo")
    parsed = _parse_prom(reg.to_prometheus_text())
    got = {frozenset(lb.items()): v for lb, v in parsed["decisions_total"]}
    assert got[frozenset({("scheme", "hybrid"), ("r", "2")})] == 2.0
    assert got[frozenset({("scheme", "coded"), ("r", "3")})] == 3.5
    assert parsed["queue_depth"] == [({"policy": "fifo"}, 7.0)]
    text = reg.to_prometheus_text()
    assert "# HELP decisions_total admission decisions" in text
    assert "# TYPE decisions_total counter" in text
    assert "# TYPE queue_depth gauge" in text


def test_prometheus_histogram_bucket_sum_count_convention():
    reg = metrics.MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v, layer="sim")
    parsed = _parse_prom(reg.to_prometheus_text())
    buckets = {lb["le"]: v for lb, v in parsed["lat_seconds_bucket"]}
    # cumulative counts per le, with a +Inf terminal equal to _count
    assert buckets["0.1"] == 1 and buckets["1.0"] == 3
    assert buckets["10.0"] == 4 and buckets["+Inf"] == 5
    assert parsed["lat_seconds_count"] == [({"layer": "sim"}, 5.0)]
    (_, total), = parsed["lat_seconds_sum"]
    assert abs(total - 56.05) < 1e-9


def test_prometheus_sanitizes_names_and_escapes_values():
    reg = metrics.MetricsRegistry()
    g = reg.gauge("9weird.name-x", 'multi\nline "help" \\slash')
    g.set(1.5, **{"bad-label": 'va"l\\ue\nz'})
    text = reg.to_prometheus_text()
    assert "_9weird_name_x" in text          # digit prefix + charset fix
    assert "bad_label" in text
    assert r'va\"l\\ue\nz' in text           # escaped label value
    assert "\\slash" in text                 # HELP keeps escaped backslash
    parsed = _parse_prom(text)
    (lb, v), = parsed["_9weird_name_x"]
    assert v == 1.5 and lb["bad_label"] == 'va"l\\ue\nz'


def test_prometheus_output_matches_snapshot_and_is_deterministic():
    reg = metrics.MetricsRegistry()
    reg.counter("a_total").inc(4, k="x")
    reg.gauge("b").set(-2.5)
    snap = reg.snapshot()
    parsed = _parse_prom(reg.to_prometheus_text())
    assert parsed["a_total"][0][1] == snap["a_total"]["samples"]['{"k": "x"}']
    assert parsed["b"][0][1] == snap["b"]["samples"]["{}"]
    assert reg.to_prometheus_text() == reg.to_prometheus_text()
    assert metrics.to_prometheus_text() == \
        metrics.registry().to_prometheus_text()


# ---------------------------------------------------------------------------
# Observatory report: new sections + edge cases
# ---------------------------------------------------------------------------

def test_report_renders_from_empty_registry():
    from repro.obs import report as obs_report
    rep = obs_report.build_report(snapshot={})
    md = obs_report.render_markdown(rep)
    html = obs_report.render_html(rep)
    assert "_registry is empty_" in md
    assert "_no network telemetry provided_" in md
    assert "_no completed-job blame provided_" in md
    assert "_no cancelled-flow bytes recorded_" in md
    assert "no network telemetry provided" in html


def test_report_tolerates_missing_metric_families():
    from repro.obs import report as obs_report
    # a snapshot with one counter and none of the families the report
    # reassembles (rack matrices, prediction hists, cancelled bytes)
    snap = {"lonely_total": {"type": "counter", "help": "",
                             "samples": {"{}": 3.0}}}
    rep = obs_report.build_report(snapshot=snap)
    assert rep["rack_matrices"] == {} and rep["wasted"] == []
    md = obs_report.render_markdown(rep)
    assert "lonely_total" in md and "_no predictions recorded_" in md
    assert "</html>" in obs_report.render_html(rep)


def test_report_renders_utilization_and_blame_sections():
    from repro.obs import report as obs_report
    metrics.reset()
    topo = RackTopology(P=3, cross_bw=1e3, intra_bw=1e4)
    sim = ClusterSim(topo, K=9, cost_model=CostModel(
        map=PhaseCoeffs(1e-3, 1e-8)), seed=0, telemetry=True)
    sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2, time=0.0)
    (stats,) = sim.run()
    rep = obs_report.build_report(telemetry=sim.telemetry, stats=[stats])
    resources = [u["resource"] for u in rep["link_utilization"]]
    assert resources == ["root", "tor:0", "tor:1", "tor:2"]
    assert rep["blame"]["jobs"][0]["jct"] == stats.jct
    assert rep["blame"]["fleet"]["n"] == 1
    md = obs_report.render_markdown(rep)
    assert "## Link utilization" in md
    assert "## JCT blame decomposition" in md
    assert "shuffle_cross" in md
    html = obs_report.render_html(rep)
    assert "<h2>Link utilization</h2>" in html
    assert "<h2>JCT blame decomposition</h2>" in html


def test_report_surfaces_cancelled_flow_bytes():
    from repro.obs import report as obs_report
    metrics.reset()
    metrics.counter("flow_cancelled_bytes_total").inc(
        12.5, stage="cross", reason="crash")
    rep = obs_report.build_report()
    assert rep["wasted"] == [{"stage": "cross", "reason": "crash",
                              "units": 12.5}]
    md = obs_report.render_markdown(rep)
    assert "## Wasted work (cancelled flows)" in md and "12.5" in md


if __name__ == "__main__":          # regenerate the committed golden file
    doc = tracing.to_chrome_trace(_golden_sim().tracer.events)
    GOLDEN.write_text(json.dumps(doc, sort_keys=True, indent=1) + "\n")
    print(f"wrote {GOLDEN} ({len(doc['traceEvents'])} events)")
