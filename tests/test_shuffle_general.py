"""General-r executable hybrid shuffle: plan-table correctness (bit-exact
vs the dense oracle via a NumPy re-execution of the two-stage schedule,
in both unicast and coded-multicast wire formats), closed-form cost
agreement, key-order output assembly, the fused device-resident pipeline
(in-process on a trivial mesh; the 8-device run lives in
tests/multidevice/driver_shuffle.py), back-compat aliases, and
plan-compilation performance (vectorized compile + LRU cache)."""
import dataclasses
import time

import numpy as np
import pytest

from repro.core.assignment import hybrid_assignment
from repro.core.coded_collectives import (
    HybridShufflePlan, HybridShufflePlanR2, compile_hybrid_plan,
    compile_hybrid_plan_r2, plan_shuffle_reference, reduce_output_keys,
    reduce_ready_order, simulate_plan_shuffle)
from repro.core.costs import hybrid_cost
from repro.core.params import SchemeParams
from repro.core.shuffle_plan import count_plan, make_plan

# The NumPy re-execution oracle now lives beside the plan compilers (it is
# family-agnostic and shared with benchmarks/scale_bench.py).
simulate_shuffle_numpy = simulate_plan_shuffle


# P=4 racks x Kr=2; N=48 satisfies C(4,r) | NP/K and r | M for every
# r in {1, 2, 3, 4} — r=4 = P exercises the n_send == 0 (no cross-rack
# stage) path
GENERAL_R_PARAMS = [SchemeParams(K=8, P=4, Q=16, N=48, r=r)
                    for r in (1, 2, 3, 4)]


@pytest.mark.parametrize("p", GENERAL_R_PARAMS,
                         ids=lambda p: f"r{p.r}")
def test_general_r_shuffle_bit_exact(p):
    plan = compile_hybrid_plan(p)
    rng = np.random.default_rng(p.r)
    V = rng.integers(-100, 100, size=(p.N, p.Q, 3)).astype(np.float32)
    got = simulate_shuffle_numpy(V, plan)
    ref = plan_shuffle_reference(V, p)
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("p", GENERAL_R_PARAMS,
                         ids=lambda p: f"r{p.r}")
def test_general_r_plan_structure(p):
    """Each replica sources 1/r of every needed block; receives cover every
    non-local layer-table row exactly once."""
    plan = compile_hybrid_plan(p)
    n_layer = p.subfiles_per_layer
    assert plan.local_subfiles.shape[-1] == p.N * p.r // p.K
    for i in range(p.P):
        for j in range(p.Kr):
            # local rows + received rows partition the layer table
            recv = [plan.cross_recv_pos[i, j, z]
                    for z in range(p.P) if z != i and plan.n_send]
            recv = np.concatenate(recv) if recv else np.empty(0, np.int64)
            local = plan.local_pos[i, j]
            seen = np.concatenate([local, recv])
            assert len(np.unique(seen)) == len(seen)       # no row hit twice
            assert sorted(seen) == list(range(n_layer))    # full coverage
            # local_mask marks exactly the locally mapped rows
            np.testing.assert_array_equal(
                np.nonzero(plan.local_mask[i, j])[0], np.sort(local))
            # sends reference only locally mapped rows
            if plan.n_send:
                for z in range(p.P):
                    if z != i:
                        assert plan.cross_send_pos[i, j, z].max() < len(local)


@pytest.mark.parametrize("p", GENERAL_R_PARAMS,
                         ids=lambda p: f"r{p.r}")
def test_general_r_counts_match_closed_form(p):
    """Enumerated message schedule == Thm III.1 closed form for each r."""
    counts = count_plan(make_plan(hybrid_assignment(p)), p)
    c = hybrid_cost(p)
    assert counts.cross == pytest.approx(c.cross)
    assert counts.intra == pytest.approx(c.intra)


def test_reduce_ready_order_is_layer_major():
    p = GENERAL_R_PARAMS[1]
    plan = compile_hybrid_plan(p)
    order = reduce_ready_order(plan)
    assert order.shape == (p.P, p.Kr, p.N)
    for i in range(p.P):
        # every subfile exactly once, identical for all servers of a rack
        assert sorted(order[i, 0]) == list(range(p.N))
        np.testing.assert_array_equal(order[i, 0], order[i, 1])


def test_r2_alias_unchanged():
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    assert HybridShufflePlanR2 is HybridShufflePlan
    plan = compile_hybrid_plan_r2(p)
    assert isinstance(plan, HybridShufflePlan)
    with pytest.raises(ValueError):
        compile_hybrid_plan_r2(SchemeParams(K=8, P=4, Q=16, N=48, r=3))


def test_compile_rejects_r_not_dividing_M():
    # P=4, r=3: M = (N/2)/4; N=40 -> M=5, 3 does not divide 5
    with pytest.raises(ValueError):
        compile_hybrid_plan(SchemeParams(K=8, P=4, Q=16, N=40, r=3))


@pytest.mark.parametrize("p", [q for q in GENERAL_R_PARAMS if q.r >= 2],
                         ids=lambda p: f"r{p.r}")
def test_coded_multicast_tables_decode_bit_exact(p):
    """NumPy re-execution of the coded multicast wire format (packets =
    f(v_1..v_r), receivers decode from replicated-map side information)
    delivers exactly the dense oracle — the multicast tables are a valid,
    decodable schedule for every supported r."""
    plan = compile_hybrid_plan(p)
    rng = np.random.default_rng(p.r)
    V = rng.integers(-100, 100, size=(p.N, p.Q, 3)).astype(np.float32)
    got = simulate_shuffle_numpy(V, plan, multicast="coded")
    np.testing.assert_array_equal(got, plan_shuffle_reference(V, p))


def test_mcast_component_zero_is_the_destination():
    """Component c of a packet with mcast_comp_rack == z must be exactly the
    subfile whose layer-table row cross_recv_pos points at — i.e. the coded
    stream carries the same missing values as the unicast stream."""
    p = GENERAL_R_PARAMS[1]                    # r = 2
    plan = compile_hybrid_plan(p)
    for i in range(p.P):
        for z in range(p.P):
            if z == i or not plan.n_send:
                continue
            # sender i -> dest z: the component destined to z, as a local pos
            dest_c = plan.mcast_comp_rack[i, z] == z       # [n_send, r]
            assert (dest_c.sum(axis=1) == 1).all()
            pos = plan.mcast_comp_pos[i, z][dest_c]        # [n_send]
            np.testing.assert_array_equal(pos, plan.cross_send_pos[i, 0, z])


def test_reduce_output_keys_partition():
    p = GENERAL_R_PARAMS[1]
    plan = compile_hybrid_plan(p)
    keys = reduce_output_keys(plan)
    assert keys.shape == (p.K, p.Q // p.K)
    assert sorted(keys.reshape(-1).tolist()) == list(range(p.Q))


class _InterleavedKeys(SchemeParams):
    """Non-contiguous (strided) key partition: server s reduces keys
    {s, s + K, s + 2K, ...} — exercises the explicit key-order assembly."""

    def keys_of_server(self, server: int) -> range:
        return range(server, self.Q, self.K)

    def server_of_key(self, key: int) -> int:
        return key % self.K


def test_assembly_derives_key_order_not_row_order():
    """Regression for the bare ``out.reshape(Q, -1)`` assembly: with a
    non-contiguous key partition the flat row order is NOT key order, and
    assemble_outputs must still place every reduce row at its global key."""
    import jax.numpy as jnp
    from repro.mapreduce.engine import assemble_outputs

    p = _InterleavedKeys(K=4, P=2, Q=8, N=8, r=1)
    plan = compile_hybrid_plan(p)
    keys = reduce_output_keys(plan)
    assert not np.array_equal(keys.reshape(-1), np.arange(p.Q))  # truly permuted
    # out[s, q] = the global key id it holds -> assembled must be arange(Q)
    out = jnp.asarray(keys, jnp.float32)[:, :, None]             # [K, q_srv, 1]
    final = np.asarray(assemble_outputs(out, plan))
    np.testing.assert_array_equal(final[:, 0], np.arange(p.Q, dtype=np.float32))


def test_fused_pipeline_in_process_trivial_mesh():
    """The fused jitted map->pack->shuffle->reduce program matches run_job
    bit-exactly on the K=1 mesh that fits the in-process device (full
    8-device parity for r in {1,2,3} runs in the multidevice driver)."""
    import jax.numpy as jnp
    from repro.distributed.meshes import make_mesh
    from repro.mapreduce.engine import run_job, run_job_distributed
    from repro.mapreduce.jobs import histogram_job

    p = SchemeParams(K=1, P=1, Q=4, N=6, r=1)
    mesh = make_mesh((1, 1), ("rack", "server"))
    job = histogram_job()
    rng = np.random.default_rng(0)
    subs = rng.integers(0, 1 << 16, size=(p.N, 64)).astype(np.int32)
    ref = run_job(job, jnp.asarray(subs), p, "hybrid")
    for combine_impl in ("xla", "pallas"):
        got = run_job_distributed(job, subs, p, mesh, fused=True,
                                  combine_impl=combine_impl)
        np.testing.assert_array_equal(np.asarray(got.outputs),
                                      np.asarray(ref.outputs))
    legacy = run_job_distributed(job, subs, p, mesh, fused=False)
    np.testing.assert_array_equal(np.asarray(legacy.outputs),
                                  np.asarray(ref.outputs))


def test_plan_compile_fast_and_cached():
    """Vectorized compile on an N~2k config stays inside a sane wall-clock
    budget; a repeated call is an O(1) LRU-cache hit returning the same
    plan object."""
    p = SchemeParams(K=8, P=4, Q=16, N=2016, r=2)
    compile_hybrid_plan.cache_clear()
    t0 = time.perf_counter()
    plan = compile_hybrid_plan(p)
    cold = time.perf_counter() - t0
    # seed (quadratic, list.index-based) took ~50 ms here and ~4 s at
    # N=20k; the vectorized path is ~1 ms — budget leaves 100x headroom
    assert cold < 1.0, f"plan compile too slow: {cold:.3f}s"
    t0 = time.perf_counter()
    again = compile_hybrid_plan(p)
    warm = time.perf_counter() - t0
    assert again is plan                       # cache hit, not a recompile
    assert warm < 0.01, f"cached recompile not O(1): {warm:.4f}s"
    info = compile_hybrid_plan.cache_info()
    assert info.hits >= 1
