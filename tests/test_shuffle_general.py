"""General-r executable hybrid shuffle: plan-table correctness (bit-exact
vs the dense oracle via a NumPy re-execution of the two-stage schedule),
closed-form cost agreement, back-compat aliases, and plan-compilation
performance (vectorized compile + LRU cache)."""
import time

import numpy as np
import pytest

from repro.core.assignment import hybrid_assignment
from repro.core.coded_collectives import (
    HybridShufflePlan, HybridShufflePlanR2, compile_hybrid_plan,
    compile_hybrid_plan_r2, pack_local_values, plan_shuffle_reference,
    reduce_ready_order)
from repro.core.costs import hybrid_cost
from repro.core.params import SchemeParams
from repro.core.shuffle_plan import count_plan, make_plan


def simulate_shuffle_numpy(values: np.ndarray,
                           plan: HybridShufflePlan) -> np.ndarray:
    """Re-execute the exact data movement of ``hybrid_shuffle`` with NumPy
    indexing: stage-1 table fill (local rows + per-source-rack received
    blocks), then the stage-2 intra-rack key split.  Independent of jax and
    of device count, so it validates the index tables in-process."""
    p = plan.params
    q_rack, q_srv = p.Q // p.P, p.Q // p.K
    n_layer = p.subfiles_per_layer
    d = values.shape[-1]
    local = pack_local_values(values, plan).reshape(
        p.P, p.Kr, -1, p.Q, d)                      # [P, Kr, n_loc, Q, d]

    # ---- Stage 1: per-device layer table over its rack's q_rack keys ------
    table = np.zeros((p.P, p.Kr, n_layer, q_rack, d), values.dtype)
    for i in range(p.P):
        keys_i = np.arange(i * q_rack, (i + 1) * q_rack)
        for j in range(p.Kr):
            table[i, j, plan.local_pos[i, j]] = local[i, j][:, keys_i]
            if plan.n_send:
                for z in range(p.P):
                    if z == i:
                        continue
                    # what z sends to i: its share rows, i's rack keys
                    sent = local[z, j][plan.cross_send_pos[z, j, i]][:, keys_i]
                    table[i, j, plan.cross_recv_pos[i, j, z]] = sent

    # ---- Stage 2: intra-rack all_to_all == per-server key split -----------
    out = np.zeros((p.K, p.Kr * n_layer, q_srv, d), values.dtype)
    for i in range(p.P):
        for j in range(p.Kr):
            s = p.server_id(i, j)
            # device (i, j) collects key-chunk j of every layer jp's table
            out[s] = table[i, :, :, j * q_srv:(j + 1) * q_srv, :].reshape(
                p.Kr * n_layer, q_srv, d)
    return out


# P=4 racks x Kr=2; N=48 satisfies C(4,r) | NP/K and r | M for every
# r in {1, 2, 3, 4} — r=4 = P exercises the n_send == 0 (no cross-rack
# stage) path
GENERAL_R_PARAMS = [SchemeParams(K=8, P=4, Q=16, N=48, r=r)
                    for r in (1, 2, 3, 4)]


@pytest.mark.parametrize("p", GENERAL_R_PARAMS,
                         ids=lambda p: f"r{p.r}")
def test_general_r_shuffle_bit_exact(p):
    plan = compile_hybrid_plan(p)
    rng = np.random.default_rng(p.r)
    V = rng.integers(-100, 100, size=(p.N, p.Q, 3)).astype(np.float32)
    got = simulate_shuffle_numpy(V, plan)
    ref = plan_shuffle_reference(V, p)
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("p", GENERAL_R_PARAMS,
                         ids=lambda p: f"r{p.r}")
def test_general_r_plan_structure(p):
    """Each replica sources 1/r of every needed block; receives cover every
    non-local layer-table row exactly once."""
    plan = compile_hybrid_plan(p)
    n_layer = p.subfiles_per_layer
    assert plan.local_subfiles.shape[-1] == p.N * p.r // p.K
    for i in range(p.P):
        for j in range(p.Kr):
            # local rows + received rows partition the layer table
            recv = [plan.cross_recv_pos[i, j, z]
                    for z in range(p.P) if z != i and plan.n_send]
            recv = np.concatenate(recv) if recv else np.empty(0, np.int64)
            local = plan.local_pos[i, j]
            seen = np.concatenate([local, recv])
            assert len(np.unique(seen)) == len(seen)       # no row hit twice
            assert sorted(seen) == list(range(n_layer))    # full coverage
            # local_mask marks exactly the locally mapped rows
            np.testing.assert_array_equal(
                np.nonzero(plan.local_mask[i, j])[0], np.sort(local))
            # sends reference only locally mapped rows
            if plan.n_send:
                for z in range(p.P):
                    if z != i:
                        assert plan.cross_send_pos[i, j, z].max() < len(local)


@pytest.mark.parametrize("p", GENERAL_R_PARAMS,
                         ids=lambda p: f"r{p.r}")
def test_general_r_counts_match_closed_form(p):
    """Enumerated message schedule == Thm III.1 closed form for each r."""
    counts = count_plan(make_plan(hybrid_assignment(p)), p)
    c = hybrid_cost(p)
    assert counts.cross == pytest.approx(c.cross)
    assert counts.intra == pytest.approx(c.intra)


def test_reduce_ready_order_is_layer_major():
    p = GENERAL_R_PARAMS[1]
    plan = compile_hybrid_plan(p)
    order = reduce_ready_order(plan)
    assert order.shape == (p.P, p.Kr, p.N)
    for i in range(p.P):
        # every subfile exactly once, identical for all servers of a rack
        assert sorted(order[i, 0]) == list(range(p.N))
        np.testing.assert_array_equal(order[i, 0], order[i, 1])


def test_r2_alias_unchanged():
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    assert HybridShufflePlanR2 is HybridShufflePlan
    plan = compile_hybrid_plan_r2(p)
    assert isinstance(plan, HybridShufflePlan)
    with pytest.raises(ValueError):
        compile_hybrid_plan_r2(SchemeParams(K=8, P=4, Q=16, N=48, r=3))


def test_compile_rejects_r_not_dividing_M():
    # P=4, r=3: M = (N/2)/4; N=40 -> M=5, 3 does not divide 5
    with pytest.raises(ValueError):
        compile_hybrid_plan(SchemeParams(K=8, P=4, Q=16, N=40, r=3))


def test_plan_compile_fast_and_cached():
    """Vectorized compile on an N~2k config stays inside a sane wall-clock
    budget; a repeated call is an O(1) LRU-cache hit returning the same
    plan object."""
    p = SchemeParams(K=8, P=4, Q=16, N=2016, r=2)
    compile_hybrid_plan.cache_clear()
    t0 = time.perf_counter()
    plan = compile_hybrid_plan(p)
    cold = time.perf_counter() - t0
    # seed (quadratic, list.index-based) took ~50 ms here and ~4 s at
    # N=20k; the vectorized path is ~1 ms — budget leaves 100x headroom
    assert cold < 1.0, f"plan compile too slow: {cold:.3f}s"
    t0 = time.perf_counter()
    again = compile_hybrid_plan(p)
    warm = time.perf_counter() - t0
    assert again is plan                       # cache hit, not a recompile
    assert warm < 0.01, f"cached recompile not O(1): {warm:.4f}s"
    info = compile_hybrid_plan.cache_info()
    assert info.hits >= 1
