"""Critical-path observatory tests (repro.obs.blame + sim telemetry): the
JCT blame exactness law on Table I rows, cause attribution (stragglers,
crashes), the trace-side critical-path extractor, network-telemetry
determinism, cancelled-flow byte accounting, and the scheduler's
per-admission component-error feed."""
import hashlib
import json
import math

import pytest

from repro.core.params import TABLE1_GRID
from repro.obs import blame as obs_blame
from repro.obs import metrics
from repro.obs.tracing import to_chrome_trace
from repro.sim import (ClusterSim, CostModel, ExponentialTail, JobSpec,
                       MultiJobScheduler, PhaseCoeffs, PoissonWorkload,
                       RackTopology, SchemeChooser, default_catalog)

COSTS = CostModel(map=PhaseCoeffs(1e-3, 1e-8),
                  pack=PhaseCoeffs(5e-4, 5e-9),
                  reduce=PhaseCoeffs(1e-3, 1e-8))
SCHEMES = ("uncoded", "coded", "hybrid", "hybrid_resolvable")


def _solo(scheme="hybrid", r=2, stragglers=None, crash_at=None,
          telemetry=False, seed=0, topo=None, costs=COSTS):
    topo = topo or RackTopology(P=4, cross_bw=1e3, intra_bw=1e4)
    sim = ClusterSim(topo, 8, costs, stragglers=stragglers, seed=seed,
                     telemetry=telemetry)
    sim.submit(JobSpec("j", 48, 16, 2), scheme, r, time=0.0)
    if crash_at is not None:
        sim.inject_crash(crash_at, [0])
    (stats,) = sim.run()
    return stats, sim


def _scheduled(seed=0, n_jobs=8, rate=4.0, telemetry=True):
    topo = RackTopology(P=4, cross_bw=2e4, intra_bw=2e5)
    cluster = ClusterSim(topo, 8, seed=seed, telemetry=telemetry)
    chooser = SchemeChooser(8, cost_model=COSTS, compile_real_plans=False)
    wl = PoissonWorkload(default_catalog(8, 4), n_jobs=n_jobs, rate=rate)
    sched = MultiJobScheduler(chooser, policy="fifo", max_concurrent=4)
    stats = sched.run(wl.generate(seed), cluster)
    return cluster, sched, stats


def _residual(stats):
    return abs(stats.jct - math.fsum(stats.blame.values()))


# ---------------------------------------------------------------------------
# Exactness law
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
def test_blame_sums_to_jct_on_table1_rows(scheme):
    for (K, P, Q, N, r) in TABLE1_GRID[:3]:
        topo = RackTopology(P=P, cross_bw=1e3, intra_bw=1e4)
        sim = ClusterSim(topo, K, COSTS, seed=0)
        sim.submit(JobSpec("exact", N, Q, 2), scheme, r, time=0.0,
                   check=False)
        (stats,) = sim.run()
        assert stats.blame is not None
        assert _residual(stats) <= 1e-9 * max(stats.jct, 1.0)
        # zero-contention calibration identity: solo job => no contention
        assert abs(stats.blame["contention"]) < 1e-9


def test_blame_components_match_schema():
    stats, _ = _solo()
    assert set(stats.blame) == set(obs_blame.COMPONENTS)
    rep = obs_blame.blame_report(stats)
    assert rep.jct == stats.jct
    assert abs(rep.residual) <= 1e-12


def test_decompose_degrades_gracefully_without_ideals():
    # missing ideal/failure-free inputs default to the actuals: the sum
    # law must hold even for a caller that only has phase times
    comps = obs_blame.decompose(
        jct=10.0, queueing=1.0,
        phase_times={"plan_compile": 0.5, "map": 3.0, "pack": 0.5,
                     "shuffle:cross": 2.0, "shuffle:intra": 1.0,
                     "reduce": 2.0})
    assert abs(math.fsum(comps.values()) - 10.0) < 1e-12
    assert comps["contention"] == 0.0 and comps["recovery"] == 0.0


# ---------------------------------------------------------------------------
# Cause attribution
# ---------------------------------------------------------------------------

def test_straggler_tail_lands_in_map_straggle():
    topo = RackTopology(P=4, cross_bw=1e6, intra_bw=1e7)
    plain, _ = _solo(topo=topo)
    tail, _ = _solo(topo=topo, stragglers=ExponentialTail(3.0))
    assert abs(plain.blame["map_straggle"]) < 1e-12
    assert tail.blame["map_straggle"] > 0
    assert _residual(tail) <= 1e-9 * max(tail.jct, 1.0)


def test_crash_recovery_blame_equals_degraded_delta():
    ff, _ = _solo()
    crash_at = ff.phase_times.get("map", 0.0) + 0.6 * (
        ff.jct - ff.phase_times.get("map", 0.0))
    crashed, _ = _solo(crash_at=crash_at)
    delta = crashed.jct - ff.jct
    assert delta > 0
    assert abs(crashed.blame["recovery"] - delta) <= 1e-9 * ff.jct
    assert _residual(crashed) <= 1e-9 * max(crashed.jct, 1.0)


def test_rack_skew_shifts_intra_blame_and_telemetry_busy_time():
    skewed = RackTopology(P=4, cross_bw=1e3, intra_bw=1e4,
                          rack_bw_scale=(0.25, 1.0, 1.0, 1.0))
    s0, _ = _solo()
    s1, sim = _solo(topo=skewed, telemetry=True)
    assert s1.blame["shuffle_intra"] > 1.5 * s0.blame["shuffle_intra"]
    busy = {k: v["busy_s"] for k, v in sim.telemetry.utilization().items()
            if k.startswith("tor:")}
    assert max(sorted(busy), key=lambda k: busy[k]) == "tor:0"


# ---------------------------------------------------------------------------
# Trace-side extraction + fleet rollup
# ---------------------------------------------------------------------------

def test_extract_blame_agrees_with_stats_blame():
    cluster, _, stats = _scheduled()
    events = list(cluster.tracer.events)
    done = [s for s in stats if s.blame is not None]
    assert done
    for s in done:
        rep = obs_blame.extract_blame(events, s)   # raises on disagreement
        assert abs(rep.residual) <= 1e-9 * max(rep.jct, 1.0)


def test_critical_path_segments_are_ordered_and_cover_phases():
    cluster, _, stats = _scheduled(n_jobs=4)
    s = next(x for x in stats if x.blame is not None)
    path = obs_blame.critical_path(list(cluster.tracer.events), s.job_id)
    assert path
    for a, b in zip(path, path[1:]):
        assert a.end <= b.start + 1e-9
    assert all(seg.end >= seg.start for seg in path)


def test_fleet_blame_rollup_shape_and_tail():
    _, _, stats = _scheduled()
    reports = [obs_blame.blame_report(s) for s in stats
               if s.blame is not None]
    fleet = obs_blame.fleet_blame(reports, q=0.9)
    assert fleet["n"] == len(reports)
    assert fleet["jct_q"] >= fleet["jct_mean"] * 0.0
    assert set(fleet["mean"]) == set(fleet["tail_share"])
    assert fleet["max_abs_residual"] <= 1e-9
    # empty fleet is well-defined (report edge case)
    assert obs_blame.fleet_blame([])["n"] == 0


# ---------------------------------------------------------------------------
# Telemetry determinism + cancelled-byte accounting
# ---------------------------------------------------------------------------

def test_network_telemetry_byte_identical_per_seed():
    dumps = []
    for _ in range(2):
        cluster, _, _ = _scheduled(seed=3)
        dumps.append(json.dumps(cluster.telemetry.to_dict(),
                                sort_keys=True).encode())
    assert hashlib.sha256(dumps[0]).hexdigest() == \
        hashlib.sha256(dumps[1]).hexdigest()


def test_traces_unchanged_with_telemetry_on_or_off():
    docs = []
    for telem in (True, False):
        cluster, _, _ = _scheduled(seed=1, telemetry=telem)
        docs.append(json.dumps(to_chrome_trace(cluster.tracer.events),
                               sort_keys=True))
    assert docs[0] == docs[1]


def test_crash_cancel_counts_partially_drained_bytes():
    metrics.reset()
    ff, _ = _solo()
    crash_at = ff.phase_times.get("map", 0.0) + 0.6 * (
        ff.jct - ff.phase_times.get("map", 0.0))
    _, sim = _solo(crash_at=crash_at, telemetry=True, seed=0)
    snap = metrics.snapshot()
    samples = snap.get("flow_cancelled_bytes_total", {}).get("samples", {})
    crash_units = sum(v for k, v in samples.items()
                      if json.loads(k).get("reason") == "crash")
    assert crash_units > 0
    # the telemetry-side mirror agrees on the total
    assert abs(sum(sim.telemetry.cancelled_units().values())
               - crash_units) < 1e-9 * max(crash_units, 1.0)


def test_flow_records_carry_rate_history_and_outcomes():
    _, sim = _solo(telemetry=True)
    recs = list(sim.telemetry.flows.values())
    assert recs
    assert all(r.state == "done" for r in recs)
    for r in recs:
        assert r.rates and r.end >= r.start
        assert all(rate >= 0 for _, rate in r.rates)


# ---------------------------------------------------------------------------
# Scheduler: component estimates + drift feed
# ---------------------------------------------------------------------------

def test_estimate_components_sum_to_estimate():
    topo = RackTopology(P=4, cross_bw=1e3, intra_bw=1e4)
    cluster = ClusterSim(topo, 8, COSTS, seed=0)
    chooser = SchemeChooser(8, cost_model=COSTS, compile_real_plans=False)
    spec = JobSpec("j", 48, 16, 2)
    for scheme, r in (("hybrid", 2), ("coded", 3), ("uncoded", 1)):
        est = chooser.estimate(spec, scheme, r, cluster)
        comps = chooser.estimate_components(spec, scheme, r, cluster)
        if est is None:
            assert comps is None
            continue
        assert abs(math.fsum(comps.values()) - est) <= 1e-9 * max(est, 1.0)
        assert comps["queueing"] == 0.0    # priced at admission


def test_scheduler_records_blame_and_component_error_metrics():
    metrics.reset()
    _, sched, stats = _scheduled()
    n_done = sum(1 for s in stats if s.blame is not None)
    snap = metrics.snapshot()
    jobs = sum(snap["jct_blame_jobs_total"]["samples"].values())
    assert jobs == n_done
    blame_comps = {json.loads(k)["component"]
                   for k in snap["jct_blame_seconds"]["samples"]}
    assert blame_comps == set(obs_blame.COMPONENTS)
    assert "jct_component_bias_seconds" in snap
    assert "jct_component_error_seconds" in snap
    for d in sched.decisions.values():
        assert d.est_components is not None


# ---------------------------------------------------------------------------
# Engine-side blame (measured spans)
# ---------------------------------------------------------------------------

def test_engine_job_result_blame_sums_to_traced_walls():
    import numpy as np
    from repro.core.params import SchemeParams
    from repro.distributed.meshes import make_mesh
    from repro.mapreduce.engine import run_job_distributed
    from repro.mapreduce.jobs import histogram_job
    from repro.obs.tracing import enable_tracing, get_tracer

    p = SchemeParams(K=1, P=1, Q=4, N=6, r=1)
    mesh = make_mesh((1, 1), ("rack", "server"))
    job = histogram_job()
    subs = np.random.default_rng(0).integers(
        0, 1 << 16, size=(p.N, 64)).astype(np.int32)

    res = run_job_distributed(job, subs, p, mesh, fused=True)
    assert res.blame is None               # tracing disabled -> no blame

    tracer = enable_tracing(True)
    try:
        for fused in (True, False):
            n0 = len(tracer.events)
            res = run_job_distributed(job, subs, p, mesh, fused=fused)
            total = math.fsum(float(e.dur) for e in tracer.events[n0:]
                              if e.kind == "engine_phase" and e.dur)
            assert res.blame is not None
            assert abs(math.fsum(res.blame.values()) - total) <= 1e-9
            if not fused:       # legacy shuffle wall is split by tier
                assert "shuffle_cross" in res.blame
                assert "shuffle_intra" in res.blame
    finally:
        enable_tracing(False)


def test_blame_from_phase_timings_splits_shuffle_by_tier():
    row = {"seconds": {"plan_compile": 0.1, "map": 1.0, "pack": 0.2,
                       "reduce": 0.3},
           "meta": {"K": 8, "P": 4, "Q": 16, "N": 48, "r": 2,
                    "shuffle_s": 0.6}}
    comps = obs_blame.blame_from_phase_timings(row)
    assert abs(math.fsum(comps.values()) - 2.2) < 1e-12
    assert comps["shuffle_cross"] > 0 and comps["shuffle_intra"] > 0
