"""Cluster-simulator tests: traffic export vs closed forms, determinism
(same seed => bit-identical trace), monotonicity properties (contention and
stragglers never DECREASE simulated JCT), calibration fitting, plan-cache
introspection, workload generators and scheduler behavior."""
import numpy as np
import pytest

from repro.core.assignment import (coded_assignment, hybrid_assignment,
                                   uncoded_assignment)
from repro.core.coded_collectives import (compile_hybrid_plan,
                                          configure_plan_cache,
                                          plan_cache_clear, plan_cache_info,
                                          plan_transfer_matrices)
from repro.core.costs import cost_table, hybrid_cost
from repro.core.params import SchemeParams
from repro.core.shuffle_plan import plan_stage_traffic, scheme_stage_traffic
from repro.sim import (BurstyWorkload, ClusterSim, CostModel,
                       DeterministicSlowdown, DiurnalWorkload,
                       ExponentialTail, JobSpec, PhaseCoeffs,
                       PoissonWorkload, RackCorrelated, RackTopology,
                       SchemeChooser, calibrate, default_catalog,
                       measurements_from_pipeline_bench, run_scheduled,
                       simulate_single_job, valid_subfile_counts)

P9 = SchemeParams(9, 3, 18, 72, 2)


# ---------------------------------------------------------------------------
# Traffic export: enumerated schedule == closed forms, per stage & per rack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme,mk", [
    ("uncoded", uncoded_assignment), ("coded", coded_assignment),
    ("hybrid", hybrid_assignment)])
def test_stage_traffic_enumerated_equals_closed_form(scheme, mk):
    enum = plan_stage_traffic(mk(P9))
    closed = scheme_stage_traffic(P9, scheme)
    assert [s.stage for s in enum] == [s.stage for s in closed]
    for a, b in zip(enum, closed):
        assert a.cross_pairs == pytest.approx(b.cross_pairs)
        assert a.intra_pairs_per_rack == pytest.approx(b.intra_pairs_per_rack)
    c = cost_table(P9)[scheme]
    assert sum(s.cross_pairs for s in enum) == pytest.approx(c.cross)
    assert sum(s.intra_pairs for s in enum) == pytest.approx(c.intra)


def test_plan_transfer_matrices_match_closed_forms():
    p = SchemeParams(8, 4, 16, 48, 2)
    plan = compile_hybrid_plan(p)
    c = hybrid_cost(p)
    tm = plan_transfer_matrices(plan, "coded")
    assert tm["cross_rack_matrix"].sum() == pytest.approx(c.cross)
    assert np.diag(tm["cross_rack_matrix"]).sum() == 0
    assert tm["intra_per_rack"].sum() == pytest.approx(c.intra)
    # unicast wire format moves r copies of every coded packet
    tmu = plan_transfer_matrices(plan, "unicast")
    assert tmu["cross_rack_matrix"].sum() == pytest.approx(c.cross * p.r)


# ---------------------------------------------------------------------------
# Determinism: same seed => bit-identical event trace and JCTs
# ---------------------------------------------------------------------------

def _scheduled_run(seed, policy="srpt"):
    jobs = PoissonWorkload(default_catalog(8, 4), n_jobs=25,
                           rate=3.0).generate(seed=seed)
    topo = RackTopology(P=4, cross_bw=1e5, intra_bw=1e6)
    cluster = ClusterSim(topo, K=8, cost_model=CostModel(
        map=PhaseCoeffs(1e-3, 1e-8)), stragglers=ExponentialTail(0.5),
        seed=seed)
    chooser = SchemeChooser(8, cost_model=cluster.cost_model)
    stats, sched = run_scheduled(jobs, cluster, chooser, policy=policy,
                                 max_concurrent=3)
    decisions = [(sched.decisions[s.job_id].scheme,
                  sched.decisions[s.job_id].r) for s in stats]
    return [s.jct for s in stats], list(cluster.trace), decisions, cluster


def test_same_seed_bit_identical():
    jcts1, trace1, dec1, c1 = _scheduled_run(seed=11)
    jcts2, trace2, dec2, c2 = _scheduled_run(seed=11)
    assert jcts1 == jcts2          # exact float equality, not approx
    assert trace1 == trace2
    assert dec1 == dec2
    # the full structured schema too: spans, scheduler events, labels
    assert c1.tracer.events == c2.tracer.events
    assert any(e.dur is not None for e in c1.tracer.events)
    assert any(e.kind == "sched_admit" for e in c1.tracer.events)


def test_different_seed_differs():
    jcts1, _, _, _ = _scheduled_run(seed=11)
    jcts2, _, _, _ = _scheduled_run(seed=12)
    assert jcts1 != jcts2


@pytest.mark.parametrize("policy", ["fifo", "srpt", "fair"])
def test_policies_complete_all_jobs(policy):
    jcts, trace, decisions, _ = _scheduled_run(seed=3, policy=policy)
    assert len(jcts) == 25
    assert all(j > 0 for j in jcts)
    assert sum(1 for t in trace if t[1] == "job_done") == 25


# ---------------------------------------------------------------------------
# Zero-contention anchor on a non-Table-I config (Table I grid is covered
# by tests/test_table1_regression.py)
# ---------------------------------------------------------------------------

def test_straggler_barrier_adds_exactly_max_factor():
    """Compute phases end at the SLOWEST server: a deterministic 3x
    slowdown of one server must scale the map phase by exactly 3."""
    cost = CostModel(map=PhaseCoeffs(0.0, 1e-6))
    spec = JobSpec("histogram", 72, 18, 1)
    topo = RackTopology(P=3, cross_bw=1e5, intra_bw=1e6)
    base = simulate_single_job(spec, topo, 9, "hybrid", 2, cost_model=cost)
    factors = (1.0,) * 8 + (3.0,)
    slow = simulate_single_job(spec, topo, 9, "hybrid", 2, cost_model=cost,
                               stragglers=DeterministicSlowdown(factors))
    t_map = base.phase_times["map"]
    assert slow.phase_times["map"] == pytest.approx(3 * t_map)
    assert slow.jct == pytest.approx(base.jct + 2 * t_map)


def test_rack_correlated_factors_shape():
    rng = np.random.default_rng(0)
    f = RackCorrelated(p_slow=0.5, factor=4.0).factors(rng, K=12, P=3)
    assert f.shape == (12,)
    assert set(np.unique(f)) <= {1.0, 4.0}
    # whole racks move together
    assert all(len(set(f[i * 4:(i + 1) * 4])) == 1 for i in range(3))


# ---------------------------------------------------------------------------
# Monotonicity: contention / stragglers / less bandwidth never decrease JCT
# ---------------------------------------------------------------------------

def _jct(slowdown=1.0, bw_scale=1.0, background_jobs=0):
    K = 8
    spec = JobSpec("histogram", 48, 16, 1)
    topo = RackTopology(P=4, cross_bw=1e4 * bw_scale,
                        intra_bw=1e5 * bw_scale)
    cost = CostModel(map=PhaseCoeffs(1e-4, 1e-8),
                     reduce=PhaseCoeffs(1e-4, 1e-8))
    sim = ClusterSim(topo, K, cost,
                     DeterministicSlowdown((slowdown,) + (1.0,) * (K - 1)),
                     seed=0)
    target = sim.submit(spec, "hybrid", 2, time=0.0)
    for b in range(background_jobs):
        sim.submit(JobSpec("histogram", 48, 16, 1), "hybrid", 2, time=0.0)
    stats = {s.job_id: s for s in sim.run()}
    return stats[target].jct


def test_run_until_truncation_resumes_consistently():
    """A run truncated at an arbitrary horizon and then resumed must finish
    with the same JCTs as one uninterrupted run, with a monotone trace."""
    def make_sim():
        topo = RackTopology(P=3, cross_bw=1e3, intra_bw=1e4)
        sim = ClusterSim(topo, K=9, cost_model=CostModel(
            map=PhaseCoeffs(1e-3, 1e-8)))
        sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2, time=0.0)
        sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2, time=0.05)
        return sim

    full = make_sim()
    want = [s.jct for s in full.run()]
    half_t = want[0] * 0.4
    resumed = make_sim()
    resumed.run(until=half_t)
    assert resumed.now == half_t
    got = [s.jct for s in resumed.run()]
    assert got == pytest.approx(want, rel=1e-9)
    times = [t for t, _, _ in resumed.trace]
    assert times == sorted(times)


def test_monotone_examples():
    base = _jct()
    assert _jct(slowdown=2.5) >= base
    assert _jct(bw_scale=0.5) >= base
    assert _jct(background_jobs=2) >= base


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(slowdown=st.floats(1.0, 10.0),
           bw_scale=st.floats(0.05, 1.0),
           background=st.integers(0, 4))
    def test_contention_and_stragglers_never_decrease_jct(
            slowdown, bw_scale, background):
        """Hardening knobs only ever hurt: any straggler slowdown, any
        bandwidth reduction, any amount of competing load must yield
        JCT >= the unloaded baseline, and each knob is monotone from the
        baseline."""
        base = _jct()
        worse = _jct(slowdown=slowdown, bw_scale=bw_scale,
                     background_jobs=background)
        assert worse >= base * (1 - 1e-9)
        assert _jct(slowdown=slowdown) >= base * (1 - 1e-9)
        assert _jct(bw_scale=bw_scale) >= base * (1 - 1e-9)
        assert _jct(background_jobs=background) >= base * (1 - 1e-9)
else:                                                  # pragma: no cover
    def test_contention_and_stragglers_never_decrease_jct():
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (pip install .[test])")


# ---------------------------------------------------------------------------
# Placement bridge (repro.placement.sim_bridge): Table II in time units
# ---------------------------------------------------------------------------

def _bridged_jct(solver, K=16, P=4, rf=2, N=192, seed=0):
    from repro.placement import place_replicas, simulate_placement, solve
    p = SchemeParams(K, P, Q=K, N=N, r=2, r_f=rf)
    replicas = place_replicas(p, np.random.default_rng(seed))
    res = solve(p, replicas, solver, seed=seed + 1)
    topo = RackTopology(P=P, cross_bw=1e4, intra_bw=1e5)
    cost = CostModel(map=PhaseCoeffs(0.0, 1e-8))
    return simulate_placement(res, topo, cost_model=cost), res


def test_placement_bridge_optimized_strictly_lowers_jct():
    """Acceptance pin (straggler-free Table II row (16,4,2,192)): the flow
    placement's simulated JCT is strictly below the random placement's, and
    the gap comes from the fetch stage + map imbalance, not the shuffle."""
    stats_opt, res_opt = _bridged_jct("flow")
    stats_ran, res_ran = _bridged_jct("random")
    assert res_opt.node_locality > res_ran.node_locality
    assert stats_opt.jct < stats_ran.jct
    # shuffle stages are placement-invariant
    for key in ("shuffle:cross", "shuffle:intra"):
        assert stats_opt.phase_times[key] == \
            pytest.approx(stats_ran.phase_times[key])
    assert stats_opt.phase_times["fetch"] < stats_ran.phase_times["fetch"]
    assert stats_opt.phase_times["map"] <= stats_ran.phase_times["map"]


def test_placement_fetch_contends_with_other_jobs():
    """Fetch flows share the network: background shuffle load on the root
    switch must delay a placement-bridged job's fetch stage."""
    from repro.placement import place_replicas, solve, traffic_for_result
    p = SchemeParams(8, 4, 16, 48, 2, r_f=2)
    res = solve(p, place_replicas(p, np.random.default_rng(0)), "random",
                seed=1)
    tr = traffic_for_result(res)
    assert tr.cross_units > 0          # random placement does miss racks

    def jct(background):
        topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e5)
        sim = ClusterSim(topo, K=8)
        target = sim.submit(JobSpec("histogram", 48, 16, 1), "hybrid", 2,
                            time=0.0, placement=tr)
        for _ in range(background):
            sim.submit(JobSpec("histogram", 48, 16, 1), "hybrid", 2,
                       time=0.0)
        return {s.job_id: s for s in sim.run()}[target].jct

    assert jct(background=2) > jct(background=0)


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def test_calibrate_recovers_affine_coeffs():
    alpha, beta = 3e-3, 7e-9
    rows = [{"work": {"map": w, "reduce": w / 2},
             "seconds": {"map": alpha + beta * w,
                         "reduce": alpha + 2 * beta * (w / 2)}}
            for w in (1e4, 1e5, 1e6, 1e7)]
    model = calibrate(rows)
    assert model.map.alpha == pytest.approx(alpha, rel=1e-6)
    assert model.map.beta == pytest.approx(beta, rel=1e-6)
    assert model.reduce.beta == pytest.approx(2 * beta, rel=1e-6)
    assert model.pack.beta == 0.0                      # absent phase -> zero


def test_calibrate_from_pipeline_bench_rows():
    report = {"schema_version": 1, "results": [
        {"N": 96, "Q": 16, "d": 8, "r": 2,
         "legacy": {"phases_s": {"map_to_host": 0.012,
                                 "host_pack_upload": 0.024,
                                 "shuffle_reduce": 0.05}}},
        {"N": 192, "Q": 16, "d": 8, "r": 2,
         "legacy": {"phases_s": {"map_to_host": 0.024,
                                 "host_pack_upload": 0.048,
                                 "shuffle_reduce": 0.1}}},
    ]}
    rows = measurements_from_pipeline_bench(report)
    model = calibrate(rows)
    assert model.map.beta > 0 and model.pack.beta > 0
    # pure rate data: secs double when work doubles => alpha ~ 0
    assert model.map.alpha == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# Plan-cache introspection (configurable LRU)
# ---------------------------------------------------------------------------

def test_plan_cache_info_and_configurable_maxsize():
    try:
        configure_plan_cache(2)
        p1 = SchemeParams(8, 4, 16, 48, 2)
        p2 = SchemeParams(8, 4, 16, 96, 2)
        p3 = SchemeParams(8, 4, 16, 144, 2)
        assert plan_cache_info().maxsize == 2
        compile_hybrid_plan(p1)
        compile_hybrid_plan(p1)
        info = plan_cache_info()
        assert (info.hits, info.misses, info.currsize) == (1, 1, 1)
        compile_hybrid_plan(p2)
        compile_hybrid_plan(p3)                 # evicts p1 (maxsize 2)
        compile_hybrid_plan(p1)
        info = plan_cache_info()
        assert info.misses == 4 and info.currsize == 2
        plan_cache_clear()
        assert plan_cache_info().currsize == 0
    finally:
        configure_plan_cache()                  # restore default


def test_plan_cache_maxsize_env(monkeypatch):
    try:
        monkeypatch.setenv("REPRO_PLAN_CACHE_MAXSIZE", "7")
        configure_plan_cache()
        assert plan_cache_info().maxsize == 7
    finally:
        monkeypatch.delenv("REPRO_PLAN_CACHE_MAXSIZE", raising=False)
        configure_plan_cache()


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

def test_valid_subfile_counts_admit_all_candidates():
    for n in valid_subfile_counts(8, 4, rs=(1, 2, 3), coded_rs=(2,)):
        for r in (1, 2, 3):
            SchemeParams(8, 4, 16, n, r).validate_hybrid()
        SchemeParams(8, 4, 16, n, 2).validate_coded()
        SchemeParams(8, 4, 16, n, 1).validate_uncoded()


@pytest.mark.parametrize("wl_cls,kwargs", [
    (PoissonWorkload, {"rate": 2.0}),
    (BurstyWorkload, {"burst_size": 3, "burst_gap": 0.5}),
    (DiurnalWorkload, {"base_rate": 1.0, "peak_rate": 5.0, "period": 60.0}),
])
def test_workload_generators_deterministic_and_sorted(wl_cls, kwargs):
    wl = wl_cls(default_catalog(8, 4), n_jobs=30, **kwargs)
    jobs1, jobs2 = wl.generate(seed=5), wl.generate(seed=5)
    assert jobs1 == jobs2
    assert len(jobs1) == 30
    arrivals = [j.arrival for j in jobs1]
    assert arrivals == sorted(arrivals)
    assert wl.generate(seed=6) != jobs1


def test_bursty_arrivals_batch():
    wl = BurstyWorkload(default_catalog(8, 4), n_jobs=9, burst_size=3,
                        burst_gap=2.0)
    arrivals = [j.arrival for j in wl.generate(seed=0)]
    assert arrivals == [0.0] * 3 + [2.0] * 3 + [4.0] * 3


# ---------------------------------------------------------------------------
# Scheduler: adaptive choice tracks the bandwidth regime
# ---------------------------------------------------------------------------

def _choose(cross_bw):
    topo = RackTopology(P=4, cross_bw=cross_bw, intra_bw=1e6)
    cluster = ClusterSim(topo, K=8)
    chooser = SchemeChooser(8)
    return chooser.choose(JobSpec("histogram", 336, 16, 4), cluster)


def test_chooser_adapts_to_bandwidth_ratio():
    slow_cross = _choose(cross_bw=1e4)
    assert slow_cross.scheme == "hybrid"    # scarce root -> min cross traffic
    fast_cross = _choose(cross_bw=1e6)
    assert fast_cross.scheme in ("coded", "uncoded")  # parity -> min total


def test_chooser_charges_compile_once_then_hits_cache():
    plan_cache_clear()
    topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e6)
    cluster = ClusterSim(topo, K=8, cost_model=CostModel(
        plan_compile=PhaseCoeffs(1e-2, 0.0)))
    chooser = SchemeChooser(8, cost_model=cluster.cost_model)
    spec = JobSpec("histogram", 336, 16, 4)
    first = chooser.choose(spec, cluster)
    assert first.scheme == "hybrid"
    assert not first.cache_hit and first.compile_s == pytest.approx(1e-2)
    second = chooser.choose(spec, cluster)
    assert second.cache_hit and second.compile_s == 0.0


def test_fixed_chooser_is_a_baseline():
    topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e6)
    cluster = ClusterSim(topo, K=8)
    chooser = SchemeChooser(8, adaptive=False, fixed=("uncoded", 1))
    d = chooser.choose(JobSpec("histogram", 336, 16, 4), cluster)
    assert (d.scheme, d.r) == ("uncoded", 1)


def test_fixed_chooser_rejects_inadmissible_job_clearly():
    cluster = ClusterSim(RackTopology(P=4, cross_bw=1e4, intra_bw=1e6), K=8)
    chooser = SchemeChooser(8, adaptive=False, fixed=("hybrid", 3))
    # C(4,3) = 4 does not divide N*P/K = 10
    with pytest.raises(ValueError, match="inadmissible"):
        chooser.choose(JobSpec("histogram", 20, 16, 1), cluster)


def test_chooser_probe_tolerates_non_executable_plan():
    """N=16, r=3: closed-form admissible (C(4,3) | 8) but the EXECUTABLE
    plan needs r | M (3 does not divide 2) — the probe compile must degrade
    to a modeled compile charge, not crash the stream."""
    cluster = ClusterSim(RackTopology(P=4, cross_bw=1e4, intra_bw=1e6), K=8)
    chooser = SchemeChooser(8, adaptive=False, fixed=("hybrid", 3))
    d = chooser.choose(JobSpec("histogram", 16, 16, 1), cluster)
    assert (d.scheme, d.r, d.cache_hit) == ("hybrid", 3, False)


# ---------------------------------------------------------------------------
# MultiJobScheduler drain-order edge cases: simultaneous completions + ties
# ---------------------------------------------------------------------------

def _tied_stream(policy, n_jobs=4, max_concurrent=1, seed=0):
    """n identical-size jobs (distinct names) arriving simultaneously:
    every SRPT/fair ordering signal ties."""
    jobs = [JobSpec(f"job{i}", 48, 16, 1, arrival=0.0)
            for i in range(n_jobs)]
    topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e5)
    cluster = ClusterSim(topo, K=8, cost_model=CostModel(
        map=PhaseCoeffs(1e-4, 1e-8)), seed=seed)
    chooser = SchemeChooser(8, cost_model=cluster.cost_model)
    stats, sched = run_scheduled(jobs, cluster, chooser, policy=policy,
                                 max_concurrent=max_concurrent)
    return stats, sched, cluster


@pytest.mark.parametrize("policy", ["fifo", "srpt", "fair"])
def test_tied_queue_drains_in_arrival_order(policy):
    """All ordering signals tie -> every policy must fall back to arrival
    (seq) order: np.argmin picks the FIRST minimal index."""
    stats, sched, _ = _tied_stream(policy)
    assert [s.name for s in stats] == ["job0", "job1", "job2", "job3"]
    assert len(sched.decisions) == 4


@pytest.mark.parametrize("policy", ["fifo", "srpt", "fair"])
def test_simultaneous_job_done_admits_each_queued_job_once(policy):
    """max_concurrent=2 with identical jobs: both running jobs finish at
    the SAME instant, firing two _job_done drains back to back — each must
    admit exactly one queued job (no double-admission, no lost slot)."""
    stats, sched, cluster = _tied_stream(policy, n_jobs=6, max_concurrent=2)
    assert len(stats) == 6
    assert sorted(s.name for s in stats) == sorted(f"job{i}"
                                                   for i in range(6))
    submits = [t for t in cluster.trace if t[1] == "submit"]
    assert len(submits) == 6                    # one submission per job
    # the two leaders really did finish simultaneously (the edge case)
    finishes = sorted(s.finish for s in stats)
    assert finishes[0] == finishes[1]


@pytest.mark.parametrize("policy", ["srpt", "fair"])
def test_tied_drain_is_bit_identical_across_reruns(policy):
    s1, d1, c1 = _tied_stream(policy, n_jobs=5, max_concurrent=2)
    s2, d2, c2 = _tied_stream(policy, n_jobs=5, max_concurrent=2)
    assert [s.jct for s in s1] == [s.jct for s in s2]
    assert [s.name for s in s1] == [s.name for s in s2]
    assert c1.trace == c2.trace


def test_srpt_reprices_non_tied_queue_at_pop_time():
    """Sanity alongside the tie tests: with genuinely different sizes SRPT
    pops the shortest of the QUEUED jobs first, regardless of arrival
    order (a blocker pins the slot so both contenders actually queue)."""
    jobs = [JobSpec("blocker", 48, 16, 1, arrival=0.0),
            JobSpec("big", 336, 16, 16, arrival=0.0),
            JobSpec("small", 48, 16, 1, arrival=0.0)]
    topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e5)
    cluster = ClusterSim(topo, K=8)
    chooser = SchemeChooser(8)
    stats, _ = run_scheduled(jobs, cluster, chooser, policy="srpt",
                             max_concurrent=1)
    assert [s.name for s in stats] == ["blocker", "small", "big"]


# ---------------------------------------------------------------------------
# Engine instrumentation feeds the calibration pipeline end to end
# ---------------------------------------------------------------------------

def test_measure_phase_timings_feeds_calibrate():
    from repro.distributed.meshes import make_mesh
    from repro.mapreduce.engine import measure_phase_timings
    from repro.mapreduce.jobs import histogram_job

    p = SchemeParams(K=1, P=1, Q=4, N=6, r=1)
    mesh = make_mesh((1, 1), ("rack", "server"))
    rng = np.random.default_rng(0)
    subs = rng.integers(0, 1 << 16, size=(p.N, 64)).astype(np.int32)
    row = measure_phase_timings(histogram_job(), subs, p, mesh, iters=1)
    for phase in ("map", "pack", "reduce", "plan_compile"):
        assert row["seconds"][phase] >= 0.0
        assert row["work"][phase] > 0.0
    assert row["work"]["map"] == p.N * p.Q * 1
    model = calibrate([row])
    assert model.map.beta >= 0.0 and model.plan_compile.beta >= 0.0
