"""Training stack: loss descent, grad-accum equivalence, coded_r2 vs dp
exactness, straggler decode, optimizers, checkpoint/resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import SyntheticPipeline
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.optimizer import (OptimizerConfig, adafactor_update,
                                   init_opt_state, lr_at)
from repro.train.trainer import (TrainConfig, accumulate_grads,
                                 coded_grads_r2, init_train_state,
                                 make_coded_batch_r2, make_train_step)

CFG = ARCHS["qwen2-1.5b"].reduced()
KEY = jax.random.PRNGKey(0)


def _tc(**kw):
    base = dict(n_microbatches=1, remat=False, dense_moe=True,
                opt=OptimizerConfig(lr=1e-3, warmup_steps=2, decay_steps=50))
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases():
    tc = _tc(n_microbatches=2, remat=True)
    state = init_train_state(KEY, CFG, tc)
    pipe = SyntheticPipeline(CFG, global_batch=8, seq_len=32)
    step = make_train_step(CFG, tc)
    losses = []
    for i in range(6):
        state, m = step(state, pipe.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_grad_accum_equals_full_batch():
    """n_microbatches grad == single-shot grad (uniform loss masks)."""
    pipe = SyntheticPipeline(CFG, global_batch=8, seq_len=16)
    batch = pipe.batch_at(0)
    params = init_train_state(KEY, CFG, _tc())["params"]
    g1, l1 = accumulate_grads(params, CFG, _tc(), batch)
    g4, l4 = accumulate_grads(params, CFG, _tc(n_microbatches=4), batch)
    assert abs(float(l1) - float(l4)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


@pytest.fixture(scope="module")
def pod_mesh():
    if jax.device_count() < 8:
        pytest.skip("needs 8 host devices (run via tests/conftest device "
                    "count)")
    from repro.distributed.meshes import make_mesh
    return make_mesh((4, 2), ("pod", "data"))


def test_coded_r2_exact_and_straggler(pod_mesh):
    """The paper's r=2 coded gradient sync: exact vs plain DP, and exact
    under any single failed pod (the straggler-tolerance claim)."""
    tc = _tc()
    pipe = SyntheticPipeline(CFG, global_batch=12, seq_len=16)
    batch = pipe.batch_at(0)
    params = init_train_state(KEY, CFG, tc)["params"]
    g_ref, l_ref = accumulate_grads(params, CFG, tc, batch)
    coded = make_coded_batch_r2(batch, 4)
    for failed in [None, 0, 1, 2, 3]:
        g_c, l_c = coded_grads_r2(params, CFG, tc, coded, pod_mesh,
                                  failed=failed)
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_c)))
        assert err < 1e-5, (failed, err)
    assert abs(float(l_ref) - float(l_c)) < 1e-5


def test_adafactor_descends():
    tc = _tc(opt=OptimizerConfig(kind="adafactor", lr=3e-3, warmup_steps=1,
                                 decay_steps=50))
    state = init_train_state(KEY, CFG, tc)
    # factored state is much smaller than params
    p_sz = sum(l.size for l in jax.tree.leaves(state["params"]))
    o_sz = sum(l.size for l in jax.tree.leaves(state["opt"]))
    assert o_sz < 0.1 * p_sz
    pipe = SyntheticPipeline(CFG, global_batch=4, seq_len=32)
    step = make_train_step(CFG, tc)
    losses = []
    for i in range(6):
        state, m = step(state, pipe.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_lr_schedule():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(lr_at(cfg, jnp.asarray(1000))) == pytest.approx(0.1)


def test_checkpoint_resume_bitwise(tmp_path):
    """Preemption contract: resume from step s == uninterrupted run."""
    tc = _tc()
    state = init_train_state(KEY, CFG, tc)
    pipe = SyntheticPipeline(CFG, global_batch=4, seq_len=16)
    step = make_train_step(CFG, tc, donate=False)
    s = state
    for i in range(5):
        s, _ = step(s, pipe.batch_at(i))
        if i == 1:
            save_checkpoint(s, str(tmp_path), 2)
    s2, st = restore_checkpoint(jax.eval_shape(lambda: state),
                                str(tmp_path))
    assert st == 2
    for i in range(2, 5):
        s2, _ = step(s2, pipe.batch_at(i))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    state = {"x": jnp.arange(10), "step": jnp.zeros(())}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(state, str(tmp_path), s, keep_last=3)
    assert latest_step(str(tmp_path)) == 5
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4, 5]


def test_preemption_restart_loop(tmp_path):
    """fault.run_with_restarts drives a preempted loop to completion."""
    from repro.train.fault import PreemptionSimulator, run_with_restarts
    tc = _tc()
    pipe = SyntheticPipeline(CFG, global_batch=4, seq_len=16)
    step = make_train_step(CFG, tc, donate=False)
    state0 = init_train_state(KEY, CFG, tc)
    sim = PreemptionSimulator(preempt_at_step=3)

    def loop(start):
        if start == 0:
            s = state0
        else:
            s, _ = restore_checkpoint(jax.eval_shape(lambda: state0),
                                      str(tmp_path))
        for i in range(start, 6):
            sim.check(i) if sim.preempt_at_step == i and start == 0 else None
            s, m = step(s, pipe.batch_at(i))
            save_checkpoint(s, str(tmp_path), i)
            yield i, m

    done = list(run_with_restarts(loop, str(tmp_path)))
    assert [i for i, _ in done][-1] == 5
    assert latest_step(str(tmp_path)) == 5


def test_elastic_plan():
    from repro.train.fault import ElasticPlan
    p = ElasticPlan(4)
    assert p.n_chunks == 6
    assert p.shrink().n_pods == 3 and p.grow().n_pods == 5
    with pytest.raises(ValueError):
        ElasticPlan(2).shrink()
