"""Core scheme tests: assignments, closed-form costs vs counted schedules,
bit-exact shuffle execution, Theorem IV.1 constraints."""
import numpy as np
import pytest

from repro.core.params import SchemeParams
from repro.core.assignment import (
    Assignment, check_hybrid_constraints, coded_assignment,
    hybrid_assignment, pair_common_counts, uncoded_assignment,
)
from repro.core.costs import (
    coded_cost, corollary_bounds, cost_table, hybrid_cost, uncoded_cost,
)
from repro.core.shuffle_plan import (
    check_reduce_ready, count_plan, execute_plan, make_plan,
)

# Paper Table I rows that satisfy every divisibility hypothesis.
VALID_ROWS = [
    (9, 3, 18, 72, 2),
    (16, 4, 16, 240, 2),
    (16, 4, 16, 1680, 3),
    (15, 3, 15, 210, 2),
    (25, 5, 25, 600, 2),
]
# Rows whose hybrid column violates C(P,r) | (NP/K) (paper-table slips).
INVALID_HYBRID_ROWS = [
    (20, 4, 20, 380, 2),
    (30, 5, 30, 870, 2),
    (30, 6, 30, 870, 2),
]


# ---------------------------------------------------------------------------
# Assignment structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("row", VALID_ROWS)
def test_assignment_replication(row):
    K, P, Q, N, r = row
    p = SchemeParams(K, P, Q, N, r)
    for mk, expect_r in [(uncoded_assignment, 1), (coded_assignment, r),
                         (hybrid_assignment, r)]:
        a = mk(p)
        assert len(a.servers_of_subfile) == N
        for servers in a.servers_of_subfile:
            assert len(servers) == expect_r
            assert len(set(servers)) == expect_r


def test_hybrid_cross_rack_only():
    p = SchemeParams(12, 4, 12, 144, 2)
    a = hybrid_assignment(p)
    for servers in a.servers_of_subfile:
        racks = [p.rack_of(s) for s in servers]
        slots = [p.slot_of(s) for s in servers]
        assert len(set(racks)) == len(servers)     # across racks only
        assert len(set(slots)) == 1                # within one layer


def test_hybrid_map_load_balanced():
    p = SchemeParams(12, 4, 12, 144, 2)
    a = hybrid_assignment(p)
    load = a.map_load()
    assert (load == load[0]).all()
    assert load[0] == p.N * p.r // p.K


@pytest.mark.parametrize("row", VALID_ROWS[:3])
def test_theorem_iv1_constraints(row):
    K, P, Q, N, r = row
    p = SchemeParams(K, P, Q, N, r)
    check_hybrid_constraints(hybrid_assignment(p))


def test_hybrid_permutation_is_valid():
    p = SchemeParams(9, 3, 18, 72, 2)
    rng = np.random.default_rng(3)
    perm = rng.permutation(p.N)
    a = hybrid_assignment(p, perm)
    check_hybrid_constraints(a)
    vals = rng.integers(-99, 99, size=(p.N, p.Q))
    know = execute_plan(a, vals)
    check_reduce_ready(a, know, vals)


def test_uncoded_pairs_share_nothing():
    p = SchemeParams(8, 2, 8, 32, 2)
    common = pair_common_counts(uncoded_assignment(p))
    assert common.max() == 0


def test_incidence_matrix_consistency():
    """subfiles_of_server / map_load / pair_common_counts all derive from
    one incidence matrix and agree with the servers_of_subfile tuples."""
    p = SchemeParams(9, 3, 18, 72, 2)
    a = hybrid_assignment(p)
    X = a.incidence()
    assert X.shape == (p.N, p.K) and X.sum() == p.N * p.r
    for i, servers in enumerate(a.servers_of_subfile):
        assert set(np.nonzero(X[i])[0].tolist()) == set(servers)
    by_server = a.subfiles_of_server
    for s in range(p.K):
        assert by_server[s] == np.nonzero(X[:, s])[0].tolist()
    np.testing.assert_array_equal(a.map_load(), X.sum(axis=0))


def test_constraint_check_rejects_corrupted_assignment():
    """The broadcast-vectorized Theorem IV.1 checks still FAIL on an
    assignment that violates them (swap one subfile's servers into a single
    rack — breaks constraint 1)."""
    p = SchemeParams(9, 3, 18, 72, 2)
    a = hybrid_assignment(p)
    servers = list(a.servers_of_subfile)
    servers[0] = (0, 1)                       # two servers of rack 0
    bad = Assignment("hybrid", p, tuple(servers), a.meta)
    with pytest.raises(AssertionError):
        check_hybrid_constraints(bad)


# ---------------------------------------------------------------------------
# Counted schedules == closed forms  (the paper's Props 1-2 / Thm III.1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("row", VALID_ROWS)
def test_counts_match_formulas(row):
    K, P, Q, N, r = row
    p = SchemeParams(K, P, Q, N, r)
    forms = cost_table(p)
    for scheme, mk in [("uncoded", uncoded_assignment),
                       ("coded", coded_assignment),
                       ("hybrid", hybrid_assignment)]:
        counts = count_plan(make_plan(mk(p)), p)
        assert counts.intra == pytest.approx(forms[scheme].intra)
        assert counts.cross == pytest.approx(forms[scheme].cross)


@pytest.mark.parametrize("row", INVALID_HYBRID_ROWS)
def test_paper_rows_violating_divisibility(row):
    K, P, Q, N, r = row
    p = SchemeParams(K, P, Q, N, r)
    with pytest.raises(ValueError):
        p.validate_hybrid()
    # the closed form still evaluates with check=False (as the paper did)
    c = hybrid_cost(p, check=False)
    assert c.cross == pytest.approx(Q * N / r * (1 - r / P))


def test_hybrid_beats_uncoded_cross_rack():
    for row in VALID_ROWS:
        K, P, Q, N, r = row
        p = SchemeParams(K, P, Q, N, r)
        t = cost_table(p)
        assert t["hybrid"].cross < t["coded"].cross < t["uncoded"].cross


def test_coded_total_minimal():
    for row in VALID_ROWS:
        K, P, Q, N, r = row
        p = SchemeParams(K, P, Q, N, r)
        t = cost_table(p)
        assert t["coded"].total <= t["uncoded"].total + 1e-9
        assert t["coded"].total <= t["hybrid"].total + 1e-9


def test_corollary_bounds_hold():
    p = SchemeParams(25, 5, 25, 600, 2)
    b = corollary_bounds(p)
    assert b["cross_ratio_exact"] >= b["cross_ratio_lower_bound"] - 1e-9
    assert b["intra_ratio_exact"] <= b["intra_ratio_upper_bound"] + 1e-9


def test_full_replication_zero_cross():
    # r == P: every rack maps everything; no cross-rack traffic at all.
    p = SchemeParams(8, 2, 8, 32, 2)
    assert hybrid_cost(p).cross == 0
    a = hybrid_assignment(p)
    counts = count_plan(make_plan(a), p)
    assert counts.cross == 0


# ---------------------------------------------------------------------------
# Bit-exact execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("row", [(9, 3, 18, 72, 2), (16, 4, 16, 240, 2),
                                 (12, 4, 12, 396, 2), (8, 2, 16, 56, 2)])
def test_execute_and_decode(row):
    K, P, Q, N, r = row
    p = SchemeParams(K, P, Q, N, r)
    rng = np.random.default_rng(row[0])
    vals = rng.integers(-10**6, 10**6, size=(N, Q))
    for mk in [uncoded_assignment, coded_assignment, hybrid_assignment]:
        a = mk(p)
        know = execute_plan(a, vals)
        check_reduce_ready(a, know, vals)


def test_execute_r3():
    p = SchemeParams(8, 4, 8, 48, 3)
    rng = np.random.default_rng(7)
    vals = rng.integers(-99, 99, size=(p.N, p.Q))
    a = hybrid_assignment(p)
    know = execute_plan(a, vals)
    check_reduce_ready(a, know, vals)


def test_scheme_param_validation_errors():
    with pytest.raises(ValueError):
        SchemeParams(9, 2, 9, 18)          # P does not divide K
    p = SchemeParams(8, 2, 7, 16)
    with pytest.raises(ValueError):
        p.validate_uncoded()               # K does not divide Q
    with pytest.raises(ValueError):
        SchemeParams(8, 2, 8, 17).validate_uncoded()
