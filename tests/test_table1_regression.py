"""Regression pins for paper Table I and the simulator's zero-contention
anchor.

Every (K, P, Q, N, r) row of Table I — INCLUDING the three rows whose
hybrid column violates the theorem's own divisibility hypothesis
C(P,r) | (NP/K) (e.g. (20,4,20,380,2), flagged in the ``hybrid_cost``
docstring) — must keep producing these exact closed-form values with
``check=False``, and the cluster simulator's single-job JCT with zero
compute cost must equal ``CommCost.weighted_time`` on the whole grid.
"""
import pytest

from repro.core.costs import coded_cost, hybrid_cost, uncoded_cost
from repro.core.params import SchemeParams
from repro.sim import JobSpec, RackTopology, simulate_single_job

# (K, P, Q, N, r) -> (unc_cross, unc_intra, cod_cross, cod_intra,
#                     hyb_cross, hyb_intra) in <key, value> pairs.
# Pinned from Props 1-2 / Thm III.1; where the paper's printed Table I
# disagrees (a handful of cells) the paper contradicts its own closed
# forms — see benchmarks/table1_costs.py for the cell-level comparison.
TABLE1_EXPECTED = [
    ((9, 3, 18, 72, 2),
     (864.0, 288.0, 486.0, 18.0, 216.0, 864.0)),
    ((16, 4, 16, 240, 2),
     (2880.0, 720.0, 1632.0, 48.0, 960.0, 2880.0)),
    ((16, 4, 16, 1680, 3),
     (20160.0, 5040.0, 7264.0, 16.0, 2240.0, 20160.0)),
    ((15, 3, 15, 210, 2),
     (2100.0, 840.0, 1275.0, 90.0, 525.0, 2520.0)),
    ((20, 4, 20, 380, 2),                      # violates C(P,r) | (NP/K)
     (5700.0, 1520.0, 3300.0, 120.0, 1900.0, 6080.0)),
    ((25, 5, 25, 600, 2),
     (12000.0, 2400.0, 6750.0, 150.0, 4500.0, 12000.0)),
    ((25, 5, 25, 6900, 3),
     (138000.0, 27600.0, 50500.0, 100.0, 23000.0, 138000.0)),
    ((30, 5, 30, 870, 2),                      # violates C(P,r) | (NP/K)
     (20880.0, 4350.0, 11880.0, 300.0, 7830.0, 21750.0)),
    ((30, 6, 30, 870, 2),                      # violates C(P,r) | (NP/K)
     (21750.0, 3480.0, 12000.0, 180.0, 8700.0, 20880.0)),
]


@pytest.mark.parametrize("row,expected", TABLE1_EXPECTED,
                         ids=[str(r) for r, _ in TABLE1_EXPECTED])
def test_table1_closed_forms_pinned(row, expected):
    p = SchemeParams(*row)
    unc = uncoded_cost(p, check=False)
    cod = coded_cost(p, check=False)
    hyb = hybrid_cost(p, check=False)
    got = (unc.cross, unc.intra, cod.cross, cod.intra, hyb.cross, hyb.intra)
    assert got == pytest.approx(expected, rel=1e-12)


@pytest.mark.parametrize("row", [r for r, _ in TABLE1_EXPECTED],
                         ids=[str(r) for r, _ in TABLE1_EXPECTED])
@pytest.mark.parametrize("scheme,cost_fn", [
    ("uncoded", uncoded_cost), ("coded", coded_cost), ("hybrid", hybrid_cost),
])
def test_sim_zero_contention_equals_weighted_time(row, scheme, cost_fn):
    """The simulator's network model is anchored to the paper's metric:
    one job, zero compute cost, no stragglers => JCT == weighted_time."""
    K, P, Q, N, r = row
    intra_bw, cross_bw = 10.0, 1.0
    want = cost_fn(SchemeParams(*row), check=False).weighted_time(
        intra_bw, cross_bw)
    topo = RackTopology(P=P, cross_bw=cross_bw, intra_bw=intra_bw)
    stats = simulate_single_job(JobSpec("histogram", N, Q, 1), topo, K,
                                scheme, r, check=False)
    assert stats.jct == pytest.approx(want, rel=1e-9)
