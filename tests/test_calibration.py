"""Sim-to-metal conformance observatory tests: the calibrated cost-model
artifact (save/load/versioning + the committed default), prediction-drift
monitoring and the scheduler's online refit loop, the JCT-level conformance
fit the simulator reproduces exactly, the bench-trajectory ledger gate, and
the standalone observatory report renderers."""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from benchmarks import history
from repro.core.params import SchemeParams
from repro.obs import metrics
from repro.obs.drift import DriftConfig, DriftMonitor, record_prediction
from repro.obs.report import (build_report, render_html, render_markdown,
                              write_report)
from repro.sim import (ClusterSim, ConformanceModel, CostModel,
                       DeterministicSlowdown, MultiJobScheduler, PhaseCoeffs,
                       PoissonWorkload, RackTopology, SchemeChooser,
                       calibrate, calibrate_with_residuals,
                       conformance_report, default_catalog, fit_conformance,
                       load_cost_model, load_default_cost_model,
                       measurement_row_from_stats,
                       measurements_from_pipeline_bench, phase_work,
                       save_cost_model, simulate_single_job)
from repro.sim.calibration import (COST_MODEL_SCHEMA_VERSION,
                                   conformance_features, fit_residuals)

REPO_ROOT = pathlib.Path(__file__).parent.parent


# ---------------------------------------------------------------------------
# record_prediction + DriftMonitor
# ---------------------------------------------------------------------------

def test_record_prediction_returns_relative_error_and_registers():
    reg = metrics.MetricsRegistry()
    rel = record_prediction(12.0, 10.0, layer="sim", reg=reg, scheme="hyb")
    assert rel == pytest.approx(0.2)
    snap = reg.snapshot()
    assert snap["jct_predictions_total"]["samples"]
    assert snap["jct_prediction_error_seconds"]["type"] == "histogram"
    assert snap["jct_prediction_relative_error"]["type"] == "histogram"
    assert reg.counter("jct_predictions_total").value(
        layer="sim", scheme="hyb") == 1.0


def test_drift_monitor_warms_up_before_firing():
    reg = metrics.MetricsRegistry()
    mon = DriftMonitor(DriftConfig(ewma_alpha=0.5, threshold=0.1,
                                   min_observations=3), reg=reg)
    # large errors, but the warm-up gate holds the first two back
    assert mon.observe(2.0, 1.0) is False
    assert mon.observe(2.0, 1.0) is False
    assert mon.observe(2.0, 1.0) is True
    assert mon.drift_events == 1
    assert reg.counter("jct_drift_events_total").value(layer="sim") == 1.0


def test_drift_monitor_stays_quiet_on_accurate_predictions():
    reg = metrics.MetricsRegistry()
    mon = DriftMonitor(DriftConfig(threshold=0.25, min_observations=2),
                       reg=reg)
    assert not any(mon.observe(1.0 + 1e-3, 1.0) for _ in range(20))
    assert mon.drift_events == 0 and mon.total_observations == 20


def test_drift_monitor_refit_banks_regret_and_restarts_warmup():
    reg = metrics.MetricsRegistry()
    mon = DriftMonitor(DriftConfig(min_observations=1, threshold=0.1),
                       reg=reg)
    mon.observe(3.0, 1.0)                          # regret 2.0, fires
    mon.observe(2.0, 1.0)                          # regret 3.0 total
    mon.refitted()
    assert mon.refits == 1 and mon.observations == 0 and mon.ewma is None
    assert mon.regret_s == 0.0
    assert reg.counter("stale_model_regret_seconds_total").value(
        layer="sim") == pytest.approx(3.0)
    assert reg.gauge("jct_model_regret_seconds").value(layer="sim") == 0.0
    state = mon.state()
    assert state["refits"] == 1 and state["total_observations"] == 2


# ---------------------------------------------------------------------------
# Per-phase fit + artifact round-trip
# ---------------------------------------------------------------------------

def _affine_rows(alpha=2e-3, beta=4e-8):
    return [{"work": {"map": w, "reduce": w / 2},
             "seconds": {"map": alpha + beta * w,
                         "reduce": alpha + 2 * beta * (w / 2)}}
            for w in (1e4, 1e5, 1e6, 1e7)]


def test_calibrate_with_residuals_reports_near_zero_on_affine_data():
    model, res = calibrate_with_residuals(_affine_rows())
    assert model.map.beta == pytest.approx(4e-8, rel=1e-6)
    assert res["map"]["n"] == 4
    assert res["map"]["rel_rmse"] == pytest.approx(0.0, abs=1e-9)
    assert res["reduce"]["max_abs_err_s"] == pytest.approx(0.0, abs=1e-9)


def test_fit_residuals_flags_a_wrong_model():
    rows = _affine_rows()
    wrong = CostModel(map=PhaseCoeffs(0.0, 1e-6))
    res = fit_residuals(wrong, rows)
    assert res["map"]["rel_rmse"] > 0.5


def test_cost_model_artifact_round_trip(tmp_path):
    model, res = calibrate_with_residuals(_affine_rows())
    path = tmp_path / "cm.json"
    doc = save_cost_model(model, str(path), residuals=res,
                          provenance={"bench": "unit-test"})
    assert doc["schema_version"] == COST_MODEL_SCHEMA_VERSION
    loaded, doc2 = load_cost_model(str(path))
    assert loaded == model
    assert doc2["provenance"]["bench"] == "unit-test"
    assert doc2["residuals"]["map"]["n"] == 4


def test_cost_model_loader_rejects_unknown_schema_version(tmp_path):
    path = tmp_path / "cm.json"
    path.write_text(json.dumps({"schema_version": 999, "cost_model": {}}))
    with pytest.raises(ValueError, match="schema_version=999"):
        load_cost_model(str(path))


def test_committed_default_cost_model_loads():
    model, doc = load_default_cost_model()
    assert model.map.beta > 0 and model.reduce.beta > 0
    prov = doc["provenance"]
    assert prov["bench"] == "calibration_bench.phase_fit"
    assert prov["mesh_shape"] == [4, 2] and not prov["smoke"]
    assert doc["residuals"]["map"]["n"] >= 4


# ---------------------------------------------------------------------------
# Live measurement rows from completed sim jobs
# ---------------------------------------------------------------------------

def _single_job_stats(slowdown=1.0, d=64):
    topo = RackTopology(P=4, cross_bw=2e5, intra_bw=2e6)
    cm = CostModel(map=PhaseCoeffs(1e-3, 5e-7), pack=PhaseCoeffs(0.0, 2e-7),
                   reduce=PhaseCoeffs(1e-3, 5e-7))
    from repro.sim import JobSpec
    spec = JobSpec("j", 96, 16, d, arrival=0.0)
    stragglers = (DeterministicSlowdown((slowdown,) * 8)
                  if slowdown != 1.0 else None)
    return simulate_single_job(spec, topo, 8, "hybrid", 2, cost_model=cm,
                               stragglers=stragglers)


def test_measurement_row_from_stats_feeds_calibrate():
    stats = _single_job_stats()
    p = SchemeParams(K=8, P=4, Q=16, N=96, r=2)
    row = measurement_row_from_stats(stats, p, "hybrid", 64)
    assert set(row["work"]) == set(row["seconds"])
    assert row["work"]["map"] == phase_work(p, "hybrid", 64)["map"]
    model = calibrate([row])
    assert model.map.beta >= 0.0


def test_refit_from_straggler_rows_absorbs_inflation():
    p = SchemeParams(K=8, P=4, Q=16, N=96, r=2)
    rows = [measurement_row_from_stats(_single_job_stats(3.0, d), p,
                                       "hybrid", d) for d in (32, 64, 128)]
    refit = calibrate(rows)
    base = CostModel(map=PhaseCoeffs(1e-3, 5e-7))
    # a uniform 3x slowdown must show up as ~3x the compute rate
    assert refit.map.beta == pytest.approx(3 * base.map.beta, rel=1e-6)


# ---------------------------------------------------------------------------
# Scheduler reconciliation + online refit
# ---------------------------------------------------------------------------

def _scheduled_run(reg, recalibrate, n_jobs=24, threshold=0.2,
                   shift_at=None, seed=7):
    topo = RackTopology(P=4, cross_bw=2e5, intra_bw=2e6)
    cm = CostModel(map=PhaseCoeffs(1e-3, 5e-7), pack=PhaseCoeffs(5e-4, 2e-7),
                   reduce=PhaseCoeffs(1e-3, 5e-7))
    cluster = ClusterSim(topo, K=8, cost_model=cm, seed=seed)
    if shift_at is not None:
        cluster.at(shift_at, lambda: setattr(
            cluster, "stragglers", DeterministicSlowdown((3.0,) * 8)))
    chooser = SchemeChooser(8, cost_model=cm, compile_real_plans=False)
    mon = DriftMonitor(DriftConfig(ewma_alpha=0.3, threshold=threshold,
                                   min_observations=3), reg=reg)
    sched = MultiJobScheduler(chooser, max_concurrent=2, drift=mon,
                              recalibrate=recalibrate)
    wl = PoissonWorkload(default_catalog(8, 4), n_jobs=n_jobs, rate=2.0)
    stats = sched.run(wl.generate(seed), cluster)
    return stats, sched, mon, cluster


def test_scheduler_reconciles_every_admission():
    reg = metrics.MetricsRegistry()
    stats, sched, mon, _ = _scheduled_run(reg, recalibrate=False)
    assert mon.total_observations == len(stats) == 24
    assert reg.counter("jct_predictions_total").value(
        layer="sim", scheme="hybrid") + reg.counter(
        "jct_predictions_total").value(
        layer="sim", scheme="coded") + reg.counter(
        "jct_predictions_total").value(
        layer="sim", scheme="uncoded") + reg.counter(
        "jct_predictions_total").value(
        layer="sim", scheme="hybrid_resolvable") == float(len(stats))


def test_scheduler_online_refit_fires_and_rewrites_cost_model():
    reg = metrics.MetricsRegistry()
    stats, sched, mon, cluster = _scheduled_run(reg, recalibrate=True,
                                                shift_at=6.0)
    assert mon.refits >= 1 and mon.drift_events >= 1
    refit_events = [e for e in cluster.tracer.events
                    if e.kind == "sched_refit"]
    assert len(refit_events) == mon.refits
    # the chooser's model was rewritten toward the 3x regime
    assert sched.chooser.cost_model.map.beta > 5e-7
    assert reg.counter("stale_model_regret_seconds_total").value(
        layer="sim") > 0.0


def test_scheduler_without_recalibrate_never_refits_or_traces():
    reg = metrics.MetricsRegistry()
    _, sched, mon, cluster = _scheduled_run(reg, recalibrate=False,
                                            shift_at=6.0)
    assert mon.refits == 0
    assert not [e for e in cluster.tracer.events if e.kind == "sched_refit"]
    assert sched.chooser.cost_model.map.beta == pytest.approx(5e-7)


# ---------------------------------------------------------------------------
# JCT-level conformance fit: the simulator reproduces the linear predictor
# ---------------------------------------------------------------------------

def _synthetic_cells(theta=(2e-3, 3e-7, 5e-7, 2e-6, 1e-6)):
    cells = []
    for n in (48, 96, 192):
        for r in (1, 2, 3):
            p = SchemeParams(K=8, P=4, Q=16, N=n, r=r)
            y = float(np.dot(np.asarray(theta),
                             conformance_features(p, "hybrid", 64)))
            cells.append({"p": p, "scheme": "hybrid", "d": 64,
                          "measured_s": y})
    return cells


def test_fit_conformance_recovers_synthetic_predictions():
    cells = _synthetic_cells()
    model = fit_conformance(cells)
    for c in cells:
        pred = model.predict(c["p"], "hybrid", 64)
        assert pred == pytest.approx(c["measured_s"], rel=1e-9)


def test_sim_reproduces_the_conformance_predictor_exactly():
    model = fit_conformance(_synthetic_cells())
    rows = conformance_report(model, _synthetic_cells(), via_sim=True)
    for row in rows:
        assert row["rel_err"] < 1e-9
    lin = conformance_report(model, _synthetic_cells(), via_sim=False)
    for a, b in zip(rows, lin):
        assert a["predicted_s"] == pytest.approx(b["predicted_s"], rel=1e-9)


def test_conformance_model_with_zero_network_coeffs_is_compute_bound():
    model = ConformanceModel((1e-3, 2e-7, 3e-7, 0.0, 0.0))
    p = SchemeParams(K=8, P=4, Q=16, N=96, r=2)
    stats = model.sim_stats(p, "hybrid", 64)
    assert stats.jct == pytest.approx(model.predict(p, "hybrid", 64),
                                      rel=1e-9)


def test_fit_conformance_rejects_empty_cells():
    with pytest.raises(ValueError):
        fit_conformance([])


# ---------------------------------------------------------------------------
# Pipeline-bench envelope validation + the committed artifact
# ---------------------------------------------------------------------------

def test_pipeline_bench_adapter_rejects_missing_schema_version():
    with pytest.raises(ValueError, match="schema_version=None"):
        measurements_from_pipeline_bench({"results": []})


def test_pipeline_bench_adapter_rejects_future_schema_version():
    with pytest.raises(ValueError, match="schema_version=99"):
        measurements_from_pipeline_bench({"schema_version": 99,
                                          "results": []})


def test_committed_pipeline_bench_feeds_calibrate():
    with open(REPO_ROOT / "BENCH_pipeline.json") as f:
        report = json.load(f)
    rows = measurements_from_pipeline_bench(report)
    assert len(rows) >= 3
    model = calibrate(rows)
    assert model.map.beta > 0 and model.pack.beta >= 0


def test_committed_calibration_bench_pins_conformance_band():
    with open(REPO_ROOT / "BENCH_calibration.json") as f:
        report = json.load(f)
    assert report["schema_version"] == 1 and not report["smoke"]
    conf = report["conformance"]
    assert conf["ok"] and conf["max_rel_err"] <= conf["tol_rel"]
    drift = report["drift"]
    assert drift["drift_fired"] and drift["refits"] >= 1
    assert drift["refit_mean_rel_err"] < drift["stale_mean_rel_err"]
    assert report["determinism"]["identical"]


# ---------------------------------------------------------------------------
# Bench-trajectory ledger
# ---------------------------------------------------------------------------

def _envelope(max_rel=0.1, smoke=False):
    return {"schema_version": 1, "bench": "calibration", "smoke": smoke,
            "seed": 0, "conformance": {"max_rel_err": max_rel,
                                       "mean_rel_err": max_rel / 2},
            "drift": {"refit_mean_rel_err": 0.2},
            "phase_fit": {"worst_rel_rmse": 0.3}}


def test_history_append_and_check_pass_on_stable_scalars(tmp_path):
    out = tmp_path / "BENCH_calibration.json"
    for _ in range(2):
        history.append_entry(_envelope(0.10), str(out))
    ledger = history.ledger_path_for(str(out))
    entries = history.read_ledger(ledger)
    assert len(entries) == 2
    assert entries[0]["scalars"]["conformance.max_rel_err"] == 0.10
    assert history.check(ledger) == []


def test_history_check_fails_on_regression_beyond_gate(tmp_path):
    out = tmp_path / "BENCH_calibration.json"
    history.append_entry(_envelope(0.10), str(out))
    history.append_entry(_envelope(0.20), str(out))      # +100% worse
    violations = history.check(history.ledger_path_for(str(out)))
    assert len(violations) == 2          # max_rel_err and mean_rel_err
    assert "conformance.max_rel_err" in violations[0]


def test_history_check_never_compares_smoke_with_full(tmp_path):
    out = tmp_path / "BENCH_calibration.json"
    history.append_entry(_envelope(0.10, smoke=False), str(out))
    history.append_entry(_envelope(0.50, smoke=True), str(out))
    assert history.check(history.ledger_path_for(str(out))) == []


def test_history_check_respects_higher_is_better_direction(tmp_path):
    out = tmp_path / "BENCH_pipeline.json"
    env = {"schema_version": 1, "bench": "pipeline", "smoke": False,
           "default_size_speedup": 3.0}
    history.append_entry(env, str(out))
    history.append_entry({**env, "default_size_speedup": 1.5}, str(out))
    violations = history.check(history.ledger_path_for(str(out)))
    assert len(violations) == 1 and "default_size_speedup" in violations[0]
    # improvement in the same direction is never a violation
    history.append_entry({**env, "default_size_speedup": 4.0}, str(out))
    assert history.check(history.ledger_path_for(str(out))) == []


def test_history_cli_check_exits_nonzero_on_regression(tmp_path):
    out = tmp_path / "BENCH_calibration.json"
    history.append_entry(_envelope(0.10), str(out))
    history.append_entry(_envelope(0.30), str(out))
    proc = subprocess.run(
        [sys.executable, str(REPO_ROOT / "benchmarks" / "history.py"),
         "check", "--ledger", history.ledger_path_for(str(out))],
        capture_output=True, text=True)
    assert proc.returncode == 1 and "REGRESSION" in proc.stderr


def test_committed_ledger_passes_the_gate():
    assert history.check(str(REPO_ROOT / history.LEDGER_NAME)) == []


# ---------------------------------------------------------------------------
# Observatory report
# ---------------------------------------------------------------------------

def _populated_snapshot():
    reg = metrics.MetricsRegistry()
    record_prediction(1.2, 1.0, layer="sim", reg=reg)
    record_prediction(0.9, 1.0, layer="engine", reg=reg)
    reg.counter("rack_pair_bytes_total").inc(64, src=0, dst=1, layer="sim")
    reg.counter("rack_pair_bytes_total").inc(32, src=1, dst=0, layer="sim")
    reg.gauge("jct_drift_ewma").set(0.12, layer="sim")
    return reg.snapshot()


def test_build_report_sections():
    rep = build_report(_populated_snapshot())
    assert {h["name"] for h in rep["prediction_hists"]} == {
        "jct_prediction_error_seconds", "jct_prediction_relative_error"}
    assert rep["rack_matrices"]["sim"][0][1] == 64.0
    assert rep["rack_matrices"]["sim"][1][0] == 32.0
    assert rep["drift_gauges"][0]["value"] == pytest.approx(0.12)


def test_render_markdown_and_html_carry_the_content():
    rep = build_report(_populated_snapshot(), title="Unit report")
    md = render_markdown(rep)
    assert "# Unit report" in md
    assert "jct_prediction_relative_error" in md
    assert "Per-rack byte matrices" in md
    html = render_html(rep)
    assert html.startswith("<!doctype html>")
    assert "jct_prediction_relative_error" in html
    assert "Trace summary" in html


def test_write_report_picks_format_by_extension(tmp_path):
    rep = build_report(_populated_snapshot())
    md_path = write_report(str(tmp_path / "r.md"), rep)
    html_path = write_report(str(tmp_path / "r.html"), rep)
    assert (tmp_path / "r.md").read_text().startswith("# ")
    assert (tmp_path / "r.html").read_text().startswith("<!doctype html>")
    assert md_path.endswith(".md") and html_path.endswith(".html")


def test_report_cli_demo_writes_both_formats(tmp_path):
    from repro.obs.report import main as report_main
    report_main(["--out-dir", str(tmp_path), "--seed", "3"])
    md = (tmp_path / "obs_report.md").read_text()
    assert "jct_prediction" in md                # demo schedules + reconciles
    assert (tmp_path / "obs_report.html").exists()


# ---------------------------------------------------------------------------
# Engine traces export to Perfetto + cache gauges refresh at job boundaries
# ---------------------------------------------------------------------------

def _run_engine_job():
    from repro.distributed.meshes import make_mesh
    from repro.mapreduce.engine import run_job_distributed
    from repro.mapreduce.jobs import histogram_job

    p = SchemeParams(K=1, P=1, Q=4, N=6, r=1)
    mesh = make_mesh((1, 1), ("rack", "server"))
    rng = np.random.default_rng(0)
    subs = rng.integers(0, 1 << 16, size=(p.N, 64)).astype(np.int32)
    return run_job_distributed(histogram_job(), subs, p, mesh)


def test_engine_trace_exports_valid_perfetto_document():
    from repro.obs import tracing
    tracer = tracing.enable_tracing(True)
    try:
        _run_engine_job()
        events = list(tracer.events)
    finally:
        tracing.enable_tracing(False)
    phases = {e.phase for e in events if e.kind == "engine_phase"}
    assert {"plan_compile", "pack", "map_shuffle_reduce"} <= phases
    doc = tracing.to_chrome_trace(events)
    assert tracing.validate_chrome_trace(doc) == len(doc["traceEvents"])
    assert any(ev["ph"] == "X" for ev in doc["traceEvents"])


def test_cache_gauges_refresh_at_engine_job_result():
    metrics.reset()
    _run_engine_job()
    snap = metrics.snapshot()        # no manual collect_cache_metrics pull
    assert "plan_cache" in snap and snap["plan_cache"]["samples"]
    assert "plan_cache_size" in snap


def test_cache_gauges_refresh_at_sim_job_completion():
    metrics.reset()
    _single_job_stats()
    snap = metrics.snapshot()
    assert "plan_cache" in snap and snap["plan_cache"]["samples"]
    assert "degraded_cache" in snap
