"""Scheme-family registry + resolvable-design shuffle family.

Pins the tentpole refactor from every side: the refactored binomial
compiler stays BIT-IDENTICAL to the pre-refactor plans (sha256 goldens in
tests/golden_plans.json), every registered family's plans pass the NumPy
re-execution oracle in both wire formats, the resolvable message schedule
is strictly decodable and reproduces the closed-form costs, the plan cache
keys on (params, perm, family) with honest per-family counters, and the
SchemeChooser / engine / workload layers thread the family end to end.
"""
import hashlib
import json
import pathlib

import numpy as np
import pytest

from repro.core.coded_collectives import (
    compile_hybrid_plan, plan_cache_clear, plan_cache_info,
    plan_shuffle_reference, plan_transfer_matrices, simulate_plan_shuffle)
from repro.core.costs import hybrid_cost, hybrid_resolvable_cost
from repro.core.params import SchemeParams
from repro.core.plan_registry import (family_of_scheme, get_plan_compiler,
                                      plan_families, register_plan_compiler,
                                      scheme_of_family)
from repro.core.resolvable import (resolvable_assignment, shared_group_counts,
                                   spc_codewords)
from repro.core.shuffle_plan import (check_reduce_ready, count_plan,
                                     execute_plan, make_plan,
                                     plan_stage_traffic,
                                     scheme_stage_traffic)

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden_plans.json"

# Feasible resolvable configs spanning q in {2, 3, 4}, r in {2, 3, 4},
# Kr in {1, 2} — including power-of-two N where the binomial family is
# infeasible for every r >= 2 (the scaling win the family exists for).
RESOLVABLE_PARAMS = [
    SchemeParams(K=12, P=6, Q=24, N=48, r=3),     # q=2, Kr=2
    SchemeParams(K=12, P=6, Q=24, N=48, r=2),     # q=3
    SchemeParams(K=8, P=8, Q=16, N=64, r=2),      # q=4, Kr=1, pow-2 N
    SchemeParams(K=18, P=9, Q=36, N=108, r=3),    # q=3
    SchemeParams(K=16, P=8, Q=32, N=96, r=4),     # q=2, arity 3
]

# (family, params) pairs for the any-registered-compiler oracle sweep
FAMILY_CASES = (
    [("binomial", SchemeParams(K=8, P=4, Q=16, N=48, r=r))
     for r in (1, 2, 3, 4)]
    + [("resolvable", p) for p in RESOLVABLE_PARAMS]
)


def _plan_digest(plan) -> str:
    """sha256 over every table's (name, shape, dtype, bytes) + n_send —
    the bit-identity fingerprint pinned before the refactor."""
    fields = json.loads(GOLDEN_PATH.read_text())["fields"]
    h = hashlib.sha256()
    for f in fields:
        a = np.asarray(getattr(plan, f))
        h.update(f.encode())
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    h.update(str(plan.n_send).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Tentpole pin 1: refactored binomial backend is bit-identical
# ---------------------------------------------------------------------------

def test_binomial_plans_bit_identical_to_pre_refactor_goldens():
    golden = json.loads(GOLDEN_PATH.read_text())
    assert len(golden["cases"]) >= 10
    for case in golden["cases"]:
        K, P, Q, N, r = case["params"]
        p = SchemeParams(K=K, P=P, Q=Q, N=N, r=r)
        plan = compile_hybrid_plan(p, perm=case["perm"], family="binomial")
        assert _plan_digest(plan) == case["sha256"], (
            f"binomial plan for {case['params']} (perm="
            f"{case['perm'] is not None}) drifted from the pre-refactor "
            f"golden")
        # registry defaults must reproduce the old schema exactly
        assert plan.family == "binomial"
        assert plan.cross_valid is None
        assert plan.mcast_arity == r


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_families_and_scheme_names():
    assert plan_families() == ("binomial", "resolvable")
    assert scheme_of_family("binomial") == "hybrid"
    assert scheme_of_family("resolvable") == "hybrid_resolvable"
    assert family_of_scheme("hybrid") == "binomial"
    assert family_of_scheme("hybrid_resolvable") == "resolvable"
    assert family_of_scheme("uncoded") is None
    assert get_plan_compiler("binomial") is not get_plan_compiler(
        "resolvable")


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(ValueError, match="unknown scheme family"):
        compile_hybrid_plan(RESOLVABLE_PARAMS[0], family="steiner")
    with pytest.raises(ValueError, match="already registered"):
        register_plan_compiler("binomial")(lambda p, perm=None: None)


def test_resolvable_divisibility_rejections():
    # r=1: no parallel classes
    with pytest.raises(ValueError, match="r >= 2"):
        SchemeParams(K=8, P=4, Q=16, N=48, r=1).validate_hybrid_resolvable()
    # r does not divide P
    with pytest.raises(ValueError, match=r"r\|P"):
        SchemeParams(K=12, P=6, Q=24, N=48, r=4).validate_hybrid_resolvable()
    # q = P/r = 1 (degenerate single-value classes)
    with pytest.raises(ValueError, match="q=P/r >= 2"):
        SchemeParams(K=8, P=4, Q=16, N=48, r=4).validate_hybrid_resolvable()
    # q^{r-1} does not divide NP/K
    with pytest.raises(ValueError, match=r"q\^\(r-1\)"):
        SchemeParams(K=12, P=6, Q=24, N=30, r=3).validate_hybrid_resolvable()
    # (r-1) does not divide M
    with pytest.raises(ValueError, match=r"\(r-1\)\|M"):
        SchemeParams(K=16, P=8, Q=32, N=64, r=4).validate_hybrid_resolvable()


# ---------------------------------------------------------------------------
# Tentpole pin 2: any registered family passes the re-execution oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,p", FAMILY_CASES,
                         ids=lambda v: str(getattr(v, "r", v)))
def test_any_family_plan_passes_numpy_oracle(family, p):
    """Plans of EVERY registered compiler re-execute bit-exactly against
    the dense oracle, in both the unicast and the coded wire format — the
    non-hypothesis twin of the property test in test_properties.py."""
    plan = compile_hybrid_plan(p, family=family)
    rng = np.random.default_rng(p.r)
    V = rng.integers(-100, 100, size=(p.N, p.Q, 3)).astype(np.float32)
    ref = plan_shuffle_reference(V, p, family=family)
    for mc in ("unicast", "coded"):
        got = simulate_plan_shuffle(V, plan, multicast=mc)
        np.testing.assert_array_equal(got, ref, err_msg=f"{family} {mc}")


@pytest.mark.parametrize("p", RESOLVABLE_PARAMS,
                         ids=lambda p: f"P{p.P}r{p.r}")
def test_resolvable_plan_structure(p):
    """Structural invariants: local rows + VALID received slots partition
    each layer table; padded slots are exactly the same-class (or r=2
    same-value) pairs; packets carry r-1 components."""
    plan = compile_hybrid_plan(p, family="resolvable")
    q = p.spc_q
    assert plan.family == "resolvable"
    assert plan.mcast_arity == p.r - 1
    assert plan.cross_valid is not None
    n_layer = p.subfiles_per_layer
    assert plan.local_subfiles.shape[-1] == p.N * p.r // p.K
    counts = shared_group_counts(p)
    sh = p.M_res // (p.r - 1)
    for i in range(p.P):
        for j in range(p.Kr):
            recv = [plan.cross_recv_pos[i, j, z][plan.cross_valid[i, z]]
                    for z in range(p.P) if z != i]
            recv = np.concatenate(recv)
            local = plan.local_pos[i, j]
            seen = np.concatenate([local, recv])
            assert len(np.unique(seen)) == len(seen)     # no row hit twice
            assert sorted(seen) == list(range(n_layer))  # full coverage
        for z in range(p.P):
            got = int(plan.cross_valid[i, z].sum())
            assert got == counts[z, i] * sh   # receiver i <- source z
            if z // q == i // q:              # same class: padding only
                assert got == 0


@pytest.mark.parametrize("p", RESOLVABLE_PARAMS,
                         ids=lambda p: f"P{p.P}r{p.r}")
def test_resolvable_schedule_decodable_and_counts_match(p):
    """Message-level proof: execute_plan's strict side-information
    assertions pass and the enumerated counts equal the closed form."""
    a = resolvable_assignment(p)
    counts = count_plan(make_plan(a), p)
    c = hybrid_resolvable_cost(p)
    assert counts.cross == pytest.approx(c.cross)
    assert counts.intra == pytest.approx(c.intra)
    rng = np.random.default_rng(0)
    V = rng.integers(-1000, 1000, size=(p.N, p.Q))
    know = execute_plan(a, V, strict=True)
    check_reduce_ready(a, know, V)
    # stage-traffic export agrees with the closed-form path
    enum = plan_stage_traffic(a)
    closed = scheme_stage_traffic(p, "hybrid_resolvable")
    assert [s.stage for s in enum] == [s.stage for s in closed]
    for se, sc in zip(enum, closed):
        assert se.cross_pairs == pytest.approx(sc.cross_pairs)
        assert se.intra_pairs == pytest.approx(sc.intra_pairs)


def test_resolvable_transfer_matrices_total_to_closed_form():
    p = RESOLVABLE_PARAMS[0]
    plan = compile_hybrid_plan(p, family="resolvable")
    c = hybrid_resolvable_cost(p)
    tm = plan_transfer_matrices(plan, multicast="coded")
    assert tm["cross_rack_matrix"].sum() == pytest.approx(c.cross)
    assert tm["intra_per_rack"].sum() == pytest.approx(c.intra)
    # unicast wire format carries arity copies of each coded packet
    tmu = plan_transfer_matrices(plan, multicast="unicast")
    assert tmu["cross_rack_matrix"].sum() == pytest.approx(
        c.cross * plan.mcast_arity)
    # same-class rack pairs exchange nothing
    q = p.spc_q
    cls = np.arange(p.P) // q
    same = cls[:, None] == cls[None, :]
    assert (tm["cross_rack_matrix"][same] == 0).all()


def test_resolvable_gain_is_arity_and_beats_uncoded():
    """Multicast gain r-1: cross cost is the uncoded cross scaled by
    (1 - r/P)/((r-1)(1 - 1/P))."""
    from repro.core.costs import uncoded_cost
    for p in RESOLVABLE_PARAMS:
        res = hybrid_resolvable_cost(p)
        unc = uncoded_cost(p, check=False)
        assert res.cross == pytest.approx(
            p.Q * p.N / (p.r - 1) * (1 - p.r / p.P))
        assert res.cross < unc.cross
        # binomial at the same r (when its closed form is defined) is the
        # stronger code: gain r vs r-1
        assert res.cross > hybrid_cost(p, check=False).cross


def test_resolvable_assignment_invariants():
    p = RESOLVABLE_PARAMS[0]
    a = resolvable_assignment(p)
    assert a.scheme == "hybrid_resolvable"
    q = p.spc_q
    inc = a.incidence()
    # every subfile mapped r times, one rack per class, same layer
    for subfile, servers in enumerate(a.servers_of_subfile):
        assert len(servers) == p.r
        racks = [s // p.Kr for s in servers]
        layers = {s % p.Kr for s in servers}
        assert len(layers) == 1
        assert sorted(rk // q for rk in racks) == list(range(p.r))
    # per-server load: r N / K (same computation load as binomial)
    assert (inc.sum(axis=0) == p.N * p.r // p.K).all()


def test_spc_codewords_are_the_parity_check_code():
    cw = spc_codewords(3, 3)
    assert cw.shape == (9, 3)
    assert ((cw[:, :-1].sum(axis=1) % 3) == cw[:, -1]).all()
    assert len({tuple(c) for c in cw.tolist()}) == 9


# ---------------------------------------------------------------------------
# Plan cache: (params, perm, family) key + per-family counters
# ---------------------------------------------------------------------------

def test_plan_cache_keys_on_family_and_reports_per_family():
    # N=60: per-layer 30 admits binomial (C(6,2)=15, M=2 even) AND
    # resolvable (q=3 | 30) at r=2
    p = SchemeParams(K=12, P=6, Q=24, N=60, r=2)
    plan_cache_clear()
    b1 = compile_hybrid_plan(p, family="binomial")
    r1 = compile_hybrid_plan(p, family="resolvable")
    assert b1 is not r1                       # families never alias
    assert b1.family == "binomial" and r1.family == "resolvable"
    assert compile_hybrid_plan(p, family="binomial") is b1
    assert compile_hybrid_plan(p, family="resolvable") is r1
    info = plan_cache_info()
    assert info.hits == 2 and info.misses == 2
    assert info.families["binomial"] == (1, 1)
    assert info.families["resolvable"] == (1, 1)
    # perm is part of the key for every family
    perm = list(np.random.default_rng(0).permutation(p.N))
    r2 = compile_hybrid_plan(p, perm=perm, family="resolvable")
    assert r2 is not r1
    assert compile_hybrid_plan(p, perm=perm, family="resolvable") is r2
    assert plan_cache_info().families["resolvable"] == (2, 2)
    plan_cache_clear()
    assert plan_cache_info().families == {}


def test_plan_cache_back_compat_attrs_still_work():
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    compile_hybrid_plan.cache_clear()
    compile_hybrid_plan(p)
    compile_hybrid_plan(p)
    info = compile_hybrid_plan.cache_info()
    assert info.hits >= 1 and info.misses >= 1


# ---------------------------------------------------------------------------
# Threading: chooser, engine, workload
# ---------------------------------------------------------------------------

def test_chooser_selects_resolvable_where_it_wins():
    """At a power-of-two-ish N where EVERY binomial r (and uncoded/coded)
    is inadmissible, the chooser must land on hybrid_resolvable — and a
    scheduled sim run completes the job under it."""
    from repro.sim.cluster import ClusterSim, CostModel
    from repro.sim.network import RackTopology
    from repro.sim.scheduler import SchemeChooser, run_scheduled
    from repro.sim.workload import JobSpec

    K, P = 12, 6
    spec = JobSpec("histogram", N=32, Q=24, d=1)
    topo = RackTopology(P=P, cross_bw=1e5, intra_bw=1e6)
    cluster = ClusterSim(topo, K=K, cost_model=CostModel())
    chooser = SchemeChooser(K, cost_model=cluster.cost_model, rs=(1, 2, 3))
    d = chooser.choose(spec, cluster)
    assert d.scheme == "hybrid_resolvable" and d.r == 3
    assert d.compile_s >= 0.0
    stats, sched = run_scheduled([spec], cluster, chooser)
    assert len(stats) == 1 and stats[0].jct > 0
    assert sched.decisions[stats[0].job_id].scheme == "hybrid_resolvable"


def test_chooser_prices_resolvable_against_binomial():
    """When both families are admissible at the same r, the chooser keeps
    whichever estimates faster — and the resolvable estimate exists (is
    not rejected) alongside the binomial one."""
    from repro.sim.cluster import ClusterSim, CostModel
    from repro.sim.network import RackTopology
    from repro.sim.scheduler import SchemeChooser
    from repro.sim.workload import JobSpec

    K, P = 12, 6
    spec = JobSpec("histogram", N=720, Q=24, d=1)   # feasible both families
    topo = RackTopology(P=P, cross_bw=1e5, intra_bw=1e6)
    cluster = ClusterSim(topo, K=K, cost_model=CostModel())
    chooser = SchemeChooser(K, cost_model=cluster.cost_model, rs=(2, 3))
    est_bin = chooser.estimate(spec, "hybrid", 2, cluster)
    est_res = chooser.estimate(spec, "hybrid_resolvable", 2, cluster)
    assert est_bin is not None and est_res is not None
    d = chooser.choose(spec, cluster)
    best = min(e for e in (
        chooser.estimate(spec, s, r, cluster)
        for s, r in chooser.candidates()) if e is not None)
    assert d.est_jct == pytest.approx(best)


def test_chooser_compile_charge_per_family_is_honest():
    """Probing a binomial plan must NOT register as a cache hit for the
    resolvable sibling of the same params."""
    from repro.sim.cluster import ClusterSim, CostModel
    from repro.sim.network import RackTopology
    from repro.sim.scheduler import SchemeChooser

    K, P = 12, 6
    p = SchemeParams(K=K, P=P, Q=24, N=720, r=2)
    plan_cache_clear()
    topo = RackTopology(P=P, cross_bw=1e5, intra_bw=1e6)
    cluster = ClusterSim(topo, K=K, cost_model=CostModel())
    chooser = SchemeChooser(K, cost_model=cluster.cost_model)
    secs_b, hit_b = chooser._compile_charge(p, "hybrid", probe=True)
    assert not hit_b and secs_b >= 0
    # binomial now cached — the resolvable probe must still be a miss
    secs_r, hit_r = chooser._compile_charge(p, "hybrid_resolvable",
                                            probe=True)
    assert not hit_r and secs_r >= 0
    # and both are hits the second time around
    assert chooser._compile_charge(p, "hybrid", probe=True)[1]
    assert chooser._compile_charge(p, "hybrid_resolvable", probe=True)[1]


def test_run_job_distributed_scheme_family(tmp_path):
    """Engine threading: the resolvable family produces outputs identical
    to run_job on a feasible config, with the family's cost accounting."""
    import jax.numpy as jnp
    from repro.distributed.meshes import make_mesh
    from repro.mapreduce.engine import run_job, run_job_distributed
    from repro.mapreduce.jobs import histogram_job

    p = SchemeParams(K=1, P=1, Q=4, N=6, r=1)
    mesh = make_mesh((1, 1), ("rack", "server"))
    job = histogram_job()
    rng = np.random.default_rng(0)
    subs = rng.integers(0, 1 << 16, size=(p.N, 64)).astype(np.int32)
    # K=1 has no resolvable design (q < 2): the family must reject loudly
    with pytest.raises(ValueError):
        run_job_distributed(job, subs, p, mesh, scheme_family="resolvable")
    # binomial default unchanged
    got = run_job_distributed(job, subs, p, mesh)
    ref = run_job(job, jnp.asarray(subs), p, "hybrid")
    np.testing.assert_array_equal(np.asarray(got.outputs),
                                  np.asarray(ref.outputs))
    assert got.scheme == "hybrid"


def test_run_job_resolvable_cost_accounting():
    import jax.numpy as jnp
    from repro.mapreduce.engine import run_job
    from repro.mapreduce.jobs import histogram_job

    p = RESOLVABLE_PARAMS[0]
    rng = np.random.default_rng(0)
    subs = rng.integers(0, 1 << 16, size=(p.N, 16)).astype(np.int32)
    res = run_job(histogram_job(), jnp.asarray(subs), p, "hybrid_resolvable")
    c = hybrid_resolvable_cost(p)
    assert res.cross_cost == pytest.approx(c.cross)
    assert res.intra_cost == pytest.approx(c.intra)


def test_valid_subfile_counts_per_family():
    from repro.sim.workload import default_catalog, valid_subfile_counts

    K, P = 12, 6
    binom = valid_subfile_counts(K, P, rs=(1, 2, 3))
    both = valid_subfile_counts(K, P, rs=(1, 2, 3),
                                families=("binomial", "resolvable"))
    resol = valid_subfile_counts(K, P, rs=(2, 3), families=("resolvable",))
    # sorted, deduped, and the union covers the binomial-only list
    for lst in (binom, both, resol):
        assert lst == sorted(set(lst))
    assert set(binom) <= set(both)
    # every emitted N is admissible for its family at every structural r
    for n in resol:
        for r in (2, 3):
            SchemeParams(K=K, P=P, Q=2 * K, N=n,
                         r=r).validate_hybrid_resolvable()
    for n in binom:
        for r in (1, 2, 3):
            SchemeParams(K=K, P=P, Q=2 * K, N=n, r=r).validate_hybrid()
    # resolvable minimum is far below the binomial one at this (K, P)
    assert min(resol) < min(binom)
    with pytest.raises(ValueError, match="unknown scheme families"):
        valid_subfile_counts(K, P, rs=(2,), families=("steiner",))
    cat = default_catalog(K, P, rs=(1, 2, 3),
                          families=("binomial", "resolvable"))
    assert len(cat) == 4
    for _, n, q, _ in cat:
        # union catalog: every size admits at least one family at r=2
        p = SchemeParams(K=K, P=P, Q=q, N=n, r=2)
        try:
            p.validate_hybrid()
        except ValueError:
            p.validate_hybrid_resolvable()


def test_structured_replicas_unchanged_by_refactor():
    """placement.structured now delegates its parallel-class shift to
    repro.core.resolvable — placements must be pinned bit-identical."""
    from repro.placement.structured import (replica_load,
                                            structured_replicas)

    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2, r_f=3)
    reps = structured_replicas(p, policy="resolvable")
    # layer c is the base layout shifted by c: rack +c, slot +c//P
    base = np.arange(p.N) % p.K
    np.testing.assert_array_equal(reps[:, 0], base)
    np.testing.assert_array_equal(
        reps[:, 1], ((base // p.Kr + 1) % p.P) * p.Kr + base % p.Kr)
    assert (replica_load(reps, p.K) == p.N * p.r_f // p.K).all()
