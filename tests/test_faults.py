"""repro failure-tolerance tests: degraded plan compilation (decode-around
and partial re-map) bit-exact vs the NumPy shuffle oracle for BOTH plan
families, the bounded degraded-plan cache, the shared restart/backoff
budget, seeded fault injection, mesh validation, and the simulator's crash
-> recovery pipeline (flow cancellation, re-map phase, chooser availability
term, trace determinism)."""
import numpy as np
import pytest

from repro.core.coded_collectives import (compile_hybrid_plan,
                                          pack_local_values,
                                          plan_shuffle_reference,
                                          simulate_plan_shuffle)
from repro.core.degraded import (build_patch, compile_degraded_plan,
                                 configure_degraded_cache,
                                 degraded_cache_clear, degraded_cache_info,
                                 degraded_stage_traffic)
from repro.core.params import SchemeParams
from repro.resilience import (BackoffPolicy, CrashEvent, FaultInjector,
                              FaultSpec, RestartBudget,
                              RestartBudgetExceeded)
from repro.sim import (ClusterSim, CostModel, JobSpec, PhaseCoeffs,
                       RackTopology, SchemeChooser)
from repro.sim.events import EventQueue
from repro.sim.network import FluidNetwork

PARAMS = {r: SchemeParams(K=8, P=4, Q=16, N=48, r=r) for r in (1, 2, 3)}
FAMILY_GRID = [("binomial", 1), ("binomial", 2), ("binomial", 3),
               ("resolvable", 2)]


def _values(p, seed=0, d=3):
    rng = np.random.default_rng(seed)
    return rng.integers(-100, 100, size=(p.N, p.Q, d)).astype(np.float32)


def _degraded_output(p, family, failed, V):
    """Run the degraded pipeline host-side: compile around the failures,
    re-map orphans into a patch, shuffle with failed servers zeroed."""
    dplan = compile_degraded_plan(p, failed, family=family)
    patch = build_patch(dplan, V[dplan.orphan_subfiles])
    out = simulate_plan_shuffle(V, dplan.plan, failed=dplan.failed,
                                patch=patch)
    return dplan, out


# ---------------------------------------------------------------------------
# Degraded plans vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,r", FAMILY_GRID)
@pytest.mark.parametrize("failed", [(0,), (3,), (7,), (0, 5), (1, 6),
                                    (0, 2), (0, 2, 5)])
def test_degraded_shuffle_bit_exact(family, r, failed):
    """Every failure set (decode-around AND partial re-map) recovers the
    exact failure-free shuffle output — the r-fold replication read as an
    erasure code, with re-mapped orphans patched in."""
    p = PARAMS[r]
    V = _values(p, seed=r)
    dplan, out = _degraded_output(p, family, failed, V)
    ref = plan_shuffle_reference(V, p, family=family)
    np.testing.assert_array_equal(out, ref)
    assert dplan.decode_around == (dplan.orphan_subfiles.size == 0)


@pytest.mark.parametrize("family,r", FAMILY_GRID)
def test_failed_servers_never_send(family, r):
    """Structural no-information-flow: a failed server appears in NO valid
    slot of the degraded cross tables — recovery provably never reads a
    dead server's memory."""
    p = PARAMS[r]
    for failed in [(0,), (3,), (0, 5), (0, 2)]:
        dplan = compile_degraded_plan(p, failed, family=family)
        cv = dplan.plan.cross_valid
        assert cv is not None and cv.ndim == 4
        for s in failed:
            z, j = s // p.Kr, s % p.Kr
            assert not cv[:, j, z, :].any()


def test_orphan_counts_follow_replication():
    """f <= r-1 per layer-group => zero orphans; r=1 orphans every lost
    subfile; a same-layer rack pair defeats r=2 but not r=3."""
    # single failure: r=1 loses its whole partition, r>=2 decode around
    assert compile_degraded_plan(PARAMS[1], (3,)).orphan_subfiles.size == 6
    for r in (2, 3):
        assert compile_degraded_plan(PARAMS[r], (3,)).decode_around
    # servers 0 and 2 share layer j=0 in racks 0 and 1: two owners of the
    # same replica group die together
    assert compile_degraded_plan(PARAMS[1], (0, 2)).orphan_subfiles.size == 12
    d2 = compile_degraded_plan(PARAMS[2], (0, 2))
    assert d2.orphan_subfiles.size == 4 and not d2.decode_around
    assert compile_degraded_plan(PARAMS[3], (0, 2)).decode_around
    assert compile_degraded_plan(
        PARAMS[2], (0, 2), family="resolvable").decode_around
    # same rack, different layers: different replica groups, r=2 survives
    assert compile_degraded_plan(PARAMS[2], (0, 1)).decode_around


def test_degraded_plan_rejects_bad_failures():
    with pytest.raises(ValueError):
        compile_degraded_plan(PARAMS[2], (8,))
    with pytest.raises(ValueError):
        compile_degraded_plan(PARAMS[2], (-1,))
    with pytest.raises(ValueError):          # every server dead
        compile_degraded_plan(PARAMS[2], tuple(range(8)))


def test_empty_failure_set_matches_base_routing():
    p = PARAMS[2]
    V = _values(p, seed=9)
    _, out = _degraded_output(p, "binomial", (), V)
    np.testing.assert_array_equal(out, plan_shuffle_reference(V, p))


def test_degraded_transfer_loads_unicast():
    """The degraded stage-1 is unicast: repairing a failure moves strictly
    more cross pairs than the repair-free degraded routing."""
    p = PARAMS[2]
    clean = compile_degraded_plan(p, ()).transfer_loads()
    dplan = compile_degraded_plan(p, (3,))
    loads = dplan.transfer_loads()
    assert loads["cross_rack_matrix"].sum() > clean["cross_rack_matrix"].sum()
    assert dplan.n_repaired_rows > 0
    np.testing.assert_array_equal(loads["intra_per_rack"],
                                  clean["intra_per_rack"])


# ---------------------------------------------------------------------------
# Bounded degraded-plan cache
# ---------------------------------------------------------------------------

def test_degraded_cache_bounded_with_eviction_stats():
    p = PARAMS[2]
    configure_degraded_cache(maxsize=4)
    try:
        for s in range(8):
            compile_degraded_plan(p, (s,))
        info = degraded_cache_info()
        assert info.maxsize == 4 and info.currsize == 4
        assert info.misses == 8 and info.evictions == 4
        # the most recent entries are retained -> hits
        compile_degraded_plan(p, (7,))
        assert degraded_cache_info().hits == 1
        # the oldest were evicted -> recompile is a miss
        compile_degraded_plan(p, (0,))
        assert degraded_cache_info().misses == 9
    finally:
        configure_degraded_cache()           # restore default size
    degraded_cache_clear()
    info = degraded_cache_info()
    assert info.currsize == 0 and info.hits == 0 and info.misses == 0


def test_degraded_cache_memoizes_identity():
    degraded_cache_clear()
    p = PARAMS[2]
    a = compile_degraded_plan(p, (5, 1, 1))
    b = compile_degraded_plan(p, [1, 5])     # order/dup-insensitive key
    assert a is b


# ---------------------------------------------------------------------------
# Shared restart budget / backoff
# ---------------------------------------------------------------------------

def test_backoff_policy_exponential_with_jitter():
    pol = BackoffPolicy(base_delay=1.0, factor=2.0, max_delay=8.0,
                        jitter=0.0)
    rng = np.random.default_rng(0)
    assert [pol.delay(k, rng) for k in range(5)] == [1.0, 2.0, 4.0, 8.0, 8.0]
    jit = BackoffPolicy(base_delay=1.0, factor=2.0, max_delay=64.0,
                        jitter=0.25)
    d = [jit.delay(1, np.random.default_rng(7)) for _ in range(3)]
    assert d[0] == d[1] == d[2]              # seeded => reproducible
    assert 1.5 <= d[0] <= 2.5


def test_restart_budget_exhausts():
    budget = RestartBudget(max_restarts=2, seed=0)
    budget.next_restart()
    budget.next_restart()
    assert not budget.exhausted and len(budget.delays) == 2
    with pytest.raises(RestartBudgetExceeded):
        budget.next_restart()
    assert budget.exhausted
    # with an error attached, the original error is re-raised
    budget2 = RestartBudget(max_restarts=0)
    with pytest.raises(InterruptedError):
        budget2.next_restart(InterruptedError("crash"))


def test_restart_budget_sleeps_through_hook():
    slept = []
    budget = RestartBudget(max_restarts=3, seed=1, sleep=slept.append)
    budget.next_restart()
    budget.next_restart()
    assert slept == list(budget.delays)
    assert all(s > 0 for s in slept)


def test_trainer_restart_uses_shared_budget(tmp_path):
    """train.fault.run_with_restarts delegates to the shared RestartBudget:
    same recovery semantics for the trainer and the engine ladder."""
    from repro.train.fault import run_with_restarts
    calls = []

    def flaky(resume_step):
        calls.append(resume_step)
        if len(calls) < 3:
            raise InterruptedError("preempted")
        yield (resume_step, {"loss": 0.0})

    budget = RestartBudget(max_restarts=5, seed=0)
    steps = list(run_with_restarts(flaky, str(tmp_path), budget=budget))
    assert steps == [(0, {"loss": 0.0})]
    assert budget.restarts == 2 and len(calls) == 3

    def always(resume_step):
        raise InterruptedError("always")
        yield  # pragma: no cover

    with pytest.raises(InterruptedError):
        list(run_with_restarts(always, str(tmp_path), max_restarts=1))


# ---------------------------------------------------------------------------
# Fault injection spec
# ---------------------------------------------------------------------------

def test_crash_event_validates_and_normalizes():
    e = CrashEvent(servers=(5, 1, 1), phase="map", time=2.0)
    assert e.servers == (1, 5)
    with pytest.raises(ValueError):
        CrashEvent(servers=(0,), phase="reduce")


def test_fault_injector_deterministic_and_filtered():
    a = FaultInjector.random(seed=3, K=8, n_events=4, max_servers=2)
    b = FaultInjector.random(seed=3, K=8, n_events=4, max_servers=2)
    assert a.events == b.events
    assert a.events != FaultInjector.random(seed=4, K=8, n_events=4,
                                            max_servers=2).events
    inj = FaultInjector((CrashEvent((0,), attempt=0),
                         CrashEvent((1,), attempt=1)))
    assert [e.servers for e in inj.events_for_attempt(0)] == [(0,)]
    assert [e.servers for e in inj.events_for_attempt(1)] == [(1,)]
    assert inj.all_servers() == (0, 1)


def test_rack_crash_covers_all_layers():
    p = PARAMS[2]
    inj = FaultInjector.rack_crash(p, rack=1)
    assert inj.events[0].servers == (2, 3)


def test_fault_spec_defaults():
    spec = FaultSpec(FaultInjector.crash((3,)))
    assert spec.allow_partial_remap and spec.max_restarts == 2
    assert isinstance(spec.backoff, BackoffPolicy)


# ---------------------------------------------------------------------------
# Mesh validation (engine entry)
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(self.shape)


def test_mesh_validation_messages():
    from repro.mapreduce.engine import _validate_mesh
    p = PARAMS[2]
    _validate_mesh(_FakeMesh({"rack": 4, "server": 2}), p)
    with pytest.raises(ValueError, match="rack"):
        _validate_mesh(_FakeMesh({"x": 4, "y": 2}), p)
    with pytest.raises(ValueError, match="rack=P=4"):
        _validate_mesh(_FakeMesh({"rack": 2, "server": 4}), p)


# ---------------------------------------------------------------------------
# Simulator: crash events, recovery, pricing
# ---------------------------------------------------------------------------

TOPO = RackTopology(P=4, cross_bw=1e4, intra_bw=1e5)
SPEC = JobSpec("histogram", 48, 16, 1)


def _crashed_run(scheme, r, crash_t=0.01, servers=(3,), topo=TOPO,
                 cost=CostModel()):
    sim = ClusterSim(topo, K=8, cost_model=cost)
    sim.submit(SPEC, scheme, r, time=0.0)
    FaultInjector.crash(servers, phase="shuffle",
                        time=crash_t).inject_into(sim)
    stats = sim.run()
    return sim, stats[0]


def test_crash_mid_shuffle_cancels_all_job_flows():
    """The regression the issue names: a crash voids the whole in-flight
    stage — no orphan flows keep draining in the FluidNetwork."""
    sim, st = _crashed_run("hybrid", 2)
    cancelled = [d for t, k, d in sim.trace if k == "flows_cancelled"]
    assert cancelled and cancelled[0][1] >= 1
    assert len(sim.network.flows) == 0        # nothing orphaned at the end
    assert st.crashes == 1 and st.recoveries == 1
    # recovery re-ran the shuffle: job still finishes, later than baseline
    base = ClusterSim(TOPO, K=8)
    base.submit(SPEC, "hybrid", 2, time=0.0)
    assert st.finish > base.run()[0].finish


def test_crash_recovery_r1_remaps_r2_decodes_around():
    _, st1 = _crashed_run("uncoded", 1)
    assert st1.remapped_subfiles == 6 and "remap" in st1.phase_times
    _, st2 = _crashed_run("hybrid", 2)
    assert st2.remapped_subfiles == 0 and "remap" not in st2.phase_times
    _, st3 = _crashed_run("hybrid", 3)
    assert st3.remapped_subfiles == 0


def test_crash_before_map_is_noop():
    sim = ClusterSim(TOPO, K=8)
    sim.submit(SPEC, "hybrid", 2, time=10.0)
    FaultInjector.crash((0,), phase="map", time=0.0).inject_into(sim)
    st = sim.run()[0]
    assert st.crashes == 0 and st.recoveries == 0


def test_seeded_crash_trace_bit_identical():
    def trace(seed):
        sim = ClusterSim(TOPO, K=8, cost_model=CostModel(
            map=PhaseCoeffs(0.0, 1e-6)))
        sim.submit(SPEC, "hybrid", 2, time=0.0)
        sim.submit(JobSpec("histogram", 96, 16, 2), "hybrid", 2, time=0.005)
        FaultInjector.random(seed=seed, K=8, n_events=2, max_servers=2,
                             max_time=0.03).inject_into(sim)
        sim.run()
        return tuple(sim.trace)

    assert trace(11) == trace(11)
    assert trace(11) != trace(12)


def test_degraded_stage_traffic_consistency():
    p = PARAMS[2]
    base, _ = degraded_stage_traffic(p, "hybrid", ())
    stages, n_remap = degraded_stage_traffic(p, "hybrid", (3,))
    assert n_remap == 0
    assert stages[0].cross_pairs > base[0].cross_pairs
    stages1, n_remap1 = degraded_stage_traffic(PARAMS[1], "hybrid", (3,))
    assert n_remap1 == 6
    _, n_unc = degraded_stage_traffic(p, "uncoded", (3,))
    assert n_unc == 6                         # r=1 semantics for uncoded


def test_chooser_availability_term_shifts_to_replication():
    """At crash_prob=0 an expensive-map config picks r=1; pricing crashes
    in flips the choice to a replicated scheme (r as failure tolerance)."""
    topo = RackTopology(P=4, cross_bw=1e8, intra_bw=1e9)
    cost = CostModel(map=PhaseCoeffs(beta=1e-5))
    spec = JobSpec("histogram", 336, 16, 4)

    def pick(cp):
        cluster = ClusterSim(topo, K=8, cost_model=cost)
        return SchemeChooser(K=8, cost_model=cost,
                             crash_prob=cp).choose(spec, cluster)

    blind = pick(0.0)
    assert blind.r == 1
    aware = pick(2.0)
    assert aware.r >= 2
    # estimates are monotone in crash_prob, r=1 penalised harder
    cluster = ClusterSim(topo, K=8, cost_model=cost)
    e = [SchemeChooser(K=8, cost_model=cost, crash_prob=cp).estimate(
        spec, "uncoded", 1, cluster) for cp in (0.0, 1.0)]
    h = [SchemeChooser(K=8, cost_model=cost, crash_prob=cp).estimate(
        spec, "hybrid", 2, cluster) for cp in (0.0, 1.0)]
    assert e[1] > e[0] and h[1] > h[0]
    assert (e[1] - e[0]) > (h[1] - h[0])


def test_task_map_crash_reexecutes_lost_tasks():
    """Task-granular map absorbs crashes internally: lost map outputs are
    re-executed and the job completes without a degraded shuffle."""
    from repro.resilience import get_policy
    sim = ClusterSim(TOPO, K=8, cost_model=CostModel(
        map=PhaseCoeffs(0.0, 1e-4)))
    sim.submit(SPEC, "hybrid", 2, time=0.0,
               speculation=get_policy("none"))
    # crash while the task-map phase is running
    FaultInjector.crash((3,), phase="map", time=0.002).inject_into(sim)
    st = sim.run()[0]
    assert st.crashes == 1
    assert st.recoveries == 0                 # no shuffle recovery needed
    lost = [d for t, k, d in sim.trace if k == "task_lost"]
    assert lost                               # some attempts were lost


# ---------------------------------------------------------------------------
# Primitive units: cancel_where / cancel_flows
# ---------------------------------------------------------------------------

def test_event_queue_cancel_where():
    q = EventQueue()
    q.push(1.0, "stage_latency", (7, "x"))
    q.push(2.0, "phase_done", (7, "map"))
    q.push(3.0, "phase_done", (8, "map"))
    assert q.cancel_where(lambda ev: ev.data[0] == 7) == 2
    assert q.pop().data[0] == 8


def test_fluid_network_cancel_flows():
    net = FluidNetwork(RackTopology(P=2))
    net.start_flow("root", 10.0, (1, "shuffle"))
    net.start_flow("root", 10.0, (2, "shuffle"))
    net.start_flow(("tor", 0), 5.0, (1, "shuffle"))
    assert net.cancel_flows(lambda tag: tag[0] == 1) == 2
    assert len(net.flows) == 1 and net.backlog("root") == 10.0
