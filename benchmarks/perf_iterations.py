"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> validate,
on the three chosen cells.  Each experiment is a CellPlan/policy variant
of launch/dryrun.run_cell; results cache under results/dryrun/ with a
``__<variant>`` suffix and are summarized here.

  PYTHONPATH=src python -m benchmarks.perf_iterations [--exp 1 2 3]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

# NOTE: importing repro.launch.dryrun sets the 512-device XLA flag — this
# module must run in its own process (it does: python -m ...).
from repro.launch.dryrun import run_cell


def _fmt(r: Dict) -> str:
    if not r.get("ok"):
        return "FAIL " + r.get("error", "")[:100]
    rf = r["roofline"]
    mp = r.get("memory_plan", {})
    return (f"t_comp={rf['t_compute']:.3g}s t_mem={rf['t_memory']:.3g}s "
            f"t_coll={rf['t_collective']:.3g}s (ici={rf['t_ici']:.3g} "
            f"dcn={rf['t_dcn']:.3g}) dom={rf['dominant']} "
            f"frac={rf['roofline_fraction']:.3f} "
            f"plan={mp.get('total_gib', 0):.1f}GiB"
            f"{'fits' if mp.get('fits_16gib') else 'OVER'}")


def exp1_llama_train(force: bool = False) -> List[Dict]:
    """Cell: llama3-405b x train_4k x single (worst train fraction).

    Baseline: ZeRO-3 re-gathers every microbatch (n_micro=16) -> collective
    bound.  H1: fewer microbatches amortize the per-micro weight gather
    (bytes ~ 3 x P_gathered x n_micro); seq-TP boundaries keep activations
    affordable.  H2: even n_micro=4 with more remat blocks."""
    out = []
    cell = ("llama3-405b", "train_4k", "single")
    out.append(("baseline_nmicro16", run_cell(*cell, force=force)))
    out.append(("nmicro8", run_cell(
        *cell, variant="nmicro8", force=force,
        overrides={"n_micro": 8})))
    out.append(("nmicro4", run_cell(
        *cell, variant="nmicro4", force=force,
        overrides={"n_micro": 4, "remat_blocks": 18})))
    out.append(("nmicro2", run_cell(
        *cell, variant="nmicro2", force=force,
        overrides={"n_micro": 2, "remat_blocks": 18})))
    return out


def exp2_decode_tp2d(force: bool = False) -> List[Dict]:
    """Cell: qwen2-72b x decode_32k x single (most collective-bound).

    Baseline: ZeRO-3 sharded weights are re-gathered EVERY TOKEN (~GB/step
    on ICI).  H: 2D tensor parallelism (weights statically sharded over
    ('data','model'), cache batch-sharded) moves only MB-scale activations
    -> decode becomes memory-bound (its true roofline), step time drops by
    the gather time."""
    out = []
    cell = ("qwen2-72b", "decode_32k", "single")
    out.append(("baseline_zero3", run_cell(*cell, force=force)))
    out.append(("tp_model_only", run_cell(
        *cell, variant="tponly", force=force, overrides={"fsdp": False})))
    out.append(("tp2d", run_cell(
        *cell, variant="tp2d", force=force,
        overrides={"fsdp": False, "tp2d": True})))
    return out


def exp3_coded_dp(force: bool = False) -> List[Dict]:
    """Cell: deepseek-v2-lite x train_4k x multi (the paper's technique).

    The cross-pod (DCN) gradient stage IS the paper's cross-rack shuffle.
    Baseline dp_flat: batch over ('pod','data') -> DCN all-reduce of grads.
    Variant 'replicated' = map replication r = P (2 pods): ZERO DCN bytes
    for 2x map FLOPs — the paper's L_cro = (QN/r)(1-r/P) = 0 corner,
    measured end-to-end from the compiled HLO."""
    out = []
    cell = ("deepseek-v2-lite-16b", "train_4k", "multi")
    out.append(("dp_flat", run_cell(*cell, force=force)))
    out.append(("replicated_rP", run_cell(*cell, dp_mode="replicated",
                                          force=force)))
    return out


EXPS = {"1": exp1_llama_train, "2": exp2_decode_tp2d, "3": exp3_coded_dp}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--exp", nargs="*", default=["1", "2", "3"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    for e in args.exp:
        print(f"=== experiment {e}: {EXPS[e].__doc__.splitlines()[0]} ===")
        for name, r in EXPS[e](force=args.force):
            print(f"  {name:22s} {_fmt(r)}", flush=True)


if __name__ == "__main__":
    main()
