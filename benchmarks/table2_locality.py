"""Paper Table II: node/rack data locality of random vs optimization-based
Map-task assignment under Hybrid Coded MapReduce, for the paper's ten
(K, P, r_f, N) rows (r = 2 throughout, lambda in (0.5, 1])."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.locality import table2_experiment
from repro.core.params import SchemeParams

# (K, P, r_f, N) -> paper's (node_ran, node_opt, rack_ran, rack_opt) in %
PAPER_ROWS: List[Tuple[Tuple[int, int, int, int], Tuple[float, ...]]] = [
    ((8, 2, 2, 160), (25, 60, 80, 80)),
    ((8, 2, 3, 100), (39, 76, 95, 95)),
    ((9, 3, 2, 144), (17, 64, 57, 86)),
    ((9, 3, 3, 90), (33, 87, 77, 98)),
    ((10, 5, 2, 100), (19, 80, 41, 92.5)),
    ((16, 4, 2, 192), (10, 64, 45, 90)),
    ((16, 4, 3, 192), (19, 84, 63, 99)),
    ((18, 3, 2, 180), (11, 60, 57, 83)),
    ((20, 5, 2, 200), (13, 66, 38, 90)),
    ((21, 3, 2, 84), (12, 63, 56, 81)),
]


def run(verbose: bool = True, seed: int = 0) -> List[dict]:
    rows = []
    print_hdr = True
    for (K, P, r_f, N), paper in PAPER_ROWS:
        t0 = time.perf_counter()
        p = SchemeParams(K=K, P=P, Q=K, N=N, r=2, r_f=r_f)
        res = table2_experiment(p, lam=0.8, seed=seed)
        rows.append({
            "params": (K, P, r_f, N),
            "node_ran": 100 * res.node_random, "node_opt": 100 * res.node_opt,
            "rack_ran": 100 * res.rack_random, "rack_opt": 100 * res.rack_opt,
            "paper": paper,
            "s": time.perf_counter() - t0,
        })
        if verbose:
            if print_hdr:
                print(f"{'(K,P,rf,N)':16s} {'node ran/opt':>14s} "
                      f"{'rack ran/opt':>14s}   paper(n-ran n-opt r-ran "
                      "r-opt)")
                print_hdr = False
            r = rows[-1]
            print(f"{str((K, P, r_f, N)):16s} "
                  f"{r['node_ran']:5.1f}/{r['node_opt']:5.1f}% "
                  f"{r['rack_ran']:6.1f}/{r['rack_opt']:5.1f}%   "
                  + " ".join(f"{v:5.1f}" for v in paper))
    if verbose:
        gains = [r["node_opt"] - r["node_ran"] for r in rows]
        print(f"mean node-locality gain (opt - random): "
              f"{sum(gains) / len(gains):.1f} points "
              "(paper's qualitative claim reproduced; exact cells depend on "
              "the paper's unpublished replica-placement seeds)")
    return rows


def main() -> None:
    for r in run(verbose=False):
        K, P, rf, N = r["params"]
        print(f"table2_{K}_{P}_{rf}_{N},{r['s'] * 1e6:.0f},"
              f"node {r['node_ran']:.0f}->{r['node_opt']:.0f} "
              f"rack {r['rack_ran']:.0f}->{r['rack_opt']:.0f}")


if __name__ == "__main__":
    run()
