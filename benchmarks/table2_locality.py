"""Paper Table II under the full repro.placement solver suite — locality
percentages AND time units, multi-trial mean ± std, to BENCH_locality.json.

Sections (all seeded -> deterministic):

  * ``table2`` — for each of the paper's ten (K, P, r_f, N) rows, every
    registered solver's node/rack locality (mean ± std over ``n_trials``
    replica-placement instances) plus solver wall clock.  HARD assertions:
    the ``flow`` solver reproduces the legacy ``table2_experiment``
    optimized locality EXACTLY (bit-identical draw sequence), ``anneal_jax``
    (flow-warm-started, i.e. polishing the exact optimum) matches or beats
    flow on objective and node locality, and every non-random solver beats
    the random baseline on mean node locality.
  * ``table2_time_units`` — the ROADMAP item "Table II in time units": each
    row's random and flow placements run through the cluster simulator
    (fetch traffic + map imbalance, straggler-free); asserts optimized
    placement STRICTLY lowers mean JCT on every row.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

try:
    from ._common import emit_report, make_parser
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser

from repro.core.params import SchemeParams
from repro.placement import (jct_gap, table2_experiment, table2_trials)
from repro.sim import CostModel, PhaseCoeffs, RackTopology

# (K, P, r_f, N) -> paper's (node_ran, node_opt, rack_ran, rack_opt) in %
PAPER_ROWS: List[Tuple[Tuple[int, int, int, int], Tuple[float, ...]]] = [
    ((8, 2, 2, 160), (25, 60, 80, 80)),
    ((8, 2, 3, 100), (39, 76, 95, 95)),
    ((9, 3, 2, 144), (17, 64, 57, 86)),
    ((9, 3, 3, 90), (33, 87, 77, 98)),
    ((10, 5, 2, 100), (19, 80, 41, 92.5)),
    ((16, 4, 2, 192), (10, 64, 45, 90)),
    ((16, 4, 3, 192), (19, 84, 63, 99)),
    ((18, 3, 2, 180), (11, 60, 57, 83)),
    ((20, 5, 2, 200), (13, 66, 38, 90)),
    ((21, 3, 2, 84), (12, 63, 56, 81)),
]

SOLVERS = ("random", "greedy", "flow", "local_search", "anneal_jax")

# time-units cluster: the paper's server-rack regime (root 10x slower than
# the ToR tier) with a calibrated-magnitude map cost so locality imbalance
# moves the barrier, straggler-free (the acceptance grid)
TIME_TOPO = dict(cross_bw=1e4, intra_bw=1e5)
TIME_COST = CostModel(map=PhaseCoeffs(alpha=0.0, beta=1e-8))


def _solver_kwargs(smoke: bool) -> Dict[str, Dict]:
    return {
        "anneal_jax": {
            # polish the exact optimum: the flow warm start guarantees the
            # matches-or-beats-flow OBJECTIVE invariant, and putting flow
            # FIRST makes argmax ties return the flow perm itself — so the
            # node-locality comparison below can never lose to an
            # equal-objective perm with a different node/rack split
            "init_solvers": ("flow", "greedy"),
            "n_chains": 16 if smoke else 64,
            "n_steps": 200 if smoke else 1000,
        },
        "local_search": {"max_sweeps": 5 if smoke else 20},
    }


def run(smoke: bool = False, seed: int = 0, n_trials: int | None = None,
        verbose: bool = True) -> Dict:
    if n_trials is None:
        n_trials = 2 if smoke else 5
    kw = _solver_kwargs(smoke)
    rows = []
    time_rows = []
    for (K, P, r_f, N), paper in PAPER_ROWS:
        p = SchemeParams(K=K, P=P, Q=K, N=N, r=2, r_f=r_f)
        res = table2_trials(p, lam=0.8, seed=seed, n_trials=n_trials,
                            solvers=SOLVERS, per_solver_kwargs=kw)
        s = res.stats

        # --- hard assertions (acceptance criteria) -------------------------
        # the legacy optimizer must be reproduced EXACTLY; one legacy trial
        # suffices (same master-rng draw order => trial 0 sees the same
        # replica instance), keeping the duplicate flow solve to 1 per row
        legacy = table2_experiment(p, seed=seed, trials=1)
        t0_flow, t0_ran = res.trials[0]["flow"], res.trials[0]["random"]
        assert (t0_flow.node_locality, t0_flow.rack_locality) == \
            (legacy.node_opt, legacy.rack_opt), \
            f"flow diverged from the legacy optimizer on {(K, P, r_f, N)}"
        assert t0_ran.node_locality == legacy.node_random
        a, f = s["anneal_jax"], s["flow"]
        assert a.objective_mean >= f.objective_mean - 1e-6, \
            f"anneal lost to flow on {(K, P, r_f, N)}"
        assert a.node_mean >= f.node_mean - 1e-9
        for name in SOLVERS:
            if name != "random":
                assert s[name].node_mean > s["random"].node_mean, \
                    f"{name} did not beat random on {(K, P, r_f, N)}"

        rows.append({
            "params": [K, P, r_f, N], "paper_pct": list(paper),
            "n_trials": n_trials,
            "solvers": {name: s[name].as_dict() for name in SOLVERS},
        })

        # --- time units: simulate trial placements, straggler-free ---------
        topo = RackTopology(P=P, **TIME_TOPO)
        jr, jo = [], []
        for trial in res.trials:
            r_ran, r_opt = jct_gap(trial["flow"], trial["random"], topo,
                                   cost_model=TIME_COST)
            jr.append(r_ran)
            jo.append(r_opt)
        mean_ran, mean_opt = float(np.mean(jr)), float(np.mean(jo))
        assert mean_opt < mean_ran, \
            f"optimized placement did not lower JCT on {(K, P, r_f, N)}"
        time_rows.append({
            "params": [K, P, r_f, N],
            "mean_jct_random": mean_ran, "mean_jct_flow": mean_opt,
            "speedup": mean_ran / mean_opt,
            "node_random": s["random"].node_mean,
            "node_flow": s["flow"].node_mean,
        })

        if verbose:
            r = rows[-1]
            print(f"{str((K, P, r_f, N)):16s} "
                  + " | ".join(
                      f"{n}: {100 * s[n].node_mean:4.1f}±"
                      f"{100 * s[n].node_std:3.1f}%"
                      for n in ("random", "greedy", "flow", "anneal_jax"))
                  + f" | jct {mean_ran:.4f}->{mean_opt:.4f}s "
                  f"({mean_ran / mean_opt:.2f}x)")

    if verbose:
        walls = {n: float(np.mean([r["solvers"][n]["wall_s_mean"]
                                   for r in rows])) for n in SOLVERS}
        print("mean solver wall clock: "
              + ", ".join(f"{n} {w * 1e3:.1f}ms" for n, w in walls.items()))
        print("all rows: flow == legacy optimum exactly; anneal >= flow; "
              "all solvers beat random; optimized JCT < random JCT")
    return {
        "n_trials": n_trials, "lam": 0.8,
        "table2": rows,
        "time_units_cluster": {**TIME_TOPO,
                               "map_beta": TIME_COST.map.beta},
        "table2_time_units": time_rows,
        "all_assertions_passed": True,
    }


def main() -> None:
    ap = make_parser(__doc__, "BENCH_locality.json")
    ap.add_argument("--trials", type=int, default=None,
                    help="replica-placement instances per row "
                         "(default 5; 2 under --smoke)")
    args = ap.parse_args()
    report = run(smoke=args.smoke, seed=args.seed, n_trials=args.trials)
    emit_report(report, "locality", args.out, smoke=args.smoke,
                seed=args.seed)


if __name__ == "__main__":
    main()
