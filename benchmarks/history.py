"""Bench-trajectory ledger: every bench CLI appends its headline scalars
to ``BENCH_history.jsonl`` (one JSON object per line, append-only), and
``check`` diffs the newest entry against the previous comparable run so a
silent perf/quality regression fails loudly in CI.

Wired into :func:`benchmarks._common.emit_report`, so any bench that emits
the common envelope gets a ledger entry for free; the ledger lives next to
the emitted ``BENCH_*.json`` (repo root for the committed artifacts, the
bench's --out directory otherwise — CI smoke runs therefore never touch
the committed ledger).

    python benchmarks/history.py check [--bench NAME] [--max-regress PCT]
    python benchmarks/history.py show  [--bench NAME]

``check`` compares only same-(bench, smoke) pairs — a smoke run is never
diffed against a full run — and passes when no comparable prior entry
exists (the first run of a new bench cannot regress).  Each tracked scalar
carries its good direction: ``higher`` (a speedup dropping is a
regression) or ``lower`` (an error/overhead rising is one).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

LEDGER_NAME = "BENCH_history.jsonl"

#: headline scalars per bench: dotted path into the envelope -> direction
#: in which BIGGER is BETTER ("higher") or WORSE ("lower")
TRACKED: Dict[str, Dict[str, str]] = {
    "pipeline": {"default_size_speedup": "higher"},
    "calibration": {
        "conformance.max_rel_err": "lower",
        "conformance.mean_rel_err": "lower",
        "drift.refit_mean_rel_err": "lower",
        "phase_fit.worst_rel_rmse": "lower",
    },
    "obs": {"overhead.overhead_frac": "lower"},
    "blame": {
        "exactness.max_rel_residual": "lower",
        "extract.max_rel_residual": "lower",
        "attribution.crash.recovery_rel_err": "lower",
        "attribution.skew.intra_blame_ratio": "higher",
        "attribution.straggle.map_straggle_share": "higher",
    },
    "sim": {"scheduler_wins.mean_jct_ratio": "lower"},
}


def _get_path(doc: Dict, dotted: str) -> Optional[float]:
    cur: object = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    try:
        return float(cur)                    # type: ignore[arg-type]
    except (TypeError, ValueError):
        return None


def entry_from_envelope(envelope: Dict, out_path: str) -> Dict:
    bench = envelope.get("bench", "unknown")
    scalars = {path: v for path, _ in TRACKED.get(bench, {}).items()
               if (v := _get_path(envelope, path)) is not None}
    return {
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "bench": bench,
        "smoke": bool(envelope.get("smoke", False)),
        "seed": envelope.get("seed"),
        "schema_version": envelope.get("schema_version"),
        "out": os.path.basename(out_path),
        "scalars": scalars,
    }


def ledger_path_for(out_path: str) -> str:
    return os.path.join(os.path.dirname(os.path.abspath(out_path)),
                        LEDGER_NAME)


def append_entry(envelope: Dict, out_path: str,
                 ledger_path: Optional[str] = None) -> Dict:
    """Append one ledger line for an emitted report; returns the entry."""
    path = ledger_path or ledger_path_for(out_path)
    entry = entry_from_envelope(envelope, out_path)
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def read_ledger(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def check(path: str, bench: Optional[str] = None,
          max_regress_pct: float = 25.0) -> List[str]:
    """Violations of the regression gate: for every (bench, smoke) group
    with >= 2 entries, the newest tracked scalars must not be worse than
    the previous entry's by more than ``max_regress_pct`` percent (in the
    scalar's bad direction).  Empty list = gate passes."""
    groups: Dict[Tuple[str, bool], List[Dict]] = {}
    for e in read_ledger(path):
        if bench is not None and e.get("bench") != bench:
            continue
        groups.setdefault((e.get("bench"), bool(e.get("smoke"))),
                          []).append(e)
    violations: List[str] = []
    for (b, smoke), entries in sorted(groups.items()):
        if len(entries) < 2:
            continue
        prev, last = entries[-2], entries[-1]
        directions = TRACKED.get(b, {})
        for key, direction in directions.items():
            p = prev.get("scalars", {}).get(key)
            l = last.get("scalars", {}).get(key)
            if p is None or l is None or p == 0:
                continue
            change = (l - p) / abs(p)
            regress = change < -max_regress_pct / 100.0 \
                if direction == "higher" else change > max_regress_pct / 100.0
            if regress:
                violations.append(
                    f"{b}{' (smoke)' if smoke else ''}: {key} went "
                    f"{p:.6g} -> {l:.6g} ({change:+.1%}), worse than the "
                    f"{max_regress_pct:.0f}% gate in the "
                    f"'{direction}-is-better' direction")
    return violations


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("check", "show"):
        sp = sub.add_parser(name)
        sp.add_argument("--ledger", default=os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            LEDGER_NAME))
        sp.add_argument("--bench", default=None)
        if name == "check":
            sp.add_argument("--max-regress", type=float, default=25.0,
                            help="max tolerated regression, percent")
    args = ap.parse_args(argv)
    if args.cmd == "show":
        for e in read_ledger(args.ledger):
            if args.bench is None or e.get("bench") == args.bench:
                print(json.dumps(e, sort_keys=True))
        return
    violations = check(args.ledger, bench=args.bench,
                       max_regress_pct=args.max_regress)
    for v in violations:
        print(f"REGRESSION: {v}", file=sys.stderr)
    if violations:
        sys.exit(1)
    print("bench-trajectory gate: OK "
          f"({len(read_ledger(args.ledger))} ledger entries)")


if __name__ == "__main__":
    main()
