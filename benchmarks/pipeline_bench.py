"""End-to-end MapReduce pipeline benchmark: legacy host-round-trip path vs
the fused device-resident pipeline (`run_job_distributed(fused=True)`).

For each (r, N, Q, d) point on an 8-host-device ('rack','server') mesh this
measures:

  * end-to-end wall clock of both paths (post-compile, best of ``iters``);
  * per-phase timings of the legacy path (map / host pack / shuffle+reduce)
    — the fused path is ONE jitted program, so it reports a single fused
    phase plus its compile time;
  * inter-phase host-transfer bytes: the legacy path copies the full
    V[N, Q, d] device->host after map and re-uploads the packed
    [K, n_loc, Q, d] tensor before the shuffle; the fused path moves ZERO
    bytes between phases (only subfiles in, outputs out — both paths pay
    those);
  * output parity (bit-exact, asserted every run).

Emits ``BENCH_pipeline.json`` (repo root by default) — the perf trajectory
seed.  ``--smoke`` runs one small config for CI.
"""
from __future__ import annotations

import os
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

try:                                                           # noqa: E402
    from ._common import emit_report, make_parser, seeded_rng
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser, seeded_rng

from repro.core.coded_collectives import (                     # noqa: E402
    compile_hybrid_plan, hybrid_shuffle, pack_local_values)
from repro.core.params import SchemeParams                     # noqa: E402
from repro.distributed.meshes import make_mesh                 # noqa: E402
from repro.mapreduce.engine import (                           # noqa: E402
    _fused_executable, assemble_outputs, map_phase,
    pack_local_subfiles, run_job, run_job_distributed)
from repro.mapreduce.jobs import wide_histogram_job            # noqa: E402

MESH_SHAPE = (4, 2)                  # P=4 racks x Kr=2 servers = 8 devices
SUBFILE_TOKENS = 256
# default sweep: N=96 satisfies C(4,r) | NP/K and r | M for r in {1, 2, 3}.
# The default benchmark point is the FIRST size at the LARGEST r of the
# sweep: the legacy path must materialize and upload the r-fold-replicated
# packed tensor (r * N*Q*d*4 bytes) on the host, so higher map replication
# — the paper's deep-tradeoff regime — is exactly where the host round
# trip hurts most and where killing it pays; the fused path never
# materializes that buffer at all.
DEFAULT_SIZES = [(96, 16, 2048), (96, 16, 512), (192, 16, 1024)]
DEFAULT_RS = (1, 2, 3)
SMOKE_SIZES = [(48, 16, 64)]
SMOKE_RS = (2,)


def _timeit(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_point(mesh, r: int, N: int, Q: int, d: int, iters: int,
                seed: int = 0) -> dict:
    p = SchemeParams(K=MESH_SHAPE[0] * MESH_SHAPE[1], P=MESH_SHAPE[0],
                     Q=Q, N=N, r=r)
    plan = compile_hybrid_plan(p)
    job = wide_histogram_job(d)
    rng = seeded_rng(seed * 1009 + r)     # distinct data per (seed, r)
    subfiles = rng.integers(0, 1 << 16, size=(N, SUBFILE_TOKENS)
                            ).astype(np.int32)

    # ---- parity: fused == legacy == single-device oracle, bit-exact --------
    oracle = np.asarray(run_job(job, jnp.asarray(subfiles), p,
                                "hybrid").outputs)
    legacy = run_job_distributed(job, subfiles, p, mesh, fused=False)
    fused = run_job_distributed(job, subfiles, p, mesh, fused=True)
    np.testing.assert_array_equal(np.asarray(legacy.outputs), oracle)
    np.testing.assert_array_equal(np.asarray(fused.outputs), oracle)

    # ---- legacy path, per phase --------------------------------------------
    # Strongest possible host-round-trip baseline: both device phases are
    # jitted ONCE and reused warm, so the measured gap is the architecture
    # (host round-trip + single-device map) — not trace-cache artifacts.
    subs_dev = jnp.asarray(subfiles)
    map_jit = jax.jit(lambda s: map_phase(job, s, p.Q))

    def shuf_reduce(local):
        shuffled = hybrid_shuffle(local, plan, mesh)
        out = jax.vmap(jax.vmap(job.reduce_fn, in_axes=1))(shuffled)
        return assemble_outputs(out, plan)

    shuf_jit = jax.jit(shuf_reduce)

    def legacy_map():
        return np.asarray(map_jit(subs_dev))                 # device -> host

    V_host = legacy_map()

    def legacy_pack():
        return jnp.asarray(pack_local_values(V_host, plan)   # host -> device
                           ).block_until_ready()

    local_dev = legacy_pack()

    def legacy_shuffle_reduce():
        return shuf_jit(local_dev).block_until_ready()

    legacy_shuffle_reduce()                                   # compile
    t_map = _timeit(legacy_map, iters)
    t_pack = _timeit(legacy_pack, iters)
    t_shuf = _timeit(legacy_shuffle_reduce, iters)

    def legacy_e2e():
        V = np.asarray(map_jit(subs_dev))
        local = jnp.asarray(pack_local_values(V, plan))
        return shuf_jit(local).block_until_ready()

    t_legacy = _timeit(legacy_e2e, iters)

    # ---- fused path --------------------------------------------------------
    t0 = time.perf_counter()
    exe = _fused_executable(job, plan, mesh, "unicast", "xla")
    packed = jnp.asarray(pack_local_subfiles(subfiles, plan))
    exe(packed).block_until_ready()                           # compile
    t_compile = time.perf_counter() - t0

    def fused_e2e():
        packed = jnp.asarray(pack_local_subfiles(subfiles, plan))
        out = exe(packed)
        return assemble_outputs(out, plan).block_until_ready()

    t_fused = _timeit(fused_e2e, iters)

    def fused_device_only():
        return exe(jnp.asarray(pack_local_subfiles(subfiles, plan))
                   ).block_until_ready()

    t_fused_dev = _timeit(fused_device_only, iters)

    itemsize = 4                                              # float32
    v_bytes = N * Q * d * itemsize
    packed_bytes = p.K * plan.local_subfiles.shape[-1] * Q * d * itemsize
    return {
        "r": r, "N": N, "Q": Q, "d": d,
        "legacy": {
            "total_s": t_legacy,
            "phases_s": {"map_to_host": t_map, "host_pack_upload": t_pack,
                         "shuffle_reduce": t_shuf},
            "interphase_host_bytes": v_bytes + packed_bytes,
        },
        "fused": {
            "total_s": t_fused,
            "phases_s": {"fused_map_shuffle_reduce": t_fused_dev},
            "compile_s": t_compile,
            "interphase_host_bytes": 0,
        },
        "speedup": t_legacy / t_fused,
        "bit_exact": True,
    }


def run(smoke: bool = False, iters: int = 5, verbose: bool = True,
        seed: int = 0) -> dict:
    mesh = make_mesh(MESH_SHAPE, ("rack", "server"))
    sizes = SMOKE_SIZES if smoke else DEFAULT_SIZES
    rs = SMOKE_RS if smoke else DEFAULT_RS
    rows = []
    for (N, Q, d) in sizes:
        for r in rs:
            row = bench_point(mesh, r, N, Q, d, iters, seed=seed)
            rows.append(row)
            if verbose:
                lp = row["legacy"]["phases_s"]
                print(f"r={r} N={N:4d} Q={Q} d={d:4d}  "
                      f"legacy {row['legacy']['total_s'] * 1e3:8.2f} ms "
                      f"(map {lp['map_to_host'] * 1e3:.2f} / pack "
                      f"{lp['host_pack_upload'] * 1e3:.2f} / shuf "
                      f"{lp['shuffle_reduce'] * 1e3:.2f})  "
                      f"fused {row['fused']['total_s'] * 1e3:8.2f} ms  "
                      f"{row['speedup']:5.2f}x  "
                      f"host-bytes {row['legacy']['interphase_host_bytes']}"
                      " -> 0")
    default_size = DEFAULT_SIZES[0] if not smoke else SMOKE_SIZES[0]
    default_r = max(rs)
    report = {
        "mesh": {"shape": MESH_SHAPE, "axes": ["rack", "server"],
                 "backend": jax.default_backend()},
        "iters": iters,
        "results": rows,
        "default_point": {"N": default_size[0], "Q": default_size[1],
                          "d": default_size[2], "r": default_r},
        "default_size_speedup": next(
            (x["speedup"] for x in rows
             if (x["N"], x["Q"], x["d"]) == default_size
             and x["r"] == default_r), None),
    }
    return report


def main() -> None:
    args = make_parser(__doc__, "BENCH_pipeline.json").parse_args()
    report = run(smoke=args.smoke, iters=2 if args.smoke else args.iters,
                 seed=args.seed)
    emit_report(report, "pipeline", args.out, smoke=args.smoke,
                seed=args.seed)


if __name__ == "__main__":
    main()
