"""Benchmark aggregator: one section per paper table + the systems benches.

Sections print their own summaries; the ``table1``/``table2``/``scale``
sections run their full bench CLIs with default args, REWRITING the
corresponding committed ``BENCH_*.json`` artifacts in the repo root (that
is how the artifacts are regenerated — expect a dirty git tree afterwards).
``shuffle``/``roofline`` print ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--section table1|table2|shuffle|
                                              roofline|scale|faults|all]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (blame_bench, calibration_bench, faults_bench, obs_bench,
               roofline_report, scale_bench, shuffle_bench, table1_costs,
               table2_locality)


def _obs_report() -> None:
    from repro.obs.report import main as report_main
    report_main([])


SECTIONS = {
    "table1": table1_costs.main,
    "table2": table2_locality.main,
    "shuffle": shuffle_bench.main,
    "roofline": roofline_report.main,
    "scale": scale_bench.main,
    "faults": faults_bench.main,
    "obs": obs_bench.main,
    "blame": blame_bench.main,
    "calibration": calibration_bench.main,
    "report": _obs_report,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--section", default="all",
                    choices=["all"] + sorted(SECTIONS))
    args = ap.parse_args()
    names = sorted(SECTIONS) if args.section == "all" else [args.section]
    failed = []
    for name in names:
        print(f"# --- {name} ---", flush=True)
        argv = sys.argv
        sys.argv = [f"benchmarks/{name}"]   # sections parse their own CLI;
        try:                                # keep --section out of their argv
            SECTIONS[name]()
        except Exception:                                    # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
        finally:
            sys.argv = argv
    if failed:
        sys.exit(f"benchmark sections failed: {failed}")


if __name__ == "__main__":
    main()
