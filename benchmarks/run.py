"""Benchmark aggregator: one section per paper table + the systems benches.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--section table1|table2|shuffle|
                                                      roofline|all]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import roofline_report, shuffle_bench, table1_costs, table2_locality

SECTIONS = {
    "table1": table1_costs.main,
    "table2": table2_locality.main,
    "shuffle": shuffle_bench.main,
    "roofline": roofline_report.main,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--section", default="all",
                    choices=["all"] + sorted(SECTIONS))
    args = ap.parse_args()
    names = sorted(SECTIONS) if args.section == "all" else [args.section]
    failed = []
    for name in names:
        print(f"# --- {name} ---", flush=True)
        try:
            SECTIONS[name]()
        except Exception:                                    # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
    if failed:
        sys.exit(f"benchmark sections failed: {failed}")


if __name__ == "__main__":
    main()
