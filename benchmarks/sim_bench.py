"""Cluster-simulator benchmark: Table I as the zero-contention special case,
plus the scenario sweeps the closed forms cannot answer.

Sections (all seeded -> deterministic; results land in ``BENCH_sim.json``):

  * ``table1_zero_contention`` — for every (K, P, Q, N, r) row of paper
    Table I and every scheme, the simulated single-job JCT with zero compute
    cost must equal ``CommCost.weighted_time(intra_bw, cross_bw)`` to float
    tolerance (HARD assertion — the simulator's network model is anchored to
    the paper's cost metric before any scenario is trusted).
  * ``straggler_r_tradeoff`` — single-job JCT vs (r, exponential-tail scale):
    map replication r buys shuffle savings but multiplies straggler
    exposure; the sweep exhibits the optimal-r shift.
  * ``stragglers`` / ``bandwidth_skew`` / ``offered_load`` — multi-job
    scenario sweeps comparing the ONLINE adaptive scheduler (per-job
    (scheme, r) by minimum estimated JCT under current load) against
    fixed-scheme baselines on mean and p99 JCT.  The bench asserts the
    adaptive scheduler beats the fixed Coded-MapReduce baseline on BOTH
    aggregates in EVERY sweep (CI fails loudly on a scheduling regression).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:
    from ._common import emit_report, make_parser
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser

from repro.core.coded_collectives import plan_cache_clear
from repro.core.costs import coded_cost, hybrid_cost, uncoded_cost
from repro.core.params import SchemeParams, TABLE1_GRID
from repro.sim import (ClusterSim, CostModel, ExponentialTail, JobSpec,
                       NoStragglers, PhaseCoeffs, PoissonWorkload,
                       RackTopology, SchemeChooser, default_catalog,
                       run_scheduled, simulate_single_job)

# Paper Table I rows (K, P, Q, N, r) — including the three rows whose hybrid
# column violates the divisibility hypothesis C(P,r) | (NP/K); the closed
# forms (and hence the simulator's traffic model) evaluate them with
# check=False, exactly as the paper implicitly did.
TABLE1_ROWS: List[Tuple[int, int, int, int, int]] = list(TABLE1_GRID)

COST_FNS = {"uncoded": uncoded_cost, "coded": coded_cost,
            "hybrid": hybrid_cost}

# ---- default scenario cluster ---------------------------------------------

K, P = 8, 4
INTRA_BW = 1e7                      # value-units/s, aggregate intra tier
CROSS_BW = 1e6                      # root switch (10x slower: server-rack)
FIXED_BASELINES = [("coded", 2), ("hybrid", 2), ("uncoded", 1)]

# Plausible host-calibrated compute costs (seconds = alpha + beta * work);
# replace via --calibrate-from BENCH_pipeline.json for measured constants.
DEFAULT_COST = CostModel(
    map=PhaseCoeffs(alpha=2e-3, beta=5e-9),
    pack=PhaseCoeffs(alpha=5e-4, beta=2e-9),
    reduce=PhaseCoeffs(alpha=1e-3, beta=5e-9),
    plan_compile=PhaseCoeffs(alpha=5e-3, beta=1e-6),
)


# ---------------------------------------------------------------------------
# Section 1: Table I == zero-contention simulation (hard anchor)
# ---------------------------------------------------------------------------

def table1_zero_contention(intra_bw: float = 10.0,
                           cross_bw: float = 1.0) -> List[Dict]:
    rows = []
    for (k, p_, q, n, r) in TABLE1_ROWS:
        topo = RackTopology(P=p_, cross_bw=cross_bw, intra_bw=intra_bw)
        params = SchemeParams(k, p_, q, n, r)
        for scheme, fn in COST_FNS.items():
            want = fn(params, check=False).weighted_time(intra_bw, cross_bw)
            got = simulate_single_job(JobSpec("histogram", n, q, 1),
                                      topo, k, scheme, r, check=False).jct
            rel = abs(got - want) / max(abs(want), 1e-12)
            assert rel < 1e-9, (
                f"sim JCT diverged from weighted_time: {scheme} "
                f"{(k, p_, q, n, r)}: {got} vs {want}")
            rows.append({"params": [k, p_, q, n, r], "scheme": scheme,
                         "sim_jct": got, "weighted_time": want,
                         "rel_err": rel, "match": True})
    return rows


# ---------------------------------------------------------------------------
# Section 2: straggler tail vs replication r (single-job tradeoff curve)
# ---------------------------------------------------------------------------

def straggler_r_tradeoff(scales: Sequence[float], n_seeds: int,
                         cost: CostModel) -> List[Dict]:
    topo = RackTopology(P=P, cross_bw=CROSS_BW, intra_bw=INTRA_BW)
    spec = JobSpec("wide_histogram_d16", 336, 16, 16)
    rows = []
    for scale in scales:
        for r in (1, 2, 3):
            jcts = []
            for seed in range(n_seeds):
                model = ExponentialTail(scale) if scale else NoStragglers()
                jcts.append(simulate_single_job(
                    spec, topo, K, "hybrid", r, cost_model=cost,
                    stragglers=model, seed=seed).jct)
            rows.append({"tail_scale": scale, "r": r,
                         "mean_jct": float(np.mean(jcts)),
                         "p99_jct": float(np.percentile(jcts, 99))})
    return rows


# ---------------------------------------------------------------------------
# Sections 3-5: multi-job scenario sweeps, adaptive vs fixed baselines
# ---------------------------------------------------------------------------

def _stream(jobs: List[JobSpec], topo: RackTopology, cost: CostModel,
            stragglers, seed: int, policy: str, max_concurrent: int,
            adaptive: bool, fixed: Tuple[str, int] = ("coded", 2),
            expected_straggler: float = 1.0) -> Dict:
    # fresh plan cache per stream: compile charges land identically whatever
    # order the streams run in (adaptive vs fixed, sweep point vs sweep
    # point), so every row is reproducible in isolation
    plan_cache_clear()
    cluster = ClusterSim(topo, K, cost, stragglers, seed)
    chooser = SchemeChooser(K, cost_model=cost, adaptive=adaptive,
                            fixed=fixed,
                            expected_straggler=expected_straggler)
    stats, sched = run_scheduled(jobs, cluster, chooser, policy=policy,
                                 max_concurrent=max_concurrent)
    jcts = np.asarray([s.jct for s in stats])
    picks: Dict[str, int] = {}
    for s in stats:
        d = sched.decisions[s.job_id]
        picks[f"{d.scheme}:r{d.r}"] = picks.get(f"{d.scheme}:r{d.r}", 0) + 1
    return {"mean_jct": float(jcts.mean()),
            "p99_jct": float(np.percentile(jcts, 99)),
            "n_jobs": len(jcts), "decisions": picks}


def _sweep_point(jobs, topo, cost, stragglers, seed,
                 expected_straggler: float = 1.0,
                 policy: str = "fifo", max_concurrent: int = 4) -> Dict:
    out = {"adaptive": _stream(jobs, topo, cost, stragglers, seed, policy,
                               max_concurrent, adaptive=True,
                               expected_straggler=expected_straggler)}
    for scheme, r in FIXED_BASELINES:
        out[f"fixed_{scheme}_r{r}"] = _stream(
            jobs, topo, cost, stragglers, seed, policy, max_concurrent,
            adaptive=False, fixed=(scheme, r))
    return out


def straggler_sweep(scales: Sequence[float], n_jobs: int, seed: int,
                    cost: CostModel) -> List[Dict]:
    catalog = default_catalog(K, P)
    topo = RackTopology(P=P, cross_bw=CROSS_BW, intra_bw=INTRA_BW)
    rows = []
    for scale in scales:
        jobs = PoissonWorkload(catalog, n_jobs, rate=4.0).generate(seed)
        stragglers = ExponentialTail(scale) if scale else NoStragglers()
        row = _sweep_point(jobs, topo, cost, stragglers, seed,
                           expected_straggler=1.0 + scale)
        row["tail_scale"] = scale
        rows.append(row)
    return rows


def bandwidth_skew_sweep(ratios: Sequence[float], n_jobs: int, seed: int,
                         cost: CostModel) -> List[Dict]:
    """Sweep the cross/intra bandwidth ratio: rho = cross_bw / intra_bw.
    Low rho is the paper's server-rack regime (hybrid territory); rho -> 1
    makes the root as fast as the ToRs (coded/uncoded territory)."""
    catalog = default_catalog(K, P)
    rows = []
    for rho in ratios:
        topo = RackTopology(P=P, cross_bw=INTRA_BW * rho, intra_bw=INTRA_BW)
        jobs = PoissonWorkload(catalog, n_jobs, rate=4.0).generate(seed)
        row = _sweep_point(jobs, topo, cost, NoStragglers(), seed)
        row["cross_over_intra_bw"] = rho
        rows.append(row)
    return rows


def offered_load_sweep(rates: Sequence[float], n_jobs: int, seed: int,
                       cost: CostModel) -> List[Dict]:
    catalog = default_catalog(K, P)
    topo = RackTopology(P=P, cross_bw=CROSS_BW, intra_bw=INTRA_BW)
    rows = []
    for rate in rates:
        jobs = PoissonWorkload(catalog, n_jobs, rate=rate).generate(seed)
        row = _sweep_point(jobs, topo, cost, NoStragglers(), seed)
        row["arrival_rate"] = rate
        rows.append(row)
    return rows


def _beats_fixed(rows: List[Dict], baseline: str = "fixed_coded_r2") -> bool:
    """Adaptive must not lose on mean or p99 at ANY sweep point, and must
    strictly win both aggregated over the sweep."""
    tol = 1.0 + 1e-9
    mean_a = [r["adaptive"]["mean_jct"] for r in rows]
    mean_b = [r[baseline]["mean_jct"] for r in rows]
    p99_a = [r["adaptive"]["p99_jct"] for r in rows]
    p99_b = [r[baseline]["p99_jct"] for r in rows]
    pointwise = all(a <= b * tol for a, b in zip(mean_a, mean_b)) and \
        all(a <= b * tol for a, b in zip(p99_a, p99_b))
    return pointwise and sum(mean_a) < sum(mean_b) and \
        sum(p99_a) < sum(p99_b)


# ---------------------------------------------------------------------------

def _load_calibrated(path: Optional[str]) -> CostModel:
    if not path:
        return DEFAULT_COST
    import json
    from repro.sim import calibrate, measurements_from_pipeline_bench
    with open(path) as f:
        report = json.load(f)
    return calibrate(measurements_from_pipeline_bench(report))


def run(smoke: bool = False, seed: int = 0,
        calibrate_from: Optional[str] = None,
        verbose: bool = True, iters: int = 20) -> Dict:
    """``iters`` = independent straggler draws per straggler_r_tradeoff
    point (the only repeated-measurement section; everything else is a
    deterministic function of ``seed``)."""
    cost = _load_calibrated(calibrate_from)
    n_jobs = 40 if smoke else 100
    scales = (0.0, 1.0) if smoke else (0.0, 0.5, 1.5)
    ratios = (0.05, 1.0) if smoke else (0.02, 0.1, 0.5, 1.0)
    rates = (1.0, 8.0) if smoke else (0.5, 2.0, 8.0)

    table1 = table1_zero_contention()
    scenarios = {
        "straggler_r_tradeoff": straggler_r_tradeoff(
            scales, n_seeds=5 if smoke else iters, cost=cost),
        "stragglers": straggler_sweep(scales, n_jobs, seed, cost),
        "bandwidth_skew": bandwidth_skew_sweep(ratios, n_jobs, seed, cost),
        "offered_load": offered_load_sweep(rates, n_jobs, seed, cost),
    }
    beats = {name: _beats_fixed(scenarios[name])
             for name in ("stragglers", "bandwidth_skew", "offered_load")}
    if verbose:
        print(f"table1 zero-contention: {len(table1)} cells, all matched")
        for name, rows in scenarios.items():
            if name == "straggler_r_tradeoff":
                continue
            for row in rows:
                knob = {k: v for k, v in row.items()
                        if not isinstance(v, dict)}
                a, b = row["adaptive"], row["fixed_coded_r2"]
                print(f"{name} {knob}: adaptive mean {a['mean_jct']:.4f} "
                      f"p99 {a['p99_jct']:.4f} | fixed-coded mean "
                      f"{b['mean_jct']:.4f} p99 {b['p99_jct']:.4f} | "
                      f"picks {a['decisions']}")
        print(f"scheduler beats fixed-coded baseline: {beats}")
    if not all(beats.values()):
        raise RuntimeError(
            f"adaptive scheduler lost to the fixed baseline: {beats}")
    return {
        "cluster": {"K": K, "P": P, "intra_bw": INTRA_BW,
                    "cross_bw": CROSS_BW},
        "cost_model_calibrated_from": calibrate_from,
        "table1_zero_contention": {"rows": table1, "all_match": True},
        "scenarios": scenarios,
        "scheduler_beats_fixed_coded": beats,
    }


def main() -> None:
    ap = make_parser(__doc__, "BENCH_sim.json", default_iters=20)
    ap.add_argument("--calibrate-from", default=None, metavar="BENCH_JSON",
                    help="fit the compute cost model from a "
                         "BENCH_pipeline.json instead of the defaults")
    args = ap.parse_args()
    report = run(smoke=args.smoke, seed=args.seed,
                 calibrate_from=args.calibrate_from, iters=args.iters)
    emit_report(report, "sim", args.out, smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
