"""Observability benchmark: byte-accounting reconciliation, trace exports,
trace determinism, and instrumentation overhead.

Sections (results land in ``BENCH_obs.json``):

  * ``reconciliation`` — for EVERY paper Table I row x both plan families
    (binomial -> ``hybrid``, resolvable -> ``hybrid_resolvable``), a seeded
    single-job sim run's recorded ``JobStats.intra/cross_rack_bytes`` must
    reconcile with the closed-form :class:`repro.core.costs.CommCost`
    (``check=False`` evaluates the rows whose divisibility hypotheses the
    construction does not meet — the formulas still price them, exactly as
    the paper's table does).  Where the family actually compiles an
    executable plan, the plan-derived transfer matrices
    (:func:`repro.obs.bytes.plan_rack_bytes`) are reconciled too — a HARD
    assertion tying measured bytes to the compiled schedule.
  * ``traces`` — a seeded sim run and an 8-host-device engine run both
    export Chrome/Perfetto ``trace_event`` documents (written under
    ``bench_out/``, git-ignored) which must pass
    :func:`repro.obs.tracing.validate_chrome_trace`; the sim export is run
    twice and its sha256 must match (bit-identical trace artifact per seed).
  * ``overhead`` — the fused 8-device smoke pipeline timed with the global
    tracer disabled vs enabled: overhead must stay below 5 % (or below 1 ms
    absolute, whichever is looser — the pipeline is sub-millisecond-noisy
    on shared CI runners).
"""
from __future__ import annotations

import hashlib
import json
import os
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np                                             # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

try:                                                           # noqa: E402
    from ._common import emit_report, make_parser, repo_root, seeded_rng
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser, repo_root, seeded_rng

from repro.core.coded_collectives import compile_hybrid_plan   # noqa: E402
from repro.core.params import SchemeParams, TABLE1_GRID        # noqa: E402
from repro.core.plan_registry import (plan_families,           # noqa: E402
                                      scheme_of_family)
from repro.distributed.meshes import make_mesh                 # noqa: E402
from repro.mapreduce.engine import run_job_distributed         # noqa: E402
from repro.mapreduce.jobs import wide_histogram_job            # noqa: E402
from repro.obs import metrics                                  # noqa: E402
from repro.obs.bytes import (closed_form_bytes,                # noqa: E402
                             plan_rack_bytes, reconcile)
from repro.obs.tracing import (enable_tracing, get_tracer,     # noqa: E402
                               to_chrome_trace,
                               validate_chrome_trace)
from repro.sim import (ClusterSim, CostModel, JobSpec,         # noqa: E402
                       PhaseCoeffs, RackTopology,
                       simulate_single_job)

MESH_SHAPE = (4, 2)                  # P=4 racks x Kr=2 servers = 8 devices
SUBFILE_TOKENS = 128
PLAN_COMPILE_N_MAX = 2048            # skip plan enumeration above this N
OVERHEAD_BOUND = 0.05
OVERHEAD_ABS_FLOOR = 1e-3            # seconds; timer noise on tiny pipelines


# ---------------------------------------------------------------------------
# Section 1: reconciliation grid (Table I rows x plan families)
# ---------------------------------------------------------------------------

def reconciliation_grid(d: int, seed: int, smoke: bool) -> list:
    grid = TABLE1_GRID[:2] if smoke else TABLE1_GRID
    rows = []
    for (K, P, Q, N, r) in grid:
        p = SchemeParams(K=K, P=P, Q=Q, N=N, r=r)
        for family in plan_families():
            scheme = scheme_of_family(family)
            closed = closed_form_bytes(p, scheme, d=d, check=False)
            spec = JobSpec("recon", Q, N, d)
            stats = simulate_single_job(spec, RackTopology(P=P), K, scheme,
                                        r, seed=seed, check=False)
            reconcile(stats.intra_rack_bytes, stats.cross_rack_bytes,
                      p, scheme, d=d, check=False)      # raises on mismatch
            plan_checked = False
            if N <= PLAN_COMPILE_N_MAX:
                try:
                    plan = compile_hybrid_plan(p, family=family)
                except (ValueError, AssertionError):
                    plan = None          # row violates the family's
                if plan is not None:     # divisibility hypotheses
                    rb = plan_rack_bytes(plan, "coded", d=d)
                    reconcile(rb.intra_total, rb.cross_total, p, scheme, d=d)
                    plan_checked = True
            rows.append({
                "K": K, "P": P, "Q": Q, "N": N, "r": r,
                "family": family, "scheme": scheme,
                "closed_intra": closed["intra"],
                "closed_cross": closed["cross"],
                "sim_intra": stats.intra_rack_bytes,
                "sim_cross": stats.cross_rack_bytes,
                "reconciled": True,          # reconcile() raised otherwise
                "plan_checked": plan_checked,
            })
            if not plan_checked:
                print(f"  [reconciliation] ({K},{P},{Q},{N},{r}) {family}: "
                      f"closed-form + sim only (no executable plan"
                      f"{' at this size' if N > PLAN_COMPILE_N_MAX else ''})")
    return rows


# ---------------------------------------------------------------------------
# Section 2: trace exports + determinism
# ---------------------------------------------------------------------------

def _sim_trace_doc(seed: int) -> dict:
    topo = RackTopology(P=3, cross_bw=1e3, intra_bw=1e4)
    sim = ClusterSim(topo, K=9, cost_model=CostModel(
        map=PhaseCoeffs(1e-3, 1e-8)), seed=seed)
    sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2, time=0.0)
    sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2, time=0.05)
    sim.run()
    return to_chrome_trace(sim.tracer.events)


def trace_exports(seed: int, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)

    # -- sim: deterministic per seed, exported twice, hashes must match ----
    doc1 = _sim_trace_doc(seed)
    doc2 = _sim_trace_doc(seed)
    blob1 = json.dumps(doc1, sort_keys=True).encode()
    blob2 = json.dumps(doc2, sort_keys=True).encode()
    sha1 = hashlib.sha256(blob1).hexdigest()
    assert sha1 == hashlib.sha256(blob2).hexdigest(), \
        "sim trace export not bit-identical across reruns"
    sim_path = os.path.join(out_dir, "sim_trace.json")
    with open(sim_path, "wb") as f:
        f.write(blob1)
    n_sim = validate_chrome_trace(doc1)

    # -- engine: 8 host devices, host-side spans via the global tracer -----
    mesh = make_mesh(MESH_SHAPE, ("rack", "server"))
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    job = wide_histogram_job(2)
    subs = seeded_rng(seed).integers(
        0, 1 << 16, size=(p.N, SUBFILE_TOKENS)).astype(np.int32)
    tracer = enable_tracing(True)
    try:
        run_job_distributed(job, subs, p, mesh, fused=True)
    finally:
        enable_tracing(False)
    eng_doc = to_chrome_trace(tracer.events)
    eng_path = os.path.join(out_dir, "engine_trace.json")
    with open(eng_path, "w") as f:
        json.dump(eng_doc, f, sort_keys=True)
    n_eng = validate_chrome_trace(eng_doc)
    assert n_eng >= 1, "engine run produced no spans"

    print(f"  [traces] sim: {n_sim} events -> {sim_path} (sha {sha1[:12]})")
    print(f"  [traces] engine: {n_eng} events -> {eng_path}")
    return {"sim_events": n_sim, "sim_sha256": sha1,
            "engine_events": n_eng,
            "sim_trace_path": os.path.relpath(sim_path, repo_root()),
            "engine_trace_path": os.path.relpath(eng_path, repo_root())}


# ---------------------------------------------------------------------------
# Section 3: instrumentation overhead on the smoke pipeline
# ---------------------------------------------------------------------------

def overhead(iters: int, seed: int) -> dict:
    mesh = make_mesh(MESH_SHAPE, ("rack", "server"))
    p = SchemeParams(K=8, P=4, Q=16, N=48, r=2)
    job = wide_histogram_job(2)
    subs = seeded_rng(seed).integers(
        0, 1 << 16, size=(p.N, SUBFILE_TOKENS)).astype(np.int32)

    def run_once():
        res = run_job_distributed(job, subs, p, mesh, fused=True)
        jnp.asarray(res.outputs).block_until_ready()

    def timed(n):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            run_once()
            best = min(best, time.perf_counter() - t0)
        return best

    run_once()                                   # compile once, warm
    enable_tracing(False)
    t_off = timed(iters)
    enable_tracing(True)
    try:
        t_on = timed(iters)
    finally:
        enable_tracing(False)
    frac = (t_on - t_off) / t_off if t_off > 0 else 0.0
    ok = frac < OVERHEAD_BOUND or (t_on - t_off) < OVERHEAD_ABS_FLOOR
    assert ok, (f"tracing overhead {frac:.1%} exceeds "
                f"{OVERHEAD_BOUND:.0%} ({t_off:.6f}s -> {t_on:.6f}s)")
    print(f"  [overhead] off={t_off * 1e3:.3f}ms on={t_on * 1e3:.3f}ms "
          f"({frac:+.2%})")
    return {"t_off_s": t_off, "t_on_s": t_on, "overhead_frac": frac,
            "bound": OVERHEAD_BOUND, "iters": iters}


def main() -> None:
    ap = make_parser(__doc__.splitlines()[0], "BENCH_obs.json",
                     default_iters=8)
    ap.add_argument("--payload-width", type=int, default=2,
                    help="value payload width d for the reconciliation grid")
    args = ap.parse_args()
    metrics.reset()

    print("# reconciliation: Table I rows x plan families")
    recon = reconciliation_grid(args.payload_width, args.seed, args.smoke)
    n_plan = sum(r["plan_checked"] for r in recon)
    print(f"  {len(recon)} grid points reconciled "
          f"({n_plan} with compiled-plan matrices)")

    print("# trace exports")
    traces = trace_exports(args.seed, os.path.join(repo_root(), "bench_out"))

    print("# instrumentation overhead")
    iters = 3 if args.smoke else args.iters
    ovh = overhead(iters, args.seed)

    # the registry itself saw all of the above — pin its metric names
    metric_names = metrics.registry().names()
    emit_report({"payload_width": args.payload_width,
                 "reconciliation": recon, "traces": traces,
                 "overhead": ovh, "metric_names": metric_names},
                bench="obs", out_path=args.out, smoke=args.smoke,
                seed=args.seed)


if __name__ == "__main__":
    main()
