"""Scheme-family scaling benchmark: how far K stretches per compiler family.

The binomial hybrid construction needs ``C(P, r) | NP/K`` — at a fixed,
realistic shard count (N a power of two) the binomial coefficient must
itself be a power of two, which pins P to tiny values.  The resolvable
family only needs ``q^{r-1} | NP/K`` with q = P/r, so at the SAME N and the
SAME multicast gain the feasible cluster is an order of magnitude wider.
This bench measures that wall, and certifies the resolvable family is not
just feasible but correct and affordable at scale:

  * ``max_k``    — max feasible K per family at equal multicast gain g and
                   fixed N (asserts resolvable/binomial >= 10x),
  * ``compile``  — plan-compile wall clock vs K on the resolvable ladder,
  * ``oracle``   — NumPy shuffle re-execution parity at the largest
                   resolvable K (asserts bit-exact),
  * ``chooser``  — a simulated job where every binomial r is inadmissible:
                   the adaptive chooser must select hybrid_resolvable and
                   the scheduled run must complete.

  PYTHONPATH=src python benchmarks/scale_bench.py [--smoke]  ->
      BENCH_scale.json
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.coded_collectives import (compile_hybrid_plan,
                                          plan_cache_clear,
                                          plan_shuffle_reference,
                                          simulate_plan_shuffle)
from repro.core.params import SchemeParams

try:                                    # run as module or as a script
    from ._common import emit_report, make_parser
except ImportError:                     # pragma: no cover
    from _common import emit_report, make_parser

GAIN = 2          # multicast gain compared at: binomial r=2, resolvable r=3
KR = 2            # servers per rack (fixed, Table I's dense-rack setting)


def _feasible(family: str, K: int, N: int) -> Optional[SchemeParams]:
    """Params at (K, N) with multicast gain GAIN under ``family``, or None."""
    if K % KR:
        return None
    P = K // KR
    r = GAIN if family == "binomial" else GAIN + 1
    if r > P or (N * P) % K:
        return None
    try:
        p = SchemeParams(K=K, P=P, Q=K, N=N, r=r)
        if family == "binomial":
            p.validate_hybrid()
        else:
            p.validate_hybrid_resolvable()
    except ValueError:
        return None
    return p


def max_feasible_k(family: str, N: int, k_cap: int) -> Dict:
    """Largest feasible K <= k_cap at gain GAIN and fixed N, plus the
    divisor the family demands of the per-layer subfile count."""
    best = None
    for K in range(2 * KR, k_cap + 1, KR):
        p = _feasible(family, K, N)
        if p is not None:
            best = p
    if best is None:
        return {"family": family, "max_k": 0}
    div = (math.comb(best.P, best.r) if family == "binomial"
           else best.spc_q ** (best.r - 1))
    return {"family": family, "max_k": best.K, "P": best.P, "r": best.r,
            "subpacketization_divisor": div}


def resolvable_ladder(N: int, k_cap: int) -> List[SchemeParams]:
    """Every feasible resolvable K <= k_cap at gain GAIN and fixed N."""
    out = []
    for K in range(2 * KR, k_cap + 1, KR):
        p = _feasible("resolvable", K, N)
        if p is not None:
            out.append(p)
    return out


def time_compile(p: SchemeParams, family: str, iters: int) -> float:
    """Best-of-iters cold-compile seconds (cache cleared each rep)."""
    best = float("inf")
    for _ in range(iters):
        plan_cache_clear()
        t0 = time.perf_counter()
        compile_hybrid_plan(p, family=family)
        best = min(best, time.perf_counter() - t0)
    return best


def oracle_check(p: SchemeParams, seed: int) -> Dict:
    """Re-execute the resolvable plan in NumPy against the dense reference
    — the end-to-end decodability proof at the largest K."""
    plan = compile_hybrid_plan(p, family="resolvable")
    rng = np.random.default_rng(seed)
    V = rng.integers(-100, 100, size=(p.N, p.Q, 1)).astype(np.float32)
    ref = plan_shuffle_reference(V, p, family="resolvable")
    ok = True
    for mc in ("unicast", "coded"):
        got = simulate_plan_shuffle(V, plan, multicast=mc)
        ok = ok and bool((got == ref).all())
    assert ok, f"oracle mismatch at K={p.K}"
    return {"K": p.K, "P": p.P, "r": p.r, "N": p.N, "pass": ok}


def chooser_section() -> Dict:
    """N=32 at (K, P)=(12, 6): every binomial r (and uncoded/coded) is
    inadmissible, resolvable r=3 is — the chooser must find it."""
    from repro.sim.cluster import ClusterSim, CostModel
    from repro.sim.network import RackTopology
    from repro.sim.scheduler import SchemeChooser, run_scheduled
    from repro.sim.workload import JobSpec

    K, P = 12, 6
    spec = JobSpec("histogram", N=32, Q=24, d=1)
    topo = RackTopology(P=P, cross_bw=1e5, intra_bw=1e6)
    cluster = ClusterSim(topo, K=K, cost_model=CostModel())
    chooser = SchemeChooser(K, cost_model=cluster.cost_model, rs=(1, 2, 3))
    d = chooser.choose(spec, cluster)
    assert d.scheme == "hybrid_resolvable", d
    stats, sched = run_scheduled([spec], cluster, chooser)
    return {"K": K, "P": P, "N": spec.N, "chosen_scheme": d.scheme,
            "chosen_r": d.r, "jct_s": stats[0].jct}


def main() -> None:
    ap = make_parser(__doc__.splitlines()[0], "BENCH_scale.json",
                     default_iters=3)
    args = ap.parse_args()
    N = 2048 if args.smoke else 8192
    k_cap = 128 if args.smoke else 512
    iters = 1 if args.smoke else args.iters

    rows = [max_feasible_k(f, N, k_cap) for f in ("binomial", "resolvable")]
    k_bin = rows[0]["max_k"]
    k_res = rows[1]["max_k"]
    ratio = k_res / max(k_bin, 1)
    print(f"N={N} gain={GAIN}: binomial max K={k_bin}, "
          f"resolvable max K={k_res}  ({ratio:.0f}x)")
    assert ratio >= 10.0, (
        f"resolvable must stretch K >= 10x past binomial; got {ratio:.1f}x")

    ladder = resolvable_ladder(N, k_cap)
    compile_rows = []
    for p in ladder:
        secs = time_compile(p, "resolvable", iters)
        compile_rows.append({"K": p.K, "P": p.P, "q": p.spc_q,
                             "compile_s": secs})
        print(f"  resolvable K={p.K:4d} (q={p.spc_q:3d}): "
              f"compile {secs * 1e3:8.1f} ms")
    p_bin = _feasible("binomial", k_bin, N)
    bin_secs = time_compile(p_bin, "binomial", iters)
    print(f"  binomial   K={k_bin:4d} (wall):  compile {bin_secs * 1e3:8.1f}"
          f" ms")

    oracle = oracle_check(ladder[-1], args.seed)
    print(f"  oracle: K={oracle['K']} bit-exact={oracle['pass']}")
    plan_cache_clear()
    chooser = chooser_section()
    print(f"  chooser: picked {chooser['chosen_scheme']} r="
          f"{chooser['chosen_r']} (jct {chooser['jct_s']:.3f}s)")

    emit_report({
        "N": N, "gain": GAIN, "Kr": KR, "k_cap": k_cap,
        "max_k": {r["family"]: r for r in rows},
        "k_ratio": ratio,
        "compile_wall_clock": compile_rows,
        "binomial_compile_s": bin_secs,
        "oracle": oracle,
        "chooser": chooser,
    }, bench="scale", out_path=args.out, smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
