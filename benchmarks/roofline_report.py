"""Roofline report: aggregates the dry-run cell JSONs into the
EXPERIMENTS.md tables (per (arch x shape x mesh): the three terms, the
dominant bottleneck, MODEL_FLOPS ratio, memory plan, fit verdicts)."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun")


def load_cells(results_dir: str = RESULTS_DIR) -> List[Dict]:
    cells = []
    for mesh in sorted(os.listdir(results_dir)) \
            if os.path.isdir(results_dir) else []:
        d = os.path.join(results_dir, mesh)
        if not os.path.isdir(d):
            continue
        for f in sorted(os.listdir(d)):
            if f.endswith(".json"):
                with open(os.path.join(d, f)) as fh:
                    cells.append(json.load(fh))
    return cells


def fmt_row(c: Dict) -> str:
    a, s, m = c["arch"], c["shape"], c["mesh"]
    if not c.get("runnable", True):
        return f"| {a} | {s} | {m} | — | — | — | — | SKIP (sub-quadratic n/a) |"
    if not c.get("ok"):
        return f"| {a} | {s} | {m} | — | — | — | — | FAIL: {c.get('error','')[:60]} |"
    r = c["roofline"]
    mp = c.get("memory_plan", {})
    fit = "fits" if mp.get("fits_16gib") else "OVER"
    return (f"| {a} | {s} | {m} | {r['t_compute']:.3g} | {r['t_memory']:.3g}"
            f" | {r['t_collective']:.3g} | **{r['dominant']}** "
            f"{r['roofline_fraction']:.3f} | {mp.get('total_gib', 0):.1f}GiB"
            f" {fit}; useful={c.get('useful_flops_ratio', 0):.2f} |")


def markdown_table(cells: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | t_comp(s) | t_mem(s) | t_coll(s) | "
           "dominant / roofline-frac | memory plan |\n"
           "|---|---|---|---|---|---|---|---|")
    return "\n".join([hdr] + [fmt_row(c) for c in cells])


def pick_hillclimb_cells(cells: List[Dict]) -> Dict[str, Optional[Dict]]:
    ok = [c for c in cells if c.get("ok") and c.get("runnable", True)
          and c["mesh"] == "single"]
    if not ok:
        return {}
    worst = min(ok, key=lambda c: c["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda c: c["roofline"]["t_collective"]
               / max(c["roofline"]["t_bound"], 1e-12))
    # most representative of the paper: the biggest TRAIN cell (DP gradient
    # shuffle across pods is the paper's mechanism)
    train = [c for c in ok if c["shape"] == "train_4k"]
    rep = max(train, key=lambda c: c["roofline"]["t_collective"]) \
        if train else None
    return {"worst_fraction": worst, "most_collective": coll,
            "paper_representative": rep}


def run(verbose: bool = True) -> List[Dict]:
    cells = load_cells()
    if verbose:
        print(markdown_table(cells))
        picks = pick_hillclimb_cells(cells)
        for k, c in picks.items():
            if c:
                print(f"\nhillclimb[{k}]: {c['arch']} x {c['shape']} "
                      f"(frac {c['roofline']['roofline_fraction']:.3f})")
    return cells


def main() -> None:
    cells = load_cells()
    for c in cells:
        if c.get("ok"):
            print(f"roofline_{c['mesh']}_{c['arch']}_{c['shape']},"
                  f"{c.get('elapsed_s', 0) * 1e6:.0f},"
                  f"dom={c['roofline']['dominant']}:"
                  f"frac={c['roofline']['roofline_fraction']:.3f}")
        else:
            print(f"roofline_{c['mesh']}_{c['arch']}_{c['shape']},0,"
                  f"{'skip' if not c.get('runnable', True) else 'fail'}")


if __name__ == "__main__":
    run()
