"""Failure-tolerance benchmark -> ``BENCH_faults.json``.

Three sections, each backing one acceptance claim with HARD assertions
(the bench fails loudly instead of emitting a wrong artifact):

  * ``decode_around_grid`` — degraded-plan compilation over both plan
    families x the r grid x single/pair failures at the canonical
    K=8, P=4, Q=16, N=48 instance.  Asserts the erasure-code reading of
    replication: f <= r-1 failures per layer-group re-map ZERO subfiles at
    r >= 2, while r = 1 re-runs the dead servers' map partitions
    (f * N/K subfiles).  Also records degraded cross-traffic inflation and
    the bounded side-cache counters (hits/misses/evictions).
  * ``engine_recovery`` — the REAL 8-device recovery ladder, run in a
    subprocess (needs a forced host-device count): for both families, a
    mid-shuffle crash recovers to BIT-IDENTICAL outputs vs the
    failure-free run, through the correct rung (decode-around / partial
    re-map / bounded restart).
  * ``sim_faults`` — seeded crash injection through the cluster sim:
    identical seeds produce bit-identical event traces, a mid-shuffle
    crash cancels every in-flight flow of the job (no orphans in the
    fluid network), r=1 pays a re-map phase where r>=2 does not, and the
    chooser's ``crash_prob`` availability term flips an expensive-map
    config from r=1 to a replicated scheme.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

try:
    from ._common import emit_report, make_parser
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser

CANON = dict(K=8, P=4, Q=16, N=48)
FAMILY_GRID = [("binomial", 1), ("binomial", 2), ("binomial", 3),
               ("resolvable", 2)]
FAILURES = [(3,), (0, 5), (0, 2)]

_DRIVER_MARK = "FAULTS_DRIVER_JSON:"


# ---------------------------------------------------------------------------
# Section 1: degraded-plan grid (host-side, no devices needed)
# ---------------------------------------------------------------------------

def decode_around_grid() -> Dict:
    from repro.core.degraded import (compile_degraded_plan,
                                     degraded_cache_clear,
                                     degraded_cache_info)
    from repro.core.params import SchemeParams

    degraded_cache_clear()
    cells: List[Dict] = []
    for family, r in FAMILY_GRID:
        p = SchemeParams(r=r, **CANON)
        clean = compile_degraded_plan(p, (), family=family)
        clean_cross = float(
            clean.transfer_loads()["cross_rack_matrix"].sum())
        for failed in FAILURES:
            dp = compile_degraded_plan(p, failed, family=family)
            n_remap = int(dp.orphan_subfiles.size)
            cross = float(dp.transfer_loads()["cross_rack_matrix"].sum())
            # acceptance (a): r>=2 decodes around any f <= r-1 per
            # layer-group; r=1 re-runs the dead servers' partitions
            if r == 1:
                assert n_remap == len(failed) * p.N // p.K, (family, failed)
            elif len(failed) == 1:
                assert n_remap == 0, (family, r, failed)
            cells.append({"family": family, "r": r,
                          "failed": list(failed),
                          "n_remapped_subfiles": n_remap,
                          "decode_around": bool(dp.decode_around),
                          "repaired_rows": int(dp.n_repaired_rows),
                          "cross_pairs": cross,
                          "cross_pairs_clean": clean_cross})
    # r=3 survives even the same-layer rack pair that defeats r=2
    assert any(c["r"] == 3 and c["failed"] == [0, 2]
               and c["n_remapped_subfiles"] == 0 for c in cells)
    info = degraded_cache_info()._asdict()
    return {"cells": cells, "degraded_cache": info}


# ---------------------------------------------------------------------------
# Section 2: 8-device recovery ladder (subprocess: forced device count)
# ---------------------------------------------------------------------------

def _driver() -> None:
    """Runs inside the subprocess with 8 forced host devices."""
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))
    import numpy as np

    from repro.core.params import SchemeParams
    from repro.distributed.meshes import make_mesh
    from repro.mapreduce.engine import run_job_distributed
    from repro.mapreduce.jobs import histogram_job
    from repro.resilience import FaultInjector, FaultSpec

    smoke = "--smoke" in sys.argv
    mesh = make_mesh((4, 2), ("rack", "server"))
    job = histogram_job()
    rng = np.random.default_rng(0)
    grid = [("binomial", 2)] if smoke else FAMILY_GRID
    failures = [(3,)] if smoke else [(3,), (0, 5)]
    out: List[Dict] = []
    for family, r in grid:
        p = SchemeParams(r=r, **CANON)
        subs = np.asarray(rng.integers(0, 1 << 16, size=(p.N, 256)),
                          dtype=np.int32)
        t0 = time.perf_counter()
        ref = run_job_distributed(job, subs, p, mesh, scheme_family=family)
        clean_s = time.perf_counter() - t0
        for failed in failures:
            faults = FaultSpec(FaultInjector.crash(failed))
            t0 = time.perf_counter()
            got = run_job_distributed(job, subs, p, mesh, faults=faults,
                                      scheme_family=family)
            rec_s = time.perf_counter() - t0
            rep = got.recovery
            out.append({
                "family": family, "r": r, "failed": list(failed),
                "bit_identical": bool(np.array_equal(
                    np.asarray(got.outputs), np.asarray(ref.outputs))),
                "rung": rep.rung, "n_remapped": int(rep.n_remapped),
                "restarts": int(rep.restarts),
                "clean_s": clean_s, "recovery_s": rec_s})
    if not smoke:
        # unrecoverable first attempt -> bounded restart, still bit-exact
        p = SchemeParams(r=2, **CANON)
        subs = np.asarray(rng.integers(0, 1 << 16, size=(p.N, 256)),
                          dtype=np.int32)
        ref = run_job_distributed(job, subs, p, mesh)
        faults = FaultSpec(FaultInjector.crash(tuple(range(8))),
                           max_restarts=2)
        got = run_job_distributed(job, subs, p, mesh, faults=faults)
        out.append({
            "family": "binomial", "r": 2, "failed": list(range(8)),
            "bit_identical": bool(np.array_equal(
                np.asarray(got.outputs), np.asarray(ref.outputs))),
            "rung": got.recovery.rung,
            "n_remapped": int(got.recovery.n_remapped),
            "restarts": int(got.recovery.restarts),
            "clean_s": None, "recovery_s": None})
    print(_DRIVER_MARK + json.dumps(out))


def engine_recovery(smoke: bool) -> Dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    argv = [sys.executable, os.path.abspath(__file__), "--_driver"]
    if smoke:
        argv.append("--smoke")
    proc = subprocess.run(
        argv, capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(root, "src")})
    if proc.returncode != 0:
        raise RuntimeError("faults driver failed:\n"
                           + proc.stdout + proc.stderr)
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith(_DRIVER_MARK))
    runs = json.loads(line[len(_DRIVER_MARK):])
    # acceptance (b): every recovery is bit-identical, through the rung
    # the failure set dictates
    for run_ in runs:
        assert run_["bit_identical"], run_
        if len(run_["failed"]) == 8:
            assert run_["rung"] == "restart" and run_["restarts"] >= 1
        elif run_["r"] == 1:
            assert run_["rung"] == "partial_remap" and run_["n_remapped"] > 0
        else:
            assert run_["rung"] == "decode_around"
            assert run_["n_remapped"] == 0
    return {"runs": runs}


# ---------------------------------------------------------------------------
# Section 3: simulator crash injection + chooser availability term
# ---------------------------------------------------------------------------

def sim_faults(seed: int) -> Dict:
    from repro.resilience import FaultInjector
    from repro.sim import (ClusterSim, CostModel, JobSpec, PhaseCoeffs,
                           RackTopology, SchemeChooser)

    topo = RackTopology(P=4, cross_bw=1e4, intra_bw=1e5)
    spec = JobSpec("histogram", 48, 16, 1)

    def crashed(scheme, r):
        sim = ClusterSim(topo, K=8, cost_model=CostModel(
            map=PhaseCoeffs(0.0, 1e-6)))
        sim.submit(spec, scheme, r, time=0.0)
        FaultInjector.random(seed=seed, K=8, n_events=2, max_servers=1,
                             max_time=0.02).inject_into(sim)
        stats = sim.run()[0]
        return sim, stats

    # acceptance (c): seeded crash traces are bit-identical across reruns
    t1 = crashed("hybrid", 2)[0].trace
    t2 = crashed("hybrid", 2)[0].trace
    assert tuple(t1) == tuple(t2), "seeded crash trace not deterministic"
    trace_hash = hashlib.sha256(
        json.dumps(t1, default=str).encode()).hexdigest()

    sim_h, st_h = crashed("hybrid", 2)
    assert len(sim_h.network.flows) == 0, "orphan flows after crash"
    _, st_u = crashed("uncoded", 1)
    assert st_u.remapped_subfiles > 0 and st_h.remapped_subfiles == 0

    # chooser availability flip: expensive map, near-free network
    flip_topo = RackTopology(P=4, cross_bw=1e8, intra_bw=1e9)
    cost = CostModel(map=PhaseCoeffs(beta=1e-5))
    flip_spec = JobSpec("histogram", 336, 16, 4)

    def pick(cp):
        cluster = ClusterSim(flip_topo, K=8, cost_model=cost)
        d = SchemeChooser(K=8, cost_model=cost,
                          crash_prob=cp).choose(flip_spec, cluster)
        return {"scheme": d.scheme, "r": d.r, "est_jct": d.est_jct}

    blind, aware = pick(0.0), pick(2.0)
    assert blind["r"] == 1 and aware["r"] >= 2, (blind, aware)
    return {
        "trace_sha256": trace_hash,
        "trace_events": len(t1),
        "crashed_hybrid_r2": {"crashes": st_h.crashes,
                              "recoveries": st_h.recoveries,
                              "remapped_subfiles": st_h.remapped_subfiles,
                              "finish_s": st_h.finish},
        "crashed_uncoded_r1": {"crashes": st_u.crashes,
                               "recoveries": st_u.recoveries,
                               "remapped_subfiles": st_u.remapped_subfiles,
                               "remap_phase_s":
                                   st_u.phase_times.get("remap", 0.0),
                               "finish_s": st_u.finish},
        "chooser_flip": {"crash_prob_0": blind, "crash_prob_2": aware},
    }


# ---------------------------------------------------------------------------

def main() -> None:
    if "--_driver" in sys.argv:
        _driver()
        return
    args = make_parser(__doc__, "BENCH_faults.json").parse_args()
    report = {
        "decode_around_grid": decode_around_grid(),
        "engine_recovery": engine_recovery(smoke=args.smoke),
        "sim_faults": sim_faults(seed=args.seed),
    }
    n_runs = len(report["engine_recovery"]["runs"])
    print(f"decode-around grid: {len(report['decode_around_grid']['cells'])}"
          f" cells OK; engine recovery: {n_runs} runs bit-identical; "
          "sim traces deterministic; chooser flips at crash_prob=2")
    emit_report(report, "faults", args.out, smoke=args.smoke,
                seed=args.seed)


if __name__ == "__main__":
    main()
