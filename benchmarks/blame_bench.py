"""Blame benchmark: JCT blame-decomposition exactness, cause attribution,
and telemetry determinism (the critical-path observatory's pin).

Sections (results land in ``BENCH_blame.json``):

  * ``exactness`` — for EVERY paper Table I row x all four schemes (both
    plan families), a seeded single-job sim run's blame components must
    sum to the measured JCT (relative residual <= 1e-9; in practice the
    decomposition telescopes and the residual is ~1e-16), with the
    zero-contention calibration identity (solo job => contention == 0)
    asserted on every cell; a contended scheduled run re-checks the law
    under queueing + link sharing.
  * ``attribution`` — three seeded cause-injection scenarios, each of
    which must move blame to the injected cause:
      - ``skew``: ``rack_bw_scale`` slows one rack's ToR; ``shuffle_intra``
        blame grows vs the uniform baseline and the slow rack's ToR is the
        busiest intra link in the telemetry;
      - ``straggle``: an :class:`repro.sim.ExponentialTail` map tail makes
        ``map_straggle`` the dominant component;
      - ``crash``: an injected mid-shuffle crash's ``recovery`` component
        equals the JCT delta vs the failure-free run (the degraded
        schedule's full price, to 1e-9 relative);
    plus a monotonicity sweep: mean contention+queueing blame is
    nondecreasing in offered load at fixed seed.
  * ``determinism`` — the network-telemetry dump is byte-identical (sha256)
    across same-seed reruns, and the golden trace-event stream is
    byte-identical with telemetry on vs off (observation is free).
  * ``extract`` — :func:`repro.obs.blame.extract_blame` re-derives every
    scheduled job's decomposition from the trace stream alone and must
    agree with the stats-side blame (cross-check raises on mismatch).
"""
from __future__ import annotations

import hashlib
import json
import math
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

try:                                                           # noqa: E402
    from ._common import emit_report, make_parser
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser

from repro.core.params import TABLE1_GRID                      # noqa: E402
from repro.obs import blame as obs_blame                       # noqa: E402
from repro.obs import metrics                                  # noqa: E402
from repro.obs.tracing import to_chrome_trace                  # noqa: E402
from repro.sim import (ClusterSim, CostModel, ExponentialTail,  # noqa: E402
                       JobSpec, MultiJobScheduler, PhaseCoeffs,
                       PoissonWorkload, RackTopology, SchemeChooser,
                       default_catalog)

SCHEMES = ("uncoded", "coded", "hybrid", "hybrid_resolvable")
RESIDUAL_TOL = 1e-9
CONTENTION_TOL = 1e-9

# nonzero compute so every component is exercised (zero coeffs would make
# the law trivially shuffle-only)
COSTS = CostModel(map=PhaseCoeffs(1e-3, 1e-8),
                  pack=PhaseCoeffs(5e-4, 5e-9),
                  reduce=PhaseCoeffs(1e-3, 1e-8))


def _rel_residual(stats) -> float:
    s = math.fsum(stats.blame.values())
    return abs(stats.jct - s) / max(abs(stats.jct), 1e-12)


# ---------------------------------------------------------------------------
# Section 1: exactness law on the Table I grid + under contention
# ---------------------------------------------------------------------------

def exactness(seed: int, smoke: bool) -> dict:
    grid = TABLE1_GRID[:2] if smoke else TABLE1_GRID
    rows = []
    for (K, P, Q, N, r) in grid:
        for scheme in SCHEMES:
            topo = RackTopology(P=P, cross_bw=1e3, intra_bw=1e4)
            sim = ClusterSim(topo, K, COSTS, seed=seed)
            sim.submit(JobSpec("exact", N, Q, 2), scheme, r, time=0.0,
                       check=False)
            (stats,) = sim.run()
            res = _rel_residual(stats)
            contention = abs(stats.blame["contention"])
            assert res <= RESIDUAL_TOL, \
                f"({K},{P},{Q},{N},{r}) {scheme}: residual {res:.3e}"
            assert contention <= CONTENTION_TOL, \
                f"({K},{P},{Q},{N},{r}) {scheme}: solo-job contention " \
                f"{contention:.3e} != 0"
            rows.append({"K": K, "P": P, "Q": Q, "N": N, "r": r,
                         "scheme": scheme, "jct": stats.jct,
                         "rel_residual": res, "solo_contention": contention})

    # contended rerun: the law must survive queueing + shared links
    stats_list = _scheduled_run(seed, n_jobs=8 if smoke else 24, rate=4.0)[2]
    sched_res = [_rel_residual(s) for s in stats_list if s.blame is not None]
    assert sched_res and max(sched_res) <= RESIDUAL_TOL
    out = {"rows": rows, "n_grid": len(rows),
           "max_rel_residual": max(
               max(r["rel_residual"] for r in rows), max(sched_res)),
           "max_solo_contention": max(r["solo_contention"] for r in rows),
           "scheduled_jobs": len(sched_res),
           "scheduled_max_rel_residual": max(sched_res)}
    print(f"  [exactness] {len(rows)} grid cells + {len(sched_res)} "
          f"scheduled jobs, max rel residual {out['max_rel_residual']:.3e}")
    return out


# ---------------------------------------------------------------------------
# Section 2: cause attribution (skew / straggle / crash / load sweep)
# ---------------------------------------------------------------------------

def _solo_stats(topo: RackTopology, seed: int, telemetry: bool = False,
                stragglers=None, crash_at: float | None = None,
                K: int = 8, scheme: str = "hybrid", r: int = 2,
                costs: CostModel = COSTS):
    sim = ClusterSim(topo, K, costs, stragglers=stragglers, seed=seed,
                     telemetry=telemetry)
    sim.submit(JobSpec("attr", 48, 16, 2), scheme, r, time=0.0)
    if crash_at is not None:
        sim.inject_crash(crash_at, [0])
    (stats,) = sim.run()
    return stats, sim


def attribution_skew(seed: int) -> dict:
    base = RackTopology(P=4, cross_bw=1e3, intra_bw=1e4)
    skew = RackTopology(P=4, cross_bw=1e3, intra_bw=1e4,
                        rack_bw_scale=(0.25, 1.0, 1.0, 1.0))
    s0, _ = _solo_stats(base, seed)
    s1, sim1 = _solo_stats(skew, seed, telemetry=True)
    util = sim1.telemetry.utilization()
    tor_busy = {k: v["busy_s"] for k, v in util.items()
                if k.startswith("tor:")}
    busiest = max(sorted(tor_busy), key=lambda k: tor_busy[k])
    ratio = s1.blame["shuffle_intra"] / max(s0.blame["shuffle_intra"], 1e-12)
    assert ratio > 1.5, \
        f"intra blame did not follow the slow rack (ratio {ratio:.3f})"
    assert busiest == "tor:0", \
        f"slowest rack's ToR is not the busiest link ({busiest})"
    print(f"  [skew] shuffle_intra x{ratio:.2f}, busiest link {busiest}")
    return {"intra_blame_base": s0.blame["shuffle_intra"],
            "intra_blame_skew": s1.blame["shuffle_intra"],
            "intra_blame_ratio": ratio, "busiest_tor": busiest,
            "tor_busy_s": tor_busy}


def attribution_straggle(seed: int) -> dict:
    topo = RackTopology(P=4, cross_bw=1e6, intra_bw=1e7)  # shuffle ~free
    # map-heavy coefficients: the injected tail rides on the map barrier,
    # so the scenario isolates it from pack/reduce serial time
    costs = CostModel(map=PhaseCoeffs(2e-3, 2e-8),
                      pack=PhaseCoeffs(1e-4, 1e-9),
                      reduce=PhaseCoeffs(1e-4, 1e-9))
    plain, _ = _solo_stats(topo, seed, costs=costs)
    tail, _ = _solo_stats(topo, seed, stragglers=ExponentialTail(3.0),
                          costs=costs)
    rep = obs_blame.blame_report(tail)
    assert rep.dominant() == "map_straggle", \
        f"expected map_straggle dominant, got {rep.dominant()}"
    assert abs(plain.blame["map_straggle"]) < 1e-12
    share = rep.share("map_straggle")
    print(f"  [straggle] map_straggle dominant ({share:.1%} of JCT)")
    return {"dominant": rep.dominant(), "map_straggle_share": share,
            "map_straggle_s": tail.blame["map_straggle"],
            "plain_map_straggle_s": plain.blame["map_straggle"]}


def attribution_crash(seed: int) -> dict:
    topo = RackTopology(P=4, cross_bw=1e3, intra_bw=1e4)
    ff, _ = _solo_stats(topo, seed)
    # crash mid-shuffle: past the map phase, inside the JCT
    crash_at = ff.phase_times.get("map", 0.0) + 0.6 * (
        ff.jct - ff.phase_times.get("map", 0.0))
    crashed, _ = _solo_stats(topo, seed, crash_at=crash_at)
    delta = crashed.jct - ff.jct
    rel_err = abs(crashed.blame["recovery"] - delta) / max(ff.jct, 1e-12)
    assert delta > 0, "crash did not slow the job"
    assert rel_err <= RESIDUAL_TOL, \
        f"recovery blame != degraded-schedule delta (rel err {rel_err:.3e})"
    assert _rel_residual(crashed) <= RESIDUAL_TOL
    print(f"  [crash] recovery {crashed.blame['recovery']:.4f}s == "
          f"JCT delta {delta:.4f}s (rel err {rel_err:.1e})")
    return {"jct_ff": ff.jct, "jct_crashed": crashed.jct,
            "recovery_s": crashed.blame["recovery"], "jct_delta": delta,
            "recovery_rel_err": rel_err}


def _scheduled_run(seed: int, n_jobs: int, rate: float,
                   telemetry: bool = True):
    topo = RackTopology(P=4, cross_bw=2e4, intra_bw=2e5)
    cluster = ClusterSim(topo, 8, seed=seed, telemetry=telemetry)
    chooser = SchemeChooser(8, cost_model=COSTS, compile_real_plans=False)
    wl = PoissonWorkload(default_catalog(8, 4), n_jobs=n_jobs, rate=rate)
    sched = MultiJobScheduler(chooser, policy="fifo", max_concurrent=4)
    stats = sched.run(wl.generate(seed), cluster)
    return cluster, sched, stats


def attribution_load_sweep(seed: int, smoke: bool) -> dict:
    n_jobs = 8 if smoke else 16
    points = []
    for rate in (0.5, 2.0, 8.0):
        _, _, stats = _scheduled_run(seed, n_jobs, rate, telemetry=False)
        blames = [s.blame for s in stats if s.blame is not None]
        mean = math.fsum(b["contention"] + b["queueing"]
                         for b in blames) / len(blames)
        points.append({"rate": rate, "n": len(blames),
                       "mean_contention_queueing_s": mean})
    vals = [p["mean_contention_queueing_s"] for p in points]
    assert all(vals[i] <= vals[i + 1] + 1e-12 for i in range(len(vals) - 1)), \
        f"contention blame not monotone in offered load: {vals}"
    print(f"  [load] mean contention+queueing {['%.4f' % v for v in vals]} "
          f"over rates (0.5, 2, 8)")
    return {"points": points}


# ---------------------------------------------------------------------------
# Section 3: determinism (telemetry bytes, golden traces untouched)
# ---------------------------------------------------------------------------

def determinism(seed: int, smoke: bool, out_dir: str) -> dict:
    n_jobs = 6 if smoke else 12
    shas = []
    trace_shas = {}
    for tag, telem in (("on_a", True), ("on_b", True), ("off", False)):
        cluster, _, _ = _scheduled_run(seed, n_jobs, 4.0, telemetry=telem)
        trace_blob = json.dumps(to_chrome_trace(cluster.tracer.events),
                                sort_keys=True).encode()
        trace_shas[tag] = hashlib.sha256(trace_blob).hexdigest()
        if telem:
            blob = json.dumps(cluster.telemetry.to_dict(),
                              sort_keys=True).encode()
            shas.append(hashlib.sha256(blob).hexdigest())
    assert shas[0] == shas[1], "telemetry dump not byte-identical per seed"
    assert len(set(trace_shas.values())) == 1, \
        "trace events differ with telemetry on vs off"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "blame_telemetry.json")
    cluster, _, _ = _scheduled_run(seed, n_jobs, 4.0, telemetry=True)
    with open(path, "w") as f:
        json.dump(cluster.telemetry.to_dict(), f, sort_keys=True)
    print(f"  [determinism] telemetry sha {shas[0][:12]} (x2), traces "
          f"identical on/off")
    return {"telemetry_sha256": shas[0], "telemetry_reruns_match": True,
            "trace_invariant_under_telemetry": True,
            "telemetry_path": os.path.relpath(path)}


# ---------------------------------------------------------------------------
# Section 4: trace-side extraction agrees with stats-side blame
# ---------------------------------------------------------------------------

def extraction(seed: int, smoke: bool) -> dict:
    cluster, _, stats = _scheduled_run(seed, 6 if smoke else 16, 4.0)
    events = list(cluster.tracer.events)
    reports = [obs_blame.extract_blame(events, s)   # raises on disagreement
               for s in stats if s.blame is not None]
    fleet = obs_blame.fleet_blame(reports)
    max_res = max(abs(r.residual) / max(r.jct, 1e-12) for r in reports)
    assert max_res <= RESIDUAL_TOL
    print(f"  [extract] {len(reports)} jobs re-derived from trace, "
          f"max rel residual {max_res:.3e}")
    return {"n_jobs": len(reports), "max_rel_residual": max_res,
            "fleet_p99": fleet}


def main() -> None:
    ap = make_parser(__doc__.splitlines()[0], "BENCH_blame.json",
                     default_iters=1)
    args = ap.parse_args()
    metrics.reset()

    print("# exactness: blame sums to JCT on the Table I grid")
    exact = exactness(args.seed, args.smoke)

    print("# attribution: injected causes move the blame")
    attr = {"skew": attribution_skew(args.seed),
            "straggle": attribution_straggle(args.seed),
            "crash": attribution_crash(args.seed),
            "load": attribution_load_sweep(args.seed, args.smoke)}

    print("# determinism: telemetry bytes + golden traces")
    det = determinism(args.seed, args.smoke, "bench_out")

    print("# extraction: trace-derived blame agrees with stats")
    ext = extraction(args.seed, args.smoke)

    emit_report({"exactness": exact, "attribution": attr,
                 "determinism": det, "extract": ext},
                bench="blame", out_path=args.out, smoke=args.smoke,
                seed=args.seed)


if __name__ == "__main__":
    main()
