"""Splice the dry-run roofline table and the §Perf variant tables into
EXPERIMENTS.md (idempotent; run after the sweep + perf_iterations).

  PYTHONPATH=src python -m benchmarks.write_experiments
"""
from __future__ import annotations

import json
import os
import re

from .roofline_report import RESULTS_DIR, load_cells

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXP = os.path.join(ROOT, "EXPERIMENTS.md")


def _cell(mesh: str, arch: str, shape: str, suffix: str = "") -> dict:
    tag = f"{arch}__{shape}" + (f"__{suffix}" if suffix else "")
    path = os.path.join(RESULTS_DIR, mesh, tag + ".json")
    with open(path) as f:
        return json.load(f)


def roofline_table() -> str:
    cells = [c for c in load_cells()
             if "__" not in (c.get("variant") or "")]
    # keep only baseline cells (no dp_mode/variant suffix files)
    rows = ["## §Roofline — all 40 cells × 2 meshes (baseline)",
            "",
            "`t_*` in seconds per step; `frac` = t_compute / max(terms) "
            "(perfect-overlap roofline fraction); `plan` = analytic "
            "capacity per chip (16 GiB budget); `useful` = 6·N_active·D ÷ "
            "compiled FLOPs.",
            "",
            "| arch | shape | mesh | t_comp | t_mem | t_coll (dcn) | "
            "dominant | frac | plan | useful |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    seen = set()
    for c in cells:
        key = (c["arch"], c["shape"], c["mesh"])
        if key in seen or c.get("dp_mode", "dp") != "dp" \
                or c.get("overrides"):
            continue
        seen.add(key)
        if not c.get("runnable", True):
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | —"
                        " | — | n/a (full attn @524k) | — | — | — |")
            continue
        if not c.get("ok"):
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                        f"FAIL {c.get('error', '')[:40]} | | | | | | |")
            continue
        r = c["roofline"]
        mp = c.get("memory_plan", {})
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['t_compute']:.3g} | {r['t_memory']:.3g} "
            f"| {r['t_collective']:.3g} ({r['t_dcn']:.2g}) "
            f"| {r['dominant']} | {r['roofline_fraction']:.3f} "
            f"| {mp.get('total_gib', 0):.1f} GiB "
            f"{'✓' if mp.get('fits_16gib') else '✗'} "
            f"| {c.get('useful_flops_ratio', 0):.2f} |")
    n_ok = sum(1 for c in cells if c.get("ok"))
    n_skip = sum(1 for c in cells if not c.get("runnable", True))
    rows.append("")
    rows.append(f"Cells compiled OK: {n_ok}; by-design skips: {n_skip}; "
                "every runnable cell lowered AND compiled on both meshes.")
    return "\n".join(rows)


def _perf_row1(name, c) -> str:
    r = c["roofline"]
    mp = c.get("memory_plan", {})
    return (f"| {name} | {r['t_compute']:.3g} | {r['t_collective']:.3g} "
            f"({r['t_ici']:.3g}) | {r['dominant']} "
            f"| {r['roofline_fraction']:.3f} | {mp.get('total_gib', 0):.1f} "
            f"| {'fits' if mp.get('fits_16gib') else 'OVER'} |")


def perf_tables() -> dict:
    out = {}
    try:
        rows = []
        base = _cell("single", "llama3-405b", "train_4k")
        rows.append(_perf_row1("baseline n_micro=16", base))
        for v in ("nmicro8", "nmicro4", "nmicro2"):
            rows.append(_perf_row1(v, _cell("single", "llama3-405b",
                                            "train_4k", v)))
        out["PERF1_TABLE"] = "\n".join(rows)
    except FileNotFoundError:
        pass
    try:
        rows = []
        for name, sfx in (("baseline ZeRO-3", ""), ("TP(model) only",
                                                    "tponly"),
                          ("2D TP", "tp2d")):
            c = _cell("single", "qwen2-72b", "decode_32k", sfx)
            r = c["roofline"]
            mp = c.get("memory_plan", {})
            rows.append(f"| {name} | {r['t_memory']:.3g} "
                        f"| {r['t_collective']:.4g} | {r['dominant']} "
                        f"| {r['t_bound']:.4g} "
                        f"| {mp.get('total_gib', 0):.1f} "
                        f"{'fits' if mp.get('fits_16gib') else 'OVER'} |")
        out["PERF2_TABLE"] = "\n".join(rows)
    except FileNotFoundError:
        pass
    try:
        rows = []
        for name, dp in (("dp_flat (uncoded)", "dp"),
                         ("replicated (r=P corner)", "replicated")):
            tag = "deepseek-v2-lite-16b__train_4k" + \
                ("" if dp == "dp" else f"__{dp}")
            with open(os.path.join(RESULTS_DIR, "multi",
                                   tag + ".json")) as f:
                c = json.load(f)
            r = c["roofline"]
            rows.append(f"| {name} | {r['t_compute']:.3g} "
                        f"| {r['t_dcn']:.3g} | {r['t_collective']:.3g} "
                        f"| {r['dominant']} "
                        f"| {r['roofline_fraction']:.3f} |")
        out["PERF3_TABLE"] = "\n".join(rows)
    except FileNotFoundError:
        pass
    return out


def main() -> None:
    with open(EXP) as f:
        text = f.read()
    table = roofline_table()
    if "<!-- ROOFLINE_TABLE -->" in text:
        text = text.replace("<!-- ROOFLINE_TABLE -->", table)
    else:
        text = re.sub(r"## §Roofline — all 40 cells.*?(?=\n## §Perf)",
                      table + "\n\n", text, flags=re.S)
    for key, tbl in perf_tables().items():
        text = text.replace(f"| {key} |", tbl)
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
