"""Shared benchmark CLI plumbing: arg parsing, seeding, JSON report emit.

Every ``BENCH_*.json`` artifact carries the same envelope so downstream
tooling (CI artifact diffing, the simulator's calibration loader) can parse
any of them: ``schema_version``, ``bench``, ``smoke``, ``seed``, plus the
bench-specific payload.  Bump :data:`SCHEMA_VERSION` on envelope changes.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

import numpy as np

SCHEMA_VERSION = 1


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def default_out_path(name: str) -> str:
    return os.path.join(repo_root(), name)


def make_parser(description: str, out_name: str, default_iters: int = 8,
                add_seed: bool = True) -> argparse.ArgumentParser:
    """Standard benchmark CLI: ``--smoke`` (small config, few iters, CI),
    ``--iters``, ``--out``, and (unless the bench has no rng —
    ``add_seed=False``) ``--seed``."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--smoke", action="store_true",
                    help="one small config, few iters (CI)")
    ap.add_argument("--iters", type=int, default=default_iters)
    if add_seed:
        ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=default_out_path(out_name))
    return ap


def seeded_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def emit_report(report: Dict, bench: str, out_path: str,
                smoke: bool = False, seed: Optional[int] = None) -> Dict:
    """Wrap ``report`` in the common envelope, write it to ``out_path``,
    and append the bench's headline scalars to the trajectory ledger
    (``BENCH_history.jsonl`` next to ``out_path`` — see
    :mod:`benchmarks.history`)."""
    envelope = {
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "smoke": smoke,
        **({} if seed is None else {"seed": seed}),
        **report,
    }
    with open(out_path, "w") as f:
        json.dump(envelope, f, indent=2)
    print(f"wrote {out_path}")
    try:
        from .history import append_entry
    except ImportError:                   # run as a script, not a package
        from history import append_entry
    append_entry(envelope, out_path)
    return envelope
