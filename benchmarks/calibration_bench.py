"""Sim-to-metal conformance benchmark: calibrate the cost model on the
real 8-device driver, assert the simulator predicts measured fused-pipeline
wall clock within a tolerance band, and prove the online drift->refit loop
beats a stale model after a straggler-regime shift.

Four sections, all pinned in ``BENCH_calibration.json``:

  * **phase_fit** — ``measure_calibration_grid`` over (N, r, d) points on
    the 8-host-device ('rack','server') mesh; the fitted per-phase
    :class:`CostModel` is committed as
    ``calibration/default_cost_model.json`` with fit residuals and
    provenance (the artifact ``repro.sim.load_default_cost_model`` loads);
  * **conformance** — measured END-TO-END fused-pipeline wall clock over
    the pipeline-bench grid, fitted by the JCT-level
    :class:`repro.sim.ConformanceModel` (sim work conventions), then
    re-predicted by ACTUALLY RUNNING :func:`simulate_single_job` under the
    distributed (CostModel, RackTopology): every cell must land within the
    tolerance band, and each cell is reconciled into the engine-layer
    ``jct_prediction_*`` histograms;
  * **drift** — a seeded scheduled sim stream whose straggler regime
    shifts 3x mid-run: the EWMA detector must fire, the online refit
    (``MultiJobScheduler(recalibrate=True)``) must absorb the inflation,
    and the refit run's post-shift prediction error must beat the stale
    counterfactual (same seed, same workload, no refit) — with the stale
    model's regret banked in ``stale_model_regret_seconds_total``;
  * **determinism** — the drift scenario re-run in-process produces a
    byte-identical ``jct_*`` metric snapshot per seed.

``--smoke`` shrinks every grid for CI.  Emits ``BENCH_calibration.json``
(+ a ``BENCH_history.jsonl`` ledger entry via the common envelope).
"""
from __future__ import annotations

import hashlib
import json
import os
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

try:                                                           # noqa: E402
    from ._common import emit_report, make_parser, repo_root, seeded_rng
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser, repo_root, seeded_rng

from repro.core.params import SchemeParams                     # noqa: E402
from repro.distributed.meshes import make_mesh                 # noqa: E402
from repro.mapreduce.engine import (                           # noqa: E402
    _fused_executable, assemble_outputs, measure_calibration_grid,
    pack_local_subfiles)
from repro.core.coded_collectives import (                     # noqa: E402
    compile_hybrid_plan, plan_cache_clear)
from repro.mapreduce.jobs import wide_histogram_job            # noqa: E402
from repro.obs import metrics                                  # noqa: E402
from repro.obs.drift import (DriftConfig, DriftMonitor,        # noqa: E402
                             record_prediction)
from repro.sim import (ClusterSim, CostModel,                  # noqa: E402
                       DeterministicSlowdown, MultiJobScheduler,
                       PhaseCoeffs, PoissonWorkload, RackTopology,
                       SchemeChooser, default_catalog, fit_conformance,
                       load_cost_model)
from repro.sim.calibration import (calibrate_with_residuals,   # noqa: E402
                                   conformance_report, save_cost_model)

MESH_SHAPE = (4, 2)                  # P=4 racks x Kr=2 servers = 8 devices
K, P, Q = 8, 4, 16
SUBFILE_TOKENS = 256

# phase-fit grid: (N, r, d) spread so the affine per-phase fit is
# overdetermined in work for every phase
GRID_POINTS = [(48, 2, 256), (48, 2, 1024), (96, 2, 512), (96, 2, 2048),
               (96, 3, 1024), (192, 2, 1024)]
SMOKE_GRID_POINTS = [(48, 2, 64), (48, 2, 256)]

# conformance grid mirrors benchmarks/pipeline_bench.py
CONFORMANCE_SIZES = [(96, 16, 2048), (96, 16, 512), (192, 16, 1024)]
CONFORMANCE_RS = (1, 2, 3)
SMOKE_CONFORMANCE_SIZES = [(48, 16, 64)]
SMOKE_CONFORMANCE_RS = (2,)

TOL_REL = 0.35                       # conformance tolerance band


def _timeit(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Section 1: per-phase calibration on the 8-device driver -> artifact
# ---------------------------------------------------------------------------

def phase_fit(mesh, smoke: bool, iters: int, seed: int,
              calib_out: str) -> dict:
    points = [(SchemeParams(K=K, P=P, Q=Q, N=n, r=r), d)
              for n, r, d in (SMOKE_GRID_POINTS if smoke else GRID_POINTS)]
    rows = measure_calibration_grid(wide_histogram_job, mesh, points,
                                    iters=iters)
    model, residuals = calibrate_with_residuals(rows)
    provenance = {
        "bench": "calibration_bench.phase_fit",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "mesh_shape": list(MESH_SHAPE),
        "points": [{"N": p.N, "Q": p.Q, "r": p.r, "d": d}
                   for p, d in points],
        "iters": iters, "seed": seed, "smoke": smoke,
    }
    save_cost_model(model, calib_out, residuals=residuals,
                    provenance=provenance)
    reloaded, doc = load_cost_model(calib_out)       # round-trip check
    assert reloaded == model, "artifact round-trip must be exact"
    assert model.map.beta > 0 and model.reduce.beta > 0, \
        "calibration must see positive compute rates"
    worst = max(residuals[ph]["rel_rmse"]
                for ph in ("map", "pack", "reduce") if ph in residuals)
    for ph, res in sorted(residuals.items()):
        print(f"  [phase_fit] {ph:12s} n={res['n']} "
              f"rmse={res['rmse_s'] * 1e3:.3f}ms "
              f"rel_rmse={res['rel_rmse']:.3f}")
    print(f"  [phase_fit] wrote {calib_out}")
    return {"cost_model": doc["cost_model"], "residuals": residuals,
            "provenance": provenance, "artifact": calib_out,
            "worst_rel_rmse": worst}


# ---------------------------------------------------------------------------
# Section 2: measured fused wall clock vs simulated JCT, per grid cell
# ---------------------------------------------------------------------------

def measure_fused_e2e(mesh, p: SchemeParams, d: int, iters: int,
                      seed: int) -> float:
    """Warm end-to-end fused pipeline seconds (host pack -> jitted fused
    program -> output assembly), best of ``iters`` — the wall clock the
    simulator is asked to predict."""
    plan = compile_hybrid_plan(p)
    job = wide_histogram_job(d)
    rng = seeded_rng(seed * 1009 + p.r)
    subfiles = rng.integers(0, 1 << 16, size=(p.N, SUBFILE_TOKENS)
                            ).astype(np.int32)
    exe = _fused_executable(job, plan, mesh, "unicast", "xla")
    exe(jnp.asarray(pack_local_subfiles(subfiles, plan))
        ).block_until_ready()                                  # compile

    def e2e():
        packed = jnp.asarray(pack_local_subfiles(subfiles, plan))
        return assemble_outputs(exe(packed), plan).block_until_ready()

    return _timeit(e2e, iters)


def conformance(mesh, smoke: bool, iters: int, seed: int,
                tol: float) -> dict:
    sizes = SMOKE_CONFORMANCE_SIZES if smoke else CONFORMANCE_SIZES
    rs = SMOKE_CONFORMANCE_RS if smoke else CONFORMANCE_RS
    cells = []
    for (n, q, d) in sizes:
        for r in rs:
            p = SchemeParams(K=K, P=P, Q=q, N=n, r=r)
            meas = measure_fused_e2e(mesh, p, d, iters, seed)
            cells.append({"p": p, "scheme": "hybrid", "d": d,
                          "measured_s": meas})
    model = fit_conformance(cells)
    # honesty check: the sim must REPRODUCE the fitted linear predictor
    for c in cells:
        lin = model.predict(c["p"], "hybrid", c["d"])
        sim = model.sim_stats(c["p"], "hybrid", c["d"]).jct
        assert abs(sim - lin) <= 1e-9 * max(lin, 1e-12), \
            f"sim JCT {sim} must equal the linear predictor {lin}"
    rows = conformance_report(model, cells, via_sim=True)
    for row, c in zip(rows, cells):
        record_prediction(row["predicted_s"], row["measured_s"],
                          layer="engine", scheme="hybrid")
        print(f"  [conformance] N={row['N']:3d} r={row['r']} "
              f"d={row['d']:4d}  measured {row['measured_s'] * 1e3:8.2f}ms"
              f"  sim {row['predicted_s'] * 1e3:8.2f}ms  "
              f"rel_err {row['rel_err']:.3f}")
    max_rel = max(r["rel_err"] for r in rows)
    mean_rel = float(np.mean([r["rel_err"] for r in rows]))
    ok = max_rel <= tol
    assert ok, (f"sim-predicted JCT misses measured wall clock beyond the "
                f"band: max rel err {max_rel:.3f} > tol {tol}")
    return {"model": model.to_dict(), "cells": rows, "tol_rel": tol,
            "max_rel_err": max_rel, "mean_rel_err": mean_rel, "ok": ok}


# ---------------------------------------------------------------------------
# Section 3: drift detector + online refit vs the stale counterfactual
# ---------------------------------------------------------------------------

STALE_COST = CostModel(map=PhaseCoeffs(1e-3, 5e-7),
                       pack=PhaseCoeffs(5e-4, 2e-7),
                       reduce=PhaseCoeffs(1e-3, 5e-7))
SHIFT_FACTOR = 3.0


def _drift_run(n_jobs: int, seed: int, t_shift: float,
               recalibrate: bool) -> dict:
    """One seeded scheduled run whose straggler regime shifts at
    ``t_shift``; returns per-job prediction errors and monitor state."""
    topo = RackTopology(P=P, cross_bw=2e5, intra_bw=2e6)
    cluster = ClusterSim(topo, K=K, cost_model=STALE_COST, seed=seed)
    cluster.at(t_shift, lambda: setattr(
        cluster, "stragglers",
        DeterministicSlowdown((SHIFT_FACTOR,) * K)))
    chooser = SchemeChooser(K, cost_model=STALE_COST,
                            compile_real_plans=False)
    monitor = DriftMonitor(DriftConfig(ewma_alpha=0.3, threshold=0.2,
                                       min_observations=3))
    sched = MultiJobScheduler(chooser, policy="fifo", max_concurrent=2,
                              drift=monitor, recalibrate=recalibrate)
    wl = PoissonWorkload(default_catalog(K, P), n_jobs=n_jobs, rate=2.0)
    stats = sched.run(wl.generate(seed), cluster)
    post = []
    for s in stats:
        d = sched.decisions.get(s.job_id)
        if d is None or s.submit < t_shift:
            continue
        actual = s.finish - s.submit
        post.append(abs(d.est_jct - actual) / max(actual, 1e-12))
    return {"post_shift_rel_errs": post, "monitor": monitor.state(),
            "n_jobs": len(stats),
            "refit_trace_events": sum(
                1 for e in cluster.tracer.events if e.kind == "sched_refit"),
            "banked_regret_s": metrics.registry().counter(
                "stale_model_regret_seconds_total").value(layer="sim")}


def drift(smoke: bool, seed: int) -> dict:
    n_jobs = 30 if smoke else 60
    t_shift = 8.0 if smoke else 15.0
    metrics.reset()
    stale = _drift_run(n_jobs, seed, t_shift, recalibrate=False)
    metrics.reset()
    refit = _drift_run(n_jobs, seed, t_shift, recalibrate=True)
    stale_mean = float(np.mean(stale["post_shift_rel_errs"]))
    refit_mean = float(np.mean(refit["post_shift_rel_errs"]))
    fired = refit["monitor"]["drift_events"] >= 1
    refits = refit["monitor"]["refits"]
    print(f"  [drift] shift@{t_shift}s x{SHIFT_FACTOR}: stale mean rel err "
          f"{stale_mean:.3f} -> refit {refit_mean:.3f} "
          f"({refits} refits, regret banked "
          f"{refit['banked_regret_s']:.2f}s)")
    assert fired, "EWMA drift detector must fire after the regime shift"
    assert refits >= 1 and refit["refit_trace_events"] == refits
    assert refit_mean < stale_mean, \
        (f"online refit must beat the stale model post-shift: "
         f"{refit_mean:.3f} !< {stale_mean:.3f}")
    return {"n_jobs": n_jobs, "t_shift": t_shift,
            "shift_factor": SHIFT_FACTOR,
            "stale_mean_rel_err": stale_mean,
            "refit_mean_rel_err": refit_mean,
            "improvement": stale_mean / max(refit_mean, 1e-12),
            "drift_fired": fired, "refits": refits,
            "banked_regret_s": refit["banked_regret_s"],
            "stale_monitor": stale["monitor"],
            "refit_monitor": refit["monitor"], "ok": True}


# ---------------------------------------------------------------------------
# Section 4: per-seed determinism of the prediction-error histograms
# ---------------------------------------------------------------------------

def _jct_snapshot(seed: int, n_jobs: int, t_shift: float) -> str:
    metrics.reset()
    _drift_run(n_jobs, seed, t_shift, recalibrate=True)
    snap = metrics.snapshot()
    sub = {name: snap[name] for name in sorted(snap)
           if name.startswith("jct_") or name.startswith("stale_model")}
    return json.dumps(sub, sort_keys=True)


def determinism(smoke: bool, seed: int) -> dict:
    n_jobs = 20 if smoke else 40
    t_shift = 6.0 if smoke else 10.0
    a = _jct_snapshot(seed, n_jobs, t_shift)
    b = _jct_snapshot(seed, n_jobs, t_shift)
    sha_a = hashlib.sha256(a.encode()).hexdigest()
    sha_b = hashlib.sha256(b.encode()).hexdigest()
    assert a == b, "jct_* metric snapshots must be bit-identical per seed"
    print(f"  [determinism] jct_* snapshot sha256 {sha_a[:16]}… "
          f"(bit-identical across reruns)")
    return {"n_jobs": n_jobs, "sha256": sha_a, "identical": sha_a == sha_b,
            "ok": True}


# ---------------------------------------------------------------------------

def run(smoke: bool = False, iters: int = 5, seed: int = 0,
        tol: float = TOL_REL, calib_out: str | None = None) -> dict:
    mesh = make_mesh(MESH_SHAPE, ("rack", "server"))
    if calib_out is None:
        calib_out = os.path.join(repo_root(), "calibration",
                                 "default_cost_model.json")
    print("# phase_fit: per-phase calibration on the 8-device driver")
    plan_cache_clear()
    fit = phase_fit(mesh, smoke, iters, seed, calib_out)

    print("# conformance: simulated JCT vs measured fused wall clock")
    metrics.reset()
    conf = conformance(mesh, smoke, iters, seed, tol)

    print("# drift: regime shift -> EWMA fires -> online refit wins")
    dr = drift(smoke, seed)

    print("# determinism: jct_* histograms bit-identical per seed")
    det = determinism(smoke, seed)

    return {"mesh": {"shape": list(MESH_SHAPE),
                     "axes": ["rack", "server"],
                     "backend": jax.default_backend()},
            "iters": iters, "phase_fit": fit, "conformance": conf,
            "drift": dr, "determinism": det}


def main() -> None:
    ap = make_parser(__doc__.splitlines()[0], "BENCH_calibration.json",
                     default_iters=5)
    ap.add_argument("--tol", type=float, default=TOL_REL,
                    help="conformance tolerance band (relative error)")
    ap.add_argument("--calib-out", default=None,
                    help="cost-model artifact path (default: "
                         "calibration/default_cost_model.json)")
    args = ap.parse_args()
    report = run(smoke=args.smoke, iters=2 if args.smoke else args.iters,
                 seed=args.seed, tol=args.tol, calib_out=args.calib_out)
    emit_report(report, "calibration", args.out, smoke=args.smoke,
                seed=args.seed)


if __name__ == "__main__":
    main()
