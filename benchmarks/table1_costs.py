"""Paper Table I: cross-rack / intra-rack communication cost of Uncoded,
Coded and Hybrid Coded MapReduce for the paper's nine (K,P,Q,N,r) rows —
closed forms (Props 1-2, Thm III.1) AND, where the divisibility hypotheses
admit an executable schedule, the enumerated message counts (proving the
formulas describe a realizable shuffle).

Values are in thousands of <key,value> transfers, as in the paper.
Discrepant paper cells are flagged (see EXPERIMENTS.md §Fidelity).

Emits ``BENCH_table1.json`` in the shared benchmark envelope
(``benchmarks/_common.py``: schema_version + seeded CLI), like every other
bench; the table is pure closed forms, so ``--smoke`` only trims the
printed output, and the seed is recorded for envelope uniformity.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

try:
    from ._common import emit_report, make_parser
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser

from repro.core.costs import coded_cost, hybrid_cost, uncoded_cost
from repro.core.params import SchemeParams, TABLE1_GRID

# Paper's printed values /1000 per TABLE1_GRID row:
# (unc_cro, cod_cro, hyb_cro, unc_int, cod_int, hyb_int)
PAPER_VALUES: List[Tuple[float, ...]] = [
    (0.864, 0.486, 0.216, 0.288, 0.018, 0.864),
    (2.88, 1.632, 0.96, 0.72, 0.048, 2.88),
    (20.16, 6.976, 2.24, 5.04, 0.304, 20.16),
    (2.1, 1.275, 0.525, 0.84, 0.09, 2.520),
    (5.7, 3.3, 1.9, 1.52, 0.12, 0.608),
    (12, 6.75, 4.5, 2.4, 1.5, 12),
    (138, 50.6, 23, 27.6, 0.1, 13.8),
    (16.56, 11.88, 7.83, 3.45, 0.3, 17.25),
    (21.75, 12, 8.7, 3.48, 0.18, 20.88),
]
PAPER_ROWS: List[Tuple[Tuple[int, int, int, int, int],
                       Tuple[float, ...]]] = \
    list(zip(TABLE1_GRID, PAPER_VALUES))


def run(verbose: bool = True) -> List[dict]:
    rows = []
    for (K, P, Q, N, r), paper in PAPER_ROWS:
        t0 = time.perf_counter()
        p = SchemeParams(K=K, P=P, Q=Q, N=N, r=r)
        unc = uncoded_cost(p, check=False)
        cod = coded_cost(p, check=False)
        hyb = hybrid_cost(p, check=False)
        ours = (unc.cross, cod.cross, hyb.cross,
                unc.intra, cod.intra, hyb.intra)
        ours_k = tuple(v / 1000.0 for v in ours)
        match = [abs(a - b) / max(abs(b), 1e-9) < 5e-3
                 for a, b in zip(ours_k, paper)]
        rows.append({
            "params": (K, P, Q, N, r), "ours": ours_k, "paper": paper,
            "cells_matching": sum(match), "match": all(match),
            "us": (time.perf_counter() - t0) * 1e6,
        })
        if verbose:
            flag = "" if all(match) else \
                f"   <- {6 - sum(match)} paper cell(s) disagree"
            print(f"({K},{P},{Q},{N},{r}): "
                  + " ".join(f"{v:8.3f}" for v in ours_k) + flag)
    n_match = sum(r["match"] for r in rows)
    if verbose:
        print(f"rows fully matching the paper: {n_match}/9 "
              "(mismatches are paper typos contradicting its own Thm III.1;"
              " see EXPERIMENTS.md)")
    return rows


def report(verbose: bool = True) -> Dict:
    rows = run(verbose=verbose)
    return {
        "rows": [{**r, "params": list(r["params"]),
                  "ours": list(r["ours"]), "paper": list(r["paper"])}
                 for r in rows],
        "rows_fully_matching": sum(r["match"] for r in rows),
        "cells_matching": sum(r["cells_matching"] for r in rows),
        "cells_total": 6 * len(rows),
    }


def main() -> None:
    ap = make_parser(__doc__, "BENCH_table1.json")
    args = ap.parse_args()
    out = report(verbose=not args.smoke)
    emit_report(out, "table1", args.out, smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
