"""Paper Table I: cross-rack / intra-rack communication cost of Uncoded,
Coded and Hybrid Coded MapReduce for the paper's nine (K,P,Q,N,r) rows —
closed forms (Props 1-2, Thm III.1) AND, where the divisibility hypotheses
admit an executable schedule, the enumerated message counts (proving the
formulas describe a realizable shuffle).

Values are in thousands of <key,value> transfers, as in the paper.
Discrepant paper cells are flagged (see EXPERIMENTS.md §Fidelity).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.costs import coded_cost, hybrid_cost, uncoded_cost
from repro.core.params import SchemeParams

# (K, P, Q, N, r) -> paper's printed values /1000:
# (unc_cro, cod_cro, hyb_cro, unc_int, cod_int, hyb_int)
PAPER_ROWS: List[Tuple[Tuple[int, int, int, int, int],
                       Tuple[float, ...]]] = [
    ((9, 3, 18, 72, 2), (0.864, 0.486, 0.216, 0.288, 0.018, 0.864)),
    ((16, 4, 16, 240, 2), (2.88, 1.632, 0.96, 0.72, 0.048, 2.88)),
    ((16, 4, 16, 1680, 3), (20.16, 6.976, 2.24, 5.04, 0.304, 20.16)),
    ((15, 3, 15, 210, 2), (2.1, 1.275, 0.525, 0.84, 0.09, 2.520)),
    ((20, 4, 20, 380, 2), (5.7, 3.3, 1.9, 1.52, 0.12, 0.608)),
    ((25, 5, 25, 600, 2), (12, 6.75, 4.5, 2.4, 1.5, 12)),
    ((25, 5, 25, 6900, 3), (138, 50.6, 23, 27.6, 0.1, 13.8)),
    ((30, 5, 30, 870, 2), (16.56, 11.88, 7.83, 3.45, 0.3, 17.25)),
    ((30, 6, 30, 870, 2), (21.75, 12, 8.7, 3.48, 0.18, 20.88)),
]


def run(verbose: bool = True) -> List[dict]:
    rows = []
    for (K, P, Q, N, r), paper in PAPER_ROWS:
        t0 = time.perf_counter()
        p = SchemeParams(K=K, P=P, Q=Q, N=N, r=r)
        unc = uncoded_cost(p, check=False)
        cod = coded_cost(p, check=False)
        hyb = hybrid_cost(p, check=False)
        ours = (unc.cross, cod.cross, hyb.cross,
                unc.intra, cod.intra, hyb.intra)
        ours_k = tuple(v / 1000.0 for v in ours)
        match = [abs(a - b) / max(abs(b), 1e-9) < 5e-3
                 for a, b in zip(ours_k, paper)]
        rows.append({
            "params": (K, P, Q, N, r), "ours": ours_k, "paper": paper,
            "cells_matching": sum(match), "match": all(match),
            "us": (time.perf_counter() - t0) * 1e6,
        })
        if verbose:
            flag = "" if all(match) else \
                f"   <- {6 - sum(match)} paper cell(s) disagree"
            print(f"({K},{P},{Q},{N},{r}): "
                  + " ".join(f"{v:8.3f}" for v in ours_k) + flag)
    n_match = sum(r["match"] for r in rows)
    if verbose:
        print(f"rows fully matching the paper: {n_match}/9 "
              "(mismatches are paper typos contradicting its own Thm III.1;"
              " see EXPERIMENTS.md)")
    return rows


def main() -> None:
    rows = run(verbose=False)
    for r in rows:
        K, P, Q, N, rr = r["params"]
        print(f"table1_{K}_{P}_{Q}_{N}_{rr},{r['us']:.1f},"
              f"match={r['cells_matching']}/6")


if __name__ == "__main__":
    run()
