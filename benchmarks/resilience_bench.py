"""Resilience benchmark: the cloning-vs-coding frontier + hedged r-policy,
to ``BENCH_resilience.json``.

Sections (all seeded -> deterministic):

  * ``frontier`` — mean/p99 JCT vs replication budget (map copies:
    ``uncoded r=1`` + clone budget against ``coded``/``hybrid`` at the
    row's r) for every speculation policy {none, clone, late, mantri} over
    the straggler regimes {NoStragglers, ExponentialTail, RackCorrelated}
    on the paper's Table I grid.  HARD assertions:
      - speculation is a bit-identical NO-OP under NoStragglers (per-seed
        JCTs of clone/late/mantri == the none policy's, exactly);
      - ``late`` and ``clone`` strictly improve summed p99 JCT under
        ExponentialTail, with no single cell regressing;
      - ``mantri`` strictly improves summed p99 under RackCorrelated (its
        design regime; aggregate only — cause attribution is heuristic);
      - one frontier cell re-simulated twice produces a bit-identical
        event trace (per-seed determinism with speculation enabled).
  * ``frontier_curves`` — per regime, the best (scheme, r, policy) at each
    budget: the literal answer to "when does cloning beat coding".
  * ``hedged_vs_static`` — multi-job streams under RackCorrelated: the
    straggler-aware :class:`repro.resilience.HedgedRPolicy` (probe-fit +
    online refits, rack-hedged structured placements) against the static
    fetch-aware chooser on the same stream.  HARD assertion: hedged wins
    p99 (and mean) JCT.
"""
from __future__ import annotations

from typing import Dict, Optional

try:
    from ._common import emit_report, make_parser
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser

from repro.resilience import (DEFAULT_POLICIES, TABLE1_ROWS,
                              check_frontier_invariants,
                              cloning_vs_coding_frontier, frontier_curve,
                              get_policy, hedged_vs_static_stream,
                              straggler_regimes)
from repro.sim import (ClusterSim, CostModel, ExponentialTail, JobSpec,
                       PhaseCoeffs, RackCorrelated, RackTopology)

# compute costs sized so map time is commensurate with shuffle time on the
# Table I grid at the bench bandwidths — the regime where the
# cloning-vs-coding tradeoff is live (map-free sims cannot straggle)
BENCH_COST = CostModel(
    map=PhaseCoeffs(alpha=1e-4, beta=2e-8),
    pack=PhaseCoeffs(alpha=5e-5, beta=1e-8),
    reduce=PhaseCoeffs(alpha=1e-4, beta=2e-8),
    plan_compile=PhaseCoeffs(alpha=5e-3, beta=1e-6),
)
INTRA_BW = 1e7
CROSS_BW = 1e6


def _determinism_check(seed: int = 7) -> bool:
    """One straggling frontier cell, simulated twice: traces must be
    bit-identical with speculation enabled."""
    def run():
        topo = RackTopology(P=3, cross_bw=CROSS_BW, intra_bw=INTRA_BW)
        sim = ClusterSim(topo, 9, BENCH_COST, ExponentialTail(1.0), seed,
                         speculation=get_policy("late"))
        sim.submit(JobSpec("histogram", 72, 18, 1), "hybrid", 2)
        stats = sim.run()
        return [s.jct for s in stats], list(sim.trace)

    (j1, t1), (j2, t2) = run(), run()
    return j1 == j2 and t1 == t2


def run(smoke: bool = False, seed: int = 0, iters: int = 10,
        verbose: bool = True) -> Dict:
    """``iters`` = independent straggler seeds per frontier cell."""
    rows = TABLE1_ROWS[:3] if smoke else TABLE1_ROWS
    n_seeds = 5 if smoke else iters
    regimes = straggler_regimes(exp_scale=1.0, rack_p=0.25, rack_factor=4.0)

    cells = cloning_vs_coding_frontier(
        rows=rows, policies=DEFAULT_POLICIES, regimes=regimes,
        cost=BENCH_COST, intra_bw=INTRA_BW, cross_bw=CROSS_BW,
        n_seeds=n_seeds, tasks_per_server=8)
    invariants = check_frontier_invariants(cells)
    curves = {name: frontier_curve(cells, name) for name in regimes}

    hedged = hedged_vs_static_stream(
        K=8, P=4, stragglers=RackCorrelated(0.25, 4.0), cost=BENCH_COST,
        intra_bw=1e6, cross_bw=1e5, rate=4.0,
        n_jobs=30 if smoke else 80, n_probe=15 if smoke else 30, seed=seed)

    deterministic = _determinism_check()

    if verbose:
        print(f"frontier: {len(cells)} cells over {len(rows)} rows x "
              f"{len(regimes)} regimes x {len(DEFAULT_POLICIES)} policies")
        print(f"invariants: {invariants}")
        for name, curve in curves.items():
            print(f"frontier[{name}]: " + " | ".join(
                f"budget {c['budget']:g}: {c['scheme']} r={c['r']} "
                f"{c['policy']} p99={c['p99_jct']:.4f}" for c in curve))
        h = hedged
        print(f"hedged fit: {h['fit']}")
        print(f"hedged p99 {h['hedged']['p99_jct']:.4f} vs static "
              f"{h['static']['p99_jct']:.4f} | mean "
              f"{h['hedged']['mean_jct']:.4f} vs "
              f"{h['static']['mean_jct']:.4f}")
        print(f"speculation-enabled traces deterministic: {deterministic}")

    failures = [k for k, v in invariants.items() if not v]
    if failures:
        raise RuntimeError(f"frontier invariants failed: {failures}")
    if not hedged["hedged_beats_static_p99"]:
        raise RuntimeError(
            "hedged r-policy lost to the static chooser on p99 under "
            f"RackCorrelated: {hedged}")
    if not deterministic:
        raise RuntimeError("speculation-enabled trace not deterministic")

    return {
        "cluster": {"intra_bw": INTRA_BW, "cross_bw": CROSS_BW,
                    "cost_model": "BENCH_COST (see resilience_bench.py)"},
        "n_seeds": n_seeds,
        "frontier": [c.to_row() for c in cells],
        "frontier_curves": curves,
        "invariants": invariants,
        "hedged_vs_static": hedged,
        "trace_deterministic": deterministic,
    }


def main() -> None:
    ap = make_parser(__doc__, "BENCH_resilience.json", default_iters=10)
    args = ap.parse_args()
    report = run(smoke=args.smoke, seed=args.seed, iters=args.iters)
    emit_report(report, "resilience", args.out, smoke=args.smoke,
                seed=args.seed)


if __name__ == "__main__":
    main()
