"""Executable-shuffle benchmark: runs the REAL distributed two-stage hybrid
shuffle (shard_map all_to_all over a ('rack','server') host-device mesh)
against the dense oracle, times the coded-combine kernel paths, sweeps the
map-replication factor r (the paper's computation/communication tradeoff
curve, emitting per-r cross/intra traffic), and times general-r plan
compilation (cold vs LRU-cached).

Byte accounting comes from the schedule enumerator (== closed forms,
asserted); wall-times here are CPU host-device times (structural, not TPU
perf — the TPU story is the dry-run roofline)."""
from __future__ import annotations

import os
import subprocess
import sys
import time

try:
    from ._common import emit_report, make_parser
except ImportError:                       # run as a script, not a package
    from _common import emit_report, make_parser

# r sweep config: P=4 racks x Kr=2; N=2016 satisfies C(4,r) | NP/K and
# r | M for r in {1, 2, 3, 4} — one config, the whole tradeoff curve.
SWEEP = dict(K=8, P=4, Q=16, N=2016)
PAYLOAD_BYTES = 4                    # fp32 <key, value> payload unit


def _kernel_times(iters: int = 10, smoke: bool = False) -> list:
    import jax
    import jax.numpy as jnp
    from repro.kernels.coded_combine import ops
    rows = []
    key = jax.random.PRNGKey(0)
    shapes = [(2, 4096, 256), (3, 4096, 256), (4, 16384, 512)]
    for r, T, d in shapes[:1] if smoke else shapes:
        streams = [jax.random.normal(jax.random.fold_in(key, i), (T, d))
                   for i in range(r)]
        coeffs = jnp.arange(1.0, r + 1.0)
        f = ops.coded_encode(streams, coeffs)          # compile
        f.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f = ops.coded_encode(streams, coeffs)
        f.block_until_ready()
        enc_us = (time.perf_counter() - t0) / iters * 1e6
        dec = ops.coded_decode(f, streams[1:], coeffs)
        dec.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            dec = ops.coded_decode(f, streams[1:], coeffs)
        dec.block_until_ready()
        dec_us = (time.perf_counter() - t0) / iters * 1e6
        gb = r * T * d * 4 / 1e9
        rows.append((f"coded_encode_r{r}_{T}x{d}", enc_us,
                     f"{gb / (enc_us / 1e6):.2f}GB/s-interp"))
        rows.append((f"coded_decode_r{r}_{T}x{d}", dec_us,
                     f"{gb / (dec_us / 1e6):.2f}GB/s-interp"))
    return rows


def _r_sweep() -> list:
    """Per-r shuffle traffic (closed forms == enumerated schedule, asserted
    in tests) and general-r plan-compilation time, cold vs cached."""
    from repro.core.coded_collectives import compile_hybrid_plan
    from repro.core.costs import hybrid_cost
    from repro.core.params import SchemeParams

    rows = []
    for r in (1, 2, 3, 4):
        p = SchemeParams(r=r, **SWEEP)
        compile_hybrid_plan.cache_clear()
        t0 = time.perf_counter()
        compile_hybrid_plan(p)
        cold_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        compile_hybrid_plan(p)
        warm_us = (time.perf_counter() - t0) * 1e6
        c = hybrid_cost(p)
        rows.append((f"compile_plan_r{r}_N{p.N}", cold_us,
                     f"cached={warm_us:.0f}us "
                     f"cross={c.cross * PAYLOAD_BYTES:.0f}B "
                     f"intra={c.intra * PAYLOAD_BYTES:.0f}B"))
    return rows


def run(verbose: bool = True, iters: int = 10, smoke: bool = False) -> list:
    """``smoke`` keeps one kernel shape and skips the ~5-min 8-device
    subprocess — the reduced CI profile."""
    rows = _kernel_times(iters=iters, smoke=smoke) + _r_sweep()
    if not smoke:
        # distributed shuffle in a subprocess (needs 8 host devices)
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable,
             os.path.join(root, "tests", "multidevice", "driver_shuffle.py")],
            capture_output=True, text=True, timeout=900,
            env={**os.environ, "PYTHONPATH": os.path.join(root, "src")})
        ok = proc.returncode == 0 and "ALL MULTIDEVICE" in proc.stdout
        rows.append(("distributed_hybrid_shuffle_8dev_r123",
                     (time.perf_counter() - t0) * 1e6,
                     "bit-exact" if ok else "FAILED"))
    if verbose:
        for name, us, derived in rows:
            print(f"{name:40s} {us:12.1f} us  {derived}")
    return rows


def main() -> None:
    # CSV entry point of the `python -m benchmarks.run` aggregator
    rows = run(verbose=False)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def cli() -> None:
    # no --seed / envelope seed: this bench times fixed workloads, nothing
    # here is rng-driven
    args = make_parser(__doc__, "BENCH_shuffle.json",
                       add_seed=False).parse_args()
    rows = run(verbose=True, iters=2 if args.smoke else args.iters,
               smoke=args.smoke)
    emit_report(
        {"results": [{"name": n, "us": us, "derived": derived}
                     for n, us, derived in rows]},
        "shuffle", args.out, smoke=args.smoke)


if __name__ == "__main__":
    cli()
