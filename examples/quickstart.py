"""Quickstart: the paper's scheme end to end in two minutes on CPU.

1. Closed-form costs of Uncoded / Coded / Hybrid (Props 1-2, Thm III.1).
2. An executable MapReduce job (histogram) shuffled under the hybrid
   scheme, results asserted equal to the single-device oracle.
3. The Section-IV locality optimizer on one Table-II row.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import cost_table
from repro.core.locality import table2_experiment
from repro.core.params import SchemeParams
from repro.mapreduce.engine import run_job
from repro.mapreduce.jobs import histogram_job

# -- 1. communication costs ---------------------------------------------------
p = SchemeParams(K=16, P=4, Q=16, N=240, r=2)
print(f"cluster: K={p.K} servers, P={p.P} racks, N={p.N} subfiles, "
      f"Q={p.Q} keys, map replication r={p.r}\n")
print(f"{'scheme':10s} {'cross-rack':>12s} {'intra-rack':>12s} {'total':>12s}")
for name, c in cost_table(p).items():
    print(f"{name:10s} {c.cross:12.0f} {c.intra:12.0f} {c.total:12.0f}")
hyb = cost_table(p)["hybrid"]
unc = cost_table(p)["uncoded"]
print(f"\nhybrid cuts cross-rack (slow-tier) traffic by "
      f"{unc.cross / hyb.cross:.2f}x vs uncoded "
      f"(paper: ~r = {p.r}x for large P)\n")

# -- 2. an executable job under the hybrid shuffle ---------------------------
key = jax.random.PRNGKey(0)
subfiles = jax.random.randint(key, (p.N, 512), 0, 1 << 20, dtype=jnp.int32)
job = histogram_job()
res_hyb = run_job(job, subfiles, p, scheme="hybrid")
res_unc = run_job(job, subfiles, p, scheme="uncoded")
np.testing.assert_array_equal(np.asarray(res_hyb.outputs),
                              np.asarray(res_unc.outputs))
print(f"histogram job: outputs identical under hybrid and uncoded shuffles "
      f"(checksum {float(res_hyb.outputs.sum()):.0f})")
print(f"  hybrid cross-rack cost {res_hyb.cross_cost:.0f} "
      f"vs uncoded {res_unc.cross_cost:.0f}\n")

# -- 3. locality optimization (Section IV) ------------------------------------
p2 = SchemeParams(K=9, P=3, Q=9, N=144, r=2, r_f=2)
res = table2_experiment(p2, lam=0.8, seed=0)
print("locality (Table II row (9,3,2,144)):")
print(f"  node locality: random {100 * res.node_random:.0f}% -> "
      f"optimized {100 * res.node_opt:.0f}%  (paper: 17% -> 64%)")
print(f"  rack locality: random {100 * res.rack_random:.0f}% -> "
      f"optimized {100 * res.rack_opt:.0f}%  (paper: 57% -> 86%)")
