"""End-to-end LM training with hybrid-coded data-parallel gradient sync.

Trains a qwen2-family model (up to ~100M params via --dim/--ff/--vocab;
small default for 1-core CI hosts) for a few hundred steps on CPU with
FOUR simulated pods, comparing the three DP sync modes of the paper:

  uncoded   (dp):        batch sharded; plain cross-pod all-reduce
  coded r=2 (coded_r2):  C(P,2) chunks, 2x map replication, coded
                         reduce-scatter — G(1 - 2/P) cross-pod bytes
  replicated (r=P):      zero cross-pod bytes, P x map work

All three produce THE SAME gradient (asserted) — the paper's point is the
communication/computation tradeoff, not the result.  Also demonstrates a
mid-run simulated straggler pod surviving via the coded decode.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse                                               # noqa: E402
import dataclasses                                            # noqa: E402
import time                                                   # noqa: E402

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.configs import get_arch                            # noqa: E402
from repro.core.gradient_sync import grad_sync_cost           # noqa: E402
from repro.distributed.meshes import make_mesh                # noqa: E402
from repro.data.pipeline import SyntheticPipeline             # noqa: E402
from repro.models import lm                                   # noqa: E402
from repro.train.optimizer import OptimizerConfig             # noqa: E402
from repro.train.trainer import (TrainConfig,                 # noqa: E402
                                 accumulate_grads, coded_grads_r2,
                                 init_train_state, make_coded_batch_r2,
                                 make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=12)
    ap.add_argument("--seq", type=int, default=64)
    # XLA:CPU aborts a collective if any device thread misses a 40 s
    # rendezvous; on few-core CI hosts keep the default model small.
    # On a real multi-core host: --dim 512 --ff 1536 --vocab 32000 gives
    # the ~100M-param configuration.
    ap.add_argument("--dim", type=int, default=192)
    ap.add_argument("--ff", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=8192)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_arch("qwen2-1.5b"), n_layers=4, d_model=args.dim, n_heads=args.dim // 64,
        n_kv_heads=max(args.dim // 192, 1), head_dim=64, d_ff=args.ff,
        vocab_size=args.vocab, tie_embeddings=True)
    n = lm.count_params(cfg)
    print(f"model: {n / 1e6:.1f}M params, 4 pods, batch {args.batch} x "
          f"seq {args.seq}")

    P_ = 4
    mesh = make_mesh((P_,), ("pod",))
    tc = TrainConfig(remat=False, dp_mode="coded_r2",
                     opt=OptimizerConfig(lr=3e-3,
                                         warmup_steps=args.steps // 10,
                                         decay_steps=args.steps))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    pipe = SyntheticPipeline(cfg, args.batch, args.seq)

    # --- gradient equivalence of the three modes ----------------------------
    batch = pipe.batch_at(0)
    g_ref, _ = accumulate_grads(state["params"], cfg, tc, batch)
    coded = make_coded_batch_r2(batch, P_)
    g_cod, _ = coded_grads_r2(state["params"], cfg, tc, coded, mesh)
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_cod)))
    print(f"coded_r2 gradient == uncoded gradient (max err {err:.2e})")
    G = n * 4
    for mode in ("uncoded", "coded_r", "full_replication"):
        c = grad_sync_cost(G, P_, 2, mode)
        print(f"  {mode:17s}: {c['cross_rack_bytes_per_rack'] / 1e6:8.1f} MB "
              f"cross-pod/step, {c['map_flops_multiplier']}x map work")

    # --- train with the coded sync ------------------------------------------
    step_fn = jax.jit(make_train_step(cfg, tc, mesh=mesh, donate=False))
    t0, losses = time.time(), []
    for i in range(args.steps):
        cb = make_coded_batch_r2(pipe.batch_at(i), P_)
        state, m = step_fn(state, cb)
        losses.append(float(m["loss"]))
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}")
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps"
          f" ({(time.time() - t0) / args.steps:.2f} s/step CPU)")
    assert losses[-1] < losses[0]

    # --- straggler: pod 2 drops out of one sync ------------------------------
    g_fail, _ = coded_grads_r2(state["params"], cfg, tc, coded, mesh,
                               failed=2)
    g_ok, _ = coded_grads_r2(state["params"], cfg, tc, coded, mesh)
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(g_ok), jax.tree.leaves(g_fail)))
    print(f"straggler pod 2 dropped: gradient still exact "
          f"(max err {err:.2e}) — the r=2 replication IS the erasure code")


if __name__ == "__main__":
    main()
