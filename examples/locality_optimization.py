"""Section IV walkthrough: why random hybrid assignments waste locality and
how the exact min-cost-flow solver recovers it — with the optimizer's
assignment verified against Theorem IV.1's four structural constraints.

    PYTHONPATH=src python examples/locality_optimization.py
"""
import numpy as np

from repro.core.assignment import check_hybrid_constraints, hybrid_assignment
from repro.core.locality import (greedy_perm, locality_matrix,
                                 locality_of_perm, optimal_perm,
                                 place_replicas, random_perm)
from repro.core.params import SchemeParams

p = SchemeParams(K=16, P=4, Q=16, N=192, r=2, r_f=3)
rng = np.random.default_rng(0)

print(f"K={p.K} servers in P={p.P} racks; N={p.N} subfiles stored with "
      f"r_f={p.r_f} HDFS-style replicas; map replication r={p.r}")

replicas = place_replicas(p, rng, policy="hdfs")
C = locality_matrix(p, replicas, lam=0.8)

perms = {
    "random": random_perm(p, rng),
    "greedy": greedy_perm(p, C),
    "optimal (min-cost flow)": optimal_perm(p, C),
}
print(f"\n{'assignment':26s} {'node locality':>14s} {'rack locality':>14s}")
for name, perm in perms.items():
    node, rack = locality_of_perm(p, replicas, perm)
    print(f"{name:26s} {100 * node:13.1f}% {100 * rack:13.1f}%")
    # every permutation must still be a VALID hybrid scheme (Thm IV.1)
    check_hybrid_constraints(hybrid_assignment(p, perm=perm.tolist()))
print("\nall three assignments satisfy Theorem IV.1's constraints "
      "(no intra-rack replication; 0-or-M shared files; degree P-1; "
      "layer transitivity) — locality is a FREE degree of freedom")

print("\nthe flow solver is EXACT: LP integrality of transportation "
      "polytopes makes the relaxation tight (DESIGN.md §2)")
