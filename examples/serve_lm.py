"""Batched serving with KV caches across architecture families: dense GQA,
MLA-compressed (deepseek), attention-free (rwkv6) and hybrid (hymba) —
each at a reduced config, with per-family decode-state size printed
(the decode-memory story behind the decode_32k / long_500k dry-run cells).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import lm
from repro.models.mla import mla_cache_bytes_per_token
from repro.serve.engine import Request, ServeEngine


def decode_state_bytes_per_token(cfg) -> str:
    if cfg.mla:
        return (f"{mla_cache_bytes_per_token(cfg)}B/tok/layer "
                "(MLA latent, vs "
                f"{2 * cfg.n_heads * cfg.head_dim * 2}B for full MHA)")
    if cfg.attn_free:
        return "O(1): constant WKV state, no KV growth"
    if cfg.sliding_window:
        return (f"ring cache capped at window={cfg.sliding_window} "
                "+ O(1) SSM state")
    return f"{2 * cfg.n_kv_heads * cfg.head_dim * 2}B/tok/layer (GQA KV)"


def main() -> None:
    rng = np.random.default_rng(0)
    for arch in ("qwen2-1.5b", "deepseek-v2-lite-16b", "rwkv6-3b",
                 "hymba-1.5b"):
        cfg = ARCHS[arch].reduced()
        params = lm.init_params(jax.random.PRNGKey(1), cfg)
        eng = ServeEngine(cfg, params, batch_slots=2, max_seq=96,
                          dense_moe=True)
        reqs = [Request(rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                        max_new_tokens=8),
                Request(rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
                        max_new_tokens=8, temperature=0.0)]
        done = eng.serve(reqs)
        print(f"{arch:24s} -> {done[0].out_tokens[:6]}...  "
              f"decode state: {decode_state_bytes_per_token(ARCHS[arch])}")
    print("\n(full-size decode shapes are exercised by the dry-run: "
          "decode_32k for all, long_500k for rwkv6/hymba)")


if __name__ == "__main__":
    main()
