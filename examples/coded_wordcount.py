"""Coded WordCount: the paper's scheme running DISTRIBUTED on a 12-device
host mesh (3 racks x 4 servers), with the real shard_map all_to_all
two-stage shuffle, validated bit-exactly against the dense oracle — swept
over the map-replication factor r in {1, 2, 3}, the paper's
computation/communication tradeoff axis.

    PYTHONPATH=src python examples/coded_wordcount.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=12 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core.costs import uncoded_cost                     # noqa: E402
from repro.core.params import SchemeParams                    # noqa: E402
from repro.distributed.meshes import make_mesh                # noqa: E402
from repro.mapreduce.engine import (run_job,                  # noqa: E402
                                    run_job_distributed)
from repro.mapreduce.jobs import histogram_job                # noqa: E402

# 3 racks x 4 servers; N=96 admits every replication factor r in {1, 2, 3}
p = SchemeParams(K=12, P=3, Q=24, N=96, r=2)
mesh = make_mesh((p.P, p.Kr), ("rack", "server"))
print(f"mesh: {p.P} racks x {p.Kr} servers = {p.K} devices")

key = jax.random.PRNGKey(7)
subfiles = np.asarray(
    jax.random.randint(key, (p.N, 1024), 0, 1 << 16, dtype=jnp.int32))
job = histogram_job()

oracle = run_job(job, jnp.asarray(subfiles), p, scheme="hybrid",
                 count_messages=True)
unc = uncoded_cost(p)

print(f"\n{'r':>3} {'cross <k,v>':>12} {'intra <k,v>':>12} "
      f"{'vs uncoded cross':>17}")
for r in (1, 2, 3):
    dist = run_job_distributed(job, subfiles, p, mesh, r=r)
    np.testing.assert_array_equal(np.asarray(dist.outputs),
                                  np.asarray(oracle.outputs))
    assert int(dist.outputs.sum()) == p.N * 1024      # token conservation
    ratio = (unc.cross / dist.cross_cost if dist.cross_cost
             else float("inf"))
    print(f"{r:>3} {dist.cross_cost:>12.0f} {dist.intra_cost:>12.0f} "
          f"{ratio:>16.2f}x")
print("\nevery r: distributed two-stage shuffle == dense oracle (bit-exact)")
print(f"r=2 enumerated schedule == closed form: "
      f"cross {oracle.cross_cost:.0f}, intra {oracle.intra_cost:.0f}")
