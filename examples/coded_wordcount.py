"""Coded WordCount: the paper's scheme running DISTRIBUTED on a 12-device
host mesh (3 racks x 4 servers), with the real shard_map all_to_all
two-stage shuffle, validated bit-exactly against the dense oracle — swept
over the map-replication factor r in {1, 2, 3}, the paper's
computation/communication tradeoff axis.

``--placement {random,greedy,anneal}`` additionally runs each r under a
Section-IV locality-aware placement (repro.placement): an HDFS-style
replica draw, the chosen solver's slot permutation threaded into the
executable plan, and the achieved node/rack locality printed next to the
communication costs.

``--scheme-family resolvable`` re-racks the same 12 devices as 6 racks x 2
servers and shuffles N=48 shards — a size the binomial construction cannot
handle at ANY r >= 2 (C(6, r) never divides the 24 per-layer subfiles) but
the resolvable single-parity-check design shuffles at r in {2, 3}: the K
wall the family exists to break (docs/scaling.md).

    PYTHONPATH=src python examples/coded_wordcount.py [--placement greedy]
        [--scheme-family {binomial,resolvable}]
"""
import argparse
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=12 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core.costs import uncoded_cost                     # noqa: E402
from repro.core.params import SchemeParams                    # noqa: E402
from repro.distributed.meshes import make_mesh                # noqa: E402
from repro.mapreduce.engine import (run_job,                  # noqa: E402
                                    run_job_distributed)
from repro.mapreduce.jobs import histogram_job                # noqa: E402

PLACEMENT_SOLVERS = {"random": "random", "greedy": "greedy",
                     "anneal": "anneal_jax"}

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--placement", choices=sorted(PLACEMENT_SOLVERS),
                default=None,
                help="run each r under a locality-aware placement and "
                     "print the achieved node/rack locality")
ap.add_argument("--scheme-family", choices=("binomial", "resolvable"),
                default="binomial",
                help="plan-compiler family; 'resolvable' demonstrates a "
                     "shard count infeasible for every binomial r >= 2")
ap.add_argument("--seed", type=int, default=7)
args = ap.parse_args()

if args.scheme_family == "binomial":
    # 3 racks x 4 servers; N=96 admits every replication r in {1, 2, 3}
    p = SchemeParams(K=12, P=3, Q=24, N=96, r=2)
    rs = (1, 2, 3)
else:
    # 6 racks x 2 servers, N=48: per-layer 24 is divisible by NO C(6, r)
    # with r >= 2, but the SPC design is feasible at r=2 (q=3) and r=3
    # (q=2) — same hardware, past the binomial wall
    if args.placement:
        ap.error("--placement solvers target the binomial group structure; "
                 "drop it with --scheme-family resolvable")
    p = SchemeParams(K=12, P=6, Q=24, N=48, r=2)
    rs = (2, 3)
mesh = make_mesh((p.P, p.Kr), ("rack", "server"))
print(f"mesh: {p.P} racks x {p.Kr} servers = {p.K} devices "
      f"({args.scheme_family} family)")

key = jax.random.PRNGKey(args.seed)
subfiles = np.asarray(
    jax.random.randint(key, (p.N, 1024), 0, 1 << 16, dtype=jnp.int32))
job = histogram_job()

scheme = "hybrid" if args.scheme_family == "binomial" else "hybrid_resolvable"
oracle = run_job(job, jnp.asarray(subfiles), p, scheme=scheme,
                 count_messages=True)
unc = uncoded_cost(p, check=False)

loc_hdr = " " + f"{'node/rack local':>16s}" if args.placement else ""
print(f"\n{'r':>3} {'cross <k,v>':>12} {'intra <k,v>':>12} "
      f"{'vs uncoded cross':>17}{loc_hdr}")
for r in rs:
    placement = None
    loc_col = ""
    if args.placement:
        import dataclasses

        from repro.placement import place_replicas, solve
        p_r = dataclasses.replace(p, r=r)
        rng = np.random.default_rng(args.seed + r)
        replicas = place_replicas(p_r, rng)
        placement = solve(p_r, replicas, PLACEMENT_SOLVERS[args.placement],
                          rng=rng)
        loc_col = (f" {100 * placement.node_locality:7.1f}/"
                   f"{100 * placement.rack_locality:5.1f}%")
    dist = run_job_distributed(job, subfiles, p, mesh, r=r,
                               placement=placement,
                               scheme_family=args.scheme_family)
    np.testing.assert_array_equal(np.asarray(dist.outputs),
                                  np.asarray(oracle.outputs))
    assert int(dist.outputs.sum()) == p.N * 1024      # token conservation
    ratio = (unc.cross / dist.cross_cost if dist.cross_cost
             else float("inf"))
    print(f"{r:>3} {dist.cross_cost:>12.0f} {dist.intra_cost:>12.0f} "
          f"{ratio:>16.2f}x{loc_col}")
print("\nevery r: distributed two-stage shuffle == dense oracle (bit-exact)"
      + (" under the optimized placement" if args.placement else ""))
print(f"r={p.r} enumerated schedule == closed form: "
      f"cross {oracle.cross_cost:.0f}, intra {oracle.intra_cost:.0f}")
