"""Coded WordCount: the paper's scheme running DISTRIBUTED on a 12-device
host mesh (3 racks x 4 servers), with the real shard_map all_to_all
two-stage shuffle, validated bit-exactly against the dense oracle.

    PYTHONPATH=src python examples/coded_wordcount.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=12 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core.params import SchemeParams                    # noqa: E402
from repro.mapreduce.engine import (run_job,                  # noqa: E402
                                    run_job_distributed)
from repro.mapreduce.jobs import histogram_job                # noqa: E402

# 3 racks x 4 servers; map replication r=2 across racks
p = SchemeParams(K=12, P=3, Q=24, N=96, r=2)
mesh = jax.make_mesh((p.P, p.Kr), ("rack", "server"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
print(f"mesh: {p.P} racks x {p.Kr} servers = {p.K} devices")

key = jax.random.PRNGKey(7)
subfiles = np.asarray(
    jax.random.randint(key, (p.N, 1024), 0, 1 << 16, dtype=jnp.int32))
job = histogram_job()

dist = run_job_distributed(job, subfiles, p, mesh)
oracle = run_job(job, jnp.asarray(subfiles), p, scheme="hybrid",
                 count_messages=True)
np.testing.assert_array_equal(np.asarray(dist.outputs),
                              np.asarray(oracle.outputs))
print("distributed two-stage shuffle == dense oracle (bit-exact)")
print(f"token count conservation: {float(dist.outputs.sum()):.0f} == "
      f"{p.N * 1024}")
assert int(dist.outputs.sum()) == p.N * 1024

print(f"\nshuffle cost (enumerated schedule == closed form):")
print(f"  cross-rack: {oracle.cross_cost:10.0f} <key,value> transfers")
print(f"  intra-rack: {oracle.intra_cost:10.0f}")
from repro.core.costs import uncoded_cost                     # noqa: E402
unc = uncoded_cost(p)
print(f"  (uncoded cross-rack would be {unc.cross:.0f} — "
      f"{unc.cross / oracle.cross_cost:.2f}x more root-switch traffic)")
