"""Mixture-of-Experts FFN (DeepSeek-V2-Lite fine-grained MoE; Grok-1 MoE).

Token dispatch to experts IS a MapReduce shuffle (tokens = intermediate
<key, value> pairs keyed by destination expert; experts = reducers), which is
why the paper's hierarchical shuffle applies directly to this layer — see
:mod:`repro.distributed.collectives` for the two-stage expert all-to-all.

This module provides the *math*: router, capacity-based dispatch/combine
(GSPMD-style dense einsums that shard cleanly under pjit), and the expert
FFNs.  Two dispatch paths:

  * ``moe_ffn_dense``    — capacity-less one-hot combine; exact, O(T*E) memory;
                           used by smoke tests / tiny configs.
  * ``moe_ffn_capacity`` — fixed expert capacity C with token dropping, the
                           production path (einsum dispatch keeps everything
                           static-shaped for XLA/TPU and shards over the
                           'model' (expert) axis).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoEConfig
from .layers import dense_init


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_moe_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    """Per-layer MoE params (stacked expert weights: [E, ...])."""
    m = cfg.moe
    assert m is not None
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    E = m.n_routed
    p = {
        "router": dense_init(ks[0], d, E, dtype=jnp.float32),  # fp32 router
        "w1": _expert_init(ks[1], E, d, m.d_ff_expert, dtype),
        "w3": _expert_init(ks[2], E, d, m.d_ff_expert, dtype),
        "w2": _expert_init(ks[3], E, m.d_ff_expert, d, dtype),
    }
    if m.n_shared:
        ff_sh = m.d_ff_expert * m.n_shared
        p["shared_w1"] = dense_init(ks[4], d, ff_sh, dtype=dtype)
        p["shared_w3"] = dense_init(ks[5], d, ff_sh, dtype=dtype)
        p["shared_w2"] = dense_init(ks[6], ff_sh, d, dtype=dtype)
    return p


def _expert_init(key, E, d_in, d_out, dtype):
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (E, d_in, d_out), jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def route(router_w: jax.Array, x: jax.Array, top_k: int,
          ) -> Tuple[jax.Array, jax.Array]:
    """Softmax-then-TopK routing (DeepSeek-V2 style).

    x: [T, D] tokens.  Returns (weights [T, k] renormalized, ids [T, k]).
    """
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)                           # [T, k]
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    return w, ids


def aux_load_balance_loss(router_w: jax.Array, x: jax.Array,
                          top_k: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean over experts of
    fraction_tokens * fraction_prob * E)."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    _, ids = jax.lax.top_k(probs, top_k)
    counts = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p = probs.mean(axis=0)
    return E * jnp.sum(f * p)


# ---------------------------------------------------------------------------
# Expert FFN application
# ---------------------------------------------------------------------------

def _expert_swiglu(w1, w3, w2, xe):
    """xe: [E, C, D] -> [E, C, D] through per-expert gated MLP."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def moe_ffn_dense(p: Dict, m: MoEConfig, x: jax.Array) -> jax.Array:
    """Exact (capacity-less) MoE: every token through its top-k experts via
    one-hot masking.  [T, D] -> [T, D].  O(T*E*k) combine memory — tiny
    configs only."""
    T, D = x.shape
    w, ids = route(p["router"], x, m.top_k)                    # [T,k]
    onehot = jax.nn.one_hot(ids, m.n_routed, dtype=x.dtype)    # [T,k,E]
    gate = jnp.einsum("tk,tke->te", w.astype(x.dtype), onehot)  # [T,E]
    # process ALL tokens through ALL experts (tiny configs): [E,T,D]
    xe = jnp.broadcast_to(x[None], (m.n_routed, T, D))
    ye = _expert_swiglu(p["w1"], p["w3"], p["w2"], xe)         # [E,T,D]
    out = jnp.einsum("etd,te->td", ye, gate)
    return out + _shared(p, x)


def moe_ffn_capacity(p: Dict, m: MoEConfig, x: jax.Array,
                     capacity: Optional[int] = None) -> jax.Array:
    """Capacity-based dispatch (GSPMD einsum formulation).

    x: [T, D].  Each expert processes at most C tokens; overflow tokens fall
    through with only the shared-expert output (standard TPU MoE).  All
    shapes static => shards under pjit with experts on the 'model' axis.
    """
    T, D = x.shape
    E, k = m.n_routed, m.top_k
    if capacity is None:
        capacity = max(int(T * k * m.capacity_factor / E), 1)
    C = min(capacity, T)
    w, ids = route(p["router"], x, k)                          # [T,k]

    # position of each (token, choice) within its expert's buffer
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32)           # [T,k,E]
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - 1                         # arrival order
    pos = pos.reshape(T, k, E)
    within = (pos * onehot).sum(-1)                            # [T,k]
    keep = within < C
    w = w * keep.astype(w.dtype)

    # dispatch [T, E, C] one-hot  (bool -> dtype einsums)
    pos_oh = jax.nn.one_hot(within, C, dtype=x.dtype)          # [T,k,C]
    disp = jnp.einsum("tke,tkc->tec", onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum("tk,tke,tkc->tec", w.astype(x.dtype),
                      onehot.astype(x.dtype), pos_oh)
    xe = jnp.einsum("td,tec->ecd", x, disp)                    # [E,C,D]
    ye = _expert_swiglu(p["w1"], p["w3"], p["w2"], xe)         # [E,C,D]
    out = jnp.einsum("ecd,tec->td", ye, comb)
    return out + _shared(p, x)


def moe_ffn_sorted(p: Dict, m: MoEConfig, x: jax.Array,
                   n_groups: int = 1,
                   capacity: Optional[int] = None) -> jax.Array:
    """Production dispatch: per-group sort-based routing (GShard-style).

    x: [T, D].  Tokens are split into ``n_groups`` local groups (in the
    sharded step, groups == data shards, so dispatch math is collective-
    free); within a group, token-choices are argsorted by expert id and
    scattered into an [E, C_g, D] buffer — no [T, E, C] one-hot tensor is
    ever materialized (the einsum path's memory cliff at 1M tokens).
    The buffer is annotated ('batch', 'experts', ...) so the expert
    all-to-all emerges from GSPMD when experts live on the 'model' axis.
    """
    from ..distributed.sharding import shard_acts
    T, D = x.shape
    E, k = m.n_routed, m.top_k
    assert T % n_groups == 0, (T, n_groups)
    Tg = T // n_groups
    if capacity is None:
        capacity = max(int(Tg * k * m.capacity_factor / E), 1)
    C = min(capacity, Tg * k)

    w, ids = route(p["router"], x, k)                       # [T, k]
    xg = x.reshape(n_groups, Tg, D)
    wg = w.reshape(n_groups, Tg, k).astype(x.dtype)
    eg = ids.reshape(n_groups, Tg, k)

    def dispatch_one(xl, wl, el):
        e_flat = el.reshape(Tg * k)
        w_flat = wl.reshape(Tg * k)
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        first = jnp.searchsorted(e_sorted, e_sorted, side="left")
        pos = jnp.arange(Tg * k) - first                    # rank in expert
        keep = pos < C
        slot = jnp.where(keep, e_sorted * C + pos, 0)
        tok = order // k
        contrib = jnp.where(keep[:, None], xl[tok], 0)
        xe = jnp.zeros((E * C, D), xl.dtype).at[slot].add(contrib)
        return xe.reshape(E, C, D), (slot, tok, keep,
                                     w_flat[order] * keep.astype(xl.dtype))

    xe, meta = jax.vmap(dispatch_one)(xg, wg, eg)           # [G, E, C, D]
    xe = shard_acts(xe, ("batch", "experts", None, None))
    ye = jax.vmap(lambda b: _expert_swiglu(p["w1"], p["w3"], p["w2"], b))(xe)
    ye = shard_acts(ye, ("batch", "experts", None, None))

    def combine_one(yl, mt):
        slot, tok, keep, wk = mt
        vals = yl.reshape(E * C, D)[slot] * wk[:, None]
        return jnp.zeros((Tg, D), yl.dtype).at[tok].add(
            jnp.where(keep[:, None], vals, 0))

    out = jax.vmap(combine_one)(ye, meta).reshape(T, D)
    return out + _shared(p, x)


def _shared(p: Dict, x: jax.Array) -> jax.Array:
    if "shared_w1" not in p:
        return jnp.zeros_like(x)
    h = jax.nn.silu(x @ p["shared_w1"]) * (x @ p["shared_w3"])
    return h @ p["shared_w2"]


def moe_ffn(p: Dict, m: MoEConfig, x: jax.Array, *,
            dense_dispatch: bool = False, n_groups: int = 1) -> jax.Array:
    """[.., D] -> [.., D]; flattens leading dims to a token axis."""
    lead = x.shape[:-1]
    xt = x.reshape(-1, x.shape[-1])
    if dense_dispatch:
        out = moe_ffn_dense(p, m, xt)
    else:
        out = moe_ffn_sorted(p, m, xt, n_groups=n_groups)
    return out.reshape(*lead, x.shape[-1])
