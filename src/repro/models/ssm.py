"""Selective-SSM (Mamba-style) head used by Hymba's parallel SSM branch.

Per head: a depthwise causal conv, then the selective state-space recurrence

    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t x_t        h in R^{state x hd}
    y_t = C_t^T h_t + D * x_t

mapped onto the shared chunked linear recurrence (mode='inclusive') with
  q_t = C_t,  k_t = dt_t * B_t,  v_t = x_t,  log_w = A * dt_t  (A < 0).

Full-sequence (training / prefill) and single-token (decode) forms; the
recurrent state is O(state x hd) per head — the reason ``long_500k`` is
runnable for hymba (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init
from .linrec import chunked_linear_recurrence, recurrent_step


def init_ssm_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim
    inner = h * hd
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], d, inner, dtype=dtype),     # x path
        "w_gate": dense_init(ks[1], d, inner, dtype=dtype),   # silu gate
        "conv": (jax.random.normal(ks[2], (s.conv_width, inner), jnp.float32)
                 * (1.0 / s.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((inner,), dtype),
        # selective parameters (computed from the post-conv stream)
        "w_B": dense_init(ks[3], inner, h * s.state_dim, dtype=dtype),
        "w_C": dense_init(ks[4], inner, h * s.state_dim, dtype=dtype),
        "w_dt": dense_init(ks[5], inner, h, dtype=dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        # A (negative, per head/state), D skip, out proj
        "log_a": jnp.log(jnp.linspace(1.0, float(s.state_dim),
                                      s.state_dim))[None, :]
        .repeat(h, 0).astype(jnp.float32),                    # [h, state]
        "d_skip": jnp.ones((h, 1), dtype),
        "w_out": dense_init(ks[6], inner, d, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: [B,S,C]; w: [W,C]; prev: [B,W-1,C] carry.
    Returns (y [B,S,C], new carry [B,W-1,C])."""
    W = w.shape[0]
    pad = (jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
           if prev is None else prev.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)                    # [B, S+W-1, C]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    return jax.nn.silu(y), xp[:, -(W - 1):] if W > 1 else pad


def _selective_terms(p: Dict, cfg: ArchConfig, u: jax.Array):
    """u: [..., inner] post-conv stream -> (q, k, v, log_w) per head."""
    s = cfg.ssm
    h, hd = cfg.n_heads, cfg.head_dim
    lead = u.shape[:-1]
    f32 = jnp.float32
    B_t = (u @ p["w_B"]).reshape(*lead, h, s.state_dim)
    C_t = (u @ p["w_C"]).reshape(*lead, h, s.state_dim)
    dt = jax.nn.softplus((u @ p["w_dt"]).astype(f32)
                         + p["dt_bias"].astype(f32))          # [..., h]
    A = -jnp.exp(p["log_a"])                                  # [h, state] < 0
    log_w = dt[..., None] * A                                 # [..., h, state]
    k = B_t.astype(f32) * dt[..., None]
    v = u.reshape(*lead, h, hd)
    return C_t.astype(f32), k, v, log_w


def ssm_forward(p: Dict, cfg: ArchConfig, x: jax.Array,
                state: Optional[Dict] = None, *, chunk: int = 64,
                unroll: bool = False,
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: [B,S,D] -> [B,S,D].  state: {'conv': [B,W-1,inner],
    'ssm': [B,h,state,hd]} for streaming/decode."""
    s = cfg.ssm
    h, hd = cfg.n_heads, cfg.head_dim
    keep_state = state is not None
    u = x @ p["w_in"]
    gate = jax.nn.silu(x @ p["w_gate"])
    u, conv_carry = _causal_conv(u, p["conv"], p["conv_b"],
                                 state["conv"] if keep_state else None)
    q, k, v, log_w = _selective_terms(p, cfg, u)
    out, s_new = chunked_linear_recurrence(
        q, k, v.astype(jnp.float32), log_w,
        initial_state=state["ssm"] if keep_state else None,
        mode="inclusive", chunk=chunk, return_state=keep_state,
        unroll=unroll)
    out = out + v * p["d_skip"].astype(v.dtype)[None, None]
    out = out.reshape(*x.shape[:-1], h * hd).astype(x.dtype)
    out = (out * gate) @ p["w_out"]
    new_state = ({"conv": conv_carry, "ssm": s_new} if keep_state else None)
    return out, new_state


def ssm_step(p: Dict, cfg: ArchConfig, x: jax.Array, state: Dict,
             ) -> Tuple[jax.Array, Dict]:
    """Single-token decode. x: [B,D]."""
    s = cfg.ssm
    h, hd = cfg.n_heads, cfg.head_dim
    u = x @ p["w_in"]                                         # [B, inner]
    gate = jax.nn.silu(x @ p["w_gate"])
    # conv over the carried window
    W = s.conv_width
    window = jnp.concatenate([state["conv"].astype(u.dtype), u[:, None]],
                             axis=1)                          # [B, W, inner]
    y = jnp.einsum("bwc,wc->bc", window, p["conv"]) + p["conv_b"]
    u = jax.nn.silu(y)
    q, k, v, log_w = _selective_terms(p, cfg, u)
    out, ssm_new = recurrent_step(q, k, v.astype(jnp.float32), log_w,
                                  state["ssm"], mode="inclusive")
    out = out + v * p["d_skip"].astype(v.dtype)[None]
    out = out.reshape(x.shape[0], h * hd).astype(x.dtype)
    out = (out * gate) @ p["w_out"]
    return out, {"conv": window[:, 1:], "ssm": ssm_new}


def init_ssm_state(cfg: ArchConfig, batch: int, dtype) -> Dict:
    s = cfg.ssm
    inner = cfg.n_heads * cfg.head_dim
    return {"conv": jnp.zeros((batch, s.conv_width - 1, inner), dtype),
            "ssm": jnp.zeros((batch, cfg.n_heads, s.state_dim, cfg.head_dim),
                             jnp.float32)}
