"""Shared building blocks: norms, MLPs, embeddings, RoPE, init helpers."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def dense_init(key: jax.Array, d_in: int, d_out: int,
               dtype=jnp.float32) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int,
               dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * 0.02).astype(dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
           b1: Optional[jax.Array] = None) -> jax.Array:
    """Llama-style gated MLP: (silu(x W1) * (x W3)) W2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
             b2: jax.Array) -> jax.Array:
    """Whisper-style MLP: gelu(x W1 + b1) W2 + b2."""
    return jax.nn.gelu(x @ w1 + b1, approximate=True) @ w2 + b2


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,S,hd/2]
    cos = jnp.cos(angles)[..., :, None, :]              # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper encoder's fixed sinusoidal embedding table [seq, d]."""
    return sinusoidal_at(jnp.arange(seq), d)


def sinusoidal_at(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embeddings evaluated at arbitrary positions [S] -> [S, d]."""
    pos = positions.astype(jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10_000.0)
                  * jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    tab = jnp.zeros((positions.shape[0], d), jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab
