"""Unified LM covering all 10 assigned architectures.

One parameter/apply system, composed from family-specific mixers:

  family   mixer                       ffn          notes
  ------   -----                       ---          -----
  dense    GQA attention (+rope)       swiglu       qwen2/granite/llama3
  vlm      GQA attention               swiglu       patch-embed prefix (stub)
  moe      GQA or MLA attention        MoE (+dense leading layers)
  ssm      RWKV6 time-mix              RWKV6 channel-mix (attn-free)
  hybrid   parallel GQA + SSM heads    swiglu       hymba
  encdec   bidirectional enc + causal dec w/ cross-attn, gelu mlp   whisper

Layers are grouped into homogeneous *stacks* (``layer_groups``); parameters
of a stack are stacked along a leading layer axis so the forward pass can
``jax.lax.scan`` over them (small HLO, fast compiles) or unroll them
(exact per-layer cost analysis in the dry-run; see launch/dryrun.py).

Activation sharding hints are emitted through
:func:`repro.distributed.sharding.shard_acts` — no-ops unless a policy and
mesh are active, so the same code runs single-device CPU tests and the
512-chip dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import shard_acts, sp_gather, sp_scatter
from .attention import blockwise_attention, ring_cache_attention
from .layers import (apply_rope, dense_init, embed_init, gelu_mlp, layer_norm,
                     rms_norm, sinusoidal_at, sinusoidal_positions, swiglu)
from .mla import (init_mla_cache, init_mla_params, mla_attention)
from .moe import aux_load_balance_loss, init_moe_params, moe_ffn
from .rwkv import (cmix_forward, init_cmix_params, init_tmix_params,
                   init_tmix_state, tmix_forward, tmix_step)
from .ssm import init_ssm_params, init_ssm_state, ssm_forward, ssm_step

# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    kind: str          # attn_mlp | attn_moe | rwkv | hymba | enc | dec
    count: int


def layer_groups(cfg: ArchConfig) -> List[LayerGroup]:
    """Homogeneous layer stacks of the decoder trunk (encoder is separate)."""
    if cfg.family in ("dense", "vlm"):
        return [LayerGroup("attn_mlp", cfg.n_layers)]
    if cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        groups = []
        if fd:
            groups.append(LayerGroup("attn_mlp", fd))
        groups.append(LayerGroup("attn_moe", cfg.n_layers - fd))
        return groups
    if cfg.family == "ssm":
        return [LayerGroup("rwkv", cfg.n_layers)]
    if cfg.family == "hybrid":
        return [LayerGroup("hymba", cfg.n_layers)]
    if cfg.family == "encdec":
        return [LayerGroup("dec", cfg.n_layers)]
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Attention sub-module (GQA, optional bias/rope; self or cross)
# ---------------------------------------------------------------------------

def init_attn_params(key: jax.Array, cfg: ArchConfig, dtype,
                     cross: bool = False) -> Dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype=dtype),
        "wk": dense_init(ks[1], d, KV * hd, dtype=dtype),
        "wv": dense_init(ks[2], d, KV * hd, dtype=dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _qkv(p: Dict, cfg: ArchConfig, xq: jax.Array, xkv: jax.Array,
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = xq @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = xkv @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = xkv @ p["wv"] + (p["bv"] if "bv" in p else 0)
    B, Sq = xq.shape[:2]
    Sk = xkv.shape[1]
    return (q.reshape(B, Sq, H, hd), k.reshape(B, Sk, KV, hd),
            v.reshape(B, Sk, KV, hd))


def attn_forward(p: Dict, cfg: ArchConfig, x: jax.Array,
                 positions: jax.Array, *, causal: bool = True,
                 rope: bool = True, window: Optional[int] = None,
                 cache: Optional[Dict] = None,
                 cache_index: Optional[jax.Array] = None,
                 kv_block: int = 512, unroll: bool = False,
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """Self-attention with optional KV cache (prefill writes, decode reads)."""
    B, S, D = x.shape
    q, k, v = _qkv(p, cfg, x, x)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_acts(q, ("batch", None, "heads", None))
    valid = None
    if cache is not None:
        if "kpos" in cache:                      # ring (sliding-window) cache
            Wc = cache["k"].shape[1]
            # only the last Wc tokens can matter; avoids duplicate-slot writes
            if k.shape[1] > Wc:
                kw, vw, pw = k[:, -Wc:], v[:, -Wc:], positions[-Wc:]
            else:
                kw, vw, pw = k, v, positions
            slot = pw % Wc                       # [min(S, Wc)] distinct slots
            ck = cache["k"].at[:, slot].set(kw.astype(cache["k"].dtype))
            cv = cache["v"].at[:, slot].set(vw.astype(cache["v"].dtype))
            kpos = cache["kpos"].at[slot].set(pw.astype(jnp.int32))
            cache = {"k": ck, "v": cv, "kpos": kpos}
            if S > 1:
                # prefill: the ring holds only the LAST Wc keys — early
                # queries need their own window, so attend over the full
                # (windowed) sequence; the ring is just being filled.
                out = blockwise_attention(q, k, v, positions, causal=causal,
                                          window=window, unroll=unroll)
            else:
                out = ring_cache_attention(q, ck, cv, kpos, positions,
                                           window=window)
            return out.reshape(B, S, -1) @ p["wo"], cache
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_index, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_index, 1)
        cache = {"k": ck, "v": cv}
        k, v = ck, cv
        valid = cache_index + S
    out = blockwise_attention(q, k, v, positions, kv_valid_len=valid,
                              causal=causal, window=window,
                              kv_block=min(kv_block, max(k.shape[1], 1)),
                              unroll=unroll)
    return out.reshape(B, S, -1) @ p["wo"], cache


def cross_attn_forward(p: Dict, cfg: ArchConfig, x: jax.Array,
                       kv_cache: Dict, unroll: bool = False) -> jax.Array:
    """Cross-attention reading precomputed (k, v) of the encoder output."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"] + (p["bq"] if "bq" in p else 0)).reshape(B, S, H, hd)
    out = blockwise_attention(q, kv_cache["k"], kv_cache["v"],
                              jnp.zeros((S,), jnp.int32), causal=False,
                              unroll=unroll)
    return out.reshape(B, S, -1) @ p["wo"]


def encode_cross_kv(p: Dict, cfg: ArchConfig, enc_out: jax.Array) -> Dict:
    B, Sk = enc_out.shape[:2]
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = enc_out @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = enc_out @ p["wv"] + (p["bv"] if "bv" in p else 0)
    return {"k": k.reshape(B, Sk, KV, hd), "v": v.reshape(B, Sk, KV, hd)}


# ---------------------------------------------------------------------------
# Per-kind layer parameter init
# ---------------------------------------------------------------------------

def _init_mlp(key, cfg: ArchConfig, dtype) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"w1": dense_init(ks[0], d, ff, dtype=dtype),
            "w3": dense_init(ks[1], d, ff, dtype=dtype),
            "w2": dense_init(ks[2], ff, d, dtype=dtype)}


def _init_gelu_mlp(key, cfg: ArchConfig, dtype) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {"w1": dense_init(ks[0], d, ff, dtype=dtype),
            "b1": jnp.zeros((ff,), dtype),
            "w2": dense_init(ks[1], ff, d, dtype=dtype),
            "b2": jnp.zeros((d,), dtype)}


def _ln(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def init_layer_params(key: jax.Array, kind: str, cfg: ArchConfig,
                      dtype) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind == "attn_mlp":
        attn = (init_mla_params(ks[1], cfg, dtype) if cfg.mla
                else init_attn_params(ks[1], cfg, dtype))
        return {"ln1": jnp.ones((d,), dtype), "attn": attn,
                "ln2": jnp.ones((d,), dtype), "mlp": _init_mlp(ks[2], cfg,
                                                               dtype)}
    if kind == "attn_moe":
        attn = (init_mla_params(ks[1], cfg, dtype) if cfg.mla
                else init_attn_params(ks[1], cfg, dtype))
        return {"ln1": jnp.ones((d,), dtype), "attn": attn,
                "ln2": jnp.ones((d,), dtype),
                "moe": init_moe_params(ks[2], cfg, dtype)}
    if kind == "rwkv":
        return {"ln1": _ln(d, dtype), "tmix": init_tmix_params(ks[1], cfg,
                                                               dtype),
                "ln2": _ln(d, dtype), "cmix": init_cmix_params(ks[2], cfg,
                                                               dtype)}
    if kind == "hymba":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": init_attn_params(ks[0], cfg, dtype),
                "ssm": init_ssm_params(ks[1], cfg, dtype),
                "bn_a": jnp.ones((d,), dtype),   # per-branch output norms
                "bn_s": jnp.ones((d,), dtype),
                "ln2": jnp.ones((d,), dtype),
                "mlp": _init_mlp(ks[2], cfg, dtype)}
    if kind == "enc":
        return {"ln1": _ln(d, dtype),
                "attn": init_attn_params(ks[0], cfg, dtype),
                "ln2": _ln(d, dtype),
                "mlp": _init_gelu_mlp(ks[1], cfg, dtype)}
    if kind == "dec":
        return {"ln1": _ln(d, dtype),
                "attn": init_attn_params(ks[0], cfg, dtype),
                "ln2": _ln(d, dtype),
                "xattn": init_attn_params(ks[1], cfg, dtype, cross=True),
                "ln3": _ln(d, dtype),
                "mlp": _init_gelu_mlp(ks[2], cfg, dtype)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Per-kind layer application
# ---------------------------------------------------------------------------

def apply_layer(kind: str, p: Dict, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array, *, cache: Optional[Dict] = None,
                cache_index: Optional[jax.Array] = None,
                enc_out: Optional[jax.Array] = None,
                mixer_chunk: int = 64, dense_moe: bool = False,
                unroll_scans: bool = False, moe_groups: int = 1,
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """One block. Returns (x, new_cache, moe_aux_loss)."""
    eps = cfg.norm_eps
    zero = jnp.zeros((), jnp.float32)
    x = shard_acts(x, ("batch", "seq_tp", "embed"))

    # Megatron-SP pattern (no-op unless the policy maps 'seq_tp'):
    # residual x lives seq-sharded over TP; each sublayer input is
    # all-gathered AFTER its norm (sp_gather), its output reduce-scattered
    # before the residual add (sp_scatter).  Bytes == the plain TP
    # all-reduce; boundary HBM / TP; cotangent shardings pinned by the
    # custom vjps so the backward moves activations, never weights.

    if kind in ("attn_mlp", "attn_moe"):
        h = sp_gather(rms_norm(x, p["ln1"], eps))
        if cfg.mla:
            a, cache = mla_attention(p["attn"], cfg, h, positions,
                                     cache=cache, cache_index=cache_index,
                                     unroll=unroll_scans)
        else:
            a, cache = attn_forward(p["attn"], cfg, h, positions,
                                    cache=cache, cache_index=cache_index,
                                    window=cfg.sliding_window,
                                    unroll=unroll_scans)
        x = x + sp_scatter(a)
        h = sp_gather(rms_norm(x, p["ln2"], eps))
        if kind == "attn_mlp":
            x = x + sp_scatter(swiglu(h, **p["mlp"]))
            return x, cache, zero
        aux = aux_load_balance_loss(p["moe"]["router"],
                                    h.reshape(-1, h.shape[-1]), cfg.moe.top_k)
        x = x + sp_scatter(moe_ffn(p["moe"], cfg.moe, h,
                                   dense_dispatch=dense_moe,
                                   n_groups=moe_groups))
        return x, cache, aux

    if kind == "rwkv":
        h = layer_norm(x, p["ln1"]["w"], p["ln1"]["b"], eps)
        t_state = cache["tmix"] if cache is not None else None
        a, t_new = tmix_forward(p["tmix"], cfg, h, t_state, chunk=mixer_chunk,
                                unroll=unroll_scans)
        x = x + a
        h = layer_norm(x, p["ln2"]["w"], p["ln2"]["b"], eps)
        c_prev = cache["cmix_shift"] if cache is not None else None
        c, c_shift = cmix_forward(p["cmix"], h, c_prev)
        x = x + c
        new_cache = ({"tmix": t_new, "cmix_shift": c_shift}
                     if cache is not None else None)
        return x, new_cache, zero

    if kind == "hymba":
        h = rms_norm(x, p["ln1"], eps)
        a_cache = cache["attn"] if cache is not None else None
        s_state = cache["ssm"] if cache is not None else None
        a, a_cache = attn_forward(p["attn"], cfg, h, positions,
                                  cache=a_cache, cache_index=cache_index,
                                  window=cfg.sliding_window,
                                  unroll=unroll_scans)
        s, s_state = ssm_forward(p["ssm"], cfg, h, s_state,
                                 chunk=mixer_chunk, unroll=unroll_scans)
        a = rms_norm(a, p["bn_a"], eps)
        s = rms_norm(s, p["bn_s"], eps)
        x = x + 0.5 * (a + s)
        h = rms_norm(x, p["ln2"], eps)
        x = x + swiglu(h, **p["mlp"])
        new_cache = ({"attn": a_cache, "ssm": s_state}
                     if cache is not None else None)
        return x, new_cache, zero

    if kind == "enc":
        h = layer_norm(x, p["ln1"]["w"], p["ln1"]["b"], eps)
        a, _ = attn_forward(p["attn"], cfg, h, positions, causal=False,
                            rope=False, unroll=unroll_scans)
        x = x + a
        h = layer_norm(x, p["ln2"]["w"], p["ln2"]["b"], eps)
        x = x + gelu_mlp(h, **p["mlp"])
        return x, None, zero

    if kind == "dec":
        h = layer_norm(x, p["ln1"]["w"], p["ln1"]["b"], eps)
        self_cache = cache["self"] if cache is not None else None
        a, self_cache = attn_forward(p["attn"], cfg, h, positions, rope=False,
                                     cache=self_cache,
                                     cache_index=cache_index,
                                     unroll=unroll_scans)
        x = x + a
        h = layer_norm(x, p["ln2"]["w"], p["ln2"]["b"], eps)
        if cache is not None:
            xkv = cache["cross"]
        else:
            xkv = encode_cross_kv(p["xattn"], cfg, enc_out)
        x = x + cross_attn_forward(p["xattn"], cfg, h, xkv,
                                   unroll=unroll_scans)
        h = layer_norm(x, p["ln3"]["w"], p["ln3"]["b"], eps)
        x = x + gelu_mlp(h, **p["mlp"])
        new_cache = ({"self": self_cache, "cross": cache["cross"]}
                     if cache is not None else None)
        return x, new_cache, zero

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model parameters
# ---------------------------------------------------------------------------

def _stack_layers(key: jax.Array, kind: str, count: int, cfg: ArchConfig,
                  dtype) -> Dict:
    ks = jax.random.split(key, count)
    layers = [init_layer_params(ks[i], kind, cfg, dtype)
              for i in range(count)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_params(key: jax.Array, cfg: ArchConfig,
                dtype=jnp.float32) -> Dict:
    """Model parameters. Layer stacks are ALWAYS stacked along a leading
    layer axis; scan vs unroll is chosen at apply time."""
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dtype),
    }
    for gi, g in enumerate(layer_groups(cfg)):
        p[f"group{gi}"] = _stack_layers(ks[1 + gi], g.kind, g.count, cfg,
                                        dtype)
    if cfg.family == "encdec":
        p["encoder"] = _stack_layers(ks[4], "enc", cfg.encoder_layers, cfg,
                                     dtype)
        p["enc_norm"] = _ln(cfg.d_model, dtype)
        p["final_norm"] = _ln(cfg.d_model, dtype)
    elif cfg.family == "ssm":
        p["in_norm"] = _ln(cfg.d_model, dtype)     # RWKV ln0
        p["final_norm"] = _ln(cfg.d_model, dtype)
    else:
        p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[6], cfg.d_model, cfg.vocab_size,
                                  dtype=dtype)
    return p


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    """Exact parameter count via shape-only init (no allocation)."""
    import numpy as np
    shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    if active_only and cfg.moe:
        m = cfg.moe
        per_expert = 3 * cfg.d_model * m.d_ff_expert
        n_moe_layers = cfg.n_layers - m.first_dense_layers
        total -= n_moe_layers * (m.n_routed - m.top_k) * per_expert
    return total


def count_embedding_params(cfg: ArchConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    return n if cfg.tie_embeddings else 2 * n


# ---------------------------------------------------------------------------
# Forward pass (training / prefill-style full sequence)
# ---------------------------------------------------------------------------

def _apply_stack(group_p: Dict, kind: str, cfg: ArchConfig, x: jax.Array,
                 positions: jax.Array, *, cache: Optional[Dict],
                 cache_index, enc_out, scan_layers: bool, remat: bool,
                 mixer_chunk: int, dense_moe: bool,
                 unroll_scans: bool = False, remat_blocks: int = 1,
                 moe_groups: int = 1,
                 ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    def body_fn(x, layer_p, layer_cache):
        x, new_cache, a = apply_layer(
            kind, layer_p, cfg, x, positions,
            cache=layer_cache, cache_index=cache_index,
            enc_out=enc_out, mixer_chunk=mixer_chunk,
            dense_moe=dense_moe, unroll_scans=unroll_scans,
            moe_groups=moe_groups)
        # boundary constraint: what the remat/scan machinery SAVES is this
        # carried value — under sequence-TP it is 1/TP the full-seq size
        x = shard_acts(x, ("batch", "seq_tp", "embed"))
        return x, new_cache, a
    if remat:
        body_fn = jax.checkpoint(body_fn)

    n = jax.tree.leaves(group_p)[0].shape[0]
    if scan_layers:
        def scan_body(carry, inp):
            x, aux = carry
            layer_p, layer_cache = inp
            x, new_cache, a = body_fn(x, layer_p, layer_cache)
            return (x, aux + a), new_cache

        if remat and remat_blocks > 1 and n % remat_blocks == 0:
            # 2-level remat: outer scan over layer blocks (boundaries kept),
            # inner rematerialized scan over the block's layers — live
            # activations drop from O(L) to O(L/B + B) layer boundaries,
            # what fits llama3-405b train on 16 GB/chip (EXPERIMENTS.md).
            inner = n // remat_blocks
            blocked = jax.tree.map(
                lambda a: a.reshape(remat_blocks, inner, *a.shape[1:]),
                (group_p, cache))

            @jax.checkpoint
            def outer_body(carry, blk):
                blk_p, blk_cache = blk
                return jax.lax.scan(scan_body, carry, (blk_p, blk_cache))
            (x, aux), new_cache = jax.lax.scan(
                outer_body, (x, jnp.zeros((), jnp.float32)), blocked)
            if cache is not None:
                new_cache = jax.tree.map(
                    lambda a: a.reshape(n, *a.shape[2:]), new_cache)
            return x, new_cache, aux

        (x, aux), new_cache = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), (group_p, cache))
        return x, new_cache, aux
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i in range(n):
        layer_p = jax.tree.map(lambda a: a[i], group_p)
        layer_cache = (jax.tree.map(lambda a: a[i], cache)
                       if cache is not None else None)
        x, nc, a = body_fn(x, layer_p, layer_cache)
        aux = aux + a
        new_caches.append(nc)
    new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
                 if cache is not None else None)
    return x, new_cache, aux


def encode(params: Dict, cfg: ArchConfig, enc_frames: jax.Array, *,
           scan_layers: bool = True, remat: bool = False,
           unroll_scans: bool = False, remat_blocks: int = 1) -> jax.Array:
    """Whisper encoder: frame embeddings [B, S_enc, D] -> enc_out."""
    Senc = enc_frames.shape[1]
    x = enc_frames + sinusoidal_positions(Senc, cfg.d_model).astype(
        enc_frames.dtype)
    pos = jnp.arange(Senc)
    x, _, _ = _apply_stack(params["encoder"], "enc", cfg, x, pos,
                           cache=None, cache_index=None, enc_out=None,
                           scan_layers=scan_layers, remat=remat,
                           mixer_chunk=64, dense_moe=False,
                           unroll_scans=unroll_scans,
                           remat_blocks=remat_blocks)
    return layer_norm(x, params["enc_norm"]["w"], params["enc_norm"]["b"],
                      cfg.norm_eps)


def forward(params: Dict, cfg: ArchConfig, tokens: jax.Array, *,
            prefix_embeds: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            cache: Optional[Dict] = None,
            cache_index: Optional[jax.Array] = None,
            scan_layers: bool = True, remat: bool = False,
            mixer_chunk: int = 64, dense_moe: bool = False,
            logits_f32: bool = False, unroll_scans: bool = False,
            remat_blocks: int = 1, moe_groups: int = 1,
            ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Full forward. tokens: [B, S_text].

    prefix_embeds (vlm): [B, n_front, D] prepended before the token stream.
    enc_frames (encdec): [B, S_enc, D] stub frontend output.
    Returns (logits [B, S, V], new_cache, moe_aux_loss).
    """
    x = params["embed"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    if cfg.family == "ssm":
        x = layer_norm(x, params["in_norm"]["w"], params["in_norm"]["b"],
                       cfg.norm_eps)
    if cfg.family == "encdec":
        x = x + sinusoidal_at(positions, cfg.d_model).astype(x.dtype)

    enc_out = None
    if cfg.family == "encdec" and cache is None:
        enc_out = encode(params, cfg, enc_frames, scan_layers=scan_layers,
                         remat=remat, unroll_scans=unroll_scans,
                         remat_blocks=remat_blocks)

    aux = jnp.zeros((), jnp.float32)
    groups = layer_groups(cfg)
    for gi, g in enumerate(groups):
        gcache = cache[f"group{gi}"] if cache is not None else None
        x, new_gcache, a = _apply_stack(
            params[f"group{gi}"], g.kind, cfg, x, positions, cache=gcache,
            cache_index=cache_index, enc_out=enc_out,
            scan_layers=scan_layers, remat=remat, mixer_chunk=mixer_chunk,
            dense_moe=dense_moe, unroll_scans=unroll_scans,
            remat_blocks=remat_blocks, moe_groups=moe_groups)
        aux = aux + a
        if cache is not None:
            cache = dict(cache)
            cache[f"group{gi}"] = new_gcache

    fn = params["final_norm"]
    if isinstance(fn, dict):
        x = layer_norm(x, fn["w"], fn["b"], cfg.norm_eps)
    else:
        x = rms_norm(x, fn, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    if logits_f32:
        logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    else:
        logits = x @ head
    logits = shard_acts(logits, ("batch", None, "vocab"))
    return logits, cache, aux


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------

def lm_loss(params: Dict, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            aux_coef: float = 0.01, scan_layers: bool = True,
            remat: bool = False, dense_moe: bool = False,
            mixer_chunk: int = 64, unroll_scans: bool = False,
            remat_blocks: int = 1, moe_groups: int = 1,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE.  batch: tokens [B,S], targets [B,S], loss_mask [B,S]
    (+ prefix_embeds / enc_frames per family)."""
    logits, _, aux = forward(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_frames=batch.get("enc_frames"),
        scan_layers=scan_layers, remat=remat, dense_moe=dense_moe,
        mixer_chunk=mixer_chunk, unroll_scans=unroll_scans,
        remat_blocks=remat_blocks, moe_groups=moe_groups)
    targets = batch["targets"]
    npad = logits.shape[1] - targets.shape[1]
    if npad:                                   # vlm prefix positions: no loss
        logits = logits[:, npad:]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    metrics = {"ce_loss": loss, "moe_aux": aux}
    return loss + aux_coef * aux, metrics


# ---------------------------------------------------------------------------
# Caches: init / prefill / decode
# ---------------------------------------------------------------------------

def _init_layer_cache(kind: str, cfg: ArchConfig, batch: int, max_seq: int,
                      dtype, enc_seq: int = 0) -> Dict:
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    if kind in ("attn_mlp", "attn_moe"):
        if cfg.mla:
            return init_mla_cache(cfg, batch, max_seq, dtype)
        return {"k": jnp.zeros((batch, max_seq, KV, hd), dtype),
                "v": jnp.zeros((batch, max_seq, KV, hd), dtype)}
    if kind == "rwkv":
        return {"tmix": init_tmix_state(cfg, batch, dtype),
                "cmix_shift": jnp.zeros((batch, cfg.d_model), dtype)}
    if kind == "hymba":
        Wc = min(max_seq, cfg.sliding_window or max_seq)
        return {"attn": {"k": jnp.zeros((batch, Wc, KV, hd), dtype),
                         "v": jnp.zeros((batch, Wc, KV, hd), dtype),
                         "kpos": jnp.full((Wc,), -1, jnp.int32)},
                "ssm": init_ssm_state(cfg, batch, dtype)}
    if kind == "dec":
        return {"self": {"k": jnp.zeros((batch, max_seq, KV, hd), dtype),
                         "v": jnp.zeros((batch, max_seq, KV, hd), dtype)},
                "cross": {"k": jnp.zeros((batch, enc_seq, KV, hd), dtype),
                          "v": jnp.zeros((batch, enc_seq, KV, hd), dtype)}}
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    """Stacked per-group decode caches."""
    enc_seq = cfg.encoder_seq
    cache: Dict[str, Any] = {}
    for gi, g in enumerate(layer_groups(cfg)):
        one = _init_layer_cache(g.kind, cfg, batch, max_seq, dtype, enc_seq)
        cache[f"group{gi}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (g.count,) + a.shape), one)
    return cache


def prefill(params: Dict, cfg: ArchConfig, tokens: jax.Array, cache: Dict, *,
            prefix_embeds: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None,
            scan_layers: bool = True, mixer_chunk: int = 64,
            dense_moe: bool = False, unroll_scans: bool = False,
            moe_groups: int = 1,
            ) -> Tuple[jax.Array, Dict]:
    """Run the prompt through the model, filling the cache.

    Returns (last-position logits [B, V], cache).  For encdec, also fills
    per-layer cross KV from the encoder output."""
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, enc_frames, scan_layers=scan_layers,
                         unroll_scans=unroll_scans)
        g0 = params["group0"]

        def fill_cross(layer_p):
            return encode_cross_kv(layer_p["xattn"], cfg, enc_out)
        cross = (jax.vmap(fill_cross)(g0) if scan_layers or True else None)
        cache = dict(cache)
        cache["group0"] = {**cache["group0"], "cross": cross}
    logits, cache, _ = forward(
        params, cfg, tokens, prefix_embeds=prefix_embeds,
        positions=jnp.arange(tokens.shape[1]
                             + (prefix_embeds.shape[1]
                                if prefix_embeds is not None else 0)),
        cache=cache, cache_index=jnp.zeros((), jnp.int32),
        scan_layers=scan_layers, mixer_chunk=mixer_chunk,
        dense_moe=dense_moe, unroll_scans=unroll_scans,
        moe_groups=moe_groups)
    return logits[:, -1], cache


def decode_step(params: Dict, cfg: ArchConfig, token: jax.Array,
                cache: Dict, pos: jax.Array, *, scan_layers: bool = True,
                dense_moe: bool = False,
                unroll_scans: bool = False) -> Tuple[jax.Array, Dict]:
    """One decode step. token: [B]; pos: [] int32 (current position).
    Returns (logits [B, V], cache)."""
    logits, cache, _ = forward(
        params, cfg, token[:, None], positions=pos[None],
        cache=cache, cache_index=pos, scan_layers=scan_layers,
        mixer_chunk=1, dense_moe=dense_moe, unroll_scans=unroll_scans)
    return logits[:, 0], cache
