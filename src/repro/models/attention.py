"""Memory-bounded blockwise attention (pure-JAX flash formulation).

One implementation covers every assigned architecture's needs:
  * causal / bidirectional / cross attention
  * GQA (n_kv_heads < n_heads), optional sliding window
  * prefill (Sq = Skv) and cached decode (Sq = 1, bounded valid length)

The KV axis is processed in blocks with an online-softmax accumulator, so
peak memory is O(Sq * block) instead of O(Sq * Skv) — the jnp oracle of the
Pallas flash kernel (kernels/flash_attention), and the path used by the
dry-run (Pallas requires a real TPU; see DESIGN.md).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@partial(jax.jit, static_argnames=("causal", "window", "kv_block", "unroll"))
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_positions: jax.Array,
                        kv_valid_len: Optional[jax.Array] = None,
                        *, causal: bool = True,
                        window: Optional[int] = None,
                        kv_block: int = 512,
                        unroll: bool = False) -> jax.Array:
    """q: [B, Sq, H, hd]; k, v: [B, Sk, KV, hd]; GQA via H = KV * G.

    q_positions: [Sq] global positions of the queries (decode passes [pos]).
    kv_valid_len: [] or [B] — keys at index >= valid_len are masked (cache).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5

    nb = -(-Sk // kv_block)
    pad = nb * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    valid = (jnp.asarray(Sk if kv_valid_len is None else kv_valid_len)
             .astype(jnp.int32))
    valid = jnp.broadcast_to(valid, (B,))

    def step(carry, blk):
        m, l, acc = carry
        kj, vj, j = blk
        kpos = j * kv_block + jnp.arange(kv_block)                  # [C]
        s = jnp.einsum("bqkgh,bckh->bqkgc", qg, kj.astype(jnp.float32))
        s = s * scale
        mask = kpos[None, :] < valid[:, None]                       # [B, C]
        mask = mask[:, None, :]                                     # [B,1,C]
        if causal:
            mask = mask & (kpos[None, None, :]
                           <= q_positions[None, :, None])
        if window is not None:
            mask = mask & (kpos[None, None, :]
                           > q_positions[None, :, None] - window)
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqkgc,bckh->bqkgh", p, vj.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nb)),
                                  unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def ring_cache_attention(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                         kpos: jax.Array, q_positions: jax.Array,
                         window: Optional[int] = None) -> jax.Array:
    """Attention over a sliding-window RING cache (decode path).

    q: [B, Sq, H, hd] (Sq small — usually 1); k_ring, v_ring: [B, Wc, KV, hd];
    kpos: [Wc] int32 — absolute position stored in each ring slot (-1 =
    empty); q_positions: [Sq].  Causal + window masking is by position, so
    slot order is irrelevant.
    """
    B, Sq, H, hd = q.shape
    Wc, KV = k_ring.shape[1], k_ring.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bqkgc", qg, k_ring.astype(jnp.float32))
    s = s * (hd ** -0.5)
    mask = (kpos[None, :] >= 0) & (kpos[None, :]
                                   <= q_positions[:, None])      # [Sq, Wc]
    if window is not None:
        mask = mask & (kpos[None, :] > q_positions[:, None] - window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckh->bqkgh", p, v_ring.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_positions: jax.Array,
                    kv_valid_len: Optional[jax.Array] = None,
                    *, causal: bool = True,
                    window: Optional[int] = None) -> jax.Array:
    """Unchunked oracle (small shapes / tests only)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qg, k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    kpos = jnp.arange(Sk)
    valid = (jnp.asarray(Sk if kv_valid_len is None else kv_valid_len)
             .astype(jnp.int32))
    valid = jnp.broadcast_to(valid, (B,))
    mask = kpos[None, :] < valid[:, None]
    mask = mask[:, None, :]
    if causal:
        mask = mask & (kpos[None, None, :] <= q_positions[None, :, None])
    if window is not None:
        mask = mask & (kpos[None, None, :] > q_positions[None, :, None]
                       - window)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)
