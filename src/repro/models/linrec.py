"""Chunked linear-recurrence core (shared by RWKV6 and Hymba's SSM heads).

Computes, per head, the gated linear recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          S in R^{Nk x Nv}
    out_t = q_t^T S'_t

with two diagonal conventions:

  * mode='rwkv'      — out_t = q_t^T (S_{t-1} + diag(u) k_t v_t^T)
                        (decay applied through t-1; bonus u on the diagonal)
  * mode='inclusive' — out_t = q_t^T S_t   (Mamba-2/SSD-style; s = t term
                        carries zero decay)

Chunked evaluation (the TPU-friendly form; also the spec of the Pallas
``rwkv_scan`` kernel): within a chunk of C steps all pairwise decays are
exp(A_i - A_j) with A the running log-decay sum and i >= j, so every
exponent is <= 0 — numerically safe without 1/P divisions.  Cross-chunk
state is carried exactly.  Complexity O(S*C*Nk*Nv + S*C^2*Nk) vs O(S^2) for
attention — the sub-quadratic mixer that makes ``long_500k`` runnable.

All math in fp32; inputs cast in, outputs cast back.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _pad_to(x: jax.Array, S: int, axis: int = 1) -> jax.Array:
    pad = S - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("mode", "chunk", "return_state",
                                   "unroll"))
def chunked_linear_recurrence(q: jax.Array, k: jax.Array, v: jax.Array,
                              log_w: jax.Array,
                              u: Optional[jax.Array] = None,
                              initial_state: Optional[jax.Array] = None,
                              *, mode: str = "rwkv", chunk: int = 64,
                              return_state: bool = False,
                              unroll: bool = False,
                              ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """q, k, log_w: [B, S, h, Nk]; v: [B, S, h, Nv]; u: [h, Nk] (rwkv mode).

    log_w must be <= 0 (log of a decay in (0, 1]).
    initial_state: [B, h, Nk, Nv].  Returns (out [B, S, h, Nv], final_state).
    """
    if mode not in ("rwkv", "inclusive"):
        raise ValueError(mode)
    B, S, h, Nk = q.shape
    Nv = v.shape[-1]
    dt = q.dtype
    C = min(chunk, S)
    nc = -(-S // C)
    Sp = nc * C

    f32 = jnp.float32
    q_, k_, v_, w_ = (
        _pad_to(q.astype(f32), Sp), _pad_to(k.astype(f32), Sp),
        _pad_to(v.astype(f32), Sp), _pad_to(log_w.astype(f32), Sp))

    # [nc, B, C, h, Nk/Nv]
    def to_chunks(x):
        return x.reshape(B, nc, C, h, x.shape[-1]).transpose(1, 0, 2, 3, 4)
    qc, kc, vc, wc = map(to_chunks, (q_, k_, v_, w_))

    S0 = (jnp.zeros((B, h, Nk, Nv), f32) if initial_state is None
          else initial_state.astype(f32))

    tri_strict = jnp.tril(jnp.ones((C, C), bool), k=-1)
    tri_incl = jnp.tril(jnp.ones((C, C), bool), k=0)

    def body(state, xs):
        qb, kb, vb, wb = xs                       # [B, C, h, *]
        A = jnp.cumsum(wb, axis=1)                # [B, C, h, Nk] log decays
        A_total = A[:, -1]                        # [B, h, Nk]
        if mode == "rwkv":
            # decay through t-1 for both the state read and intra pairs
            A_q = A - wb                          # A_{t-1}
            tri = tri_strict
        else:
            A_q = A                               # A_t (inclusive)
            tri = tri_incl
        # ---- inter-chunk: q_t dressed with exp(A_q) reads the carried state
        q_in = qb * jnp.exp(A_q)                  # [B, C, h, Nk]
        out_inter = jnp.einsum("bchk,bhkv->bchv", q_in, state)
        # ---- intra-chunk: pairwise exponents A_q[t] - A[s]  (<= 0 on tri)
        expo = A_q[:, :, None] - A[:, None, :, :, :]      # [B, C, C, h, Nk]
        expo = jnp.where(tri[None, :, :, None, None], expo, -jnp.inf)
        gate = jnp.exp(expo)
        M = jnp.einsum("bthk,bshk,btshk->btsh", qb, kb, gate)
        if mode == "rwkv" and u is not None:
            diag = jnp.einsum("bthk,hk,bthk->bth", qb, u.astype(f32), kb)
            M = M + diag[:, :, None, :] * jnp.eye(C, dtype=f32)[None, :, :,
                                                                None]
        out_intra = jnp.einsum("btsh,bshv->bthv", M, vb)
        # ---- state update: S' = diag(e^{A_total}) S + sum_s k_s e^{A_tot-A_s} v_s
        k_dress = kb * jnp.exp(A_total[:, None] - A)      # [B, C, h, Nk]
        new_state = (state * jnp.exp(A_total)[..., None]
                     + jnp.einsum("bchk,bchv->bhkv", k_dress, vb))
        return new_state, out_inter + out_intra

    final_state, outs = jax.lax.scan(body, S0, (qc, kc, vc, wc),
                                     unroll=nc if unroll else 1)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, h, Nv)[:, :S]
    return out.astype(dt), (final_state if return_state else None)


def recurrent_step(q: jax.Array, k: jax.Array, v: jax.Array,
                   log_w: jax.Array, state: jax.Array,
                   u: Optional[jax.Array] = None, *, mode: str = "rwkv",
                   ) -> Tuple[jax.Array, jax.Array]:
    """Single-token decode step.

    q, k, log_w: [B, h, Nk]; v: [B, h, Nv]; state: [B, h, Nk, Nv].
    Returns (out [B, h, Nv], new_state).
    """
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    w = jnp.exp(log_w.astype(f32))                        # [B, h, Nk]
    kv = kf[..., :, None] * vf[..., None, :]              # [B, h, Nk, Nv]
    if mode == "rwkv":
        read = state + (u.astype(f32)[None, :, :, None] * kv
                        if u is not None else kv)
        new_state = state * w[..., None] + kv
    else:
        new_state = state * w[..., None] + kv
        read = new_state
    out = jnp.einsum("bhk,bhkv->bhv", qf, read)
    return out.astype(q.dtype), new_state


def naive_linear_recurrence(q, k, v, log_w, u=None, initial_state=None,
                            *, mode: str = "rwkv"):
    """Step-by-step oracle (tests): same signature/semantics as the chunked
    form, O(S) sequential."""
    B, S, h, Nk = q.shape
    Nv = v.shape[-1]
    state = (jnp.zeros((B, h, Nk, Nv), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))
    outs = []
    for t in range(S):
        o, state = recurrent_step(q[:, t], k[:, t], v[:, t], log_w[:, t],
                                  state, u, mode=mode)
        outs.append(o)
    return jnp.stack(outs, axis=1), state
