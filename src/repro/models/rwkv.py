"""RWKV6 'Finch' blocks: time-mix (WKV with data-dependent decay) + channel-mix.

Faithful to arXiv:2404.05892 at the block level:

  * DDLerp token-shift: every projection input is a data-dependent lerp
    between x_t and x_{t-1} through a shared low-rank trunk (time_maa).
  * Data-dependent decay  w_t = exp(-exp(w0 + lora_w(.)))  per channel.
  * WKV: the gated linear recurrence of :mod:`repro.models.linrec`
    (mode='rwkv': state read through t-1, diagonal bonus u).
  * Per-head GroupNorm on the WKV output, SiLU(g) output gate.
  * Channel-mix: shifted lerp, squared-ReLU key MLP, sigmoid receptance.

Both a full-sequence form (training / prefill; chunked scan) and a
single-token recurrent form (decode) are provided; they are equal up to
fp32 roundoff (asserted in tests).  The chunked scan is the jnp oracle of
the Pallas ``rwkv_scan`` kernel.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init
from .linrec import chunked_linear_recurrence, recurrent_step

DDLERP_RANK = 32          # low-rank trunk width of the time_maa loras
DECAY_RANK = 64           # rank of the decay lora


def init_tmix_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim
    assert h * hd == d, "RWKV6 requires n_heads * head_dim == d_model"
    ks = jax.random.split(key, 12)
    return {
        # DDLerp base mixes (mu_x plus one per stream r,k,v,w,g)
        "mu_x": jnp.zeros((d,), dtype),
        "mu": jnp.zeros((5, d), dtype),                       # r,k,v,w,g
        "maa_w1": dense_init(ks[0], d, 5 * DDLERP_RANK, dtype=dtype),
        "maa_w2": (jax.random.normal(ks[1], (5, DDLERP_RANK, d), jnp.float32)
                   * 0.01).astype(dtype),
        # data-dependent decay
        "w0": jnp.full((d,), -6.0, dtype),                    # exp(-exp(-6))≈1
        "w_lora_a": dense_init(ks[2], d, DECAY_RANK, dtype=dtype),
        "w_lora_b": (jax.random.normal(ks[3], (DECAY_RANK, d), jnp.float32)
                     * 0.01).astype(dtype),
        # projections
        "wr": dense_init(ks[4], d, d, dtype=dtype),
        "wk": dense_init(ks[5], d, d, dtype=dtype),
        "wv": dense_init(ks[6], d, d, dtype=dtype),
        "wg": dense_init(ks[7], d, d, dtype=dtype),
        "wo": dense_init(ks[8], d, d, dtype=dtype),
        # per-head diagonal bonus u ('time_faaaa')
        "u": (jax.random.normal(ks[9], (h, hd), jnp.float32)
              * 0.1).astype(dtype),
        # per-head GroupNorm
        "gn_w": jnp.ones((d,), dtype),
        "gn_b": jnp.zeros((d,), dtype),
    }


def init_cmix_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dtype),
        "mu_r": jnp.zeros((d,), dtype),
        "wk": dense_init(ks[0], d, ff, dtype=dtype),
        "wv": dense_init(ks[1], ff, d, dtype=dtype),
        "wr": dense_init(ks[2], d, d, dtype=dtype),
    }


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} stream: [B,S,D] -> [B,S,D]; ``prev`` [B,D] seeds t=0."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _ddlerp(p: Dict, x: jax.Array, xprev: jax.Array) -> Tuple[jax.Array, ...]:
    """Data-dependent lerp for the 5 streams; returns (xr, xk, xv, xw, xg)."""
    dx = xprev - x
    xxx = x + dx * p["mu_x"]
    f32 = jnp.float32
    trunk = jnp.tanh(xxx.astype(f32) @ p["maa_w1"].astype(f32))
    B, S = x.shape[:2]
    trunk = trunk.reshape(B, S, 5, DDLERP_RANK)
    # per-stream offset: [B,S,5,D]
    off = jnp.einsum("bsfr,frd->bsfd", trunk, p["maa_w2"].astype(f32))
    mix = p["mu"].astype(f32)[None, None] + off
    streams = x[:, :, None, :] + dx[:, :, None, :] * mix.astype(x.dtype)
    return tuple(streams[:, :, i] for i in range(5))


def _decay_log_w(p: Dict, xw: jax.Array) -> jax.Array:
    """log(w_t) = -exp(w0 + lora_w(xw))  (guaranteed < 0)."""
    f32 = jnp.float32
    lora = jnp.tanh(xw.astype(f32) @ p["w_lora_a"].astype(f32)) \
        @ p["w_lora_b"].astype(f32)
    return -jnp.exp(p["w0"].astype(f32) + lora)


def _group_norm(x: jax.Array, w: jax.Array, b: jax.Array, h: int,
                eps: float = 64e-5) -> jax.Array:
    """Per-head GroupNorm over [..., D] with D = h * hd."""
    shp = x.shape
    xg = x.reshape(*shp[:-1], h, shp[-1] // h).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = ((xg - mu) ** 2).mean(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(shp) * w + b).astype(x.dtype)


def tmix_forward(p: Dict, cfg: ArchConfig, x: jax.Array,
                 state: Optional[Dict] = None, *, chunk: int = 64,
                 unroll: bool = False,
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """RWKV6 time-mix. x: [B,S,D].

    ``state`` (decode/streaming): {'shift': [B,D], 'wkv': [B,h,hd,hd]}.
    Returns (out [B,S,D], new state or None when stateless training).
    """
    B, S, D = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    keep_state = state is not None
    prev = state["shift"] if keep_state else None
    s0 = state["wkv"] if keep_state else None

    xprev = _shift(x, prev)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xprev)
    r = (xr @ p["wr"]).reshape(B, S, h, hd)
    k = (xk @ p["wk"]).reshape(B, S, h, hd)
    v = (xv @ p["wv"]).reshape(B, S, h, hd)
    g = xg @ p["wg"]
    log_w = _decay_log_w(p, xw).reshape(B, S, h, hd)

    out, s_new = chunked_linear_recurrence(
        r, k, v, log_w, u=p["u"], initial_state=s0, mode="rwkv",
        chunk=chunk, return_state=keep_state, unroll=unroll)
    out = out.reshape(B, S, D)
    out = _group_norm(out, p["gn_w"], p["gn_b"], h)
    out = (out * jax.nn.silu(g)) @ p["wo"]
    new_state = ({"shift": x[:, -1], "wkv": s_new} if keep_state else None)
    return out, new_state


def tmix_step(p: Dict, cfg: ArchConfig, x: jax.Array, state: Dict,
              ) -> Tuple[jax.Array, Dict]:
    """Single-token decode. x: [B,D]; state {'shift':[B,D],'wkv':[B,h,hd,hd]}."""
    B, D = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xs = x[:, None, :]
    xprev = state["shift"][:, None, :]
    xr, xk, xv, xw, xg = _ddlerp(p, xs, xprev)
    r = (xr @ p["wr"]).reshape(B, h, hd)
    k = (xk @ p["wk"]).reshape(B, h, hd)
    v = (xv @ p["wv"]).reshape(B, h, hd)
    g = (xg @ p["wg"])[:, 0]
    log_w = _decay_log_w(p, xw).reshape(B, h, hd)
    out, wkv = recurrent_step(r, k, v, log_w, state["wkv"], u=p["u"],
                              mode="rwkv")
    out = out.reshape(B, D)
    out = _group_norm(out, p["gn_w"], p["gn_b"], h)
    out = (out * jax.nn.silu(g)) @ p["wo"]
    return out, {"shift": x, "wkv": wkv}


def cmix_forward(p: Dict, x: jax.Array, prev: Optional[jax.Array] = None,
                 ) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 channel-mix. x: [B,S,D] -> ([B,S,D], last-token shift state)."""
    xprev = _shift(x, prev)
    dx = xprev - x
    xk = x + dx * p["mu_k"]
    xr = x + dx * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, x[:, -1]


def init_tmix_state(cfg: ArchConfig, batch: int, dtype) -> Dict:
    h, hd = cfg.n_heads, cfg.head_dim
    return {"shift": jnp.zeros((batch, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32)}
