"""Modality frontend STUBS (per assignment: `[audio]`/`[vlm]` entries specify
the transformer BACKBONE only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These produce deterministic synthetic embeddings on CPU (tests/examples) and
ShapeDtypeStruct stand-ins for the dry-run (launch/dryrun.py).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig


def audio_frames(key: jax.Array, cfg: ArchConfig, batch: int,
                 dtype=jnp.float32) -> jax.Array:
    """Stub for Whisper's conv1/conv2(mel) output: [B, encoder_seq, D]."""
    return (jax.random.normal(key, (batch, cfg.encoder_seq, cfg.d_model),
                              jnp.float32) * 0.02).astype(dtype)


def vision_patches(key: jax.Array, cfg: ArchConfig, batch: int,
                   dtype=jnp.float32) -> jax.Array:
    """Stub for the LLaVA anyres CLIP+projector output:
    [B, n_frontend_tokens, D]."""
    return (jax.random.normal(key, (batch, cfg.n_frontend_tokens,
                                    cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                      dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one training batch (dry-run input)."""
    B, S = shape.global_batch, shape.seq_len
    n_front = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    s_text = S - n_front
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, s_text), jnp.int32),
        "targets": jax.ShapeDtypeStruct((B, s_text), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((B, s_text), jnp.float32),
    }
    if cfg.frontend == "vision":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, n_front, cfg.d_model), dtype)
    if cfg.family == "encdec":
        specs["enc_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dtype)
    return specs


def make_train_batch(key: jax.Array, cfg: ArchConfig, batch: int, seq: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Concrete synthetic batch (smoke tests / examples)."""
    ks = jax.random.split(key, 3)
    n_front = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    s_text = seq - n_front
    toks = jax.random.randint(ks[0], (batch, s_text + 1), 0, cfg.vocab_size)
    out = {
        "tokens": toks[:, :-1],
        "targets": toks[:, 1:],
        "loss_mask": jnp.ones((batch, s_text), jnp.float32),
    }
    if cfg.frontend == "vision":
        out["prefix_embeds"] = vision_patches(ks[1], cfg, batch, dtype)
    if cfg.family == "encdec":
        out["enc_frames"] = audio_frames(ks[2], cfg, batch, dtype)
    return out
