"""Multi-head Latent Attention (DeepSeek-V2), cache-compressed decode.

MLA projects keys/values through a shared low-rank latent c_kv of width
``kv_lora_rank`` (+ a small decoupled RoPE key of width ``rope_head_dim``).
Only (c_kv, k_rope) is cached at decode — the architecture's decode-memory
contribution: cache bytes per token drop from  2*H*hd  to
kv_lora + rope_dim  (e.g. 4096 -> 576 for deepseek-v2-lite).

Weight-absorption at decode: rather than expanding c_kv to per-head K/V
(S * H * hd work per step), the per-head up-projections are absorbed into
the query/output sides, so attention runs directly in the latent space:

  score_t = (q_nope W_uk^T) . c_kv_t   +   q_rope . k_rope_t
  out     = (sum_t p_t c_kv_t) W_uv

This is the TPU-friendly form (two small einsums instead of re-expanding the
cache) and is also what the serving engine lowers for decode shapes.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import blockwise_attention
from .layers import apply_rope, dense_init, rms_norm


def init_mla_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    m = cfg.mla
    assert m is not None
    d, H = cfg.d_model, cfg.n_heads
    qd = H * (m.nope_head_dim + m.rope_head_dim)
    ks = jax.random.split(key, 6)
    p = {
        # query (direct projection; v2-lite has no q LoRA)
        "wq": dense_init(ks[0], d, qd, dtype=dtype),
        # joint KV down-projection: [D, kv_lora + rope_dim]
        "w_dkv": dense_init(ks[1], d, m.kv_lora_rank + m.rope_head_dim,
                            dtype=dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        # up-projections out of the latent
        "w_uk": dense_init(ks[2], m.kv_lora_rank, H * m.nope_head_dim,
                           dtype=dtype),
        "w_uv": dense_init(ks[3], m.kv_lora_rank, H * m.v_head_dim,
                           dtype=dtype),
        "wo": dense_init(ks[4], H * m.v_head_dim, d, dtype=dtype),
    }
    return p


def _project_q(p: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """-> q_nope [B,S,H,nope], q_rope [B,S,H,rope] (rope applied)."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p: Dict, cfg: ArchConfig, x: jax.Array,
                       positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> c_kv [B,S,R] (normed latent), k_rope [B,S,1,rope] (shared head)."""
    m = cfg.mla
    ckr = x @ p["w_dkv"]
    c_kv, k_rope = jnp.split(ckr, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_attention(p: Dict, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array,
                  cache: Optional[Dict] = None,
                  cache_index: Optional[jax.Array] = None,
                  unroll: bool = False,
                  ) -> Tuple[jax.Array, Optional[Dict]]:
    """MLA block.  x: [B, S, D].

    cache (decode): {'c_kv': [B, Smax, R], 'k_rope': [B, Smax, rope]};
    cache_index: [] current length.  Returns (out [B,S,D], updated cache).
    """
    m, H = cfg.mla, cfg.n_heads
    B, S, D = x.shape
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c_new, kr_new = _project_kv_latent(p, cfg, x, positions)

    if cache is None:
        c_kv, k_rope = c_new, kr_new
        valid = None
    else:
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), cache_index, 1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], kr_new[:, :, 0, :].astype(cache["k_rope"].dtype),
            cache_index, 1)
        cache = {"c_kv": c_kv, "k_rope": k_rope}
        k_rope = k_rope[:, :, None, :]
        valid = cache_index + S

    # ---- absorbed attention in latent space --------------------------------
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.nope_head_dim)
    # q_abs[b,s,h,R] = q_nope . W_uk[:,h,:]^T
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    # attention over "keys" = [c_kv | k_rope] with matching query parts
    q_full = jnp.concatenate([q_abs, q_rope], axis=-1)       # [B,S,H,R+rope]
    k_full = jnp.concatenate(
        [c_kv, k_rope[:, :, 0, :]], axis=-1)[:, :, None, :]  # [B,Sk,1,R+rope]
    # scale by the *materialized* head dim, per the paper
    scale_fix = ((m.nope_head_dim + m.rope_head_dim) ** -0.5
                 / (q_full.shape[-1] ** -0.5))
    attn_lat = blockwise_attention(
        q_full * scale_fix, k_full,
        jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)[:, :, None, :],
        positions, kv_valid_len=valid, causal=True,
        kv_block=min(512, max(k_full.shape[1], 1)), unroll=unroll)
    attn_lat = attn_lat[..., :m.kv_lora_rank]                # [B,S,H,R]
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", attn_lat, w_uv)
    out = out.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    return out, cache


def init_mla_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, m.rope_head_dim), dtype),
    }


def mla_cache_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2) -> int:
    """The MLA memory win, per token per layer (vs 2*H*hd for vanilla MHA)."""
    m = cfg.mla
    return (m.kv_lora_rank + m.rope_head_dim) * dtype_bytes
