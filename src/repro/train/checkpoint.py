"""Sharded npz checkpointing with a JSON manifest: save / restore / resume.

Layout (one step):
    <dir>/step_000123/
        manifest.json     tree structure, leaf shapes/dtypes, shard map
        shard_00000.npz   leaf arrays (chunked so no file exceeds ~2 GB)

Writes are atomic (tmp dir + rename) so a preemption mid-save never
corrupts the latest checkpoint — the fault-tolerance contract tested in
tests/test_checkpoint.py.  ``keep_last`` prunes old steps.  On a real
multi-host cluster each host would write the shards of its addressable
devices; the manifest format already records per-leaf shard files, so the
single-process writer here generalizes (see DESIGN.md §fault tolerance).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_MAX_SHARD_BYTES = 2 << 30


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", p)) for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(state: Any, ckpt_dir: str, step: int,
                    keep_last: Optional[int] = 3) -> str:
    """Write ``state`` (pytree of arrays) atomically; returns final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        leaves = _leaf_paths(state)
        manifest: Dict[str, Any] = {"step": step, "leaves": {}, "shards": []}
        shard_idx, shard_bytes, shard_data = 0, 0, {}
        for key, leaf in leaves:
            arr = np.asarray(leaf)
            nb = arr.nbytes
            if shard_bytes and shard_bytes + nb > _MAX_SHARD_BYTES:
                _flush(tmp, shard_idx, shard_data, manifest)
                shard_idx, shard_bytes, shard_data = shard_idx + 1, 0, {}
            safe = f"a{len(manifest['leaves'])}"
            shard_data[safe] = arr
            shard_bytes += nb
            manifest["leaves"][key] = {
                "shard": shard_idx, "name": safe,
                "shape": list(arr.shape), "dtype": str(arr.dtype)}
        _flush(tmp, shard_idx, shard_data, manifest)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if keep_last is not None:
        for old in sorted(list_steps(ckpt_dir))[:-keep_last]:
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{old:08d}"),
                          ignore_errors=True)
    return final


def _flush(tmp: str, idx: int, data: Dict[str, np.ndarray],
           manifest: Dict) -> None:
    path = os.path.join(tmp, f"shard_{idx:05d}.npz")
    np.savez(path, **data)
    manifest["shards"].append(os.path.basename(path))


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.startswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(like: Any, ckpt_dir: str,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (state, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shards = {i: np.load(os.path.join(path, s))
              for i, s in enumerate(manifest["shards"])}
    leaves = {k: shards[v["shard"]][v["name"]]
              for k, v in manifest["leaves"].items()}

    like_leaves = _leaf_paths(like)
    missing = [k for k, _ in like_leaves if k not in leaves]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    restored = []
    for key, leaf in like_leaves:
        arr = leaves[key]
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: shape {arr.shape} != {want}")
        restored.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(tree, restored), step
