"""Jitted training step: microbatch grad accumulation, remat, and the
paper's hybrid-coded data-parallel gradient sync.

Three DP sync modes (TrainConfig.dp_mode):

  * 'dp'          — batch sharded over every data axis; XLA inserts the
                    (hierarchical) gradient all-reduce.  Baseline = the
                    paper's *uncoded* shuffle.
  * 'replicated'  — batch replicated over the 'pod' axis (map replication
                    r = P): every pod computes the full gradient, ZERO
                    cross-pod bytes, P x map FLOPs — the paper's r = P
                    corner of L_cro = (QN/r)(1 - r/P) = 0.
  * 'coded_r2'    — the genuine r = 2 < P scheme, executable: the global
                    batch is split into C(P,2) chunks, chunk {a,b} is
                    mapped by pods a AND b (2 x replication), and the
                    cross-pod stage is the coded reduce-scatter of
                    repro.core.gradient_sync — G(1 - 2/P) cross-pod bytes
                    instead of uncoded G(1 - 1/P), plus single-pod
                    straggler tolerance for free.

The microbatch loop is a jax.lax.scan with fp32 (or bf16) accumulation;
per-layer remat bounds live activations to one microbatch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..core.gradient_sync import coded_reduce_scatter_r2
from ..distributed import sharding as shlib
from ..distributed.meshes import shard_map
from ..models import lm
from .optimizer import (OptimizerConfig, adamw_update, init_opt_state,
                        optimizer_update)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_microbatches: int = 1
    remat: bool = True
    remat_blocks: int = 1             # 2-level remat (sqrt-L memory)
    scan_layers: bool = True
    unroll_scans: bool = False        # dry-run cost extraction only
    dp_mode: str = "dp"               # dp | replicated | coded_r2
    grad_dtype: Any = jnp.float32     # accumulation dtype
    aux_coef: float = 0.01
    dense_moe: bool = False           # exact dispatch (tiny configs)
    moe_groups: int = 1               # sort-dispatch groups (= dp shards)
    mixer_chunk: int = 64
    opt: OptimizerConfig = OptimizerConfig()


def init_train_state(key: jax.Array, cfg: ArchConfig, tc: TrainConfig,
                     param_dtype=jnp.float32) -> Dict:
    params = lm.init_params(key, cfg, param_dtype)
    return {"params": params, "opt": init_opt_state(params, tc.opt),
            "step": jnp.zeros((), jnp.int32)}


def _split_micro(batch: Dict, n: int) -> Dict:
    def f(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(f, batch)


def _loss_fn(params, cfg: ArchConfig, tc: TrainConfig, mb: Dict):
    return lm.lm_loss(params, cfg, mb, aux_coef=tc.aux_coef,
                      scan_layers=tc.scan_layers, remat=tc.remat,
                      dense_moe=tc.dense_moe, mixer_chunk=tc.mixer_chunk,
                      unroll_scans=tc.unroll_scans,
                      remat_blocks=tc.remat_blocks, moe_groups=tc.moe_groups)


def _grad_constraint(params):
    """Pin gradient sharding to the (FSDP-overlaid) parameter specs so the
    scan-over-microbatches accumulator is reduce-scattered per step instead
    of living unsharded over the data axis (a 16x HBM cliff at 405B)."""
    pol = shlib.active_policy()
    if pol is None:
        return lambda g: g
    fsdp = pol.rules.get("fsdp") is not None
    specs = shlib.param_pspecs(params, pol, fsdp=fsdp)

    def constrain(g):
        return jax.tree.map(
            lambda leaf, s: jax.lax.with_sharding_constraint(
                leaf, jax.sharding.NamedSharding(pol.mesh, s)),
            g, specs, is_leaf=lambda x: not isinstance(x, (dict, list)))
    return constrain


def accumulate_grads(params, cfg: ArchConfig, tc: TrainConfig,
                     batch: Dict) -> Tuple[Any, jax.Array]:
    """Microbatch-scanned grad accumulation.  Returns (grads, mean loss)."""
    n = tc.n_microbatches
    constrain = _grad_constraint(params)
    if n == 1:
        (loss, _), grads = jax.value_and_grad(
            _loss_fn, has_aux=True)(params, cfg, tc, batch)
        return constrain(grads), loss
    micro = _split_micro(batch, n)
    g0 = constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, tc.grad_dtype),
                                params))

    def body(carry, mb):
        acc, loss_sum = carry
        (loss, _), grads = jax.value_and_grad(
            _loss_fn, has_aux=True)(params, cfg, tc, mb)
        acc = constrain(jax.tree.map(
            lambda a, g: a + g.astype(tc.grad_dtype), acc, constrain(grads)))
        return (acc, loss_sum + loss), None

    (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), micro)
    grads = jax.tree.map(lambda g: (g / n).astype(tc.grad_dtype), grads)
    return constrain(grads), loss_sum / n


# ---------------------------------------------------------------------------
# coded_r2: chunked batch layout + shard_map coded sync over 'pod'
# ---------------------------------------------------------------------------

def chunk_layout_r2(global_batch: int, P_: int) -> Tuple[int, int]:
    """(n_chunks, rows per chunk) for the C(P,2)-chunk layout."""
    n_chunks = P_ * (P_ - 1) // 2
    assert global_batch % n_chunks == 0, (global_batch, n_chunks)
    return n_chunks, global_batch // n_chunks


def make_coded_batch_r2(batch: Dict, P_: int) -> Dict:
    """Reorder a [B, ...] batch into the replicated chunk layout
    [P, P-1, B/C(P,2), ...]: row p holds the P-1 chunks pod p maps
    (each chunk appears in exactly its 2 member pods)."""
    from ..core.gradient_sync import chunk_index_table
    table = chunk_index_table(P_)                 # [P, P-1] chunk ids

    def f(x):
        n_chunks, rows = chunk_layout_r2(x.shape[0], P_)
        xc = x.reshape(n_chunks, rows, *x.shape[1:])
        return xc[table]                          # [P, P-1, rows, ...]
    return jax.tree.map(f, batch)


def coded_grads_r2(params, cfg: ArchConfig, tc: TrainConfig,
                   coded_batch: Dict, mesh: Mesh, pod_axis: str = "pod",
                   failed: Optional[int] = None) -> Tuple[Any, jax.Array]:
    """Gradient computation + coded cross-pod sync (r = 2).

    coded_batch: the [P, P-1, rows, ...] layout of make_coded_batch_r2,
    sharded over 'pod' on axis 0.  Every pod maps its P-1 chunks (the 2x
    map replication), then the coded reduce-scatter + all-gather restores
    the exact full-batch mean gradient — with any single ``failed`` pod's
    contribution recoverable from its pair partners.
    """
    P_ = mesh.shape[pod_axis]
    flat_params, tree = jax.tree.flatten(params)
    sizes = [int(np.prod(p.shape)) for p in flat_params]
    G = sum(sizes)
    pad = (-G) % P_

    other_axes = [a for a in mesh.axis_names if a != pod_axis]

    def pod_fn(pb, *ps):
        params_l = jax.tree.unflatten(tree, list(ps))
        pb = jax.tree.map(lambda x: x[0], pb)     # [P-1, rows, ...]

        def chunk_grads(mb):
            (loss, _), grads = jax.value_and_grad(
                _loss_fn, has_aux=True)(params_l, cfg, tc, mb)
            vec = jnp.concatenate(
                [g.astype(tc.grad_dtype).ravel()
                 for g in jax.tree.leaves(grads)])
            return jnp.pad(vec, (0, pad)), loss

        def body(loss_sum, mb):
            vec, loss = chunk_grads(mb)
            return loss_sum + loss, vec
        loss_sum, vecs = jax.lax.scan(body, jnp.zeros(()), pb)
        # [P-1, G+pad] per-chunk grad partials, partner-ascending order
        shard = coded_reduce_scatter_r2(vecs, pod_axis, P_, failed=failed)
        full = jax.lax.all_gather(shard, pod_axis, axis=0, tiled=True)
        n_chunks = P_ * (P_ - 1) // 2
        full = full / n_chunks                    # mean over chunks
        loss = loss_sum / (P_ - 1)
        # replica-mean over non-pod axes is a no-op (identical) but keeps
        # the result uniform across the mesh for pjit consumers
        return full[None], loss[None]

    in_spec = (P(pod_axis),) + tuple(P() for _ in flat_params)
    fn = shard_map(pod_fn, mesh=mesh, in_specs=in_spec,
                   out_specs=(P(pod_axis), P(pod_axis)),
                   check=False)
    full, loss = fn(jax.tree.map(lambda x: x, coded_batch), *flat_params)
    vec = full[0]                                 # identical across pods
    loss = loss.mean()
    # unflatten
    out, off = [], 0
    for p, s in zip(flat_params, sizes):
        out.append(vec[off:off + s].reshape(p.shape).astype(tc.grad_dtype))
        off += s
    return jax.tree.unflatten(tree, out), loss


# ---------------------------------------------------------------------------
# The jitted step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, tc: TrainConfig,
                    mesh: Optional[Mesh] = None,
                    policy: Optional[shlib.ShardingPolicy] = None,
                    donate: bool = True) -> Callable:
    """Build step(state, batch) -> (state, metrics).

    For dp_mode='coded_r2', ``batch`` must be in make_coded_batch_r2
    layout and ``mesh`` must be provided.
    """

    def step(state, batch):
        with shlib.use_policy(policy):
            if tc.dp_mode == "coded_r2":
                grads, loss = coded_grads_r2(state["params"], cfg, tc,
                                             batch, mesh)
            else:
                grads, loss = accumulate_grads(state["params"], cfg, tc,
                                               batch)
            new_params, new_opt, om = optimizer_update(
                grads, state["opt"], state["params"], tc.opt)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **om}
        return new_state, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0,) if donate else ())
    return step     # caller jits with explicit shardings (launch/dryrun)


def train_step_shardings(state, batch, policy: shlib.ShardingPolicy,
                         fsdp: bool = True):
    """(in_shardings, out_shardings) trees for jitting make_train_step's
    step under pjit on a production mesh."""
    mesh = policy.mesh
    pspec = shlib.param_pspecs(state["params"], policy, fsdp=fsdp)
    opt_spec = {"m": pspec, "v": pspec, "count": P()}
    state_spec = {"params": pspec, "opt": opt_spec, "step": P()}
    batch_spec = shlib.batch_pspecs(policy, batch)
    to_sh = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
    return ((to_sh(state_spec), to_sh(batch_spec)),
            (to_sh(state_spec), to_sh(metrics_spec)))
