"""Fault tolerance: preemption-safe resume, straggler-tolerant coded sync,
and elastic re-meshing — the 1000-node-scale substrate.

Three mechanisms, each exploiting structure the paper's scheme provides
anyway:

1. **Checkpoint/restart** (checkpoint.py): atomic saves + deterministic
   data pipeline (data/pipeline.py is stateless in the step counter), so
   a preempted run resumed from step s reproduces the uninterrupted run
   bit-for-bit (asserted in tests/test_fault.py).

2. **Straggler/failure tolerance via map replication**: HCMR's r-fold map
   replication means every microbatch chunk has r owners.  The coded
   cross-pod reduce-scatter decodes the exact full-batch gradient with
   any single pod missing (r=2) — a straggling pod is simply dropped
   from the collective instead of stalling the step
   (:func:`repro.core.gradient_sync.coded_reduce_scatter_r2` ``failed=``).

3. **Elastic re-meshing**: when a pod is lost for good (or added), the
   chunk-ownership table is a pure function of P, so the runtime rebuilds
   the assignment for P' = P ± 1 and continues from the last checkpoint —
   no resharding of params is needed for pod-axis changes in 'replicated'
   or 'coded_r2' modes because params are replicated across pods.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import numpy as np

from .checkpoint import latest_step, restore_checkpoint, save_checkpoint


@dataclasses.dataclass
class PreemptionSimulator:
    """Deterministically 'preempts' (raises) at the given step — drives the
    resume tests and examples."""
    preempt_at_step: Optional[int] = None

    def check(self, step: int) -> None:
        if self.preempt_at_step is not None and step == self.preempt_at_step:
            raise InterruptedError(f"simulated preemption at step {step}")


def run_with_restarts(train_loop: Callable[[int], Iterable[Tuple[int, Dict]]],
                      ckpt_dir: str, max_restarts: int = 3,
                      budget: Optional[object] = None):
    """Drive ``train_loop(start_step)`` restarting from the latest
    checkpoint on preemption.  Yields (step, metrics) of completed steps.

    Restart accounting lives in the shared
    :class:`repro.resilience.backoff.RestartBudget` (the same accountant
    the engine recovery ladder uses), which replaces this function's old
    inline counter loop: when the budget is spent the original
    ``InterruptedError`` is re-raised, exactly as before.  Pass ``budget``
    to share one budget (or a jittered backoff-with-sleep policy) across
    drivers; the default budget records backoff delays without sleeping —
    the historical timing behavior.
    """
    from ..resilience.backoff import RestartBudget
    if budget is None:
        budget = RestartBudget(max_restarts=max_restarts)
    while True:
        start = (latest_step(ckpt_dir) or -1) + 1
        try:
            yield from train_loop(start)
            return
        except InterruptedError as e:
            budget.next_restart(e)    # re-raises e when the budget is spent


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Chunk assignment for the coded_r2 trainer at the CURRENT pod count.

    Rebuilt whenever membership changes; everything downstream
    (make_coded_batch_r2, coded_reduce_scatter_r2) is a pure function of
    ``n_pods``, so elasticity = constructing a new plan + a new mesh.
    """
    n_pods: int

    @property
    def n_chunks(self) -> int:
        return self.n_pods * (self.n_pods - 1) // 2

    def batch_divisor(self) -> int:
        """Global batch must divide by this for the chunk layout."""
        return self.n_chunks

    def shrink(self) -> "ElasticPlan":
        if self.n_pods <= 2:
            raise ValueError("cannot shrink below 2 pods")
        return ElasticPlan(self.n_pods - 1)

    def grow(self) -> "ElasticPlan":
        return ElasticPlan(self.n_pods + 1)


def straggler_dropout_schedule(n_steps: int, n_pods: int, rate: float,
                               seed: int = 0) -> np.ndarray:
    """Synthetic straggler trace: step -> failed pod id or -1 (none).
    Used by benchmarks/fault_bench and tests."""
    rng = np.random.default_rng(seed)
    fail = rng.random(n_steps) < rate
    pods = rng.integers(0, n_pods, n_steps)
    return np.where(fail, pods, -1)
