"""AdamW + schedules, pure JAX.

Production knobs that matter at 405B scale on 16 GB/chip v5e:

  * ``moment_dtype=bfloat16`` — keeps Adam m/v in bf16 (2 bytes/param each
    instead of 4).  With ZeRO-3 sharding of params+moments this is what
    lets llama3-405b train fit a single 256-chip pod (see EXPERIMENTS.md
    §Dry-run memory table).  Update math still runs in fp32.
  * global-norm clipping fused into the update (no extra pass).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"                 # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32     # bf16 halves optimizer HBM


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def init_opt_state(params: Any, cfg: OptimizerConfig) -> Dict:
    if cfg.kind == "adafactor":
        return init_adafactor_state(params)
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_update(grads: Any, opt_state: Dict, params: Any,
                 cfg: OptimizerConfig,
                 ) -> Tuple[Any, Dict, Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = lr_at(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.ones((), jnp.float32)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment) — the production
# optimizer for the >= 300B plans: optimizer state drops from 2x params to
# ~(rows + cols) per matrix (T5/PaLM recipe), which is what lets
# llama3-405b / grok-1 train sit in 16 GB/chip HBM (EXPERIMENTS.md).
# ---------------------------------------------------------------------------

def init_adafactor_state(params: Any) -> Dict:
    def fac(p):
        if p.ndim >= 2:
            # factor over the two trailing dims (stacked layers keep lead)
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"m": jax.tree.map(fac, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads: Any, opt_state: Dict, params: Any,
                     cfg: OptimizerConfig,
                     ) -> Tuple[Any, Dict, Dict[str, jax.Array]]:
    count = opt_state["count"] + 1
    lr = lr_at(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.ones((), jnp.float32)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    c = count.astype(jnp.float32)
    b2 = 1.0 - c ** -0.8                      # Adafactor's decay schedule
    eps = 1e-30

    def upd(p, g, st):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = b2 * st["vr"] + (1 - b2) * g2.mean(axis=-1)
            vc = b2 * st["vc"] + (1 - b2) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps))
            u = g * jax.lax.rsqrt(denom + eps)
            new_st = {"vr": vr, "vc": vc}
        else:
            v = b2 * st["v"] + (1 - b2) * g2
            u = g * jax.lax.rsqrt(v + eps)
            new_st = {"v": v}
        # update clipping (RMS <= 1) + decoupled weight decay
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms)
        newp = (p.astype(jnp.float32) * (1 - lr * cfg.weight_decay)
                - lr * u)
        return newp.astype(p.dtype), new_st

    is_state = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    flat = jax.tree.map(upd, params, grads, opt_state["m"],
                        is_leaf=lambda x: is_state(x))
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return (new_params, {"m": new_m, "count": count},
            {"grad_norm": gnorm, "lr": lr})


def optimizer_update(grads: Any, opt_state: Dict, params: Any,
                     cfg: OptimizerConfig):
    if cfg.kind == "adafactor":
        return adafactor_update(grads, opt_state, params, cfg)
    return adamw_update(grads, opt_state, params, cfg)
