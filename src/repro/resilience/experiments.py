"""Cloning-vs-coding experiments: when does task replication buy more as
SPECULATION fuel (clone / restart, first finisher wins) than as CODING fuel
(the paper's shuffle-traffic reduction)?

Two drivers, both seeded and deterministic:

  * :func:`cloning_vs_coding_frontier` — per Table I row x straggler
    regime, sweep the replication budget: ``uncoded r=1`` (+ clone budget)
    against ``coded``/``hybrid`` at the row's r, under every speculation
    policy.  Each cell reports mean/p99 JCT over independent straggler
    seeds plus backup accounting; ``budget`` counts total map copies
    (``repl x (1 + n_clones)``), so the frontier reads as JCT vs
    replication spend.
  * :func:`hedged_vs_static_stream` — the multi-job check of the hedged
    r-policy (:class:`repro.resilience.replication.HedgedRPolicy`): a probe
    stream fits the straggler model online, then the SAME evaluation stream
    runs under (a) the static fetch-aware chooser and (b) the chooser with
    the pre-fit hedged r-policy (straggler-priced candidates +
    deterministic rack-hedged placements).  Under ``RackCorrelated`` the
    hedged policy must win p99 — asserted by ``benchmarks/resilience_bench
    .py``.

:func:`check_frontier_invariants` distills the acceptance criteria from a
frontier: speculation is a bit-identical no-op under ``NoStragglers``, and
``late``/``clone`` improve p99 under ``ExponentialTail``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.params import TABLE1_GRID
from ..sim import (ClusterSim, CostModel, ExponentialTail, JobSpec,
                   NoStragglers, PoissonWorkload, RackCorrelated,
                   RackTopology, SchemeChooser, StragglerModel,
                   default_catalog, run_scheduled, simulate_single_job)
from .replication import HedgedRPolicy
from .speculation import get_policy

# the paper's Table I (K, P, Q, N, r) grid — the same rows every bench
# anchors on (divisibility-violating rows run with check=False)
TABLE1_ROWS: List[Tuple[int, int, int, int, int]] = list(TABLE1_GRID)

DEFAULT_POLICIES: Tuple[Tuple[str, Dict], ...] = (
    ("none", {}),
    ("clone", {"n_clones": 1}),
    ("late", {}),
    ("mantri", {}),
)


def straggler_regimes(exp_scale: float = 1.0, rack_p: float = 0.25,
                      rack_factor: float = 4.0
                      ) -> Dict[str, StragglerModel]:
    """The three regimes of the acceptance grid."""
    return {
        "none": NoStragglers(),
        "exp_tail": ExponentialTail(exp_scale),
        "rack": RackCorrelated(rack_p, rack_factor),
    }


@dataclasses.dataclass(frozen=True)
class FrontierCell:
    """One (row, regime, scheme, r, policy) cell of the frontier."""
    params: Tuple[int, int, int, int, int]
    regime: str
    scheme: str
    r: int
    policy: str
    budget: float                  # total map copies: repl * (1 + clones)
    jcts: Tuple[float, ...]        # per-seed JCTs (kept for exact no-op
    mean_jct: float                # comparisons across policies)
    p99_jct: float
    mean_backups: float
    mean_backup_wins: float

    def to_row(self) -> Dict:
        d = dataclasses.asdict(self)
        d["params"] = list(self.params)
        d["jcts"] = list(self.jcts)
        return d


def _cell(params: Tuple[int, int, int, int, int], regime: str,
          model: StragglerModel, scheme: str, r: int, policy_name: str,
          policy_kwargs: Dict, cost: CostModel, intra_bw: float,
          cross_bw: float, n_seeds: int,
          tasks_per_server: Optional[int]) -> FrontierCell:
    K, P, Q, N, _ = params
    topo = RackTopology(P=P, cross_bw=cross_bw, intra_bw=intra_bw)
    spec = JobSpec("frontier_probe", N, Q, 1)
    policy = get_policy(policy_name, tasks_per_server=tasks_per_server,
                        **policy_kwargs)
    jcts, backups, wins = [], [], []
    for seed in range(n_seeds):
        st = simulate_single_job(spec, topo, K, scheme, r,
                                 cost_model=cost, stragglers=model,
                                 seed=seed, check=False, speculation=policy)
        jcts.append(st.jct)
        backups.append(st.n_backups)
        wins.append(st.n_backup_wins)
    repl = 1 if scheme == "uncoded" else r
    clones = policy_kwargs.get("n_clones", 0) if policy_name == "clone" \
        else 0
    return FrontierCell(params, regime, scheme, r, policy_name,
                        float(repl * (1 + clones)), tuple(jcts),
                        float(np.mean(jcts)),
                        float(np.percentile(jcts, 99)),
                        float(np.mean(backups)), float(np.mean(wins)))


def cloning_vs_coding_frontier(
        rows: Sequence[Tuple[int, int, int, int, int]] = tuple(TABLE1_ROWS),
        policies: Sequence[Tuple[str, Dict]] = DEFAULT_POLICIES,
        regimes: Optional[Dict[str, StragglerModel]] = None,
        cost: Optional[CostModel] = None,
        intra_bw: float = 1e7, cross_bw: float = 1e6,
        n_seeds: int = 10,
        tasks_per_server: Optional[int] = 8) -> List[FrontierCell]:
    """The full frontier grid: every row x regime x replication point
    (uncoded r=1, coded/hybrid at the row's r) x policy.

    ``tasks_per_server`` coalesces map tasks so the N=6900 rows stay cheap
    (speculation semantics are per-task either way); pass None for
    per-subfile tasks.
    """
    if regimes is None:
        regimes = straggler_regimes()
    if cost is None:
        cost = CostModel()
    cells: List[FrontierCell] = []
    for params in rows:
        row_r = params[4]
        points = [("uncoded", 1), ("coded", row_r), ("hybrid", row_r)]
        if row_r != 2:
            points.append(("hybrid", 2))
        for regime, model in regimes.items():
            for scheme, r in points:
                for pol_name, pol_kwargs in policies:
                    cells.append(_cell(params, regime, model, scheme, r,
                                       pol_name, pol_kwargs, cost,
                                       intra_bw, cross_bw, n_seeds,
                                       tasks_per_server))
    return cells


def frontier_curve(cells: Sequence[FrontierCell],
                   regime: str) -> List[Dict]:
    """Best (scheme, r, policy) per replication budget in one regime —
    the literal cloning-vs-coding frontier."""
    best: Dict[float, FrontierCell] = {}
    for c in cells:
        if c.regime != regime:
            continue
        if c.budget not in best or c.p99_jct < best[c.budget].p99_jct:
            best[c.budget] = c
    return [{"budget": b, "scheme": c.scheme, "r": c.r, "policy": c.policy,
             "mean_jct": c.mean_jct, "p99_jct": c.p99_jct}
            for b, c in sorted(best.items())]


def check_frontier_invariants(cells: Sequence[FrontierCell]) -> Dict:
    """The acceptance checks over a frontier grid:

    * ``noop_under_none`` — under ``NoStragglers`` every policy's per-seed
      JCTs are BIT-IDENTICAL to the ``none`` policy's (speculation never
      fires, never hurts);
    * ``late_improves_p99`` / ``clone_improves_p99`` — under
      ``ExponentialTail`` the policy's summed p99 over the grid is strictly
      below ``none``'s, and no single cell regresses beyond float noise;
    * ``mantri_improves_p99_rack`` — under ``RackCorrelated`` (Mantri's
      design regime — cause attribution needs a rack-shaped cause) the
      summed p99 is strictly below ``none``'s.  Only the aggregate is
      asserted: on i.i.d. tails Mantri can misattribute a lone straggler
      to its rack and restart sub-optimally on individual cells.
    """
    by_key: Dict[Tuple, Dict[str, FrontierCell]] = {}
    for c in cells:
        by_key.setdefault((c.params, c.regime, c.scheme, c.r),
                          {})[c.policy] = c
    noop = True
    for (params, regime, scheme, r), pols in by_key.items():
        if regime != "none" or "none" not in pols:
            continue
        base = pols["none"].jcts
        for name, c in pols.items():
            if c.jcts != base:
                noop = False
    out = {"noop_under_none": noop}

    def sums(pol: str, regime: str) -> Tuple[bool, float, float, bool]:
        tot_p, tot_b, pointwise, seen = 0.0, 0.0, True, False
        tol = 1.0 + 1e-9
        for key, pols in by_key.items():
            if key[1] != regime or pol not in pols or "none" not in pols:
                continue
            seen = True
            tot_p += pols[pol].p99_jct
            tot_b += pols["none"].p99_jct
            if pols[pol].p99_jct > pols["none"].p99_jct * tol:
                pointwise = False
        return seen, tot_p, tot_b, pointwise

    for pol in ("late", "clone"):
        seen, tot_p, tot_b, pointwise = sums(pol, "exp_tail")
        out[f"{pol}_improves_p99"] = seen and pointwise and tot_p < tot_b
    seen, tot_p, tot_b, _ = sums("mantri", "rack")
    out["mantri_improves_p99_rack"] = seen and tot_p < tot_b
    return out


def hedged_vs_static_stream(
        K: int = 8, P: int = 4,
        stragglers: Optional[StragglerModel] = None,
        cost: Optional[CostModel] = None,
        intra_bw: float = 1e6, cross_bw: float = 1e5,
        rate: float = 4.0, n_jobs: int = 60, n_probe: int = 30,
        seed: int = 0, max_concurrent: int = 4,
        placement_solver: str = "greedy",
        speculation: Optional[object] = None) -> Dict:
    """Static fetch-aware chooser vs the hedged r-policy on one stream.

    A probe stream (different seed) fits the straggler model through the
    scheduler's own ``r_policy.observe`` feedback loop; the evaluation
    stream then runs twice from identical initial state.  Both choosers are
    placement-aware (same solver) — the hedged one differs exactly by (a)
    straggler-priced candidate estimates and (b) deterministic rack-hedged
    structured placements.
    """
    if stragglers is None:
        stragglers = RackCorrelated(0.25, 4.0)
    if cost is None:
        cost = CostModel()
    catalog = default_catalog(K, P)
    topo = RackTopology(P=P, cross_bw=cross_bw, intra_bw=intra_bw)

    def stream(r_policy, jobs, stream_seed):
        cluster = ClusterSim(topo, K, cost, stragglers, stream_seed)
        chooser = SchemeChooser(K, cost_model=cost,
                                placement_solver=placement_solver,
                                placement_seed=stream_seed,
                                speculation=speculation,
                                r_policy=r_policy)
        stats, sched = run_scheduled(jobs, cluster, chooser,
                                     max_concurrent=max_concurrent)
        jcts = np.asarray([s.jct for s in stats])
        picks: Dict[str, int] = {}
        for s in stats:
            d = sched.decisions[s.job_id]
            key = f"{d.scheme}:r{d.r}"
            picks[key] = picks.get(key, 0) + 1
        return {"mean_jct": float(jcts.mean()),
                "p99_jct": float(np.percentile(jcts, 99)),
                "n_jobs": int(len(jcts)), "decisions": picks}

    # probe: fit online through the scheduler's observe feedback
    r_policy = HedgedRPolicy(K, P, placement_solver=placement_solver,
                             placement_seed=seed)
    probe_jobs = PoissonWorkload(catalog, n_probe, rate).generate(seed + 1)
    stream(r_policy, probe_jobs, seed + 1)
    fit = r_policy.fit

    eval_jobs = PoissonWorkload(catalog, n_jobs, rate).generate(seed)
    static = stream(None, eval_jobs, seed)
    hedged = stream(HedgedRPolicy(K, P, fit=fit,
                                  placement_solver=placement_solver,
                                  placement_seed=seed),
                    eval_jobs, seed)
    return {"fit": dataclasses.asdict(fit), "static": static,
            "hedged": hedged,
            "hedged_beats_static_p99":
                hedged["p99_jct"] < static["p99_jct"],
            "hedged_beats_static_mean":
                hedged["mean_jct"] < static["mean_jct"]}
