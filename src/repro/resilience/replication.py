"""Straggler-aware replication: fit a straggler model from observed phase
times, price its barrier cost into the scheduler's (scheme, r) choice, and
hedge hybrid map replicas across racks.

The paper buys cheap cross-rack shuffle with extra map replication; this
module closes the loop the ROADMAP asks for: replication is ALSO the
classic straggler weapon, so the right r depends on the tail the cluster
actually exhibits.  Three pieces:

  * :func:`fit_straggler_model` — classify observed per-job map slowdowns
    (``JobStats.phase_times['map'] / expected unstraggled map seconds``)
    into ``none`` / ``exp_tail`` / ``rack`` and estimate the parameters of
    the matching :mod:`repro.sim.cluster` model (`ExponentialTail` scale via
    the order-statistics identity ``E[max of K] = 1 + scale * H_K``;
    `RackCorrelated` ``p_slow`` via ``P(job hits a slow rack) = 1 -
    (1 - p_slow)^P`` and ``factor`` from the slow mode's mean).
  * :class:`StragglerFit` — the fitted model plus its
    :meth:`~StragglerFit.expected_barrier_factor`, the mean multiplicative
    inflation a K-server barrier phase suffers under the fit.
  * :class:`HedgedRPolicy` — the ``r_policy`` knob of
    :class:`repro.sim.scheduler.SchemeChooser`: inflates every candidate's
    compute-phase estimates by the fitted barrier factor (so map-heavy
    high-r candidates pay their true straggler exposure, which the static
    chooser ignores) and replaces the random uniform replica placement of
    hybrid admissions with a deterministic rack-spread ``resolvable``
    structured placement (:mod:`repro.placement.structured`) — map replicas
    hedged across racks, so a slow rack neither concentrates fetch traffic
    nor owns sole copies.  It keeps refitting online from completed jobs.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Optional, Sequence

import numpy as np

from ..core.params import SchemeParams


def _harmonic(n: int) -> float:
    return sum(1.0 / i for i in range(1, max(n, 1) + 1))


@dataclasses.dataclass(frozen=True)
class StragglerFit:
    """A fitted straggler model: ``kind`` in {'none', 'exp_tail', 'rack'}
    with the matching simulator-model parameters."""
    kind: str
    scale: float = 0.0          # exp_tail: factors ~ 1 + Exp(scale)
    p_slow: float = 0.0         # rack: per-rack slowdown probability
    factor: float = 1.0         # rack: slowdown multiplier
    n_obs: int = 0

    def __post_init__(self) -> None:
        if self.kind not in ("none", "exp_tail", "rack"):
            raise ValueError(f"unknown fit kind {self.kind!r}")

    def expected_barrier_factor(self, K: int, P: int) -> float:
        """Mean multiplicative inflation of a K-server barrier phase:
        E[max_k factor_k].  exp_tail uses the exact max-of-exponentials
        order statistic; rack uses 'any of the P racks slow'."""
        if self.kind == "exp_tail":
            return 1.0 + self.scale * _harmonic(K)
        if self.kind == "rack":
            p_any = 1.0 - (1.0 - self.p_slow) ** P
            return 1.0 + p_any * (self.factor - 1.0)
        return 1.0


def fit_straggler_model(slowdowns: Sequence[float], K: int, P: int,
                        rack_sep: float = 1.6,
                        noise_floor: float = 1.05) -> StragglerFit:
    """Fit a :class:`StragglerFit` from observed per-job map slowdowns.

    ``slowdowns`` are ``observed map seconds / expected unstraggled map
    seconds`` per completed job — i.e. realizations of ``max_k factor_k``
    over the job's K-server barrier.  Classification: everything within
    ``noise_floor`` of 1 is 'none'; a separated bimodal cloud (slow mode >=
    ``rack_sep`` x the fast mode, fast mode near 1 — whole racks either hit
    or don't) fits 'rack'; anything else fits the exponential tail.
    """
    x = np.asarray([max(float(s), 1.0) for s in slowdowns], dtype=float)
    n = len(x)
    if n == 0 or float(x.max()) <= noise_floor:
        return StragglerFit("none", n_obs=n)
    split = 1.0 + 0.5 * (float(x.max()) - 1.0)
    hi, lo = x[x > split], x[x <= split]
    if (len(hi) > 0 and len(lo) > 0 and float(lo.mean()) <= noise_floor
            and float(hi.mean()) >= rack_sep * float(lo.mean())):
        # bimodal: jobs either hit >= 1 slow rack (the hi mode) or none
        q = len(hi) / n
        p_slow = 1.0 - (1.0 - min(q, 1.0 - 1e-12)) ** (1.0 / max(P, 1))
        return StragglerFit("rack", p_slow=float(p_slow),
                            factor=float(hi.mean()), n_obs=n)
    scale = max(float(x.mean()) - 1.0, 0.0) / _harmonic(K)
    return StragglerFit("exp_tail", scale=float(scale), n_obs=n)


class HedgedRPolicy:
    """Straggler-aware r-policy for :class:`repro.sim.scheduler
    .SchemeChooser` (the ``r_policy=`` knob).

    * ``compute_inflation(scheme, r)`` — multiplier the chooser applies to
      every compute-phase estimate; derived from the current fit, so r's
      true straggler exposure is priced per candidate.
    * ``placement_for(p)`` — deterministic rack-spread structured replica
      placement (+ assignment solve) for hybrid admissions, replacing the
      chooser's random draw; returns ``None`` when hedging is off or
      :mod:`repro.placement` rejects the instance.
    * ``observe(stats, expected_map_s)`` — online updates: the scheduler
      feeds every completed job's map time; the policy keeps a sliding
      window of slowdowns and refits every ``refit_every`` completions.

    A pre-computed :class:`StragglerFit` may be injected (offline
    calibration from a probe run); online observations then refine it.
    """

    def __init__(self, K: int, P: int, fit: Optional[StragglerFit] = None,
                 window: int = 64, refit_every: int = 8,
                 hedge_placement: bool = True,
                 placement_policy: str = "resolvable",
                 placement_solver: str = "flow",
                 placement_lam: float = 0.8,
                 placement_remote_penalty: float = 0.5,
                 placement_seed: int = 0) -> None:
        self.K = int(K)
        self.P = int(P)
        self.fit = fit or StragglerFit("none")
        self.window: Deque[float] = deque(maxlen=int(window))
        self.refit_every = int(refit_every)
        self.hedge_placement = bool(hedge_placement)
        self.placement_policy = placement_policy
        self.placement_solver = placement_solver
        self.placement_lam = float(placement_lam)
        self.placement_remote_penalty = float(placement_remote_penalty)
        self.placement_seed = int(placement_seed)
        self._since_fit = 0
        # structured placements are deterministic per (params, d): solve
        # each instance once (the catalog has a handful), not per admission
        self._placement_cache: dict = {}

    # ---- pricing -----------------------------------------------------------

    def compute_inflation(self, scheme: str, r: int) -> float:
        """Expected barrier inflation of one compute phase for a (scheme, r)
        candidate under the current fit.  The factor itself is r-invariant
        (barriers end at the slowest server either way) — but the chooser
        multiplies it into per-phase seconds that GROW with r, which is
        exactly the exposure the static chooser never prices."""
        return self.fit.expected_barrier_factor(self.K, self.P)

    # ---- hedged placement --------------------------------------------------

    def placement_for(self, p: SchemeParams, d: int = 1) -> Optional[object]:
        """Rack-spread structured placement for one hybrid admission, as
        :class:`repro.placement.sim_bridge.PlacementTraffic` (None when
        hedging is off or the instance is structurally rejected)."""
        if not self.hedge_placement:
            return None
        key = (p, int(d))
        if key in self._placement_cache:
            return self._placement_cache[key]
        try:
            from ..placement import (solve, structured_replicas,
                                     traffic_for_result)
            replicas = structured_replicas(p, policy=self.placement_policy)
            result = solve(p, replicas, self.placement_solver,
                           self.placement_lam,
                           rng=np.random.default_rng(self.placement_seed))
            tr = traffic_for_result(result, d,
                                    self.placement_remote_penalty)
        except (ImportError, ValueError):
            tr = None
        self._placement_cache[key] = tr
        return tr

    # ---- online fitting ----------------------------------------------------

    def observe(self, stats: object, expected_map_s: float) -> None:
        """Feed one completed job (its ``phase_times['map']`` vs the
        chooser's unstraggled estimate); refits on a sliding window."""
        t = getattr(stats, "phase_times", {}).get("map")
        if t is None or expected_map_s <= 0:
            return
        self.window.append(max(float(t) / float(expected_map_s), 1.0))
        self._since_fit += 1
        if self._since_fit >= self.refit_every:
            self._since_fit = 0
            self.fit = fit_straggler_model(list(self.window), self.K, self.P)


def slowdowns_from_stats(stats: Sequence[object],
                         expected_map_s: Sequence[float]) -> list:
    """Observed map slowdowns of completed jobs (helper for offline
    calibration: zip a probe run's ``JobStats`` with unstraggled
    expectations and feed :func:`fit_straggler_model`)."""
    out = []
    for s, e in zip(stats, expected_map_s):
        t = getattr(s, "phase_times", {}).get("map")
        if t is not None and e > 0:
            out.append(max(float(t) / float(e), 1.0))
    return out
