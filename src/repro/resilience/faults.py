"""Fault injection: seeded crash schedules shared by the executable engine
and the cluster simulator.

A :class:`FaultInjector` is an immutable, seeded schedule of
:class:`CrashEvent`\\ s — which servers die, during which phase, at what
(sim) time, on which engine attempt.  The SAME injector drives both
consumers:

  * the executable path (:func:`repro.mapreduce.engine.run_job_distributed`
    with ``faults=FaultSpec(...)``): events of attempt k are applied to
    attempt k of the recovery ladder, masking the crashed devices'
    in-memory map outputs;
  * the simulator (:meth:`inject_into` →
    :meth:`repro.sim.cluster.ClusterSim.inject_crash`): events become timed
    crash events that free slots, cancel in-flight flows, and trigger
    priced recovery phases.

Schedules are plain data built from a seed, so a fault experiment is
reproducible bit-for-bit — the sim's trace determinism extends through
injected failures (asserted by ``benchmarks/faults_bench.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from .backoff import BackoffPolicy

CRASH_PHASES = ("map", "shuffle")


@dataclasses.dataclass(frozen=True)
class CrashEvent:
    """One crash: ``servers`` (flat ids) die during ``phase`` at sim time
    ``time``; the executable ladder applies it on engine attempt
    ``attempt`` (0 = the first try)."""
    servers: Tuple[int, ...]
    phase: str = "shuffle"
    time: float = 0.0
    attempt: int = 0

    def __post_init__(self):
        if self.phase not in CRASH_PHASES:
            raise ValueError(f"phase must be one of {CRASH_PHASES}")
        object.__setattr__(self, "servers",
                           tuple(sorted({int(s) for s in self.servers})))


@dataclasses.dataclass(frozen=True)
class FaultInjector:
    """An immutable crash schedule (see module docstring)."""
    events: Tuple[CrashEvent, ...] = ()

    @classmethod
    def crash(cls, servers: Sequence[int], phase: str = "shuffle",
              time: float = 0.0, attempt: int = 0) -> "FaultInjector":
        """Single-event schedule: ``servers`` die once."""
        return cls((CrashEvent(tuple(servers), phase, time, attempt),))

    @classmethod
    def rack_crash(cls, p, rack: int, phase: str = "shuffle",
                   time: float = 0.0, attempt: int = 0) -> "FaultInjector":
        """All Kr servers of one rack die (correlated failure — the case
        the per-layer erasure structure does NOT cover for that rack's
        layers beyond r - 1 owners)."""
        servers = tuple(p.server_id(rack, j) for j in range(p.Kr))
        return cls((CrashEvent(servers, phase, time, attempt),))

    @classmethod
    def random(cls, seed: int, K: int, n_events: int = 1,
               max_servers: int = 1, phase: str = "shuffle",
               max_time: float = 0.0, attempt: int = 0) -> "FaultInjector":
        """Seeded random schedule: ``n_events`` crashes, each killing
        1..max_servers distinct servers (uniform), at U(0, max_time) sim
        times.  Same seed -> same schedule, always."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            k = int(rng.integers(1, max_servers + 1))
            servers = tuple(int(s) for s in
                            rng.choice(K, size=k, replace=False))
            t = float(rng.uniform(0.0, max_time)) if max_time > 0 else 0.0
            events.append(CrashEvent(servers, phase, t, attempt))
        return cls(tuple(events))

    def events_for_attempt(self, attempt: int) -> Tuple[CrashEvent, ...]:
        """Events the executable ladder applies on engine attempt k — a
        schedule with no events for the retry attempt models transient
        failures (the restart succeeds)."""
        return tuple(e for e in self.events if e.attempt == attempt)

    def all_servers(self) -> Tuple[int, ...]:
        out = set()
        for e in self.events:
            out.update(e.servers)
        return tuple(sorted(out))

    def inject_into(self, sim) -> None:
        """Register every event as a timed crash in a
        :class:`repro.sim.cluster.ClusterSim` (duck-typed on
        ``inject_crash(time, servers)``)."""
        for e in self.events:
            sim.inject_crash(e.time, e.servers)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Everything :func:`repro.mapreduce.engine.run_job_distributed` needs
    to run under injected failures: the crash schedule, the restart budget
    (rung 3 of the ladder), and the recovery policy knobs.

    ``sleep=None`` records backoff delays without sleeping (tests, sim);
    pass ``time.sleep`` to actually wait between restarts.
    ``allow_partial_remap=False`` disables rung 2 — orphaned subfiles then
    escalate straight to a full restart."""
    injector: FaultInjector
    max_restarts: int = 2
    backoff: BackoffPolicy = BackoffPolicy()
    allow_partial_remap: bool = True
    seed: int = 0
    sleep: Optional[object] = None


__all__ = ["CrashEvent", "FaultInjector", "FaultSpec", "CRASH_PHASES"]
