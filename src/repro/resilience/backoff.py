"""Shared restart budgeting: jittered exponential backoff + max-restart cap.

One policy object serves every restart loop in the repo — the trainer's
checkpoint/resume driver (:func:`repro.train.fault.run_with_restarts`) and
the engine recovery ladder (:mod:`repro.mapreduce.recovery`) — so "how many
times do we retry, and how long do we wait" is configured in exactly one
place instead of per-call-site inline loops.

Delays are deterministic per (seed, attempt): the jitter draws from a
seeded generator, so a recovery run's backoff schedule is reproducible —
the same property the fault injector and the cluster sim guarantee for
their traces.  ``sleep`` is injectable (default: record the delay without
sleeping) because tests and the sim price time themselves; pass
``time.sleep`` to actually wait.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np


class RestartBudgetExceeded(RuntimeError):
    """Raised by :meth:`RestartBudget.next_restart` when the max-restart
    budget is spent and no original error was supplied to re-raise."""


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Jittered exponential backoff: attempt k (0-based) waits
    ``min(base_delay * factor**k, max_delay) * (1 + U(-jitter, +jitter))``
    seconds."""
    base_delay: float = 0.5
    factor: float = 2.0
    max_delay: float = 30.0
    jitter: float = 0.1

    def delay(self, attempt: int, rng: np.random.Generator) -> float:
        d = min(self.base_delay * self.factor ** attempt, self.max_delay)
        if self.jitter:
            d *= 1.0 + float(rng.uniform(-self.jitter, self.jitter))
        return max(d, 0.0)


class RestartBudget:
    """Mutable restart accountant for one job/run.

    ``next_restart(error)`` charges one restart: when the budget still has
    room it computes the (jittered, seeded) backoff delay, records it in
    ``delays``, invokes ``sleep(delay)`` if a sleeper was given, and returns
    the delay; when the budget is exhausted it re-raises ``error`` (or
    :class:`RestartBudgetExceeded` if none was passed), preserving the
    raise-the-original-failure semantics of the old inline loop in
    ``train/fault.py``.
    """

    def __init__(self, max_restarts: int = 3,
                 policy: Optional[BackoffPolicy] = None, seed: int = 0,
                 sleep: Optional[Callable[[float], None]] = None) -> None:
        self.max_restarts = int(max_restarts)
        self.policy = policy if policy is not None else BackoffPolicy()
        self.sleep = sleep
        self.restarts = 0
        self.delays: List[float] = []
        self._rng = np.random.default_rng(seed)

    def next_restart(self, error: Optional[BaseException] = None) -> float:
        self.restarts += 1
        from ..obs import metrics as obs_metrics
        obs_metrics.counter(
            "restart_budget_total",
            "restart-budget consumption across all restart loops").inc(
                outcome=("exceeded" if self.restarts > self.max_restarts
                         else "restart"))
        if self.restarts > self.max_restarts:
            if error is not None:
                raise error
            raise RestartBudgetExceeded(
                f"restart budget exhausted after {self.max_restarts} restarts")
        delay = self.policy.delay(self.restarts - 1, self._rng)
        self.delays.append(delay)
        obs_metrics.histogram(
            "restart_backoff_seconds",
            "backoff delays charged by the restart budget").observe(delay)
        if self.sleep is not None:
            self.sleep(delay)
        return delay

    @property
    def exhausted(self) -> bool:
        return self.restarts > self.max_restarts


__all__ = ["BackoffPolicy", "RestartBudget", "RestartBudgetExceeded"]
