"""Speculative re-execution policies for the task-granular map phase.

The classic straggler weapons, as pluggable policies over
:class:`repro.sim.cluster.TaskMapPhase` (which hands itself to every hook
as the read-only view):

  * ``none``   — task-granular execution, no backups: the baseline that
    isolates what speculation itself buys.
  * ``clone``  — proactive cloning a la Dolly (Ananthanarayanan et al.):
    every task gets ``n_clones`` clones up front, queued BEHIND the target
    servers' own tasks, so clones only run on slack capacity and the
    first finisher wins.
  * ``late``   — LATE-style reactive backups (Zaharia et al.): once enough
    tasks completed to estimate a progress rate, any running attempt slower
    than ``slow_ratio`` x the observed mean gets one backup on the
    least-loaded eligible server (preferring input-local slots), within a
    ``budget_frac`` budget.
  * ``mantri`` — cause-aware restarts (Mantri, Ananthanarayanan et al.):
    per-rack completion rates attribute slowness to a RACK (shared ToR/PDU
    — the paper's server-rack failure domain) or to a lone machine; tasks
    in slow racks are backed up promptly AND away from the afflicted rack,
    lone-machine stragglers wait for the more patient threshold.

Every policy decision is a deterministic function of the view, so a seeded
simulation stays bit-identical across reruns (asserted in
``tests/test_resilience.py``).  Policies return ``[(task_index, server)]``
requests; the engine enforces budget, slot contention, input-fetch flows
and first-finisher-wins cancellation.

Registry idiom mirrors :mod:`repro.placement.solvers`: ``@register_policy``
+ :func:`get_policy`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

Request = Tuple[int, int]                      # (task_index, server)

SPECULATION_POLICIES: Dict[str, Callable[..., "SpeculationPolicy"]] = {}


def register_policy(name: str):
    """Class decorator adding a policy factory to the registry."""
    def deco(cls):
        cls.name = name
        SPECULATION_POLICIES[name] = cls
        return cls
    return deco


def get_policy(name: str, **kwargs) -> "SpeculationPolicy":
    """Instantiate a registered policy by name (kwargs = its knobs)."""
    if name not in SPECULATION_POLICIES:
        raise ValueError(f"unknown speculation policy {name!r}; "
                         f"registered: {sorted(SPECULATION_POLICIES)}")
    return SPECULATION_POLICIES[name](**kwargs)


@dataclasses.dataclass
class SpeculationPolicy:
    """Base policy: the hooks the engine calls, all no-ops.

    ``tasks_per_server`` coalesces each server's subfile list into that many
    near-equal chunks (None = one task per subfile, the default); coarser
    tasks bound the event count on big Table I rows.
    """
    tasks_per_server: Optional[int] = None
    name = "base"

    def backup_budget(self, n_tasks: int) -> int:
        """Maximum backup attempts the engine may launch for one job."""
        return 0

    def on_phase_start(self, view) -> List[Request]:
        """Called once when the map phase begins (proactive policies)."""
        return []

    def on_task_complete(self, view, task_index: int) -> List[Request]:
        """Called after every task completion (reactive policies)."""
        return []

    def on_server_idle(self, view, server: int) -> List[Request]:
        """Called when a server drains its queue while tasks remain — the
        work-stealing moment real schedulers speculate on."""
        return []

    def next_check_time(self, view, server: int) -> Optional[float]:
        """When an idle server found nothing to steal: absolute time at
        which the engine should re-invoke the idle hook (None = never).
        Lets thresholds trigger even when no completion events remain."""
        return None


@register_policy("none")
@dataclasses.dataclass
class NoSpeculation(SpeculationPolicy):
    """Task-granular execution without backups — the speculation baseline."""


@register_policy("clone")
@dataclasses.dataclass
class ProactiveClone(SpeculationPolicy):
    """Dolly-style proactive cloning: ``n_clones`` clones of every task,
    spread deterministically across OTHER racks (same layer slot, next
    racks), queued behind the targets' own tasks so they consume only slack
    capacity."""
    n_clones: int = 1
    budget_frac: float = 1.0        # fraction of n_tasks * n_clones allowed

    def backup_budget(self, n_tasks: int) -> int:
        return math.ceil(self.budget_frac * n_tasks * self.n_clones)

    def on_phase_start(self, view) -> List[Request]:
        reqs: List[Request] = []
        for task in view.tasks:
            for j in range(self.n_clones):
                if view.P > 1:
                    hop = 1 + (task.index + j) % (view.P - 1)
                    target = (task.server + view.Kr * hop) % view.K
                else:
                    target = (task.server + 1 + j) % view.K
                reqs.append((task.index, target))
        return reqs


def _rate_threshold_scan(view, threshold_of, min_completed_frac: float
                         ) -> List[Tuple[float, object]]:
    """Running attempts slower than their policy threshold, worst first.

    ``threshold_of(view, attempt) -> ratio``: attempt is slow once
    ``elapsed >= ratio * expected`` where expected = observed mean rate x
    task work.  Returns [(overdue_ratio, attempt)] sorted descending by
    (overdue, -task_index) — deterministic."""
    rate = view.mean_rate()
    if rate is None or rate <= 0:
        return []
    if view.n_done < max(1, math.ceil(min_completed_frac * view.n_tasks)):
        return []
    slow: List[Tuple[float, object]] = []
    for server in range(view.K):
        a = view.running[server]
        if a is None or a.state != "running" or a.task.done:
            continue
        if view.live_backup(a.task):
            continue
        expected = rate * a.task.work
        if expected <= 0:
            continue
        ratio = view.elapsed(a) / expected
        # 1e-9 slack: a probe scheduled AT the crossing time must see the
        # attempt as slow despite float round-off, or the idle server
        # would never re-probe (t == now schedules nothing)
        if ratio >= threshold_of(view, a) - 1e-9:
            slow.append((ratio, a))
    slow.sort(key=lambda x: (-x[0], x[1].task.index))
    return slow


def _next_threshold_crossing(view, threshold_of,
                             min_completed_frac: float) -> Optional[float]:
    """Earliest future time a running, un-backed-up attempt crosses its
    slowness threshold (the probe time an idle server should wake at)."""
    rate = view.mean_rate()
    if rate is None or rate <= 0:
        return None
    if view.n_done < max(1, math.ceil(min_completed_frac * view.n_tasks)):
        return None
    times = []
    for server in range(view.K):
        a = view.running[server]
        if a is None or a.state != "running" or a.task.done:
            continue
        if view.live_backup(a.task):
            continue
        t = a.start + threshold_of(view, a) * rate * a.task.work
        if t > view.now:
            times.append(t)
    return min(times) if times else None


@register_policy("late")
@dataclasses.dataclass
class LateBackup(SpeculationPolicy):
    """LATE-style threshold backups: an attempt running ``slow_ratio``x
    longer than the observed mean (estimated after ``min_completed_frac`` of
    tasks finished) gets ONE backup on the best eligible server; idle
    servers steal the slowest overdue attempt."""
    slow_ratio: float = 1.6
    min_completed_frac: float = 0.15
    budget_frac: float = 0.25

    def backup_budget(self, n_tasks: int) -> int:
        return max(1, math.ceil(self.budget_frac * n_tasks))

    def _threshold(self, view, attempt) -> float:
        return self.slow_ratio

    def on_task_complete(self, view, task_index: int) -> List[Request]:
        reqs: List[Request] = []
        for _, a in _rate_threshold_scan(view, self._threshold,
                                         self.min_completed_frac):
            target = view.pick_backup_server(a.task)
            if target is not None:
                reqs.append((a.task.index, target))
        return reqs

    def on_server_idle(self, view, server: int) -> List[Request]:
        # the idle slot is the trigger, not necessarily the target: an
        # input-local replica holder beats a fetch-bound idle server
        return self.on_task_complete(view, -1)

    def next_check_time(self, view, server: int) -> Optional[float]:
        return _next_threshold_crossing(view, self._threshold,
                                        self.min_completed_frac)


@register_policy("mantri")
@dataclasses.dataclass
class MantriRestart(SpeculationPolicy):
    """Cause-aware restarts: per-rack completion rates flag racks whose
    mean rate exceeds ``rack_factor`` x the cluster mean (shared ToR/PDU
    slowdowns — the `RackCorrelated` failure domain).  Attempts in flagged
    racks are backed up at the prompt ``slow_ratio`` threshold AND placed
    outside the afflicted rack; lone-machine stragglers must overshoot the
    ``patient_ratio`` before restarting anywhere."""
    slow_ratio: float = 1.3
    patient_ratio: float = 2.5
    rack_factor: float = 1.3
    min_completed_frac: float = 0.15
    budget_frac: float = 0.25

    def backup_budget(self, n_tasks: int) -> int:
        return max(1, math.ceil(self.budget_frac * n_tasks))

    def _slow_racks(self, view) -> set:
        mean = view.mean_rate()
        if mean is None or mean <= 0:
            return set()
        return {r for r, rr in enumerate(view.rack_rates())
                if rr is not None and rr > self.rack_factor * mean}

    def _threshold(self, view, attempt) -> float:
        slow = self._slow_racks(view)
        return (self.slow_ratio
                if view.rack_of(attempt.server) in slow
                else self.patient_ratio)

    def _requests(self, view) -> List[Request]:
        slow_racks = self._slow_racks(view)
        reqs: List[Request] = []
        for _, a in _rate_threshold_scan(view, self._threshold,
                                         self.min_completed_frac):
            rack = view.rack_of(a.server)
            avoid = (rack,) if rack in slow_racks else ()
            target = view.pick_backup_server(a.task, avoid_racks=avoid)
            if target is None and avoid:       # cluster-wide slow: anywhere
                target = view.pick_backup_server(a.task)
            if target is not None:
                reqs.append((a.task.index, target))
        return reqs

    def on_task_complete(self, view, task_index: int) -> List[Request]:
        return self._requests(view)

    def on_server_idle(self, view, server: int) -> List[Request]:
        return self._requests(view)

    def next_check_time(self, view, server: int) -> Optional[float]:
        return _next_threshold_crossing(view, self._threshold,
                                        self.min_completed_frac)
