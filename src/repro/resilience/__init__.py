"""repro.resilience — speculative re-execution + straggler-aware replication.

The decision layer ON TOP of the cluster simulator: the paper's map
replication r reduces cross-rack shuffle traffic (coding), but replication
is also the classic straggler weapon (cloning / speculative backups).  This
package quantifies when each use of the budget wins:

  * :mod:`.speculation` — policy registry (``none`` / ``clone`` / ``late``
    / ``mantri``) driving the task-granular map phase of
    :class:`repro.sim.cluster.TaskMapPhase`;
  * :mod:`.replication` — straggler-model fitting from observed
    ``JobStats.phase_times`` and the :class:`HedgedRPolicy` that makes
    :class:`repro.sim.SchemeChooser` straggler-aware (priced candidates +
    rack-hedged structured placements);
  * :mod:`.experiments` — the cloning-vs-coding frontier over the Table I
    grid and the hedged-vs-static stream comparison feeding
    ``benchmarks/resilience_bench.py`` -> ``BENCH_resilience.json``;
  * :mod:`.faults` — seeded crash schedules (:class:`FaultInjector` /
    :class:`FaultSpec`) driving both the executable engine's recovery
    ladder (``run_job_distributed(faults=...)``) and the simulator's crash
    events — CRASHES, not just slowness (see docs/faults.md);
  * :mod:`.backoff` — the shared jittered-exponential restart budget used
    by the trainer's checkpoint/resume driver and the engine ladder.

See docs/resilience.md and docs/faults.md.
"""
from .speculation import (LateBackup, MantriRestart, NoSpeculation,
                          ProactiveClone, SPECULATION_POLICIES,
                          SpeculationPolicy, get_policy, register_policy)
from .replication import (HedgedRPolicy, StragglerFit, fit_straggler_model,
                          slowdowns_from_stats)
from .experiments import (DEFAULT_POLICIES, FrontierCell, TABLE1_ROWS,
                          check_frontier_invariants,
                          cloning_vs_coding_frontier, frontier_curve,
                          hedged_vs_static_stream, straggler_regimes)
from .backoff import BackoffPolicy, RestartBudget, RestartBudgetExceeded
from .faults import CRASH_PHASES, CrashEvent, FaultInjector, FaultSpec

__all__ = [
    "BackoffPolicy", "RestartBudget", "RestartBudgetExceeded",
    "CRASH_PHASES", "CrashEvent", "FaultInjector", "FaultSpec",
    "LateBackup", "MantriRestart", "NoSpeculation", "ProactiveClone",
    "SPECULATION_POLICIES", "SpeculationPolicy", "get_policy",
    "register_policy",
    "HedgedRPolicy", "StragglerFit", "fit_straggler_model",
    "slowdowns_from_stats",
    "DEFAULT_POLICIES", "FrontierCell", "TABLE1_ROWS",
    "check_frontier_invariants", "cloning_vs_coding_frontier",
    "frontier_curve", "hedged_vs_static_stream", "straggler_regimes",
]
