"""whisper-large-v3 [audio] — enc-dec transformer backbone; the conv/mel
frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,                # decoder layers
    encoder_layers=32,
    encoder_seq=1500,           # 30 s of audio after the conv frontend
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,              # full MHA (GQA kv=20)
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    qkv_bias=True,
    frontend="audio",
    sub_quadratic=False,        # full attention: long_500k skipped
    notes="Assigned seq_len applies to the DECODER stream; encoder is the "
          "fixed 1500-frame stub. Paper model caps decoder at 448 tokens; "
          "the assigned shapes stress the same backbone at longer lengths.",
)
