"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict

from .base import (ArchConfig, MLAConfig, MoEConfig, SHAPES, ShapeConfig,
                   SSMConfig, cell_is_runnable, shape_by_name)  # noqa: F401

from .whisper_large_v3 import CONFIG as _whisper
from .rwkv6_3b import CONFIG as _rwkv6
from .deepseek_v2_lite_16b import CONFIG as _dsv2
from .grok_1_314b import CONFIG as _grok
from .qwen2_1_5b import CONFIG as _qwen15
from .llama3_405b import CONFIG as _llama405
from .qwen2_72b import CONFIG as _qwen72
from .granite_3_2b import CONFIG as _granite
from .llava_next_34b import CONFIG as _llava
from .hymba_1_5b import CONFIG as _hymba

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in (
        _whisper, _rwkv6, _dsv2, _grok, _qwen15, _llama405, _qwen72,
        _granite, _llava, _hymba,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
