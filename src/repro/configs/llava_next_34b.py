"""llava-next-34b [vlm] — decoder LM backbone; anyres patch-embedding
frontend is a STUB (input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    n_frontend_tokens=2880,     # anyres tiling: 5 tiles x 576 patches
    rope_theta=5_000_000.0,
    sub_quadratic=False,
    notes="Patch embeddings are prepended to the token stream; assigned "
          "seq_len counts the combined stream length.",
)
