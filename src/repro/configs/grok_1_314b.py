"""grok-1-314b [moe] — 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(n_routed=8, n_shared=0, top_k=2, d_ff_expert=32768),
    sub_quadratic=False,
)
