"""rwkv6-3b 'Finch' [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                 # 2560 / 64 WKV heads
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attn_free=True,
    ssm=SSMConfig(state_dim=64),   # WKV state is head_dim x head_dim
    sub_quadratic=True,            # linear scan: long_500k RUNS
    notes="RWKV6 time-mix with data-dependent decay w = exp(-exp(.)); "
          "chunked WKV scan. Constant-size recurrent state for decode.",
)
