"""Architecture configuration system.

Every assigned architecture is an :class:`ArchConfig`; ``reduced()`` yields a
tiny same-family config for CPU smoke tests.  The FULL configs are touched
only by the dry-run (ShapeDtypeStruct — no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_ff_expert: int
    first_dense_layers: int = 0          # leading layers use the dense FFN
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    q_lora_rank: Optional[int] = None    # None => direct q projection


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 1                      # inner dim multiplier
    dt_rank: int = 32


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_free: bool = False              # RWKV: no attention at all
    encoder_layers: int = 0              # enc-dec only
    encoder_seq: int = 0                 # fixed encoder length (frames)
    frontend: str = "none"               # none | audio | vision
    n_frontend_tokens: int = 0           # image patch tokens prepended
    sliding_window: Optional[int] = None  # attention window (hybrid long ctx)
    sub_quadratic: bool = False          # supports long_500k
    notes: str = ""

    # ---- derived ------------------------------------------------------------

    @property
    def q_dim(self) -> int:
        if self.mla:
            return self.n_heads * (self.mla.nope_head_dim
                                   + self.mla.rope_head_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    def n_params(self) -> int:
        """Total parameter count (embedding + layers + head)."""
        from ..models.lm import count_params
        return count_params(self)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        from ..models.lm import count_params
        return count_params(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        scale_heads = max(self.n_heads // self.n_kv_heads, 1)
        n_kv = max(self.n_kv_heads // 4, 1)
        kw.update(
            n_layers=2, d_model=64, n_heads=n_kv * min(scale_heads, 4),
            n_kv_heads=n_kv, head_dim=16, d_ff=128, vocab_size=512,
        )
        if self.attn_free:                   # RWKV: n_heads * head_dim == d
            kw.update(n_heads=4, n_kv_heads=4, head_dim=16)
        if self.moe:
            kw["moe"] = MoEConfig(n_routed=4, n_shared=self.moe.n_shared and 1,
                                  top_k=2, d_ff_expert=32,
                                  first_dense_layers=min(
                                      self.moe.first_dense_layers, 1))
        else:
            kw["moe"] = None
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=32, rope_head_dim=8,
                                  nope_head_dim=16, v_head_dim=16,
                                  q_lora_rank=None)
            kw["head_dim"] = 16
        else:
            kw["mla"] = None
        kw["ssm"] = SSMConfig(state_dim=4, dt_rank=4) if self.ssm else None
        if self.encoder_layers:
            kw["encoder_layers"] = 2
            kw["encoder_seq"] = 32
        if self.n_frontend_tokens:
            kw["n_frontend_tokens"] = 8
        if self.sliding_window:
            kw["sliding_window"] = 16
        for k in ("moe", "mla", "ssm"):
            if isinstance(kw[k], dict):
                kw[k] = None
        return ArchConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode | long_decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "long_decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if skipped.

    ``long_500k`` needs a sub-quadratic sequence mixer; pure full-attention
    architectures skip it (documented in DESIGN.md Sec. 5)."""
    if shape.kind == "long_decode" and not cfg.sub_quadratic:
        return False, ("long_500k skipped: pure full-attention architecture "
                       "(O(S^2)); see DESIGN.md §Arch-applicability")
    return True, ""
