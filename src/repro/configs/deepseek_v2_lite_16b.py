"""deepseek-v2-lite-16b [moe] — MLA attention (kv_lora=512) + fine-grained
MoE (2 shared + 64 routed, top-6). [arXiv:2405.04434; hf]"""
from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,              # MLA: every head reads the shared kv_lora
    head_dim=128,               # nope head dim
    d_ff=10944,                 # dense FFN of the first layer
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128, q_lora_rank=None),
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408,
                  first_dense_layers=1),
    sub_quadratic=False,
    notes="MLA compressed KV cache (kv_lora+rope dims instead of full KV) — "
          "dominant decode-memory win. MoE dispatch is a literal shuffle; "
          "hybrid-coded/hierarchical all-to-all applies (DESIGN.md §4).",
)
