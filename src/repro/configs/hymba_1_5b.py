"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every layer,
sliding-window attention + constant-state SSM => sub-quadratic long context.
[arXiv:2411.13676; hf]"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm=SSMConfig(state_dim=16, conv_width=4, dt_rank=48),
    sliding_window=2048,        # attention heads use SWA; SSM path is global
    sub_quadratic=True,         # long_500k RUNS
    notes="Per-layer output = mean of normalized attention-head and "
          "SSM-head branches (paper's parallel-head fusion).",
)
