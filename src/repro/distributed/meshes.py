"""Mesh construction helpers (axis_types pinned to silence 0.9 migration)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_mesh(shape: tuple, names: tuple) -> Mesh:
    return jax.make_mesh(shape, names,
                         axis_types=(AxisType.Auto,) * len(names))
