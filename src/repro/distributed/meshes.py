"""Version-tolerant mesh / shard_map construction.

JAX moved two APIs this repo leans on:

  * ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
    ``jax.make_mesh``) only exist on jax >= 0.5; on 0.4.x meshes carry no
    axis types and ``jax.make_mesh`` rejects the kwarg.
  * ``jax.shard_map`` was promoted out of ``jax.experimental.shard_map``
    and its replication-check kwarg was renamed ``check_rep`` ->
    ``check_vma`` along the way.

Every call site in the repo routes through :func:`make_mesh` /
:func:`shard_map` below so the rest of the codebase is version-agnostic.
"""
from __future__ import annotations

import inspect

import jax
from jax.sharding import Mesh


def make_mesh(shape: tuple, names: tuple) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported (>= 0.5),
    plain mesh construction otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, names)
    return jax.make_mesh(shape, names,
                         axis_types=(axis_type.Auto,) * len(names))


def axis_size(axis_name: str):
    """Size of a named mesh axis inside shard_map (``jax.lax.axis_size`` on
    new jax; the constant-folding ``psum(1, axis)`` idiom on 0.4.x)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is None:                                     # jax 0.4.x
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    if "check_vma" in params:
        check_kw = "check_vma"
    elif "check_rep" in params:
        check_kw = "check_rep"
    else:
        check_kw = None
    return fn, check_kw


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check: bool = True):
    """Version-tolerant ``shard_map``.

    ``check=False`` disables the replication validity check, whatever the
    installed jax calls it (``check_vma`` on new jax, ``check_rep`` on 0.4.x).
    """
    fn, check_kw = _resolve_shard_map()
    kwargs = {} if (check or check_kw is None) else {check_kw: False}
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
