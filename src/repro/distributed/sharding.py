"""Logical-axis sharding: per-arch PartitionSpec rules for params/activations.

A :class:`ShardingPolicy` maps *logical* axis names (batch, embed, ffn,
heads, kv_heads, vocab, experts, ...) to mesh axes.  Model code annotates
activations with :func:`shard_acts` (no-op unless a policy is active), and
the trainer/dry-run derive parameter PartitionSpecs from
:func:`param_pspecs`, which walks the parameter pytree and assigns logical
axes by leaf path (t5x-style path rules — deterministic and testable).

Default production policy (v5e 16x16 per pod):
  batch   -> ('pod', 'data')   [dp_flat]  or  ('data',)  [dp_hybrid: the
             paper's map-replication across pods]
  heads / kv_heads / ffn / experts / vocab / qkv -> 'model'   (TP / EP)
  embed   -> None (replicated) or 'data' under FSDP overlay (ZeRO-3)
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig

# ---------------------------------------------------------------------------
# Policy + activation constraints
# ---------------------------------------------------------------------------

_STATE = threading.local()


class ShardingPolicy:
    """rules: logical axis -> mesh axis (str | tuple | None)."""

    def __init__(self, mesh: Mesh, rules: Dict[str, Any]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        out = []
        for a in axes:
            m = self.rules.get(a) if a is not None else None
            out.append(m)
        return P(*out)

    def sharding(self, axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))


def default_rules(multi_pod: bool, dp_mode: str = "dp_flat",
                  fsdp: bool = True) -> Dict[str, Any]:
    """Mesh-axis assignment for the production mesh.

    dp_mode='dp_hybrid' replicates the batch over 'pod' — the paper's map
    replication with r = n_pods: every pod computes every chunk, so the
    cross-pod gradient collective vanishes (L_cro -> 0 at r = P corner).
    """
    batch = (("pod", "data") if (multi_pod and dp_mode == "dp_flat")
             else ("data",))
    rules: Dict[str, Any] = {
        "batch": batch,
        "embed": None,
        "ffn": "model",
        "heads": "model",
        "kv_heads": "model",
        "qkv": "model",
        "vocab": "model",
        "experts": "model",
        "fsdp": "data" if fsdp else None,
        "seq": None,
        "cache_batch": batch,          # KV-cache batch dim
        "cache_feature": "model",      # KV-cache feature dim
        # Megatron-style sequence parallelism: residual-stream boundaries
        # sharded over the TP axis.  Bytes-neutral (the per-layer
        # all-reduce becomes an equal-bytes reduce-scatter + all-gather)
        # but divides boundary/activation HBM by the TP degree — what fits
        # llama3-405b remat boundaries on 16 GB chips.
        "seq_tp": None,
    }
    return rules


def with_sequence_tp(rules: Dict[str, Any]) -> Dict[str, Any]:
    out = dict(rules)
    out["seq_tp"] = "model"
    return out


def serve_tp2d_rules(multi_pod: bool) -> Dict[str, Any]:
    """2D tensor-parallel SERVING policy: weights statically sharded over
    the whole mesh (('data','model') on their parallel dim) so decode
    moves ACTIVATIONS (MBs) instead of weight shards (GBs/step under
    ZeRO-3 gathers); the KV cache stays batch-sharded over the data tier.
    The §Perf decode hillclimb variant."""
    rules = default_rules(multi_pod, fsdp=False)
    tp2 = (("pod", "data", "model") if multi_pod else ("data", "model"))
    for k in ("qkv", "ffn", "heads", "kv_heads", "vocab", "experts"):
        rules[k] = tp2
    rules["batch"] = None
    rules["cache_batch"] = (("pod", "data") if multi_pod else ("data",))
    rules["cache_feature"] = "model"
    return rules


# -- sequence-parallel boundary ops (custom-vjp) -----------------------------
#
# GSPMD is free to choose ANY backward sharding strategy for a forward
# sharding constraint; with a seq-sharded residual it picks full WEIGHT
# all-gathers for the dW einsums (3.25 GiB x 126 layers at 405B — measured).
# These identity ops pin the cotangent shardings too, forcing the Megatron
# pattern both ways: activations move (cheap), weights never do.

_FULL = ("batch", "seq", "embed")
_BOUNDARY = ("batch", "seq_tp", "embed")


@jax.custom_vjp
def sp_gather(x: jax.Array) -> jax.Array:
    """Boundary (seq-sharded over TP) -> full-sequence for sublayer math."""
    return shard_acts(x, _FULL)


def _sp_gather_fwd(x):
    return shard_acts(x, _FULL), None


def _sp_gather_bwd(_, g):
    return (shard_acts(g, _BOUNDARY),)     # dL/dx reduce-scattered back


sp_gather.defvjp(_sp_gather_fwd, _sp_gather_bwd)


@jax.custom_vjp
def sp_scatter(x: jax.Array) -> jax.Array:
    """Sublayer output -> boundary (reduce-scatter over TP)."""
    return shard_acts(x, _BOUNDARY)


def _sp_scatter_fwd(x):
    return shard_acts(x, _BOUNDARY), None


def _sp_scatter_bwd(_, g):
    return (shard_acts(g, _FULL),)         # cotangent all-gathered once


sp_scatter.defvjp(_sp_scatter_fwd, _sp_scatter_bwd)


def sequence_parallel_rules(multi_pod: bool, dp_mode: str = "dp_flat",
                            fsdp: bool = True) -> Dict[str, Any]:
    """Long-context variant: shard the sequence axis of activations over
    'data' (batch too small to fill the mesh, e.g. long_500k B=1)."""
    rules = default_rules(multi_pod, dp_mode, fsdp)
    rules["seq"] = "data"
    rules["batch"] = None
    return rules


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    prev = getattr(_STATE, "policy", None)
    _STATE.policy = policy
    try:
        yield policy
    finally:
        _STATE.policy = prev


def active_policy() -> Optional[ShardingPolicy]:
    return getattr(_STATE, "policy", None)


def shard_acts(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a policy;
    axes whose mesh size doesn't divide the dim are dropped)."""
    pol = active_policy()
    if pol is None or x.ndim != len(axes):
        return x
    eff = []
    for dim, a in zip(x.shape, axes):
        m = pol.rules.get(a) if a is not None else None
        if m is not None and dim % _axes_size(pol.mesh, m) == 0:
            eff.append(m)
        else:
            eff.append(None)
    if all(e is None for e in eff):
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(pol.mesh, P(*eff)))
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# Parameter logical axes by leaf path
# ---------------------------------------------------------------------------

# (path regex, logical axes WITHOUT the stacked-layer axis). Checked in order.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / head
    (r"embed$", ("vocab", "embed")),
    (r"lm_head$", ("embed", "vocab")),
    # attention projections (fused head dims)
    (r"attn/(wq|wk|wv)$|xattn/(wq|wk|wv)$", ("embed", "qkv")),
    (r"attn/(bq|bk|bv)$|xattn/(bq|bk|bv)$", ("qkv",)),
    (r"attn/wo$|xattn/wo$", ("qkv", "embed")),
    # MLA
    (r"attn/w_dkv$", ("embed", None)),
    (r"attn/kv_norm$", (None,)),
    (r"attn/w_uk$|attn/w_uv$", (None, "qkv")),
    # MoE (experts on the model axis = expert parallelism; when the expert
    # count doesn't divide the axis — grok's 8 experts on TP16 — the spec
    # resolver falls through to sharding the expert FFN dim instead)
    (r"moe/router$", ("embed", None)),
    (r"moe/w1$|moe/w3$", ("experts", "embed", "ffn")),
    (r"moe/w2$", ("experts", "ffn", "embed")),
    (r"moe/shared_w1$|moe/shared_w3$", ("embed", "ffn")),
    (r"moe/shared_w2$", ("ffn", "embed")),
    # dense MLPs (swiglu + whisper gelu)
    (r"mlp/w1$|mlp/w3$", ("embed", "ffn")),
    (r"mlp/b1$", ("ffn",)),
    (r"mlp/w2$", ("ffn", "embed")),
    (r"mlp/b2$", ("embed",)),
    # RWKV time-mix / channel-mix
    (r"tmix/(wr|wk|wv|wg)$", ("embed", "qkv")),
    (r"tmix/wo$", ("qkv", "embed")),
    (r"tmix/maa_w1$", ("embed", None)),
    (r"tmix/maa_w2$", (None, None, "embed")),
    (r"tmix/w_lora_a$", ("embed", None)),
    (r"tmix/w_lora_b$", (None, "embed")),
    (r"tmix/u$", ("heads", None)),
    (r"tmix/(mu_x|w0|gn_w|gn_b)$", ("embed",)),
    (r"tmix/mu$", (None, "embed")),
    (r"cmix/wk$", ("embed", "ffn")),
    (r"cmix/wv$", ("ffn", "embed")),
    (r"cmix/wr$", ("embed", "qkv")),
    (r"cmix/(mu_k|mu_r)$", ("embed",)),
    # Hymba SSM branch
    (r"ssm/(w_in|w_gate)$", ("embed", "qkv")),
    (r"ssm/conv$", (None, "qkv")),
    (r"ssm/conv_b$", ("qkv",)),
    (r"ssm/(w_B|w_C)$", ("qkv", None)),
    (r"ssm/w_dt$", ("qkv", "heads")),
    (r"ssm/dt_bias$", ("heads",)),
    (r"ssm/log_a$", ("heads", None)),
    (r"ssm/d_skip$", ("heads", None)),
    (r"ssm/w_out$", ("qkv", "embed")),
    # norms / everything 1-2D that falls through
    (r"(ln\d*|final_norm|enc_norm|in_norm)(/(w|b))?$", ("embed",)),
    (r"bn_a$|bn_s$", ("embed",)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axes_for(path: str, ndim: int, stacked: bool,
              ) -> Tuple[Optional[str], ...]:
    base_ndim = ndim - 1 if stacked else ndim
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            if len(axes) != base_ndim:
                raise ValueError(
                    f"rule {pat} gives {len(axes)} axes for {path} "
                    f"of base rank {base_ndim}")
            return (("layers",) + tuple(axes)) if stacked else tuple(axes)
    raise ValueError(f"no sharding rule for param {path!r} (rank {ndim})")


def param_logical_axes(params: Any) -> Any:
    """Pytree of logical-axis tuples mirroring ``params``.  Leaves under a
    ``group<i>/`` or ``encoder/`` prefix carry a leading 'layers' axis."""
    def assign(path, leaf):
        s = _path_str(path)
        stacked = bool(re.match(r"(group\d+|encoder)/", s))
        return _axes_for(s, leaf.ndim, stacked)
    return jax.tree_util.tree_map_with_path(assign, params)


def _fsdp_overlay(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh,
                  axis: str = "data", min_size: int = 2 ** 16) -> Tuple:
    """Shard the largest still-replicated dim over the FSDP axis (ZeRO-3).
    Skips tiny params and dims not divisible by the axis size."""
    if int(np.prod(shape)) < min_size or axis not in mesh.shape:
        return spec
    n = mesh.shape[axis]
    # pick the largest unsharded, divisible dim
    cands = [(d, i) for i, (d, s) in enumerate(zip(shape, spec))
             if s is None and d % n == 0]
    if not cands:
        return spec
    _, i = max(cands)
    out = list(spec)
    out[i] = axis
    return tuple(out)


def param_pspecs(params: Any, policy: ShardingPolicy,
                 fsdp: bool = False) -> Any:
    """PartitionSpec pytree for the parameters under ``policy``.

    fsdp=True additionally shards each large parameter's largest replicated
    dim over the 'fsdp' rule axis (ZeRO-3 parameter/optimizer sharding)."""
    fsdp_axis = policy.rules.get("fsdp")

    def to_spec_for(path, leaf):
        s = _path_str(path)
        stacked = bool(re.match(r"(group\d+|encoder)/", s))
        leaf_axes = _axes_for(s, leaf.ndim, stacked)
        return to_spec(leaf_axes, leaf)

    def to_spec(leaf_axes, leaf):
        resolved = []
        for a in leaf_axes:
            if a in (None, "layers"):
                resolved.append(None)
            else:
                resolved.append(policy.rules.get(a))
        # dims must divide their mesh-axis product, and a mesh axis may be
        # consumed at most once per leaf (first logical axis wins; later
        # ones fall back — e.g. grok's 8 experts skip TP16, FFN takes it)
        out, used = [], set()
        for dim, m in zip(leaf.shape, resolved):
            if m is None:
                out.append(None)
                continue
            axes = m if isinstance(m, tuple) else (m,)
            size = int(np.prod([policy.mesh.shape[a] for a in axes]))
            if dim % size == 0 and not (set(axes) & used):
                out.append(m)
                used.update(axes)
            else:
                out.append(None)
        if fsdp and fsdp_axis:
            out = list(_fsdp_overlay(tuple(out), leaf.shape, policy.mesh,
                                     fsdp_axis))
        return P(*out)

    return jax.tree_util.tree_map_with_path(to_spec_for, params)


def _axes_size(mesh: Mesh, m) -> int:
    if m is None:
        return 1
    axes = m if isinstance(m, tuple) else (m,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def batch_pspecs(policy: ShardingPolicy, batch: Dict[str, Any]) -> Any:
    """PartitionSpecs for a training/serving batch dict (batch axis 0;
    axes that don't divide the dim fall back to replication)."""
    b = policy.rules.get("batch")
    n = _axes_size(policy.mesh, b)

    def spec(path, leaf):
        if leaf.ndim == 0 or leaf.shape[0] % n != 0 or n == 1:
            return P(*([None] * leaf.ndim))
        return P(b, *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_pspecs(policy: ShardingPolicy, cache: Any) -> Any:
    """PartitionSpecs for decode caches.

    Leaves carry a leading stacked-layer axis (None), then [B, S, ...].
    Strategy: shard the batch dim over the cache_batch rule; shard ONE
    feature dim over cache_feature — preferring the kv-head dim, falling
    back to head_dim / latent / channel dims when heads don't divide."""
    b = policy.rules.get("cache_batch", policy.rules.get("batch"))
    m = policy.rules.get("cache_feature", policy.rules.get("heads"))
    nb = _axes_size(policy.mesh, b)
    nm = _axes_size(policy.mesh, m)

    def spec(path, leaf):
        dims = list(leaf.shape)
        out = [None] * len(dims)
        if len(dims) < 2:
            return P(*out)
        # dims[0] = stacked layer axis, dims[1] = batch
        if nb > 1 and dims[1] % nb == 0:
            out[1] = b
        # pick the LAST dim divisible by the model axis (feature-most)
        if nm > 1:
            for i in range(len(dims) - 1, 1, -1):
                if dims[i] % nm == 0:
                    out[i] = m
                    break
        return P(*out)
    return jax.tree.map(lambda l: spec(None, l), cache)


def named_sharding_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
