"""Hierarchical (rack-aware) collectives — the paper's two-stage shuffle
mapped onto TPU mesh axes.

The paper's insight is that a two-level network (fast ToR / slow root)
wants shuffles decomposed into a slow-tier stage at 1/r the volume and a
fast-tier stage that absorbs the residual.  On a multi-pod TPU mesh the
same decomposition applies with pod = rack:

  * :func:`hierarchical_all_to_all`  — MoE expert dispatch in two stages:
    tokens first move to the destination pod's matching slot (one bundled
    slow-axis a2a), then to the destination expert inside the pod (fast
    axis).  Slow-axis message count drops from K-1 distinct flows per chip
    to P-1 bundled flows (the paper's L_cro vs L_tot split for shuffles
    that are not sum-reducible).
  * :func:`hierarchical_psum` / :func:`hierarchical_psum_scatter` — the
    SUM-reducible case (gradients): intra-pod reduce-scatter, cross-pod
    all-reduce on 1/Kr shards, intra-pod all-gather.  Combined with map
    replication r over pods, the cross-pod stage vanishes entirely for
    replicated chunks (see repro.core.gradient_sync).

All functions are shard_map-level (named-axis) collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .meshes import axis_size


def hierarchical_psum(x: jax.Array, fast_axis: str, slow_axis: str,
                      scatter_dim: int = 0) -> jax.Array:
    """All-reduce over (fast x slow) with the slow stage at 1/Kr volume."""
    x = jax.lax.psum_scatter(x, fast_axis, scatter_dimension=scatter_dim,
                             tiled=True)
    x = jax.lax.psum(x, slow_axis)
    return jax.lax.all_gather(x, fast_axis, axis=scatter_dim, tiled=True)


def hierarchical_psum_scatter(x: jax.Array, fast_axis: str, slow_axis: str,
                              scatter_dim: int = 0) -> jax.Array:
    """Reduce-scatter over both tiers (result sharded over fast axis)."""
    x = jax.lax.psum_scatter(x, fast_axis, scatter_dimension=scatter_dim,
                             tiled=True)
    return jax.lax.psum(x, slow_axis)


def hierarchical_all_to_all(x: jax.Array, fast_axis: str, slow_axis: str,
                            *, split_axis: int = 0, concat_axis: int = 0,
                            ) -> jax.Array:
    """Two-stage all-to-all over a (slow, fast) product of axes.

    x: [..., n_slow * n_fast, ...] along ``split_axis`` — one slice per
    global destination, ordered slow-major (destination pod, then in-pod
    slot, matching the mesh's device order).

    Stage 1 bundles all slices bound for pod p into ONE slow-axis message
    (the paper's multicast-bundling of the cross-rack stage); stage 2
    delivers within the pod on fast links.  Equivalent to a flat
    all_to_all over the joint axis (asserted in tests), but the slow tier
    carries each byte exactly once in 1 bundled flow instead of Kr
    distinct flows — the schedule the roofline's cross-pod term wants.
    """
    n_slow = axis_size(slow_axis)
    n_fast = axis_size(fast_axis)
    n = x.shape[split_axis]
    assert n == n_slow * n_fast, (n, n_slow, n_fast)

    # reshape split axis -> (n_slow, n_fast)
    shape = list(x.shape)
    shape[split_axis:split_axis + 1] = [n_slow, n_fast]
    xs = x.reshape(shape)
    # stage 1: cross-pod exchange of pod-bundles (slow tier, bundled)
    xs = jax.lax.all_to_all(xs, slow_axis, split_axis=split_axis,
                            concat_axis=split_axis, tiled=False)
    # xs now has, at this pod, the bundle from every source pod; in-pod slot
    # axis is still the destination slot -> stage 2 on the fast tier
    xs = jax.lax.all_to_all(xs, fast_axis, split_axis=split_axis + 1,
                            concat_axis=split_axis + 1, tiled=False)
    # collapse (n_slow src-pods, n_fast src-slots) back into one axis
    shape = list(xs.shape)
    shape[split_axis:split_axis + 2] = [n]
    out = xs.reshape(shape)
    if concat_axis != split_axis:
        out = jnp.moveaxis(out, split_axis, concat_axis)
    return out


def flat_all_to_all(x: jax.Array, fast_axis: str, slow_axis: str, *,
                    split_axis: int = 0, concat_axis: int = 0) -> jax.Array:
    """Baseline: single all_to_all over the joint (slow, fast) axis."""
    return jax.lax.all_to_all(x, (slow_axis, fast_axis),
                              split_axis=split_axis,
                              concat_axis=concat_axis, tiled=False)


def coded_cross_pod_allreduce(chunk_grads: jax.Array, slow_axis: str,
                              P_: int, failed: Optional[int] = None,
                              ) -> jax.Array:
    """Convenience re-export of the r=2 coded reduce-scatter + all-gather
    over the slow axis (see repro.core.gradient_sync for the scheme)."""
    from ..core.gradient_sync import coded_reduce_scatter_r2
    shard = coded_reduce_scatter_r2(chunk_grads, slow_axis, P_,
                                    failed=failed)
    return jax.lax.all_gather(shard, slow_axis, axis=0, tiled=True)
