"""Production mesh construction.

Target hardware: TPU v5e pods — 16x16 = 256 chips per pod, 2 pods = 512
chips for the multi-pod dry-run.  Axes:

  pod    — the slow tier (DCN between pods)  == the paper's 'rack' axis
  data   — data parallel / FSDP within a pod (ICI)
  model  — tensor/expert parallel within a pod (ICI)

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any jax init).
"""
from __future__ import annotations

from jax.sharding import Mesh

from ..distributed.meshes import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_coded_mesh(pods: int = 4, data: int = 8, model: int = 16) -> Mesh:
    """Mesh for the r < P coded gradient-sync dry-runs (P >= 3 pods)."""
    return make_mesh((pods, data, model), ("pod", "data", "model"))


def pod_size(mesh: Mesh) -> int:
    """Devices per pod (= everything under the 'pod' axis)."""
    total = 1
    for name, n in zip(mesh.axis_names, mesh.devices.shape):
        if name != "pod":
            total *= n
    return total


MESH_KINDS = {
    "single": dict(multi_pod=False),
    "multi": dict(multi_pod=True),
}


def make_mesh_by_kind(kind: str) -> Mesh:
    if kind in MESH_KINDS:
        return make_production_mesh(**MESH_KINDS[kind])
    if kind == "coded4":
        return make_coded_mesh(4, 8, 16)
    raise KeyError(kind)
