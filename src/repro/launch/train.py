"""End-to-end training driver.

CPU-runnable (reduced configs) and production-lowerable (full configs on
the dry-run mesh).  Demonstrates the full substrate: synthetic pipeline,
jitted train step with the paper's DP sync modes, checkpoint/restart,
simulated preemption and straggler traces.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 100 --batch 8 --seq 64 --dp-mode dp --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_arch
from ..data.pipeline import SyntheticPipeline
from ..distributed.meshes import make_mesh
from ..train.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from ..train.fault import PreemptionSimulator
from ..train.optimizer import OptimizerConfig
from ..train.trainer import (TrainConfig, init_train_state,
                             make_coded_batch_r2, make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--dp-mode", default="dp",
                    choices=["dp", "replicated", "coded_r2"])
    ap.add_argument("--pods", type=int, default=4,
                    help="pod count for coded_r2 (uses host devices)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--preempt-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(
        n_microbatches=args.n_micro if args.dp_mode != "coded_r2" else 1,
        remat=True, dense_moe=args.reduced, dp_mode=args.dp_mode,
        opt=OptimizerConfig(kind=args.optimizer, lr=args.lr,
                            warmup_steps=max(args.steps // 10, 1),
                            decay_steps=args.steps))
    mesh = None
    if args.dp_mode == "coded_r2":
        if jax.device_count() < args.pods:
            raise SystemExit(
                f"coded_r2 needs >= {args.pods} devices; launch with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{args.pods}")
        mesh = make_mesh((args.pods,), ("pod",))

    pipe = SyntheticPipeline(cfg, args.batch, args.seq, seed=args.seed)
    step_fn = make_train_step(cfg, tc, mesh=mesh, donate=False)
    if mesh is not None:
        step_fn = jax.jit(step_fn)

    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, tc)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(jax.eval_shape(lambda: state),
                                          args.ckpt_dir)
        start += 1
        print(f"resumed from step {start - 1}")

    sim = PreemptionSimulator(args.preempt_at)
    t0 = time.time()
    for i in range(start, args.steps):
        sim.check(i)
        batch = pipe.batch_at(i)
        if args.dp_mode == "coded_r2":
            batch = make_coded_batch_r2(batch, args.pods)
        state, metrics = step_fn(state, batch)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(state, args.ckpt_dir, i)
        if i % max(args.steps // 20, 1) == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"{(time.time() - t0) / max(i - start + 1, 1):.2f}s/step",
                  flush=True)
    print("done")


if __name__ == "__main__":
    main()
