"""Roofline-term extraction from compiled XLA artifacts.

compute / memory terms come from ``compiled.cost_analysis()`` (per-device,
post-SPMD).  The collective term is NOT in cost_analysis: we parse the
compiled HLO text, classify every collective op, and apply a ring-algorithm
wire-byte model per participating chip:

    all-gather        out_bytes * (n-1)/n      (sends its shard n-1 times)
    reduce-scatter    out_bytes * (n-1)        (= in_bytes * (n-1)/n)
    all-reduce        2 * in_bytes * (n-1)/n   (RS + AG)
    all-to-all        in_bytes * (n-1)/n
    collective-permute  in_bytes

Each op's replica group is classified INTRA-POD (all members in one pod —
ICI) or CROSS-POD (spans pods — DCN); cross-pod ops additionally get an
ICI share for the intra-pod portion of their ring.  This is exactly the
paper's L_int / L_cro decomposition lifted to the TPU hierarchy.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (one link per mesh ring direction), DCN ~12.5 GB/s
(assumption, documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

HW = {
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
    "dcn_bw": 12.5e9,
    "hbm_bytes": 16 * 2 ** 30,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*(?:\},\{[^}]*)*)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _shapes_bytes(type_str: str) -> List[int]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def _parse_groups(line: str) -> Optional[List[List[int]]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            arr = arr.transpose(perm)
        return arr.reshape(g, s).tolist()
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return [[int(x) for x in grp.split(",") if x.strip()]
                for grp in m.group(1).split("},{")]
    return None


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_wire: float          # per participating chip
    group_size: int
    cross_pod: bool
    line: str


def _wire_bytes(kind: str, shapes: List[int], n: int) -> float:
    if not shapes or n <= 1:
        return 0.0
    total = sum(shapes)
    big = max(shapes)
    if kind.startswith("all-gather"):
        # tuple form of -start includes (in, out); out is the largest
        return big * (n - 1) / n
    if kind.startswith("all-reduce"):
        return 2.0 * big * (n - 1) / n
    if kind == "reduce-scatter":
        return big * (n - 1)          # output (scattered) shape parsed
    if kind == "all-to-all":
        return total * (n - 1) / n
    if kind.startswith("collective-permute"):
        return big
    return 0.0


def parse_collectives(hlo_text: str, pod_size: int) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group(2)
        shapes = _shapes_bytes(m.group(1))
        if kind.startswith("collective-permute"):
            pairs = _SRC_TGT_RE.search(line)
            cross = False
            if pairs and pairs.group(1).strip():
                for pq in pairs.group(1).split("},{"):
                    ab = [int(x) for x in pq.replace("{", "")
                          .replace("}", "").split(",")]
                    if len(ab) == 2 and ab[0] // pod_size != ab[1] // pod_size:
                        cross = True
            ops.append(CollectiveOp(kind, _wire_bytes(kind, shapes, 2),
                                    2, cross, line.strip()[:200]))
            continue
        groups = _parse_groups(line)
        if not groups:
            continue
        n = len(groups[0])
        cross = any(len({d // pod_size for d in g}) > 1 for g in groups)
        ops.append(CollectiveOp(kind, _wire_bytes(kind, shapes, n), n,
                                cross, line.strip()[:200]))
    return ops


def collective_summary(hlo_text: str, pod_size: int) -> Dict[str, float]:
    """Per-chip wire bytes, split by tier.  For a cross-pod group of size n
    spanning p pods, the DCN portion is modeled as the pod-boundary hops of
    the ring: fraction (p-1)/(n-1) of the wire bytes crosses DCN, the rest
    stays on ICI."""
    ops = parse_collectives(hlo_text, pod_size)
    out = {"ici_bytes": 0.0, "dcn_bytes": 0.0, "n_ops": len(ops),
           "n_cross_pod_ops": 0}
    per_kind: Dict[str, float] = {}
    for op in ops:
        per_kind[op.kind] = per_kind.get(op.kind, 0.0) + op.bytes_wire
        if op.cross_pod:
            out["n_cross_pod_ops"] += 1
            n = op.group_size
            p = max(2, int(np.ceil(n / pod_size)) if pod_size else 2)
            dcn_frac = (p - 1) / max(n - 1, 1)
            out["dcn_bytes"] += op.bytes_wire * dcn_frac
            out["ici_bytes"] += op.bytes_wire * (1 - dcn_frac)
        else:
            out["ici_bytes"] += op.bytes_wire
    out["per_kind"] = per_kind
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   ici_bytes: float, dcn_bytes: float,
                   hw: Dict = HW) -> Dict[str, float]:
    """The three roofline terms (seconds) + dominant classification."""
    t_compute = flops_per_dev / hw["peak_flops_bf16"]
    t_memory = bytes_per_dev / hw["hbm_bw"]
    t_ici = ici_bytes / hw["ici_bw"]
    t_dcn = dcn_bytes / hw["dcn_bw"]
    t_coll = t_ici + t_dcn
    terms = {"t_compute": t_compute, "t_memory": t_memory,
             "t_collective": t_coll, "t_ici": t_ici, "t_dcn": t_dcn}
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    terms["dominant"] = dom[0]
    terms["t_bound"] = dom[1]
    # roofline fraction: useful-compute time over the bound (perfect overlap
    # model: step time >= max(terms); fraction = t_compute / t_bound)
    terms["roofline_fraction"] = (t_compute / dom[1]) if dom[1] > 0 else 0.0
    return terms


# ---------------------------------------------------------------------------
# (L, S) polynomial cost fitting — see launch/dryrun.py
# ---------------------------------------------------------------------------

def fit_cost_poly(points: List[Tuple[int, int, float]],
                  ) -> Dict[str, float]:
    """Fit cost(L, S) = a + b L + (c + d L) S + (e + f L) S^2 through >= 6
    (L, S, cost) points (least squares; exact when cost is truly polynomial).
    Returns the coefficient dict."""
    A = np.array([[1, L, S, L * S, S * S, L * S * S]
                  for (L, S, _) in points], dtype=np.float64)
    y = np.array([c for (_, _, c) in points], dtype=np.float64)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    return dict(zip("abcdef", coef.tolist()))


def eval_cost_poly(coef: Dict[str, float], L: int, S: int) -> float:
    return (coef["a"] + coef["b"] * L + coef["c"] * S + coef["d"] * L * S
            + coef["e"] * S * S + coef["f"] * L * S * S)
