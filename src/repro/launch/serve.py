"""End-to-end serving driver: batched prefill + decode on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
      --requests 6 --slots 2 --prompt-len 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCHS, get_arch
from ..models import lm
from ..serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_seq=args.prompt_len + args.max_new + 8,
                      dense_moe=True, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rng.integers(0, cfg.vocab_size,
                                 rng.integers(4, args.prompt_len + 1)
                                 ).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.time()
    done = eng.serve(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    for i, r in enumerate(done):
        print(f"req {i}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on CPU reduced config)")


if __name__ == "__main__":
    main()
