import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")   # silence SPMD warnings

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on the production mesh and extract the roofline terms.

Two compiles per cell family:

1. **Fit compile** — the REAL production step (scanned layers, microbatch
   grad-accum scan, 2-level remat, donated state) at the FULL configuration.
   ``compiled.memory_analysis()`` proves the cell fits 16 GB/chip; success
   proves the sharding config is coherent (the deliverable's pass/fail).

2. **Cost compiles** — reduced (depth, sequence) grid with every internal
   scan UNROLLED, so ``cost_analysis()`` / HLO collective parsing count
   every FLOP/byte exactly (XLA counts a while body ONCE regardless of
   trip count — measured in this repo; see EXPERIMENTS.md §Methodology).
   Costs of these models are polynomials: linear in each layer-stack depth,
   quadratic in S (attention), so fitting
        cost(depths, S) = (1, depths) (x) (1, S, S^2)
   through (n_depth+1) x 3 exact compile points reproduces the full-size
   cost EXACTLY (polynomial interpolation, not approximation).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single,multi \
      [--arch qwen2-1.5b ...] [--shape train_4k ...] [--force]
Results are cached per cell in results/dryrun/<mesh>/<arch>__<shape>.json.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS
from ..configs.base import ArchConfig, SHAPES, ShapeConfig, cell_is_runnable
from ..distributed import sharding as shlib
from ..models import lm
from ..models.frontends import train_batch_specs
from ..train.optimizer import OptimizerConfig
from ..train.trainer import TrainConfig, accumulate_grads
from . import hlo_analysis as hlo
from .mesh import make_mesh_by_kind, pod_size

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

BIG_ARCHS = {"llama3-405b", "grok-1-314b", "qwen2-72b", "llava-next-34b"}


# ---------------------------------------------------------------------------
# Per-cell plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CellPlan:
    arch: str
    shape: str
    mesh_kind: str
    n_micro: int
    remat_blocks: int
    fsdp: bool
    dtype: Any = jnp.bfloat16
    s_points: Tuple[int, ...] = ()
    dp_mode: str = "dp"                  # dp | replicated (pod axis use)
    seq_tp: bool = False                 # Megatron sequence parallelism
    tp2d: bool = False                   # 2D-TP serving (hillclimb variant)
    moe_groups: int = 16                 # sort-dispatch groups == dp size

    @property
    def cfg(self) -> ArchConfig:
        return ARCHS[self.arch]

    @property
    def shape_cfg(self) -> ShapeConfig:
        for s in SHAPES:
            if s.name == self.shape:
                return s
        raise KeyError(self.shape)


def _best_blocks(n: int) -> int:
    """Divisor of n closest to sqrt(n) (2-level remat block count)."""
    best = 1
    for d in range(1, n + 1):
        if n % d == 0 and abs(d - n ** 0.5) < abs(best - n ** 0.5):
            best = d
    return best


def make_plan(arch: str, shape: str, mesh_kind: str,
              dp_mode: str = "dp") -> CellPlan:
    cfg = ARCHS[arch]
    sh = [s for s in SHAPES if s.name == shape][0]
    multi = mesh_kind != "single"
    dp = (2 if (multi and dp_mode == "dp") else 1) * 16   # pod x data
    big = arch in BIG_ARCHS

    if sh.kind == "train":
        rows_per_dev = max(sh.global_batch // dp, 1)
        tokens_per_dev = rows_per_dev * sh.seq_len
        n_micro = 1
        while (tokens_per_dev // n_micro > 4096 and n_micro < rows_per_dev
               and sh.global_batch % (2 * n_micro) == 0):
            n_micro *= 2
        remat_blocks = _best_blocks(cfg.n_layers
                                    - (cfg.moe.first_dense_layers
                                       if cfg.moe else 0))
    else:
        n_micro, remat_blocks = 1, 1

    if cfg.frontend == "vision":
        base = cfg.n_frontend_tokens
        s_points = (base + 256, base + 512, base + 1024)
    elif sh.kind == "train":
        s_points = (512, 1024, 2048)
    elif sh.kind == "prefill":
        s_points = (1024, 2048, 4096)
    else:                                 # decode: S = cache depth
        s_points = (1024, 2048, 4096)
    # FSDP (ZeRO-3) only where params+optimizer cannot fit replicated-
    # over-data; small models keep params on 'model' only (no per-micro
    # re-gather traffic).  Sequence-TP on big train cells (bytes-neutral,
    # divides boundary HBM by the TP degree).
    return CellPlan(arch, shape, mesh_kind, n_micro, remat_blocks,
                    fsdp=big, s_points=s_points, dp_mode=dp_mode,
                    seq_tp=big and sh.kind == "train",
                    moe_groups=dp)   # groups must tile the dp axes


# ---------------------------------------------------------------------------
# Depth grid
# ---------------------------------------------------------------------------

def _with_depth(cfg: ArchConfig, depths: Tuple[int, ...]) -> ArchConfig:
    """depths per varying stack: (main,) or (main, enc) for encdec.
    For MoE with leading dense layers, 'main' counts only the MoE stack."""
    fd = cfg.moe.first_dense_layers if cfg.moe else 0
    kw: Dict[str, Any] = {"n_layers": depths[0] + fd}
    if cfg.family == "encdec":
        kw["encoder_layers"] = depths[1]
    return dataclasses.replace(cfg, **kw)


def depth_grid(cfg: ArchConfig) -> Tuple[List[Tuple[int, ...]],
                                         Tuple[int, ...]]:
    """(depth combos to compile, target depth vector)."""
    fd = cfg.moe.first_dense_layers if cfg.moe else 0
    if cfg.family == "encdec":
        combos = [(1, 1), (2, 1), (1, 2)]
        target = (cfg.n_layers, cfg.encoder_layers)
    else:
        combos = [(1,), (2,)]
        target = (cfg.n_layers - fd,)
    return combos, target


def _fit_poly(points: List[Tuple[Tuple[int, ...], int, float]]) -> Dict:
    """Occam fit of cost = (1, depths) (x) S-basis.

    Tries S-bases of increasing order (const, linear, quadratic); keeps
    the SIMPLEST one whose relative residual on the compile points is
    < 0.1%.  This matters for costs with no real S dependence (ring-cache
    / state-space decode): blindly fitting S^2 to constant-in-S data and
    extrapolating x1e5 amplifies lstsq noise into garbage (observed:
    negative hymba decode costs before this guard)."""
    scale = max((abs(c) for (_, _, c) in points), default=1.0) or 1.0
    for order in (0, 1, 2):
        rows, y = [], []
        for depths, S, c in points:
            dvec = [1.0] + [float(d) for d in depths]
            svec = [float(S) ** k for k in range(order + 1)]
            rows.append(np.outer(dvec, svec).ravel())
            y.append(c / scale)
        A = np.array(rows)
        coef, *_ = np.linalg.lstsq(A, np.array(y), rcond=None)
        resid = np.abs(A @ coef - y).max()
        if resid < 1e-3 or order == 2:
            return {"coef": coef, "order": order, "scale": scale,
                    "resid": float(resid)}
    raise AssertionError("unreachable")


def _eval_poly(fit: Dict, depths: Tuple[int, ...], S: int) -> float:
    dvec = [1.0] + [float(d) for d in depths]
    svec = [float(S) ** k for k in range(fit["order"] + 1)]
    val = float(np.outer(dvec, svec).ravel() @ fit["coef"]) * fit["scale"]
    return max(val, 0.0)


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def _policy(plan: CellPlan, mesh) -> shlib.ShardingPolicy:
    if plan.tp2d:
        rules = shlib.serve_tp2d_rules(multi_pod=(plan.mesh_kind
                                                  != "single"))
        return shlib.ShardingPolicy(mesh, rules)
    rules = shlib.default_rules(multi_pod=(plan.mesh_kind != "single"),
                                dp_mode=("dp_flat" if plan.dp_mode == "dp"
                                         else "dp_hybrid"),
                                fsdp=plan.fsdp)
    if plan.seq_tp:
        rules = shlib.with_sequence_tp(rules)
    return shlib.ShardingPolicy(mesh, rules)


def _param_shapes(cfg: ArchConfig, dtype) -> Any:
    return jax.eval_shape(lambda k: lm.init_params(k, cfg, dtype),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def _opt_shapes(params: Any, opt_cfg) -> Dict:
    from ..train.optimizer import init_opt_state
    return jax.eval_shape(lambda: init_opt_state(params, opt_cfg))


def _opt_pspecs(params: Any, pspec: Any, opt_cfg) -> Dict:
    """Sharding specs for the optimizer state tree.

    adamw: moments mirror the parameter specs.  adafactor: the factored
    moments drop the factored dim's axis from the parameter spec."""
    if opt_cfg.kind == "adamw":
        return {"m": pspec, "v": pspec, "count": P()}

    def fac_spec(leaf, s):
        parts = list(s) + [None] * (leaf.ndim - len(s))
        if leaf.ndim >= 2:
            return {"vr": P(*parts[:-1]), "vc": P(*(parts[:-2] + parts[-1:]))}
        return {"v": P(*parts)}
    m = jax.tree.map(fac_spec, params, pspec,
                     is_leaf=lambda x: isinstance(x, P))
    return {"m": m, "count": P()}


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                seq_len: Optional[int] = None,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one batch of the cell (deliverable
    (e).2: weak-type-correct, shardable, no device allocation)."""
    S = seq_len or shape.seq_len
    sub = dataclasses.replace(shape, seq_len=S)
    return train_batch_specs(cfg, sub, dtype=dtype)


def _train_tc(plan: CellPlan, cfg: ArchConfig, *, cost_mode: bool,
              ) -> TrainConfig:
    big = plan.arch in BIG_ARCHS
    return TrainConfig(
        n_microbatches=1 if cost_mode else plan.n_micro,
        remat=True,
        remat_blocks=1 if cost_mode else plan.remat_blocks,
        scan_layers=not cost_mode,
        unroll_scans=cost_mode,
        grad_dtype=jnp.bfloat16 if big else jnp.float32,
        dense_moe=False,
        moe_groups=plan.moe_groups,
        # >=300B plans: Adafactor (factored 2nd moment) — optimizer HBM
        # drops from 2x params to ~0; T5/PaLM production recipe
        opt=OptimizerConfig(kind="adafactor" if big else "adamw",
                            moment_dtype=jnp.float32),
    )


def _collect(compiled, pod_sz: int) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    coll = hlo.collective_summary(compiled.as_text(), pod_sz)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "ici": coll["ici_bytes"], "dcn": coll["dcn_bytes"],
            "n_coll": coll["n_ops"], "n_cross": coll["n_cross_pod_ops"]}


def _memory(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    return {"argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": peak,
            "peak_gib": peak / 2 ** 30,
            "fits_16gib": bool(peak <= hlo.HW["hbm_bytes"])}


# ---------------------------------------------------------------------------
# Analytic HBM-capacity model (the 16 GiB fit verdict)
# ---------------------------------------------------------------------------
#
# XLA:CPU stages every bf16 op through synthesized f32 copies (measured:
# a bf16 [1024^2] matmul allocates 3x f32 temps), so memory_analysis() of
# the CPU-compiled module OVERSTATES TPU HBM by ~2-3x.  We therefore report
# both: the XLA number (pessimistic cross-check) and this explicit
# capacity plan (exact for state; conservative workspace model).

def tree_local_bytes(shapes_tree: Any, spec_tree: Any, mesh) -> float:
    """Per-device bytes of a sharded pytree (exact, from the pspecs)."""
    total = 0.0
    for leaf, spec in zip(jax.tree.leaves(shapes_tree),
                          jax.tree.leaves(
                              spec_tree,
                              is_leaf=lambda x: isinstance(x, P))):
        shard = 1
        for m in spec:
            if m is None:
                continue
            for a in (m if isinstance(m, tuple) else (m,)):
                shard *= mesh.shape[a]
        total += leaf.size * leaf.dtype.itemsize / shard
    return total


def analytic_peak_bytes(plan: CellPlan, cfg: ArchConfig, sh: ShapeConfig,
                        mesh, pol) -> Dict[str, float]:
    dtb = 2.0
    tp = mesh.shape.get("model", 1)
    dp = int(np.prod(mesh.devices.shape)) // tp
    params = _param_shapes(cfg, plan.dtype)
    pspec = shlib.param_pspecs(params, pol, fsdp=plan.fsdp)
    p_local = tree_local_bytes(params, pspec, mesh)
    out = {"params": p_local}

    heads_local = max(cfg.n_heads // tp, 1)
    d = cfg.d_model
    ff = cfg.d_ff
    if cfg.moe:
        ff = (cfg.moe.top_k + cfg.moe.n_shared) * cfg.moe.d_ff_expert
    if sh.kind == "train":
        tc = _train_tc(plan, cfg, cost_mode=False)
        opt = _opt_shapes(params, tc.opt)
        out["opt"] = tree_local_bytes(opt, _opt_pspecs(params, pspec,
                                                       tc.opt), mesh)
        out["grads"] = p_local * jnp.dtype(tc.grad_dtype).itemsize / dtb
        micro_tok = sh.global_batch * sh.seq_len / dp / plan.n_micro
        bnd_tok = micro_tok / (tp if plan.seq_tp else 1)
        inner = max((cfg.n_layers - (cfg.moe.first_dense_layers if cfg.moe
                                     else 0)) // plan.remat_blocks, 1)
        n_bnd = plan.remat_blocks + inner + cfg.encoder_layers
        out["boundaries"] = n_bnd * bnd_tok * d * dtb
        # live per-layer workspace during recompute+backward (f32):
        out["workspace"] = micro_tok * (6 * d + 2 * ff / tp
                                        + 512 * heads_local) * 4.0
        out["logits"] = 2 * micro_tok * cfg.vocab_size / tp * 4.0
        out["batch"] = sh.global_batch * sh.seq_len / dp * 8.0
    else:
        cache = jax.eval_shape(lambda: lm.init_cache(
            cfg, sh.global_batch, sh.seq_len, plan.dtype))
        cspec = shlib.cache_pspecs(pol, cache)
        out["cache"] = tree_local_bytes(cache, cspec, mesh)
        tok = (sh.global_batch * sh.seq_len if sh.kind == "prefill"
               else sh.global_batch)
        tok_local = tok / dp
        out["workspace"] = tok_local * (6 * d + 2 * ff / tp
                                        + 512 * heads_local) * 4.0
        if plan.fsdp:       # per-layer weight gather buffer
            out["gather_buf"] = 2 * p_local * mesh.shape.get("data", 1) \
                / max(cfg.n_layers, 1)
    out["total"] = sum(out.values())
    out["total_gib"] = out["total"] / 2 ** 30
    out["fits_16gib"] = bool(out["total"] <= hlo.HW["hbm_bytes"])
    return out


# ---------------------------------------------------------------------------
# Analytic HBM-traffic model (the roofline memory term)
# ---------------------------------------------------------------------------
#
# XLA's cost_analysis 'bytes accessed' sums EVERY op's operands post-(CPU)-
# fusion — a gross upper bound on TPU HBM traffic where elementwise chains
# fuse into the surrounding matmuls.  For the memory roofline term we use
# an explicit traffic model instead (the XLA number is kept as a
# diagnostic): weight reads per pass, activation/intermediate RW per layer
# per token, optimizer/grad RW per step, logits, and cache RW for serving.

def _params_local_bytes(plan: CellPlan, cfg: ArchConfig, mesh) -> float:
    pol = _policy(plan, mesh)
    params = _param_shapes(cfg, plan.dtype)
    return tree_local_bytes(params,
                            shlib.param_pspecs(params, pol,
                                               fsdp=plan.fsdp), mesh)


def analytic_memory_bytes(plan: CellPlan, cfg: ArchConfig,
                          sh: ShapeConfig, mesh) -> float:
    dt = 2.0
    n_chips = int(np.prod(mesh.devices.shape))
    p_local = _params_local_bytes(plan, cfg, mesh)
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.moe:
        m = cfg.moe
        ff = m.top_k * m.d_ff_expert + m.n_shared * m.d_ff_expert
    qkv = cfg.n_heads * cfg.head_dim + 2 * cfg.n_kv_heads * cfg.head_dim
    act_per_tok_layer = (6 * d + 3 * ff + 2 * qkv) * dt   # fwd RW
    L = cfg.n_layers + cfg.encoder_layers

    if sh.kind == "train":
        tokens_local = sh.global_batch * sh.seq_len / n_chips * \
            mesh.shape.get("model", 1)         # activations shard on batch
        micro_tok = tokens_local / plan.n_micro
        # fwd + remat-fwd + bwd activation traffic; boundary save/restore
        acts = plan.n_micro * micro_tok * L * act_per_tok_layer * 3
        weights = 3 * p_local * plan.n_micro    # fwd/remat/bwd reads
        logits = (plan.n_micro * micro_tok * cfg.vocab_size
                  / mesh.shape.get("model", 1) * dt * 3)
        opt = 10 * p_local                      # m,v,params,grads RW
        return weights + acts + logits + opt
    if sh.kind == "prefill":
        tokens_local = sh.global_batch * sh.seq_len / n_chips * \
            mesh.shape.get("model", 1)
        acts = tokens_local * L * act_per_tok_layer
        cache_w = tokens_local * L * 2 * cfg.n_kv_heads * cfg.head_dim * dt
        return p_local + acts + cache_w
    # decode: weights once + cache read once per token step
    if cfg.mla:
        per_tok = (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim) * dt
    elif cfg.attn_free:
        per_tok = 0.0                          # constant-size state
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * dt
    S_eff = min(sh.seq_len, cfg.sliding_window or sh.seq_len) \
        if cfg.family == "hybrid" else sh.seq_len
    state = 0.0
    if cfg.ssm:
        state = (cfg.n_heads * cfg.ssm.state_dim * cfg.head_dim * 4
                 * sh.global_batch * cfg.n_layers * 2)
    cache_local = (sh.global_batch * S_eff * cfg.n_layers * per_tok
                   + state) / n_chips * mesh.shape.get("model", 1)
    return p_local + cache_local


# ---------------------------------------------------------------------------
# TRAIN cells
# ---------------------------------------------------------------------------

def _lower_train_fit(plan: CellPlan, mesh) -> Dict:
    cfg, sh = plan.cfg, plan.shape_cfg
    pol = _policy(plan, mesh)
    tc = _train_tc(plan, cfg, cost_mode=False)
    params = _param_shapes(cfg, plan.dtype)
    state = {"params": params, "opt": _opt_shapes(params, tc.opt),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    batch = input_specs(cfg, sh, dtype=plan.dtype)

    from ..train.optimizer import optimizer_update

    def step(state, batch):
        with shlib.use_policy(pol):
            grads, loss = accumulate_grads(state["params"], cfg, tc, batch)
            new_params, new_opt, om = optimizer_update(
                grads, state["opt"], state["params"], tc.opt)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, {"loss": loss, **om})

    pspec = shlib.param_pspecs(params, pol, fsdp=plan.fsdp)
    to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    state_sh = {"params": to_sh(pspec),
                "opt": to_sh(_opt_pspecs(params, pspec, tc.opt)),
                "step": NamedSharding(mesh, P())}
    batch_sh = to_sh(shlib.batch_pspecs(pol, batch))
    with mesh:
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          donate_argnums=(0,)).lower(state, batch)
        compiled = lowered.compile()
    return {"memory": _memory(compiled)}


def _lower_train_cost_point(plan: CellPlan, mesh, cfg_d: ArchConfig,
                            S: int) -> Tuple[Dict, Dict]:
    """(micro-step costs, apply-step costs) at one (depth, S) point."""
    sh = plan.shape_cfg
    pol = _policy(plan, mesh)
    tc = _train_tc(plan, cfg_d, cost_mode=True)
    params = _param_shapes(cfg_d, plan.dtype)
    micro_rows = max(sh.global_batch // plan.n_micro, 1)
    batch = input_specs(cfg_d, dataclasses.replace(
        sh, global_batch=micro_rows), seq_len=S, dtype=plan.dtype)

    def micro(params, batch):
        with shlib.use_policy(pol):
            return accumulate_grads(params, cfg_d, tc, batch)

    pspec = shlib.param_pspecs(params, pol, fsdp=plan.fsdp)
    to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    grads_sh = to_sh(pspec)
    with mesh:
        c_micro = jax.jit(
            micro, in_shardings=(to_sh(pspec),
                                 to_sh(shlib.batch_pspecs(pol, batch))),
            out_shardings=(grads_sh, NamedSharding(mesh, P())),
        ).lower(params, batch).compile()

    from ..train.optimizer import optimizer_update
    gd = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, tc.grad_dtype),
                      params)
    opt = _opt_shapes(params, tc.opt)

    def apply_fn(grads, opt, params):
        with shlib.use_policy(pol):
            return optimizer_update(grads, opt, params, tc.opt)

    with mesh:
        c_apply = jax.jit(
            apply_fn, in_shardings=(grads_sh,
                                    to_sh(_opt_pspecs(params, pspec,
                                                      tc.opt)),
                                    to_sh(pspec)),
            donate_argnums=(1, 2),
        ).lower(gd, opt, params).compile()
    psz = pod_size(mesh)
    return _collect(c_micro, psz), _collect(c_apply, psz)


# ---------------------------------------------------------------------------
# SERVE cells (prefill / decode)
# ---------------------------------------------------------------------------

def _serve_structs(plan: CellPlan, cfg_d: ArchConfig, S: int,
                   batch: int) -> Tuple[Any, Any]:
    params = _param_shapes(cfg_d, plan.dtype)
    cache = jax.eval_shape(
        lambda: lm.init_cache(cfg_d, batch, S, plan.dtype))
    return params, cache


def _lower_decode(plan: CellPlan, mesh, cfg_d: ArchConfig, S: int,
                  unroll: bool) -> Any:
    sh = plan.shape_cfg
    pol = _policy(plan, mesh)
    params, cache = _serve_structs(plan, cfg_d, S, sh.global_batch)
    tok = jax.ShapeDtypeStruct((sh.global_batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, cache, tok, pos):
        with shlib.use_policy(pol):
            return lm.decode_step(params, cfg_d, tok, cache, pos,
                                  scan_layers=not unroll,
                                  unroll_scans=unroll)

    pspec = shlib.param_pspecs(params, pol, fsdp=plan.fsdp)
    cspec = shlib.cache_pspecs(pol, cache)
    to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    with mesh:
        return jax.jit(step,
                       in_shardings=(to_sh(pspec), to_sh(cspec),
                                     NamedSharding(mesh, P(None)),
                                     NamedSharding(mesh, P())),
                       donate_argnums=(1,),
                       ).lower(params, cache, tok, pos).compile()


def _lower_prefill(plan: CellPlan, mesh, cfg_d: ArchConfig, S: int,
                   unroll: bool) -> Any:
    sh = plan.shape_cfg
    pol = _policy(plan, mesh)
    B = sh.global_batch
    params, cache = _serve_structs(plan, cfg_d, S, B)
    n_front = cfg_d.n_frontend_tokens if cfg_d.frontend == "vision" else 0
    toks = jax.ShapeDtypeStruct((B, S - n_front), jnp.int32)
    extra = {}
    if cfg_d.frontend == "vision":
        extra["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, n_front, cfg_d.d_model), plan.dtype)
    if cfg_d.family == "encdec":
        extra["enc_frames"] = jax.ShapeDtypeStruct(
            (B, cfg_d.encoder_seq, cfg_d.d_model), plan.dtype)

    def step(params, cache, toks, extra):
        with shlib.use_policy(pol):
            logits, new_cache = lm.prefill(
                params, cfg_d, toks, cache,
                prefix_embeds=extra.get("prefix_embeds"),
                enc_frames=extra.get("enc_frames"),
                scan_layers=not unroll, unroll_scans=unroll,
                moe_groups=plan.moe_groups)
            return logits, new_cache

    pspec = shlib.param_pspecs(params, pol, fsdp=plan.fsdp)
    cspec = shlib.cache_pspecs(pol, cache)
    bspec = shlib.batch_pspecs(pol, {"toks": toks, **extra})
    to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    with mesh:
        return jax.jit(step,
                       in_shardings=(to_sh(pspec), to_sh(cspec),
                                     to_sh(bspec["toks"]),
                                     to_sh({k: bspec[k] for k in extra})),
                       donate_argnums=(1,),
                       ).lower(params, cache, toks, extra).compile()


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, mesh_kind: str, *, force: bool = False,
             dp_mode: str = "dp", results_dir: str = RESULTS_DIR,
             overrides: Optional[Dict] = None,
             variant: str = "") -> Dict:
    """``overrides``: CellPlan field overrides for §Perf hillclimb variants
    (cached under a ``__<variant>`` suffix)."""
    cfg = ARCHS[arch]
    sh = [s for s in SHAPES if s.name == shape][0]
    tag = f"{arch}__{shape}" + ("" if dp_mode == "dp" else f"__{dp_mode}") \
        + (f"__{variant}" if variant else "")
    out_dir = os.path.join(results_dir, mesh_kind)
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    runnable, why = cell_is_runnable(cfg, sh)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "dp_mode": dp_mode,
        "runnable": runnable, "skip_reason": why,
        "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
    }
    if not runnable:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
        return result

    plan = make_plan(arch, shape, mesh_kind, dp_mode)
    if overrides:
        plan = dataclasses.replace(plan, **overrides)
        result["overrides"] = {k: str(v) for k, v in overrides.items()}
    mesh = make_mesh_by_kind(mesh_kind)
    psz = pod_size(mesh)
    combos, target = depth_grid(cfg)
    t0 = time.time()
    try:
        if sh.kind == "train":
            fit = _lower_train_fit(plan, mesh)
            pts_mi: Dict[str, List] = {k: [] for k in
                                       ("flops", "bytes", "ici", "dcn")}
            pts_ap: Dict[str, List] = {k: [] for k in
                                       ("flops", "bytes", "ici", "dcn")}
            for depths in combos:
                cfg_d = _with_depth(cfg, depths)
                for S in plan.s_points:
                    mi, ap = _lower_train_cost_point(plan, mesh, cfg_d, S)
                    for k in pts_mi:
                        pts_mi[k].append((depths, S, mi[k]))
                        pts_ap[k].append((depths, S, ap[k]))
            costs = {}
            for k in pts_mi:
                poly_m = _fit_poly(pts_mi[k])
                poly_a = _fit_poly(pts_ap[k])
                costs[k] = (plan.n_micro
                            * _eval_poly(poly_m, target, sh.seq_len)
                            + _eval_poly(poly_a, target, sh.seq_len))
            tokens = sh.global_batch * sh.seq_len
        else:
            lower_one = (_lower_decode if sh.kind in ("decode",
                                                      "long_decode")
                         else _lower_prefill)
            fit_comp = lower_one(plan, mesh, cfg, sh.seq_len, unroll=False)
            fit = {"memory": _memory(fit_comp)}
            pts: Dict[str, List] = {k: [] for k in
                                    ("flops", "bytes", "ici", "dcn")}
            for depths in combos:
                cfg_d = _with_depth(cfg, depths)
                for S in plan.s_points:
                    c = lower_one(plan, mesh, cfg_d, S, unroll=True)
                    got = _collect(c, psz)
                    for k in pts:
                        pts[k].append((depths, S, got[k]))
            costs = {k: _eval_poly(_fit_poly(pts[k]), target, sh.seq_len)
                     for k in pts}
            tokens = sh.global_batch * (sh.seq_len
                                        if sh.kind == "prefill" else 1)

        n_chips = int(np.prod(mesh.devices.shape))
        hbm_bytes = analytic_memory_bytes(plan, cfg, sh, mesh)
        terms = hlo.roofline_terms(costs["flops"], hbm_bytes,
                                   costs["ici"], costs["dcn"])
        terms["t_memory_xla_upper"] = costs["bytes"] / hlo.HW["hbm_bw"]
        n_active = lm.count_params(cfg, active_only=True) \
            - lm.count_embedding_params(cfg)
        mult = 6 if sh.kind == "train" else 2
        model_flops = mult * n_active * tokens / n_chips
        pol = _policy(plan, mesh)
        result.update({
            "plan": {"n_micro": plan.n_micro,
                     "remat_blocks": plan.remat_blocks,
                     "fsdp": plan.fsdp, "seq_tp": plan.seq_tp,
                     "s_points": plan.s_points,
                     "depth_combos": combos, "depth_target": target},
            "memory": fit["memory"],
            "memory_plan": analytic_peak_bytes(plan, cfg, sh, mesh, pol),
            "per_device": costs,
            "roofline": terms,
            "model_flops_per_device": model_flops,
            "useful_flops_ratio": (model_flops / costs["flops"]
                                   if costs["flops"] else 0.0),
            "elapsed_s": time.time() - t0,
            "ok": True,
        })
    except Exception as e:                                   # noqa: BLE001
        result.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:],
                       "elapsed_s": time.time() - t0})
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", nargs="*", default=sorted(ARCHS))
    ap.add_argument("--shape", nargs="*",
                    default=[s.name for s in SHAPES])
    ap.add_argument("--mesh", nargs="*", default=["single", "multi"])
    ap.add_argument("--dp-mode", default="dp",
                    choices=["dp", "replicated"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--results-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    n_fail = 0
    for mesh_kind in args.mesh:
        for arch in args.arch:
            for shape in args.shape:
                t0 = time.time()
                r = run_cell(arch, shape, mesh_kind, force=args.force,
                             dp_mode=args.dp_mode,
                             results_dir=args.results_dir)
                if not r.get("runnable", True):
                    status = "SKIP"
                elif r.get("ok"):
                    m = r["memory"]
                    mp = r.get("memory_plan", {})
                    status = (f"OK   plan={mp.get('total_gib', 0):.2f}GiB"
                              f"({'fits' if mp.get('fits_16gib') else 'OVER'})"
                              f" xla={m['peak_gib']:.1f} "
                              f"dom={r['roofline']['dominant']:<10} "
                              f"frac={r['roofline']['roofline_fraction']:.3f}")
                else:
                    status = "FAIL " + r.get("error", "")[:120]
                    n_fail += 1
                print(f"[{mesh_kind:6s}] {arch:22s} {shape:12s} "
                      f"{time.time()-t0:6.1f}s  {status}", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
