"""Deterministic discrete-event primitives for the cluster simulator.

Two ingredients make the whole simulator reproducible bit-for-bit:

  * every scheduled event carries a monotonically increasing sequence
    number, so simultaneous events pop in a deterministic order (the order
    they were scheduled) regardless of heap internals;
  * the trace is a plain list of ``(time, kind, detail)`` tuples appended in
    processing order — two runs with the same seed must produce IDENTICAL
    traces (asserted in ``tests/test_sim.py``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, List, Optional, Tuple

TraceEntry = Tuple[float, str, Tuple[Any, ...]]


@dataclasses.dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    data: Tuple[Any, ...] = dataclasses.field(compare=False, default=())
    # optional callback fired when the event is processed
    fn: Optional[Callable[[], None]] = dataclasses.field(
        compare=False, default=None)
    # cancelled events stay in the heap but are skipped (no trace, no fn) —
    # first-finisher-wins speculation cancels the losing attempt's
    # completion event without disturbing heap order
    cancelled: bool = dataclasses.field(compare=False, default=False)

    def cancel(self) -> None:
        self.cancelled = True


class EventQueue:
    """Min-heap of events keyed on (time, seq) — fully deterministic."""

    def __init__(self) -> None:
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, data: Tuple[Any, ...] = (),
             fn: Optional[Callable[[], None]] = None) -> Event:
        ev = Event(float(time), self._seq, kind, data, fn)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        self._drop_cancelled()
        return heapq.heappop(self._heap)

    def peek_time(self) -> float:
        self._drop_cancelled()
        return self._heap[0].time if self._heap else float("inf")

    def _drop_cancelled(self) -> None:
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)

    def cancel_where(self, pred: Callable[["Event"], bool]) -> int:
        """Cancel every pending event matching ``pred``; returns the count.
        Crash handling uses this to void a job's scheduled completions
        (stage latencies, phase barriers) wholesale — cancelled events stay
        in the heap and are skipped, so determinism is untouched."""
        n = 0
        for ev in self._heap:
            if not ev.cancelled and pred(ev):
                ev.cancel()
                n += 1
        return n

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
