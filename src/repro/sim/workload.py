"""Job-arrival generators over the MapReduce job zoo.

A workload is a deterministic (seeded) stream of :class:`JobSpec` — the
paper's single-job analysis extended to the multi-job regime the ROADMAP
targets: heterogeneous sizes, Poisson / bursty / diurnal arrival processes.
Job kinds reference the executable zoo of :mod:`repro.mapreduce.jobs` (name
and payload width d match the real jobs, so a simulated stream can be
replayed against the engine).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import numpy as np

# (name, payload width d) of the executable job zoo (repro.mapreduce.jobs)
JOB_ZOO: Tuple[Tuple[str, int], ...] = (
    ("histogram", 1),
    ("groupby_mean", 2),
    ("terasort_bucket", 8),
    ("wide_histogram_d16", 16),
)


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One job of the stream: an executable-zoo kind plus its size knobs."""
    name: str
    N: int                 # subfiles
    Q: int                 # reduce keys
    d: int                 # payload width per (key, subfile)
    arrival: float = 0.0   # arrival time, seconds

    @property
    def total_pairs(self) -> float:
        """Total intermediate value-units (N * Q * d) — the size proxy used
        by SRPT ordering."""
        return float(self.N) * self.Q * self.d


SCHEME_FAMILY_NAMES = ("binomial", "resolvable")


def valid_subfile_counts(K: int, P: int, rs: Sequence[int],
                         base: int = 1, count: int = 4,
                         coded_rs: Sequence[int] = (),
                         families: Sequence[str] = ("binomial",)
                         ) -> List[int]:
    """Admissible subfile counts per scheme family, deduped and sorted.

    For each family in ``families``, finds the minimal N satisfying the
    family's divisibility hypotheses for EVERY r in ``rs`` — binomial: K|NP,
    C(P,r) | NP/K and r | M; resolvable: q^{r-1} | NP/K and (r-1) | M with
    q = P/r (rs entries structurally outside the family, e.g. r = 1 or
    r ∤ P, do not constrain it — the chooser drops those candidates the
    same way) — plus K | N (uncoded) and Coded MapReduce's C(K,r) | N for
    every r in ``coded_rs``.  Emits the smallest ``count`` multiples of each
    family's minimum and returns the sorted union, so workload generators
    produce jobs feasible for every requested family."""
    if any(r > P for r in rs):
        raise ValueError(f"hybrid requires r <= P; got rs={tuple(rs)} P={P}")
    unknown = set(families) - set(SCHEME_FAMILY_NAMES)
    if unknown:
        raise ValueError(f"unknown scheme families {sorted(unknown)}; "
                         f"known: {SCHEME_FAMILY_NAMES}")

    def ok_common(n: int) -> bool:
        if (n * P) % K or n % K:
            return False
        return all(n % math.comb(K, r) == 0 for r in coded_rs)

    def ok_family(n: int, family: str) -> bool:
        per_layer = n * P // K
        for r in rs:
            if family == "binomial":
                c = math.comb(P, r)
                if per_layer % c or (per_layer // c) % r:
                    return False
            else:                                  # resolvable
                if r < 2 or P % r or P // r < 2:
                    continue    # structurally outside the family's range
                b = (P // r) ** (r - 1)
                if per_layer % b or (per_layer // b) % (r - 1):
                    return False
        return True

    out = set()
    for family in dict.fromkeys(families):         # preserve, dedupe
        n0 = next(n for n in range(1, 10 ** 7)
                  if ok_common(n) and ok_family(n, family))
        out.update(n0 * base * m for m in range(1, count + 1))
    return sorted(out)


class Workload:
    """Base: subclasses implement arrival-time generation; sizes and kinds
    are drawn i.i.d. from a catalog of (name, N, Q, d) tuples."""

    def __init__(self, catalog: Sequence[Tuple[str, int, int, int]],
                 n_jobs: int) -> None:
        if not catalog:
            raise ValueError("catalog must be non-empty")
        self.catalog = list(catalog)
        self.n_jobs = int(n_jobs)

    def _arrival_times(self, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def generate(self, seed: int = 0) -> List[JobSpec]:
        rng = np.random.default_rng(seed)
        times = np.sort(self._arrival_times(rng))[: self.n_jobs]
        picks = rng.integers(0, len(self.catalog), size=len(times))
        jobs = []
        for t, k in zip(times, picks):
            name, N, Q, d = self.catalog[int(k)]
            jobs.append(JobSpec(name, N, Q, d, float(t)))
        return jobs


class PoissonWorkload(Workload):
    """Memoryless arrivals at ``rate`` jobs/s — the M/G/K baseline."""

    def __init__(self, catalog, n_jobs: int, rate: float) -> None:
        super().__init__(catalog, n_jobs)
        if rate <= 0:
            raise ValueError("rate must be positive")
        self.rate = float(rate)

    def _arrival_times(self, rng: np.random.Generator) -> np.ndarray:
        gaps = rng.exponential(1.0 / self.rate, size=self.n_jobs)
        return np.cumsum(gaps)


class BurstyWorkload(Workload):
    """Batches of ``burst_size`` simultaneous jobs every ``burst_gap``
    seconds (synchronized pipelines / cron storms): the worst case for
    cross-rack contention."""

    def __init__(self, catalog, n_jobs: int, burst_size: int = 4,
                 burst_gap: float = 1.0) -> None:
        super().__init__(catalog, n_jobs)
        if burst_size < 1 or burst_gap <= 0:
            raise ValueError("need burst_size >= 1 and burst_gap > 0")
        self.burst_size = int(burst_size)
        self.burst_gap = float(burst_gap)

    def _arrival_times(self, rng: np.random.Generator) -> np.ndarray:
        n_bursts = -(-self.n_jobs // self.burst_size)
        t = np.repeat(np.arange(n_bursts) * self.burst_gap, self.burst_size)
        return t[: self.n_jobs]


class DiurnalWorkload(Workload):
    """Non-homogeneous Poisson process whose rate follows a day/night
    sinusoid between ``base_rate`` and ``peak_rate`` with period ``period``
    (thinning construction — exact and deterministic per seed)."""

    def __init__(self, catalog, n_jobs: int, base_rate: float,
                 peak_rate: float, period: float = 86400.0) -> None:
        super().__init__(catalog, n_jobs)
        if not 0 < base_rate <= peak_rate:
            raise ValueError("need 0 < base_rate <= peak_rate")
        self.base_rate = float(base_rate)
        self.peak_rate = float(peak_rate)
        self.period = float(period)

    def _rate(self, t: np.ndarray) -> np.ndarray:
        mid = (self.base_rate + self.peak_rate) / 2.0
        amp = (self.peak_rate - self.base_rate) / 2.0
        return mid + amp * np.sin(2.0 * np.pi * t / self.period)

    def _arrival_times(self, rng: np.random.Generator) -> np.ndarray:
        times: List[float] = []
        t = 0.0
        while len(times) < self.n_jobs:
            t += float(rng.exponential(1.0 / self.peak_rate))
            if rng.random() < self._rate(np.asarray(t)) / self.peak_rate:
                times.append(t)
        return np.asarray(times)


def default_catalog(K: int, P: int, rs: Sequence[int] = (1, 2, 3),
                    q_mult: int = 2,
                    coded_rs: Sequence[int] = (2,),
                    families: Sequence[str] = ("binomial",)
                    ) -> List[Tuple[str, int, int, int]]:
    """Heterogeneous (name, N, Q, d) catalog: every zoo kind at a distinct
    valid size, Q = q_mult * K keys.  Sizes admit every hybrid r in ``rs``
    for every scheme family in ``families`` AND Coded MapReduce at
    ``coded_rs`` (so fixed-scheme baselines are well-defined on the whole
    stream)."""
    sizes = valid_subfile_counts(K, P, rs, count=len(JOB_ZOO),
                                 coded_rs=coded_rs, families=families)
    return [(name, n, q_mult * K, d)
            for (name, d), n in zip(JOB_ZOO, sizes)]
