"""Calibration artifacts + the sim-to-metal conformance fit.

Two fits live here, one per convention (keeping them straight matters):

  * **Per-phase host fit** — :func:`calibrate_with_residuals` wraps
    :func:`repro.sim.cluster.calibrate` over
    ``measure_phase_timings`` / ``measure_calibration_grid`` rows (HOST
    work conventions: the legacy map phase maps all N subfiles on one
    device) and reports per-phase fit residuals.  The committed artifact
    ``calibration/default_cost_model.json`` (written by
    ``benchmarks/calibration_bench.py``, loaded by
    :func:`load_default_cost_model`) is this fit plus provenance.
  * **JCT-level conformance fit** — :class:`ConformanceModel`, fitted by
    :func:`fit_conformance` on measured END-TO-END fused-pipeline wall
    clock.  Its features use the SIM work conventions (per-server
    ``n_loc * Q * d`` map/pack work, per-stage network units), and its
    fitted coefficients distribute exactly into a :class:`CostModel` +
    :class:`RackTopology` pair under which the zero-contention
    :func:`simulate_single_job` JCT REPRODUCES the linear predictor — so
    "sim predicts measured wall clock within the tolerance band" is a
    statement about one fit's residuals, checked by actually running the
    simulator (the calibration bench's conformance section).

The artifact schema is versioned (:data:`COST_MODEL_SCHEMA_VERSION`);
loaders fail legibly on a version they do not understand.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.params import SchemeParams
from ..core.shuffle_plan import scheme_stage_traffic
from .cluster import (COMPUTE_PHASES, CostModel, PhaseCoeffs, calibrate,
                      phase_work)
from .network import RackTopology

COST_MODEL_SCHEMA_VERSION = 1

#: repo-relative path of the committed calibrated-cost-model artifact
DEFAULT_COST_MODEL_PATH = os.path.join("calibration",
                                       "default_cost_model.json")


def _repo_root() -> str:
    # src/repro/sim/calibration.py -> src/repro/sim -> src/repro -> src -> /
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


# ---------------------------------------------------------------------------
# Per-phase fit with residuals + JSON artifact
# ---------------------------------------------------------------------------

def fit_residuals(model: CostModel,
                  measurements: Sequence[Dict[str, object]]
                  ) -> Dict[str, Dict[str, float]]:
    """Per-phase residuals of ``model`` against ``measurements`` (the same
    row format :func:`repro.sim.cluster.calibrate` consumes): n points,
    RMSE and max absolute error in seconds, and RMSE relative to the mean
    measured seconds (the scale-free figure the bench pins)."""
    out: Dict[str, Dict[str, float]] = {}
    for phase in COMPUTE_PHASES + ("plan_compile",):
        pred, meas = [], []
        for row in measurements:
            w = row["work"].get(phase)            # type: ignore[union-attr]
            s = row["seconds"].get(phase)         # type: ignore[union-attr]
            if w is not None and s is not None:
                pred.append(model.phase_coeffs(phase).seconds(float(w)))
                meas.append(float(s))
        if not meas:
            continue
        err = np.asarray(pred) - np.asarray(meas)
        rmse = float(np.sqrt(np.mean(err ** 2)))
        mean_s = float(np.mean(np.abs(meas)))
        out[phase] = {"n": len(meas), "rmse_s": rmse,
                      "max_abs_err_s": float(np.max(np.abs(err))),
                      "rel_rmse": rmse / mean_s if mean_s > 0 else 0.0}
    return out


def calibrate_with_residuals(measurements: Sequence[Dict[str, object]]
                             ) -> Tuple[CostModel,
                                        Dict[str, Dict[str, float]]]:
    """:func:`calibrate` plus the fit's own residual report."""
    model = calibrate(measurements)
    return model, fit_residuals(model, measurements)


def cost_model_to_dict(model: CostModel) -> Dict[str, Dict[str, float]]:
    return {phase: {"alpha": model.phase_coeffs(phase).alpha,
                    "beta": model.phase_coeffs(phase).beta}
            for phase in COMPUTE_PHASES + ("plan_compile",)}


def cost_model_from_dict(d: Dict[str, Dict[str, float]]) -> CostModel:
    return CostModel(**{phase: PhaseCoeffs(alpha=float(c["alpha"]),
                                           beta=float(c["beta"]))
                        for phase, c in d.items()})


def save_cost_model(model: CostModel, path: str,
                    residuals: Optional[Dict] = None,
                    provenance: Optional[Dict] = None) -> Dict:
    """Write the versioned cost-model artifact; returns the document."""
    doc = {"schema_version": COST_MODEL_SCHEMA_VERSION,
           "cost_model": cost_model_to_dict(model),
           "residuals": residuals or {},
           "provenance": provenance or {}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def load_cost_model(path: str) -> Tuple[CostModel, Dict]:
    """Load a saved artifact -> (model, full document).  Fails legibly on
    an unknown ``schema_version`` — regenerate with ``make
    bench-calibration`` or update the loader."""
    with open(path) as f:
        doc = json.load(f)
    ver = doc.get("schema_version")
    if ver != COST_MODEL_SCHEMA_VERSION:
        raise ValueError(
            f"cost-model artifact {path!r} has schema_version={ver!r}; "
            f"this loader understands version {COST_MODEL_SCHEMA_VERSION}. "
            f"Regenerate it with `make bench-calibration` or update "
            f"repro.sim.calibration.")
    return cost_model_from_dict(doc["cost_model"]), doc


def load_default_cost_model() -> Tuple[CostModel, Dict]:
    """The committed 8-device-driver calibration
    (``calibration/default_cost_model.json`` at the repo root)."""
    return load_cost_model(os.path.join(_repo_root(),
                                        DEFAULT_COST_MODEL_PATH))


# ---------------------------------------------------------------------------
# Live measurement rows from completed sim jobs (the online-refit feed)
# ---------------------------------------------------------------------------

def measurement_row_from_stats(stats, p: SchemeParams, scheme: str,
                               d: int) -> Dict[str, object]:
    """Rebuild a :func:`calibrate` row from a completed job's
    :class:`JobStats` — the live measurement stream the scheduler refits
    from.  Work uses the SIM conventions of :func:`phase_work` and seconds
    are the job's observed barrier phase times, so straggler inflation is
    absorbed into the refitted betas (exactly what an online model should
    learn from a shifted regime)."""
    work = dict(phase_work(p, scheme, d))
    seconds = {phase: float(stats.phase_times[phase])
               for phase in COMPUTE_PHASES if phase in stats.phase_times}
    if "plan_compile" in stats.phase_times:
        work["plan_compile"] = float(p.N)
        seconds["plan_compile"] = float(stats.phase_times["plan_compile"])
    return {"work": {k: v for k, v in work.items() if k in seconds},
            "seconds": seconds,
            "meta": {"job_id": stats.job_id, "scheme": scheme, "r": p.r,
                     "N": p.N, "Q": p.Q, "d": d}}


# ---------------------------------------------------------------------------
# JCT-level conformance fit (sim conventions, measured fused wall clock)
# ---------------------------------------------------------------------------

CONFORMANCE_FEATURES = ("const", "map_pack_work", "reduce_work",
                        "cross_units", "intra_units")


def conformance_features(p: SchemeParams, scheme: str, d: int) -> np.ndarray:
    """Feature vector of one grid cell, in sim conventions:

      [1, n_loc*Q*d (map==pack work), N*(Q/K)*d (reduce work),
       total cross-rack units, sum over stages of the max per-rack intra
       units].

    The last two are exactly the quantities a zero-contention
    :class:`ClusterSim` divides by the root / per-ToR capacities (hybrid
    stages carry a single tier each), which is what makes the fitted
    predictor reproducible by an actual sim run — see
    :meth:`ConformanceModel.sim_stats`.
    """
    work = phase_work(p, scheme, d)
    stages = scheme_stage_traffic(p, scheme, check=True)
    cross = sum(st.cross_pairs for st in stages) * d
    intra = sum(max(st.intra_pairs_per_rack) if st.intra_pairs_per_rack
                else 0.0 for st in stages) * d
    return np.array([1.0, work["map"], work["reduce"],
                     float(cross), float(intra)])


@dataclasses.dataclass(frozen=True)
class ConformanceModel:
    """Nonnegative linear JCT predictor over
    :data:`CONFORMANCE_FEATURES`, distributable into (CostModel,
    RackTopology) so the simulator reproduces it exactly."""
    theta: Tuple[float, float, float, float, float]

    def predict(self, p: SchemeParams, scheme: str, d: int) -> float:
        return float(np.dot(np.asarray(self.theta),
                            conformance_features(p, scheme, d)))

    def cost_model(self) -> CostModel:
        """The fitted compute side: the whole map+pack coefficient rides
        on map (pack keeps zero cost — the fused pipeline cannot split
        them), the constant on map.alpha."""
        t0, t_mp, t_red, _, _ = self.theta
        return CostModel(map=PhaseCoeffs(alpha=t0, beta=t_mp),
                         reduce=PhaseCoeffs(alpha=0.0, beta=t_red))

    def topology(self, P: int) -> RackTopology:
        """The fitted network side: capacities are the reciprocal fitted
        rates.  A (near-)zero coefficient means that tier's drain time
        never showed above the noise — its capacity goes effectively
        infinite rather than dividing by zero.  ``intra_bw`` is the
        AGGREGATE intra capacity (RackTopology splits it over P ToRs), so
        the per-ToR drain of the max-loaded rack matches
        ``theta_intra * intra_units`` exactly."""
        _, _, _, t_cross, t_intra = self.theta
        huge = 1e18
        cross_bw = 1.0 / t_cross if t_cross > 1e-15 else huge
        intra_bw = P / t_intra if t_intra > 1e-15 else huge
        return RackTopology(P=P, cross_bw=cross_bw, intra_bw=intra_bw,
                            cross_latency=0.0, intra_latency=0.0,
                            fetch_latency=0.0)

    def sim_stats(self, p: SchemeParams, scheme: str, d: int):
        """Run the actual simulator (zero contention, no stragglers) under
        the distributed (CostModel, RackTopology) — the sim JCT this
        returns equals :meth:`predict` up to float noise, proven in
        tests."""
        from .cluster import simulate_single_job
        from .workload import JobSpec
        spec = JobSpec(f"conformance_N{p.N}_r{p.r}_d{d}", p.N, p.Q, d,
                       arrival=0.0)
        return simulate_single_job(spec, self.topology(p.P), p.K, scheme,
                                   p.r, cost_model=self.cost_model())

    def to_dict(self) -> Dict[str, object]:
        return {"features": list(CONFORMANCE_FEATURES),
                "theta": [float(t) for t in self.theta]}


def fit_conformance(cells: Sequence[Dict[str, object]]) -> ConformanceModel:
    """Least-squares fit of measured fused-pipeline end-to-end seconds
    against :func:`conformance_features`, coefficients clipped
    nonnegative (a negative rate is unphysical; the clip trades a little
    fit quality for a model the simulator can realize as capacities).

    ``cells`` rows: {"p": SchemeParams, "scheme": str, "d": int,
    "measured_s": float}.
    """
    if not cells:
        raise ValueError("fit_conformance needs at least one cell")
    X = np.stack([conformance_features(c["p"], c["scheme"], c["d"])
                  for c in cells])
    y = np.asarray([float(c["measured_s"]) for c in cells])
    theta, *_ = np.linalg.lstsq(X, y, rcond=None)
    return ConformanceModel(tuple(float(max(t, 0.0)) for t in theta))


def conformance_report(model: ConformanceModel,
                       cells: Sequence[Dict[str, object]],
                       via_sim: bool = True) -> List[Dict[str, object]]:
    """Per-cell predicted-vs-measured table.  ``via_sim=True`` predicts by
    RUNNING the simulator under the distributed model (the honest check);
    False uses the linear form directly."""
    rows = []
    for c in cells:
        p, scheme, d = c["p"], c["scheme"], c["d"]
        pred = (model.sim_stats(p, scheme, d).jct if via_sim
                else model.predict(p, scheme, d))
        meas = float(c["measured_s"])
        rows.append({"N": p.N, "Q": p.Q, "r": p.r, "d": d,
                     "scheme": scheme, "measured_s": meas,
                     "predicted_s": float(pred),
                     "rel_err": abs(pred - meas) / max(meas, 1e-12)})
    return rows


__all__ = [
    "COST_MODEL_SCHEMA_VERSION", "DEFAULT_COST_MODEL_PATH",
    "calibrate_with_residuals", "fit_residuals", "cost_model_to_dict",
    "cost_model_from_dict", "save_cost_model", "load_cost_model",
    "load_default_cost_model", "measurement_row_from_stats",
    "CONFORMANCE_FEATURES", "conformance_features", "ConformanceModel",
    "fit_conformance", "conformance_report",
]
