"""Deterministic discrete-event cluster simulator for (Hybrid) Coded
MapReduce on a server-rack architecture.

A job advances through the phases of the executable pipeline
(:mod:`repro.mapreduce.engine`):

    [plan compile] -> [fetch] -> map -> pack -> shuffle (stages) -> reduce

(``fetch`` appears only for jobs submitted with a placement bridge: the
non-local map inputs of a :mod:`repro.placement` placement move over the
network before map starts — see ``submit(placement=...)``.)

Compute phases (map / pack / reduce) run per server with an affine cost
``alpha + beta * work`` (work units documented on :class:`CostModel`),
multiplied by a pluggable straggler factor, and complete at a barrier (the
phase ends when the SLOWEST server does — stragglers hurt exactly as in
practice).  The shuffle runs as fluid flows on the two-tier network of
:mod:`repro.sim.network`, where concurrent jobs contend for the root and ToR
switches under fair share.  Shuffle stage loads come from the stage-traffic
export of :mod:`repro.core.shuffle_plan` (enumerated schedules) or its
closed-form equivalent — i.e. the simulated traffic IS the schedule the
executable shuffle moves.

Everything is driven by one seeded ``numpy`` Generator and a sequence-
numbered event queue, so a (workload, topology, seed) triple reproduces a
bit-identical event trace.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.params import SchemeParams
from ..core.shuffle_plan import StageTraffic, scheme_stage_traffic
from .events import EventQueue, TraceEntry
from .network import ROOT, FluidNetwork, RackTopology, tor
from .workload import JobSpec

COMPUTE_PHASES = ("map", "pack", "reduce")


# ---------------------------------------------------------------------------
# Phase cost model + calibration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhaseCoeffs:
    """``seconds = alpha + beta * work`` for one phase on one server."""
    alpha: float = 0.0
    beta: float = 0.0

    def seconds(self, work: float) -> float:
        return self.alpha + self.beta * work


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-phase affine compute costs.

    Work units (value-units, matching the network's pair x width unit):
      * map    — intermediate values computed per server: n_loc * Q * d
      * pack   — values gathered/laid out per server:     n_loc * Q * d
      * reduce — values folded per server:                N * (Q/K) * d
      * plan_compile — subfiles N (charged once per plan-cache MISS; the
        scheduler reads `repro.core.coded_collectives.plan_cache_info`)
    """
    map: PhaseCoeffs = PhaseCoeffs()
    pack: PhaseCoeffs = PhaseCoeffs()
    reduce: PhaseCoeffs = PhaseCoeffs()
    plan_compile: PhaseCoeffs = PhaseCoeffs()

    def phase_coeffs(self, phase: str) -> PhaseCoeffs:
        return getattr(self, phase)


ZERO_COST = CostModel()


def phase_work(p: SchemeParams, scheme: str, d: int) -> Dict[str, float]:
    """Per-server work units of each compute phase (see :class:`CostModel`).

    ``n_loc`` is the per-server map load: N/K subfiles uncoded, r-fold
    replicated (rN/K) for coded and hybrid — the computation side of the
    paper's computation/communication tradeoff.
    """
    repl = 1 if scheme == "uncoded" else p.r
    n_loc = p.N * repl / p.K
    return {
        "map": n_loc * p.Q * d,
        "pack": n_loc * p.Q * d,
        "reduce": p.N * (p.Q / p.K) * d,
    }


def _fit_affine(work: np.ndarray, secs: np.ndarray) -> PhaseCoeffs:
    """Least-squares fit of secs ~ alpha + beta * work (alpha clipped >= 0)."""
    if len(work) < 2:                     # underdetermined: pure rate model
        return PhaseCoeffs(alpha=0.0,
                           beta=float(max(secs[0] / max(work[0], 1e-12), 0.0)))
    A = np.stack([np.ones_like(work), work], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(A, secs, rcond=None)
    return PhaseCoeffs(alpha=float(max(alpha, 0.0)), beta=float(max(beta, 0.0)))


def calibrate(measurements: Sequence[Dict[str, object]]) -> CostModel:
    """Fit per-phase alpha/beta from measured phase timings.

    ``measurements`` rows come from
    :func:`repro.mapreduce.engine.measure_phase_timings` (preferred: true
    per-phase split on the real pipeline) or from ``BENCH_pipeline.json``
    rows adapted via :func:`measurements_from_pipeline_bench`.  Each row
    holds ``work`` and ``seconds`` dicts keyed by phase name; phases missing
    everywhere keep zero cost.
    """
    fitted: Dict[str, PhaseCoeffs] = {}
    for phase in COMPUTE_PHASES + ("plan_compile",):
        work, secs = [], []
        for row in measurements:
            w = row["work"].get(phase)            # type: ignore[union-attr]
            s = row["seconds"].get(phase)         # type: ignore[union-attr]
            if w is not None and s is not None:
                work.append(float(w))
                secs.append(float(s))
        if work:
            fitted[phase] = _fit_affine(np.asarray(work), np.asarray(secs))
    return CostModel(**fitted)


def measurements_from_pipeline_bench(report: Dict) -> List[Dict[str, object]]:
    """Adapt ``BENCH_pipeline.json`` rows into :func:`calibrate` rows.

    The legacy-path phase split maps onto the model as: ``map_to_host`` is a
    single-device map of all N subfiles (work N*Q*d), ``host_pack_upload``
    moves the r-fold replicated packed tensor (work r*N*Q*d); the fused
    ``shuffle_reduce`` phase is not separable there — use
    ``measure_phase_timings`` for reduce calibration.
    """
    rows = []
    for x in report.get("results", []):
        N, Q, d, r = x["N"], x["Q"], x["d"], x["r"]
        ph = x["legacy"]["phases_s"]
        rows.append({
            "work": {"map": N * Q * d, "pack": r * N * Q * d},
            "seconds": {"map": ph["map_to_host"],
                        "pack": ph["host_pack_upload"]},
        })
    return rows


# ---------------------------------------------------------------------------
# Straggler models
# ---------------------------------------------------------------------------

class StragglerModel:
    """Multiplicative per-server slowdown factors (>= 1) for one compute
    phase of one job.  Sampled ONCE per (job, phase) from the simulator's
    seeded rng — deterministic given the seed."""

    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        raise NotImplementedError


class NoStragglers(StragglerModel):
    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        return np.ones(K)


@dataclasses.dataclass
class DeterministicSlowdown(StragglerModel):
    """Fixed per-server factors (e.g. one known-slow machine)."""
    server_factors: Tuple[float, ...]

    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        f = np.asarray(self.server_factors, dtype=float)
        if f.shape != (K,):
            raise ValueError(f"need {K} per-server factors, got {f.shape}")
        if (f < 1.0).any():
            raise ValueError("slowdown factors must be >= 1")
        return f


@dataclasses.dataclass
class ExponentialTail(StragglerModel):
    """1 + Exp(scale) per server — the classic heavy-tail straggler model."""
    scale: float = 0.2

    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        return 1.0 + rng.exponential(self.scale, size=K)


@dataclasses.dataclass
class RackCorrelated(StragglerModel):
    """Whole racks slow down together (shared ToR/PDU failures): each rack
    is slowed by ``factor`` with probability ``p_slow``."""
    p_slow: float = 0.1
    factor: float = 3.0

    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        slow = rng.random(P) < self.p_slow
        per_rack = np.where(slow, self.factor, 1.0)
        return np.repeat(per_rack, K // P)


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SimJob:
    job_id: int
    spec: JobSpec
    params: SchemeParams
    scheme: str
    stages: List[StageTraffic]
    compile_s: float
    submit_time: float
    # placement bridge (repro.placement.sim_bridge.PlacementTraffic, duck-
    # typed here to keep the sim importable without the placement package):
    # pre-map fetch loads + per-server map-work factors
    placement: Optional[object] = None
    phase: str = "submitted"
    stage_idx: int = 0
    open_flows: int = 0
    phase_start: float = 0.0
    phase_times: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class JobStats:
    job_id: int
    name: str
    scheme: str
    r: int
    arrival: float
    submit: float
    finish: float
    phase_times: Dict[str, float]

    @property
    def jct(self) -> float:
        """Completion time from ARRIVAL (includes scheduler queueing)."""
        return self.finish - self.arrival


class ClusterSim:
    """Fluid discrete-event simulator of one server-rack cluster.

    ``submit`` may be called before ``run`` (a static batch) or from
    callbacks during the run (the online scheduler).  ``stages`` defaults to
    the closed-form stage traffic of the chosen scheme; pass enumerated
    ``plan_stage_traffic`` output (or loads derived from
    ``plan_transfer_matrices``) to simulate an explicit schedule.
    """

    def __init__(self, topology: RackTopology, K: int,
                 cost_model: CostModel = ZERO_COST,
                 stragglers: StragglerModel | None = None,
                 seed: int = 0) -> None:
        if K % topology.P != 0:
            raise ValueError(f"P={topology.P} must divide K={K}")
        self.topology = topology
        self.K = K
        self.cost_model = cost_model
        self.stragglers = stragglers or NoStragglers()
        self.rng = np.random.default_rng(seed)
        self.network = FluidNetwork(topology)
        self.queue = EventQueue()
        self.now = 0.0
        self.trace: List[TraceEntry] = []
        self.stats: List[JobStats] = []
        self.on_job_done: Optional[Callable[[JobStats], None]] = None
        self._jobs: Dict[int, _SimJob] = {}
        self._next_job_id = 0

    # ---- public API --------------------------------------------------------

    def at(self, time: float, fn: Callable[[], None], kind: str = "callback",
           data: Tuple = ()) -> None:
        """Schedule an arbitrary callback (arrivals, scheduler wakeups)."""
        self.queue.push(max(time, self.now), kind, data, fn)

    def submit(self, spec: JobSpec, scheme: str, r: int,
               time: float | None = None,
               stages: List[StageTraffic] | None = None,
               compile_s: float = 0.0, check: bool = True,
               placement: object | None = None) -> int:
        """Enqueue a job start; returns its sim job id.

        ``placement`` is a :class:`repro.placement.sim_bridge
        .PlacementTraffic`: its non-local map inputs run as a ``fetch``
        network stage before the map phase (contending with concurrent
        shuffles), and its per-server factors skew the map barrier.
        """
        t = self.now if time is None else max(float(time), self.now)
        p = SchemeParams(K=self.K, P=self.topology.P, Q=spec.Q, N=spec.N, r=r)
        if stages is None:
            stages = scheme_stage_traffic(p, scheme, check=check)
        if placement is not None:
            nf = len(getattr(placement, "map_factors", ()))
            if nf != self.K:
                raise ValueError(
                    f"placement.map_factors must have K={self.K} entries, "
                    f"got {nf}")
            if len(placement.intra_units_per_rack) != self.topology.P:
                raise ValueError("placement.intra_units_per_rack must have "
                                 f"P={self.topology.P} entries")
        job = _SimJob(self._next_job_id, spec, p, scheme, stages,
                      float(compile_s), t, placement)
        self._next_job_id += 1
        self._jobs[job.job_id] = job
        self.queue.push(t, "submit", (job.job_id,),
                        lambda j=job: self._start_job(j))
        return job.job_id

    def run(self, until: float = float("inf")) -> List[JobStats]:
        """Advance until no work is left (or ``until``); returns all
        completed-job stats in completion order."""
        while True:
            # advance in DELTAS, not absolute times: at large t the next
            # flow-completion dt can be below the float resolution of
            # ``now + dt``, and an absolute-time loop would spin forever
            dt_flow = self.network.time_to_next_completion()
            t_event = self.queue.peek_time()
            dt_event = t_event - self.now
            if dt_flow == float("inf") and dt_event == float("inf"):
                break
            if min(self.now + dt_flow, t_event) > until:
                # truncated run: drain flows up to the horizon so a resumed
                # run() continues from consistent state; advance the clock
                # FIRST so completion callbacks stamp times at the horizon
                dt = until - self.now
                self.now = until
                for flow in self.network.advance(dt):
                    self._trace("flow_done", flow.tag)
                    self._flow_done(flow.tag[0])
                break
            if dt_flow < dt_event:
                done = self.network.advance(dt_flow)
                self.now += dt_flow
            else:
                done = self.network.advance(max(dt_event, 0.0))
                self.now = t_event
            for flow in done:
                self._trace("flow_done", flow.tag)
                self._flow_done(flow.tag[0])
            while self.queue and self.queue.peek_time() <= self.now:
                ev = self.queue.pop()
                self._trace(ev.kind, ev.data)
                if ev.fn is not None:
                    ev.fn()
        return self.stats

    # ---- internals ---------------------------------------------------------

    def _trace(self, kind: str, data: Tuple) -> None:
        self.trace.append((round(self.now, 12), kind, tuple(data)))

    def _start_job(self, job: _SimJob) -> None:
        if job.compile_s > 0:
            job.phase = "plan_compile"
            job.phase_start = self.now
            self.queue.push(self.now + job.compile_s, "phase_done",
                            (job.job_id, "plan_compile"),
                            lambda: self._phase_done(job, "plan_compile"))
        else:
            self._begin_fetch(job)

    def _begin_fetch(self, job: _SimJob) -> None:
        """Pre-map input-fetch stage: the non-local map inputs of a bridged
        placement move over the network BEFORE map can start (they contend
        with concurrent jobs' shuffles like any flow).  Placement-less jobs
        (and fully node-local placements) skip straight to map."""
        pl = job.placement
        job.open_flows = 0
        if pl is not None:
            if pl.cross_units > 0:
                self.network.start_flow(ROOT, pl.cross_units,
                                        (job.job_id, "fetch_cross"))
                job.open_flows += 1
            for rack, load in enumerate(pl.intra_units_per_rack):
                if load > 0:
                    self.network.start_flow(tor(rack), load,
                                            (job.job_id, "fetch_intra", rack))
                    job.open_flows += 1
        if job.open_flows == 0:
            self._begin_compute(job, "map")
        else:
            job.phase = "fetch"
            job.phase_start = self.now

    def _begin_compute(self, job: _SimJob, phase: str) -> None:
        job.phase = phase
        job.phase_start = self.now
        coeffs = self.cost_model.phase_coeffs(phase)
        work = phase_work(job.params, job.scheme, job.spec.d)[phase]
        factors = self.stragglers.factors(self.rng, self.K, self.topology.P)
        if phase == "map" and job.placement is not None:
            # locality imbalance compounds with stragglers per server; the
            # barrier still ends at the slowest server
            factors = factors * np.asarray(job.placement.map_factors)
        dur = float(np.max(factors) * coeffs.seconds(work))
        self.queue.push(self.now + dur, "phase_done", (job.job_id, phase),
                        lambda: self._phase_done(job, phase))

    def _begin_shuffle_stage(self, job: _SimJob) -> None:
        stage = job.stages[job.stage_idx]
        job.phase = f"shuffle:{stage.stage}"
        job.phase_start = self.now
        d = job.spec.d
        job.open_flows = 0
        if stage.cross_pairs > 0:
            self.network.start_flow(ROOT, stage.cross_pairs * d,
                                    (job.job_id, "cross"))
            job.open_flows += 1
        for rack, load in enumerate(stage.intra_pairs_per_rack):
            if load > 0:
                self.network.start_flow(tor(rack), load * d,
                                        (job.job_id, "intra", rack))
                job.open_flows += 1
        if job.open_flows == 0:                    # empty stage (e.g. r = K)
            self._stage_done(job)

    def _flow_done(self, job_id: int) -> None:
        job = self._jobs[job_id]
        job.open_flows -= 1
        if job.open_flows == 0:
            if job.phase == "fetch":
                latency = self.topology.latency("fetch")
                done = lambda: self._fetch_done(job)      # noqa: E731
            else:
                latency = self.topology.latency(
                    job.stages[job.stage_idx].stage)
                done = lambda: self._stage_done(job)      # noqa: E731
            if latency > 0:
                self.queue.push(self.now + latency, "stage_latency",
                                (job.job_id,), done)
            else:
                done()

    def _fetch_done(self, job: _SimJob) -> None:
        job.phase_times["fetch"] = self.now - job.phase_start
        self._begin_compute(job, "map")

    def _stage_done(self, job: _SimJob) -> None:
        job.phase_times[f"shuffle:{job.stages[job.stage_idx].stage}"] = \
            self.now - job.phase_start
        job.stage_idx += 1
        if job.stage_idx < len(job.stages):
            self._begin_shuffle_stage(job)
        else:
            self._begin_compute(job, "reduce")

    def _phase_done(self, job: _SimJob, phase: str) -> None:
        job.phase_times[phase] = self.now - job.phase_start
        if phase == "plan_compile":
            self._begin_fetch(job)
        elif phase == "map":
            self._begin_compute(job, "pack")
        elif phase == "pack":
            job.stage_idx = 0
            if job.stages:
                self._begin_shuffle_stage(job)
            else:
                self._begin_compute(job, "reduce")
        elif phase == "reduce":
            job.phase = "done"
            stats = JobStats(job.job_id, job.spec.name, job.scheme,
                             job.params.r, job.spec.arrival, job.submit_time,
                             self.now, dict(job.phase_times))
            self.stats.append(stats)
            self._trace("job_done", (job.job_id, job.scheme, job.params.r))
            if self.on_job_done is not None:
                self.on_job_done(stats)


def simulate_single_job(spec: JobSpec, topology: RackTopology, K: int,
                        scheme: str, r: int,
                        cost_model: CostModel = ZERO_COST,
                        stragglers: StragglerModel | None = None,
                        seed: int = 0, check: bool = True) -> JobStats:
    """One job, empty cluster — the zero-contention special case whose JCT
    must equal ``CommCost.weighted_time`` when compute costs are zero."""
    sim = ClusterSim(topology, K, cost_model, stragglers, seed)
    sim.submit(spec, scheme, r, time=spec.arrival, check=check)
    (stats,) = sim.run()
    return stats
