"""Deterministic discrete-event cluster simulator for (Hybrid) Coded
MapReduce on a server-rack architecture.

A job advances through the phases of the executable pipeline
(:mod:`repro.mapreduce.engine`):

    [plan compile] -> [fetch] -> map -> pack -> shuffle (stages) -> reduce

(``fetch`` appears only for jobs submitted with a placement bridge: the
non-local map inputs of a :mod:`repro.placement` placement move over the
network before map starts — see ``submit(placement=...)``.)

Compute phases (map / pack / reduce) run per server with an affine cost
``alpha + beta * work`` (work units documented on :class:`CostModel`),
multiplied by a pluggable straggler factor, and complete at a barrier (the
phase ends when the SLOWEST server does — stragglers hurt exactly as in
practice).  The shuffle runs as fluid flows on the two-tier network of
:mod:`repro.sim.network`, where concurrent jobs contend for the root and ToR
switches under fair share.  Shuffle stage loads come from the stage-traffic
export of :mod:`repro.core.shuffle_plan` (enumerated schedules) or its
closed-form equivalent — i.e. the simulated traffic IS the schedule the
executable shuffle moves.

Everything is driven by one seeded ``numpy`` Generator and a sequence-
numbered event queue, so a (workload, topology, seed) triple reproduces a
bit-identical event trace.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from ..core.assignment import (coded_assignment, hybrid_assignment,
                               uncoded_assignment)
from ..core.degraded import degraded_stage_traffic
from ..core.params import SchemeParams
from ..core.shuffle_plan import StageTraffic, scheme_stage_traffic
from ..obs import blame as obs_blame
from ..obs import metrics as obs_metrics
from ..obs.tracing import Tracer
from .events import Event, EventQueue, TraceEntry
from .network import (ROOT, FluidNetwork, NetworkTelemetry, RackTopology,
                      tor)
from .workload import JobSpec

COMPUTE_PHASES = ("map", "pack", "reduce")


# ---------------------------------------------------------------------------
# Phase cost model + calibration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhaseCoeffs:
    """``seconds = alpha + beta * work`` for one phase on one server."""
    alpha: float = 0.0
    beta: float = 0.0

    def seconds(self, work: float) -> float:
        return self.alpha + self.beta * work


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-phase affine compute costs.

    Work units (value-units, matching the network's pair x width unit):
      * map    — intermediate values computed per server: n_loc * Q * d
      * pack   — values gathered/laid out per server:     n_loc * Q * d
      * reduce — values folded per server:                N * (Q/K) * d
      * plan_compile — subfiles N (charged once per plan-cache MISS; the
        scheduler reads `repro.core.coded_collectives.plan_cache_info`)
    """
    map: PhaseCoeffs = PhaseCoeffs()
    pack: PhaseCoeffs = PhaseCoeffs()
    reduce: PhaseCoeffs = PhaseCoeffs()
    plan_compile: PhaseCoeffs = PhaseCoeffs()

    def phase_coeffs(self, phase: str) -> PhaseCoeffs:
        return getattr(self, phase)


ZERO_COST = CostModel()


def phase_work(p: SchemeParams, scheme: str, d: int) -> Dict[str, float]:
    """Per-server work units of each compute phase (see :class:`CostModel`).

    ``n_loc`` is the per-server map load: N/K subfiles uncoded, r-fold
    replicated (rN/K) for coded and hybrid — the computation side of the
    paper's computation/communication tradeoff.
    """
    repl = 1 if scheme == "uncoded" else p.r
    n_loc = p.N * repl / p.K
    return {
        "map": n_loc * p.Q * d,
        "pack": n_loc * p.Q * d,
        "reduce": p.N * (p.Q / p.K) * d,
    }


def _fit_affine(work: np.ndarray, secs: np.ndarray) -> PhaseCoeffs:
    """Least-squares fit of secs ~ alpha + beta * work (alpha clipped >= 0)."""
    if len(work) < 2:                     # underdetermined: pure rate model
        return PhaseCoeffs(alpha=0.0,
                           beta=float(max(secs[0] / max(work[0], 1e-12), 0.0)))
    A = np.stack([np.ones_like(work), work], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(A, secs, rcond=None)
    return PhaseCoeffs(alpha=float(max(alpha, 0.0)), beta=float(max(beta, 0.0)))


def calibrate(measurements: Sequence[Dict[str, object]]) -> CostModel:
    """Fit per-phase alpha/beta from measured phase timings.

    ``measurements`` rows come from
    :func:`repro.mapreduce.engine.measure_phase_timings` (preferred: true
    per-phase split on the real pipeline) or from ``BENCH_pipeline.json``
    rows adapted via :func:`measurements_from_pipeline_bench`.  Each row
    holds ``work`` and ``seconds`` dicts keyed by phase name; phases missing
    everywhere keep zero cost.
    """
    fitted: Dict[str, PhaseCoeffs] = {}
    for phase in COMPUTE_PHASES + ("plan_compile",):
        work, secs = [], []
        for row in measurements:
            w = row["work"].get(phase)            # type: ignore[union-attr]
            s = row["seconds"].get(phase)         # type: ignore[union-attr]
            if w is not None and s is not None:
                work.append(float(w))
                secs.append(float(s))
        if work:
            fitted[phase] = _fit_affine(np.asarray(work), np.asarray(secs))
    return CostModel(**fitted)


#: envelope version of ``BENCH_pipeline.json`` this adapter understands
#: (written by ``benchmarks/_common.emit_report`` — bump together)
PIPELINE_BENCH_SCHEMA_VERSION = 1


def measurements_from_pipeline_bench(report: Dict) -> List[Dict[str, object]]:
    """Adapt ``BENCH_pipeline.json`` rows into :func:`calibrate` rows.

    The legacy-path phase split maps onto the model as: ``map_to_host`` is a
    single-device map of all N subfiles (work N*Q*d), ``host_pack_upload``
    moves the r-fold replicated packed tensor (work r*N*Q*d); the fused
    ``shuffle_reduce`` phase is not separable there — use
    ``measure_phase_timings`` for reduce calibration.

    The report must carry the benchmark envelope of the version this
    adapter understands — a silent schema drift here would mis-calibrate
    every downstream simulation, so an unknown ``schema_version`` raises.
    """
    ver = report.get("schema_version")
    if ver != PIPELINE_BENCH_SCHEMA_VERSION:
        raise ValueError(
            f"BENCH_pipeline report carries schema_version={ver!r}, but "
            f"this adapter understands version "
            f"{PIPELINE_BENCH_SCHEMA_VERSION}. Regenerate the artifact "
            f"with `PYTHONPATH=src python benchmarks/pipeline_bench.py` "
            f"(or update measurements_from_pipeline_bench for the new "
            f"envelope).")
    rows = []
    for x in report.get("results", []):
        N, Q, d, r = x["N"], x["Q"], x["d"], x["r"]
        ph = x["legacy"]["phases_s"]
        rows.append({
            "work": {"map": N * Q * d, "pack": r * N * Q * d},
            "seconds": {"map": ph["map_to_host"],
                        "pack": ph["host_pack_upload"]},
        })
    return rows


# ---------------------------------------------------------------------------
# Straggler models
# ---------------------------------------------------------------------------

class StragglerModel:
    """Multiplicative per-server slowdown factors (>= 1) for one compute
    phase of one job.  Sampled once per (job, phase) from the simulator's
    seeded rng — and, when speculative re-execution is active, RESAMPLED per
    map *wave*: every batch of backup launches draws fresh factors, so a
    re-launched task sees new luck instead of replaying the wave-0 draw.
    Deterministic given the seed either way."""

    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        raise NotImplementedError


class NoStragglers(StragglerModel):
    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        return np.ones(K)


@dataclasses.dataclass
class DeterministicSlowdown(StragglerModel):
    """Fixed per-server factors (e.g. one known-slow machine)."""
    server_factors: Tuple[float, ...]

    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        f = np.asarray(self.server_factors, dtype=float)
        if f.shape != (K,):
            raise ValueError(f"need {K} per-server factors, got {f.shape}")
        if (f < 1.0).any():
            raise ValueError("slowdown factors must be >= 1")
        return f


@dataclasses.dataclass
class ExponentialTail(StragglerModel):
    """1 + Exp(scale) per server — the classic heavy-tail straggler model."""
    scale: float = 0.2

    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        return 1.0 + rng.exponential(self.scale, size=K)


@dataclasses.dataclass
class RackCorrelated(StragglerModel):
    """Whole racks slow down together (shared ToR/PDU failures): each rack
    is slowed by ``factor`` with probability ``p_slow``."""
    p_slow: float = 0.1
    factor: float = 3.0

    def factors(self, rng: np.random.Generator, K: int, P: int) -> np.ndarray:
        slow = rng.random(P) < self.p_slow
        per_rack = np.where(slow, self.factor, 1.0)
        return np.repeat(per_rack, K // P)


# ---------------------------------------------------------------------------
# Task-granular map phase with speculative re-execution
# ---------------------------------------------------------------------------
#
# With ``submit(speculation=policy)`` the map phase stops being one barrier
# event and becomes per-task execution: every server runs its assigned
# subfile chunks sequentially on one map slot, a pluggable policy
# (:mod:`repro.resilience.speculation` — duck-typed here so the sim stays
# importable without that package) observes progress and launches BACKUP
# attempts that contend for real slots (they queue behind the target
# server's own tasks) and for fetch bandwidth (a backup without a local
# input replica moves the input through the fluid network first).  The
# first finisher wins: losing attempts are cancelled — queued ones are
# dropped, fetching ones abort their flow, running ones cancel their
# completion event and free the slot immediately.

@dataclasses.dataclass
class MapTaskAttempt:
    """One execution attempt of one map task on one server."""
    attempt_id: int
    task: "MapTask"
    server: int
    wave: int                       # straggler wave the attempt belongs to
    is_backup: bool
    state: str = "queued"           # queued|fetching|running|done|cancelled
    start: float = -1.0             # compute start time (state >= running)
    fetch_flow: Optional[int] = None
    event: Optional[Event] = None   # pending completion event


@dataclasses.dataclass
class MapTask:
    """One map task: a chunk of the subfiles one server must map.

    ``stores`` are the servers holding the task's input locally (the other
    mappers of the same subfiles) — a backup attempt elsewhere must fetch
    the input intra-rack (replica in its rack) or through the root switch.
    """
    index: int
    server: int                     # home server (whose map output this is)
    subfiles: Tuple[int, ...]
    work: float                     # compute value-units (len * Q * d)
    input_units: float              # network value-units of the raw input
    stores: Tuple[int, ...]
    done: bool = False
    finish: float = -1.0
    attempts: List[MapTaskAttempt] = dataclasses.field(default_factory=list)


def _map_assignment(p: SchemeParams, scheme: str
                    ) -> Tuple[List[List[int]], List[Tuple[int, ...]]]:
    """(subfiles_of_server, servers_of_subfile) of the scheme's real map
    assignment; divisibility-violating instances (simulated with
    ``check=False``, as the paper's Table I does) fall back to a balanced
    round-robin with the same replication factor."""
    try:
        from ..core.resolvable import resolvable_assignment
        mk = {"uncoded": uncoded_assignment, "coded": coded_assignment,
              "hybrid": hybrid_assignment,
              "hybrid_resolvable": resolvable_assignment}[scheme]
        a = mk(p)
        return a.subfiles_of_server, [tuple(s) for s in a.servers_of_subfile]
    except ValueError:
        repl = 1 if scheme == "uncoded" else min(p.r, p.K)
        per: List[List[int]] = [[] for _ in range(p.K)]
        servers_of: List[Tuple[int, ...]] = []
        step = max(1, p.K // repl)
        for i in range(p.N):
            srvs = tuple(sorted((i + j * step) % p.K for j in range(repl)))
            servers_of.append(srvs)
            for s in srvs:
                per[s].append(i)
        return per, servers_of


def _chunk(seq: List[int], n_chunks: Optional[int]) -> List[List[int]]:
    """Split one server's subfile list into tasks: per-subfile by default,
    or ``n_chunks`` near-equal chunks when the policy coalesces."""
    if n_chunks is None or n_chunks <= 0 or n_chunks >= len(seq):
        return [[i] for i in seq]
    return [list(c) for c in np.array_split(np.asarray(seq), n_chunks) if
            len(c)]


class TaskMapPhase:
    """Engine of one job's task-granular map phase (see module comment).

    Doubles as the VIEW handed to speculation-policy hooks: policies read
    ``now / tasks / running / remaining / mean_rate() / rack_rates() /
    server_load() / elapsed() / live_backup() / pick_backup_server()`` and
    return ``[(task_index, server), ...]`` backup requests; the engine
    enforces the budget, slot contention and first-finisher-wins.
    """

    def __init__(self, sim: "ClusterSim", job: "_SimJob",
                 policy: object) -> None:
        self.sim = sim
        self.job = job
        self.policy = policy
        self.K = sim.K
        self.P = sim.topology.P
        self.Kr = self.K // self.P
        p, d = job.params, job.spec.d
        per_server, servers_of = _map_assignment(p, job.scheme)
        unit = float(p.Q * d)            # value-units per subfile (in + out)
        n_chunks = getattr(policy, "tasks_per_server", None)
        self.tasks: List[MapTask] = []
        self.queues: List[Deque[MapTaskAttempt]] = \
            [deque() for _ in range(self.K)]
        self.running: List[Optional[MapTaskAttempt]] = [None] * self.K
        self._attempts: Dict[int, MapTaskAttempt] = {}
        self._next_attempt = 0
        for s in range(self.K):
            for chunk in _chunk(per_server[s], n_chunks):
                stores = set(servers_of[chunk[0]])
                for i in chunk[1:]:
                    stores &= set(servers_of[i])
                stores.add(s)
                task = MapTask(len(self.tasks), s, tuple(chunk),
                               len(chunk) * unit, len(chunk) * unit,
                               tuple(sorted(stores)))
                self.tasks.append(task)
        self.remaining = len(self.tasks)
        self.backup_budget = int(policy.backup_budget(len(self.tasks)))
        self.backups_launched = 0
        self.wave = 0
        pl = job.placement
        self.pl_factors = (np.asarray(pl.map_factors, dtype=float)
                           if pl is not None else np.ones(self.K))
        self.wave_factors: List[np.ndarray] = []
        self.completed: List[Tuple[float, float, int]] = []  # (s, work, srv)
        self.done = False
        self._probes: Dict[int, Event] = {}

    # ---- view API for policies --------------------------------------------

    @property
    def now(self) -> float:
        return self.sim.now

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    @property
    def n_done(self) -> int:
        return len(self.tasks) - self.remaining

    def rack_of(self, server: int) -> int:
        return server // self.Kr

    def server_load(self, server: int) -> int:
        # count only LIVE queued attempts: cancelled losers stay in the
        # deque until dispatch skips them, and must not make an idle
        # server look busy to pick_backup_server
        live = sum(1 for a in self.queues[server]
                   if a.state == "queued" and not a.task.done)
        return live + (1 if self.running[server] is not None else 0)

    def elapsed(self, attempt: MapTaskAttempt) -> float:
        return self.now - attempt.start if attempt.state == "running" else 0.0

    def mean_rate(self) -> Optional[float]:
        """Observed seconds per work unit over completed attempts (None
        before the first completion) — the progress yardstick policies
        compare running attempts against."""
        if not self.completed:
            return None
        tot_s = sum(s for s, _, _ in self.completed)
        tot_w = sum(w for _, w, _ in self.completed)
        return tot_s / tot_w if tot_w > 0 else None

    def rack_rates(self) -> List[Optional[float]]:
        """Per-rack observed seconds per work unit (None where no completion
        happened yet) — the cause-attribution signal for Mantri-style
        policies."""
        secs = [0.0] * self.P
        work = [0.0] * self.P
        for s, w, srv in self.completed:
            secs[self.rack_of(srv)] += s
            work[self.rack_of(srv)] += w
        return [secs[r] / work[r] if work[r] > 0 else None
                for r in range(self.P)]

    def live_attempts(self, task: MapTask) -> List[MapTaskAttempt]:
        return [a for a in task.attempts
                if a.state in ("queued", "fetching", "running")]

    def live_backup(self, task: MapTask) -> bool:
        return any(a.is_backup for a in self.live_attempts(task))

    def pick_backup_server(self, task: MapTask,
                           avoid_racks: Sequence[int] = ()
                           ) -> Optional[int]:
        """Least-loaded server for a backup of ``task``: prefers idle slots,
        then input-local servers (no fetch), then rack-local ones; never a
        server already attempting the task.  Deterministic tie-break by
        server id."""
        live = {a.server for a in self.live_attempts(task)}
        best: Optional[Tuple[Tuple[int, int, int], int]] = None
        store_racks = {self.rack_of(s) for s in task.stores}
        for s in range(self.K):
            if s in live or self.rack_of(s) in avoid_racks:
                continue
            locality = (0 if s in task.stores else
                        1 if self.rack_of(s) in store_racks else 2)
            key = (self.server_load(s), locality, s)
            if best is None or key < best[0]:
                best = (key, s)
        return None if best is None else best[1]

    # ---- engine ------------------------------------------------------------

    def start(self) -> None:
        # wave 0: the same single factors() draw the barrier path makes
        self.wave_factors.append(np.asarray(
            self.sim.stragglers.factors(self.sim.rng, self.K, self.P),
            dtype=float))
        for task in self.tasks:
            self._enqueue(task, task.server, wave=0, is_backup=False)
        self._launch_backups(self._validate(
            self.policy.on_phase_start(self)))
        for s in range(self.K):
            self._dispatch(s, steal=False)

    def _enqueue(self, task: MapTask, server: int, wave: int,
                 is_backup: bool) -> MapTaskAttempt:
        a = MapTaskAttempt(self._next_attempt, task, server, wave, is_backup)
        self._next_attempt += 1
        self._attempts[a.attempt_id] = a
        task.attempts.append(a)
        self.queues[server].append(a)
        return a

    def _validate(self, reqs: Sequence[Tuple[int, int]]
                  ) -> List[Tuple[int, int]]:
        out: List[Tuple[int, int]] = []
        claimed: Dict[int, Set[int]] = {}
        for t_idx, server in reqs:
            if self.backups_launched + len(out) >= self.backup_budget:
                break
            if not (0 <= t_idx < len(self.tasks) and 0 <= server < self.K):
                continue
            task = self.tasks[t_idx]
            live = {a.server for a in self.live_attempts(task)}
            live |= claimed.setdefault(t_idx, set())
            if task.done or server in live:
                continue
            claimed[t_idx].add(server)
            out.append((t_idx, server))
        return out

    def _launch_backups(self, reqs: List[Tuple[int, int]]) -> None:
        if not reqs:
            return
        # a fresh wave: re-sample straggler luck for the new launches
        self.wave += 1
        self.wave_factors.append(np.asarray(
            self.sim.stragglers.factors(self.sim.rng, self.K, self.P),
            dtype=float))
        for t_idx, server in reqs:
            self._enqueue(self.tasks[t_idx], server, self.wave,
                          is_backup=True)
            self.backups_launched += 1
            self.job.n_backups += 1
            self.sim._trace("backup_launch",
                            (self.job.job_id, t_idx, server, self.wave))
        for server in sorted({s for _, s in reqs}):
            self._dispatch(server, steal=False)

    def _dispatch(self, server: int, steal: bool = True) -> None:
        if self.done or self.running[server] is not None:
            return
        q = self.queues[server]
        while q:
            a = q.popleft()
            if a.state != "queued" or a.task.done:
                a.state = "cancelled"
                continue
            self.running[server] = a
            if server in a.task.stores:
                self._start_compute(a)
            else:
                a.state = "fetching"
                store_racks = {self.rack_of(s) for s in a.task.stores}
                res = (tor(self.rack_of(server))
                       if self.rack_of(server) in store_racks else ROOT)
                a.fetch_flow = self.sim.network.start_flow(
                    res, a.task.input_units,
                    (self.job.job_id, "spec_fetch", a.attempt_id))
            return
        if not steal or self.remaining <= 0:
            return
        reqs = self._validate(self.policy.on_server_idle(self, server))
        if reqs:
            self._launch_backups(reqs)
            return
        t = self.policy.next_check_time(self, server)
        if t is not None and t > self.sim.now:
            self._schedule_probe(server, t)

    def _schedule_probe(self, server: int, t: float) -> None:
        old = self._probes.get(server)
        if old is not None and not old.cancelled:
            if old.time <= t:
                return                      # an earlier probe already queued
            old.cancel()
        self._probes[server] = self.sim.queue.push(
            t, "spec_probe", (self.job.job_id, server),
            lambda: self._probe(server))

    def _probe(self, server: int) -> None:
        self._probes.pop(server, None)         # fired: allow rescheduling
        if self.done or self.running[server] is not None:
            return
        self._dispatch(server)

    def _start_compute(self, a: MapTaskAttempt) -> None:
        a.state = "running"
        a.start = self.sim.now
        coeffs = self.sim.cost_model.phase_coeffs("map")
        f = self.wave_factors[a.wave][a.server] * self.pl_factors[a.server]
        dur = float(f * coeffs.seconds(a.task.work))
        a.event = self.sim.queue.push(
            self.sim.now + dur, "task_done",
            (self.job.job_id, a.task.index, a.server, a.attempt_id),
            lambda: self._attempt_done(a))

    def fetch_done(self, attempt_id: int) -> None:
        a = self._attempts.get(attempt_id)
        if a is None or a.state != "fetching" or self.done:
            return
        a.fetch_flow = None
        lat = self.sim.topology.latency("fetch")
        if lat > 0:
            self.sim.queue.push(self.sim.now + lat, "spec_fetch_latency",
                                (self.job.job_id, attempt_id),
                                lambda: self._fetch_latency_done(a))
        else:
            self._start_compute(a)

    def _fetch_latency_done(self, a: MapTaskAttempt) -> None:
        if a.state == "fetching" and not self.done and not a.task.done:
            self._start_compute(a)

    def _cancel_attempt(self, a: MapTaskAttempt,
                        reason: str = "speculation") -> None:
        state = a.state
        a.state = "cancelled"
        if state == "fetching":
            if a.fetch_flow is not None:
                self.sim.network.cancel_flow(a.fetch_flow, reason=reason)
                a.fetch_flow = None
            if self.running[a.server] is a:
                self.running[a.server] = None
        elif state == "running":
            if a.event is not None:
                a.event.cancel()
            if self.running[a.server] is a:
                self.running[a.server] = None

    def _attempt_done(self, a: MapTaskAttempt) -> None:
        if a.state != "running" or a.task.done or self.done:
            return
        task = a.task
        task.done = True
        task.finish = self.sim.now
        a.state = "done"
        self.running[a.server] = None
        self.completed.append((self.sim.now - a.start, task.work, a.server))
        self.remaining -= 1
        if a.is_backup:
            self.job.n_backup_wins += 1
        # first finisher wins: kill the losing attempts, free their slots
        freed = []
        for other in task.attempts:
            if other is a or other.state in ("done", "cancelled"):
                continue
            was_busy = other.state in ("fetching", "running")
            self._cancel_attempt(other)
            if was_busy:
                freed.append(other.server)
        if self.remaining == 0:
            self._finish()
            return
        self._launch_backups(self._validate(
            self.policy.on_task_complete(self, task.index)))
        if not self.done:
            for server in sorted(set(freed) | {a.server}):
                self._dispatch(server)

    def crash(self, servers: Sequence[int]) -> None:
        """Apply a server crash to the live task-granular map phase: live
        attempts on the crashed servers are cancelled (fetch flows aborted,
        completion events voided, slots freed), completed tasks whose
        winning attempt ran there are re-queued (their in-memory outputs
        died with the server), and the crashed servers disappear from every
        task's input ``stores`` — a replacement attempt must re-fetch the
        input from surviving replicas (or the root when none survive in
        rack).  Re-queued tasks go back to their home server at the current
        wave; the task engine then re-executes them like any other work, so
        the map phase still ends with ALL outputs present (no degraded
        shuffle needed for crashes absorbed here)."""
        if self.done:
            return
        dead = {int(s) for s in servers}
        for a in list(self._attempts.values()):
            if a.server in dead and a.state in ("queued", "fetching",
                                                "running"):
                self._cancel_attempt(a, reason="crash")
        for task in self.tasks:
            if dead.intersection(task.stores):
                task.stores = tuple(s for s in task.stores if s not in dead)
            if task.done:
                win = next((a for a in task.attempts if a.state == "done"),
                           None)
                if win is not None and win.server in dead:
                    task.done = False
                    task.finish = -1.0
                    win.state = "cancelled"
                    self.remaining += 1
                    self.sim._trace("task_lost",
                                    (self.job.job_id, task.index, win.server))
        for task in self.tasks:
            if not task.done and not self.live_attempts(task):
                self._enqueue(task, task.server, wave=self.wave,
                              is_backup=False)
        for s in range(self.K):
            self._dispatch(s, steal=False)

    def _finish(self) -> None:
        self.done = True
        for a in self._attempts.values():
            if a.state in ("queued", "fetching", "running"):
                self._cancel_attempt(a)
        for q in self.queues:
            q.clear()
        for ev in self._probes.values():
            ev.cancel()
        self._probes.clear()
        self.job.map_waves = self.wave + 1
        self.sim._task_map_done(self.job)


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SimJob:
    job_id: int
    spec: JobSpec
    params: SchemeParams
    scheme: str
    stages: List[StageTraffic]
    compile_s: float
    submit_time: float
    # placement bridge (repro.placement.sim_bridge.PlacementTraffic, duck-
    # typed here to keep the sim importable without the placement package):
    # pre-map fetch loads + per-server map-work factors
    placement: Optional[object] = None
    # speculation policy (repro.resilience.speculation, duck-typed like the
    # placement bridge): non-None turns the map phase task-granular
    speculation: Optional[object] = None
    phase: str = "submitted"
    stage_idx: int = 0
    open_flows: int = 0
    phase_start: float = 0.0
    phase_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    tasks: Optional[TaskMapPhase] = None
    n_backups: int = 0
    n_backup_wins: int = 0
    map_waves: int = 1
    # crash/recovery state (see ClusterSim.inject_crash): servers whose
    # in-memory map outputs are currently lost, the failure set the active
    # recovery stages were compiled for, and the accounting counters
    failed: Tuple[int, ...] = ()
    recovered_for: Tuple[int, ...] = ()
    remap_subfiles: int = 0
    n_crashes: int = 0
    n_recoveries: int = 0
    # rack-level byte accounting: value-units of COMPLETED flows, by tier
    # (cancelled flows' partial progress is not counted — a crashed stage
    # re-runs in full under the degraded schedule)
    bytes_intra: float = 0.0
    bytes_cross: float = 0.0
    bytes_fetch: float = 0.0
    # blame bookkeeping (repro.obs.blame): zero-contention / straggler-free
    # ideal seconds of COMPLETED network stages and the map barrier, the
    # pending ideal of the stage currently in flight (committed at stage
    # completion, discarded when a crash voids the stage), the failure-free
    # shuffle ideals by tier, and crash-voided partial-phase seconds
    ideal_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    pending_ideal: float = 0.0
    ff_ideal: Dict[str, float] = dataclasses.field(default_factory=dict)
    wasted_s: float = 0.0


@dataclasses.dataclass
class JobStats:
    job_id: int
    name: str
    scheme: str
    r: int
    arrival: float
    submit: float
    finish: float
    phase_times: Dict[str, float]
    # speculative re-execution accounting (task-granular map phase only)
    speculation: Optional[str] = None   # policy name, None = barrier map
    n_backups: int = 0                  # backup attempts launched
    n_backup_wins: int = 0              # tasks won by a backup
    map_waves: int = 1                  # straggler waves sampled for map
    # crash-recovery accounting (ClusterSim.inject_crash)
    crashes: int = 0                    # crash events that hit live state
    remapped_subfiles: int = 0          # subfiles re-mapped (all r owners lost)
    recoveries: int = 0                 # degraded-recovery passes run
    # rack-level byte accounting in value-units (pairs x d) — completed
    # shuffle flows by tier, matching JobResult on the engine side (the
    # paper metric; see repro.obs.bytes), plus pre-map fetch traffic
    intra_rack_bytes: float = 0.0
    cross_rack_bytes: float = 0.0
    fetch_bytes: float = 0.0
    # JCT blame decomposition (repro.obs.blame.decompose): components sum
    # to jct exactly — the exactness law pinned by benchmarks/blame_bench;
    # the raw inputs ride along so repro.obs.blame.extract_blame can rebuild
    # the decomposition independently from the trace and cross-check it
    blame: Optional[Dict[str, float]] = None
    ideal_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    ff_shuffle_ideal: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    wasted_s: float = 0.0

    @property
    def jct(self) -> float:
        """Completion time from ARRIVAL (includes scheduler queueing)."""
        return self.finish - self.arrival


class ClusterSim:
    """Fluid discrete-event simulator of one server-rack cluster.

    ``submit`` may be called before ``run`` (a static batch) or from
    callbacks during the run (the online scheduler).  ``stages`` defaults to
    the closed-form stage traffic of the chosen scheme; pass enumerated
    ``plan_stage_traffic`` output (or loads derived from
    ``plan_transfer_matrices``) to simulate an explicit schedule.
    """

    def __init__(self, topology: RackTopology, K: int,
                 cost_model: CostModel = ZERO_COST,
                 stragglers: StragglerModel | None = None,
                 seed: int = 0,
                 speculation: object | None = None,
                 telemetry: bool = False) -> None:
        """``speculation`` is the cluster-wide default policy applied to
        every submission that does not pass its own (see ``submit``).

        ``telemetry=True`` attaches a :class:`repro.sim.network
        .NetworkTelemetry` observer (per-resource utilization series,
        per-flow lifecycle + rate history) sampled on the sim clock; it is
        purely observational — event order, traces, and stats are
        bit-identical with it on or off."""
        if K % topology.P != 0:
            raise ValueError(f"P={topology.P} must divide K={K}")
        self.topology = topology
        self.K = K
        self.cost_model = cost_model
        self.stragglers = stragglers or NoStragglers()
        self.speculation = speculation
        self.rng = np.random.default_rng(seed)
        self.telemetry: Optional[NetworkTelemetry] = (
            NetworkTelemetry(topology, clock=lambda: self.now)
            if telemetry else None)
        self.network = FluidNetwork(topology, telemetry=self.telemetry)
        self.queue = EventQueue()
        self.now = 0.0
        # structured trace: every event/span as a repro.obs TraceEvent,
        # stamped with the EXACT sim clock (rounding happens only in the
        # exporters — see repro.obs.tracing); the legacy tuple view lives
        # on as the `.trace` property
        self.tracer = Tracer(clock=lambda: self.now, enabled=True)
        self.stats: List[JobStats] = []
        self.on_job_done: Optional[Callable[[JobStats], None]] = None
        self._jobs: Dict[int, _SimJob] = {}
        self._next_job_id = 0

    # ---- public API --------------------------------------------------------

    def at(self, time: float, fn: Callable[[], None], kind: str = "callback",
           data: Tuple = ()) -> None:
        """Schedule an arbitrary callback (arrivals, scheduler wakeups)."""
        self.queue.push(max(time, self.now), kind, data, fn)

    def submit(self, spec: JobSpec, scheme: str, r: int,
               time: float | None = None,
               stages: List[StageTraffic] | None = None,
               compile_s: float = 0.0, check: bool = True,
               placement: object | None = None,
               speculation: object | None = None) -> int:
        """Enqueue a job start; returns its sim job id.

        ``placement`` is a :class:`repro.placement.sim_bridge
        .PlacementTraffic`: its non-local map inputs run as a ``fetch``
        network stage before the map phase (contending with concurrent
        shuffles), and its per-server factors skew the map barrier.

        ``speculation`` is a :mod:`repro.resilience.speculation` policy:
        non-None turns this job's map phase task-granular with speculative
        backup launches (defaults to the cluster-wide policy passed to
        ``ClusterSim``; pass the registry's ``none`` policy to force the
        task-granular engine without backups).
        """
        t = self.now if time is None else max(float(time), self.now)
        p = SchemeParams(K=self.K, P=self.topology.P, Q=spec.Q, N=spec.N, r=r)
        if stages is None:
            stages = scheme_stage_traffic(p, scheme, check=check)
        if placement is not None:
            nf = len(getattr(placement, "map_factors", ()))
            if nf != self.K:
                raise ValueError(
                    f"placement.map_factors must have K={self.K} entries, "
                    f"got {nf}")
            if len(placement.intra_units_per_rack) != self.topology.P:
                raise ValueError("placement.intra_units_per_rack must have "
                                 f"P={self.topology.P} entries")
        job = _SimJob(self._next_job_id, spec, p, scheme, stages,
                      float(compile_s), t, placement,
                      speculation if speculation is not None
                      else self.speculation)
        self._next_job_id += 1
        self._jobs[job.job_id] = job
        # failure-free zero-contention shuffle ideals by tier: the
        # shuffle_cross / shuffle_intra blame components (repro.obs.blame)
        d = float(spec.d)
        job.ff_ideal = {"cross": 0.0, "intra": 0.0}
        for st in stages:
            job.ff_ideal[st.stage] += self._stage_ideal(st, d)
        self.queue.push(t, "submit", (job.job_id,),
                        lambda j=job: self._start_job(j))
        return job.job_id

    def inject_crash(self, time: float, servers: Sequence[int]) -> None:
        """Schedule a crash of ``servers`` (flat ids) at sim time ``time``.

        Crash model (matches :mod:`repro.core.degraded` and the engine
        ladder): the servers lose their IN-MEMORY state — map outputs,
        running task attempts, in-flight shuffle bytes — and replacement
        workers rejoin at the same coordinates with empty memory.  Effects
        depend on the phase each live job is in when the crash fires:

          * before map starts (submitted / plan_compile / fetch): nothing
            in memory yet — no effect on that job;
          * task-granular map: live attempts on the crashed servers are
            cancelled (slots freed, fetch flows aborted), finished tasks
            whose winning attempt ran there are re-queued, and the crashed
            servers are stripped from input ``stores`` (a replacement must
            re-fetch);
          * barrier map / pack: the loss is recorded; the degraded recovery
            runs right after the pack barrier (the barrier abstraction has
            no per-server progress to cancel);
          * shuffle: every in-flight flow of the job is cancelled (no
            orphan flows remain — asserted in tests), pending stage events
            voided, and recovery begins immediately;
          * reduce: the phase is voided and recovery re-runs the (degraded)
            shuffle before reducing again.

        Recovery is priced through the same fluid network: a degraded
        unicast re-shuffle (exact loads from the degraded plan where
        compilable), preceded by a re-map phase when subfiles lost all r
        owners — r >= 2 schemes decode around f <= r-1 failures with ZERO
        re-mapped subfiles, r = 1 re-runs the dead servers' map partitions.
        Seeded schedules (:class:`repro.resilience.faults.FaultInjector`
        ``.inject_into(sim)``) keep traces bit-identical across reruns.
        """
        servers_t = tuple(sorted({int(s) for s in servers}))
        for s in servers_t:
            if not 0 <= s < self.K:
                raise ValueError(f"server id {s} out of range [0, {self.K})")
        self.at(time, lambda: self._crash(servers_t), "crash", (servers_t,))

    def run(self, until: float = float("inf")) -> List[JobStats]:
        """Advance until no work is left (or ``until``); returns all
        completed-job stats in completion order."""
        while True:
            # advance in DELTAS, not absolute times: at large t the next
            # flow-completion dt can be below the float resolution of
            # ``now + dt``, and an absolute-time loop would spin forever
            dt_flow = self.network.time_to_next_completion()
            t_event = self.queue.peek_time()
            dt_event = t_event - self.now
            if dt_flow == float("inf") and dt_event == float("inf"):
                break
            if min(self.now + dt_flow, t_event) > until:
                # truncated run: drain flows up to the horizon so a resumed
                # run() continues from consistent state; advance the clock
                # FIRST so completion callbacks stamp times at the horizon
                dt = until - self.now
                self.now = until
                for flow in self.network.advance(dt):
                    self._trace("flow_done", flow.tag)
                    self._flow_done(flow.tag, flow.size)
                break
            if dt_flow < dt_event:
                done = self.network.advance(dt_flow)
                self.now += dt_flow
            else:
                done = self.network.advance(max(dt_event, 0.0))
                self.now = t_event
            for flow in done:
                self._trace("flow_done", flow.tag)
                self._flow_done(flow.tag, flow.size)
            while self.queue and self.queue.peek_time() <= self.now:
                ev = self.queue.pop()
                self._trace(ev.kind, ev.data)
                if ev.fn is not None:
                    ev.fn()
        return self.stats

    @property
    def trace(self) -> List[TraceEntry]:
        """Legacy tuple view of the structured trace: ``(ts, kind, data)``
        for every INSTANT event, exact timestamps, event order preserved.
        Spans (``phase_span`` records with a duration) are excluded — they
        are stamped at their START time, which would break the monotone-time
        reading of the flat event log.  Use ``self.tracer.events`` for the
        full structured stream and the ``repro.obs.tracing`` exporters for
        rendering."""
        return [(e.ts, e.kind, e.data) for e in self.tracer.events
                if e.dur is None]

    # ---- internals ---------------------------------------------------------

    def _trace(self, kind: str, data: Tuple,
               phase: Optional[str] = None) -> None:
        data = tuple(data)
        job_id = (int(data[0]) if data
                  and isinstance(data[0], (int, np.integer)) else None)
        self.tracer.event(kind, job_id=job_id, phase=phase, data=data)

    def _stage_ideal(self, stage: StageTraffic, d: float) -> float:
        """Zero-contention drain time of one shuffle stage: the slower of
        the root drain and the bottleneck ToR drain, plus the stage latency
        floor (0.0 for an empty stage, which completes instantly)."""
        t = -1.0
        if stage.cross_pairs > 0:
            t = stage.cross_pairs * d / self.topology.capacity(ROOT)
        for rack, load in enumerate(stage.intra_pairs_per_rack):
            if load > 0:
                t = max(t, load * d / self.topology.capacity(tor(rack)))
        if t < 0:
            return 0.0
        return t + self.topology.latency(stage.stage)

    def _fetch_ideal(self, pl: object) -> float:
        """Zero-contention drain time of the pre-map fetch stage."""
        t = -1.0
        if pl.cross_units > 0:
            t = pl.cross_units / self.topology.capacity(ROOT)
        for rack, load in enumerate(pl.intra_units_per_rack):
            if load > 0:
                t = max(t, load / self.topology.capacity(tor(rack)))
        if t < 0:
            return 0.0
        return t + self.topology.latency("fetch")

    def _trace_phase_span(self, job: "_SimJob", phase: str) -> None:
        """Record the job phase that just ENDED as a span from its recorded
        start to now (the Perfetto lane structure of a sim run)."""
        self.tracer.span_at(job.phase_start, self.now, kind="phase_span",
                            job_id=job.job_id, phase=phase,
                            scheme=job.scheme, r=job.params.r)

    def _start_job(self, job: _SimJob) -> None:
        if job.compile_s > 0:
            job.phase = "plan_compile"
            job.phase_start = self.now
            self.queue.push(self.now + job.compile_s, "phase_done",
                            (job.job_id, "plan_compile"),
                            lambda: self._phase_done(job, "plan_compile"))
        else:
            self._begin_fetch(job)

    def _begin_fetch(self, job: _SimJob) -> None:
        """Pre-map input-fetch stage: the non-local map inputs of a bridged
        placement move over the network BEFORE map can start (they contend
        with concurrent jobs' shuffles like any flow).  Placement-less jobs
        (and fully node-local placements) skip straight to map."""
        pl = job.placement
        job.open_flows = 0
        if pl is not None:
            if pl.cross_units > 0:
                self.network.start_flow(ROOT, pl.cross_units,
                                        (job.job_id, "fetch_cross"))
                job.open_flows += 1
            for rack, load in enumerate(pl.intra_units_per_rack):
                if load > 0:
                    self.network.start_flow(tor(rack), load,
                                            (job.job_id, "fetch_intra", rack))
                    job.open_flows += 1
        if job.open_flows == 0:
            self._begin_compute(job, "map")
        else:
            job.phase = "fetch"
            job.phase_start = self.now
            job.pending_ideal = self._fetch_ideal(pl)

    def _begin_compute(self, job: _SimJob, phase: str) -> None:
        if phase == "map" and job.speculation is not None:
            self._begin_task_map(job)
            return
        job.phase = phase
        job.phase_start = self.now
        coeffs = self.cost_model.phase_coeffs(phase)
        work = phase_work(job.params, job.scheme, job.spec.d)[phase]
        factors = self.stragglers.factors(self.rng, self.K, self.topology.P)
        base = np.ones(self.K)
        if phase == "map" and job.placement is not None:
            # locality imbalance compounds with stragglers per server; the
            # barrier still ends at the slowest server
            base = np.asarray(job.placement.map_factors)
            factors = factors * base
        dur = float(np.max(factors) * coeffs.seconds(work))
        if phase == "map":
            # straggler-free barrier ideal (locality imbalance included):
            # the map / map_straggle blame split (repro.obs.blame)
            job.ideal_times["map"] = float(np.max(base)
                                           * coeffs.seconds(work))
        self.queue.push(self.now + dur, "phase_done", (job.job_id, phase),
                        lambda: self._phase_done(job, phase))

    def _begin_shuffle_stage(self, job: _SimJob) -> None:
        stage = job.stages[job.stage_idx]
        job.phase = f"shuffle:{stage.stage}"
        job.phase_start = self.now
        d = job.spec.d
        job.open_flows = 0
        job.pending_ideal = self._stage_ideal(stage, float(d))
        if stage.cross_pairs > 0:
            self.network.start_flow(ROOT, stage.cross_pairs * d,
                                    (job.job_id, "cross"))
            job.open_flows += 1
        for rack, load in enumerate(stage.intra_pairs_per_rack):
            if load > 0:
                self.network.start_flow(tor(rack), load * d,
                                        (job.job_id, "intra", rack))
                job.open_flows += 1
        if job.open_flows == 0:                    # empty stage (e.g. r = K)
            self._stage_done(job)

    def _begin_task_map(self, job: _SimJob) -> None:
        """Task-granular map phase: per-subfile task events with speculative
        backups (see :class:`TaskMapPhase`)."""
        job.phase = "map"
        job.phase_start = self.now
        job.tasks = TaskMapPhase(self, job, job.speculation)
        # straggler-free serial ideal: each home server runs its own tasks
        # back to back at factor pl_factor[s] with no fetches (the home
        # server always stores its inputs) — map_straggle = actual - this,
        # and can go NEGATIVE when speculative backups steal work and beat
        # the home server's serial bound (documented in repro.obs.blame)
        coeffs = self.cost_model.phase_coeffs("map")
        per_server = [0.0] * self.K
        for task in job.tasks.tasks:
            per_server[task.server] += coeffs.seconds(task.work)
        job.ideal_times["map"] = max(
            (float(job.tasks.pl_factors[s]) * per_server[s]
             for s in range(self.K)), default=0.0)
        job.tasks.start()

    def _task_map_done(self, job: _SimJob) -> None:
        job.tasks = None
        self._phase_done(job, "map")

    def _crash(self, servers: Tuple[int, ...]) -> None:
        for job_id in sorted(self._jobs):
            job = self._jobs[job_id]
            if job.phase != "done":
                self._crash_job(job, servers)

    def _crash_job(self, job: _SimJob, servers: Tuple[int, ...]) -> None:
        ph = job.phase
        if ph in ("submitted", "plan_compile", "fetch"):
            return                   # no map output in memory yet
        job.n_crashes += 1
        obs_metrics.counter(
            "sim_crashes_total",
            "crash events that hit a job's live state").inc(
                scheme=job.scheme, phase=ph.split(":")[0])
        if ph == "map" and job.tasks is not None:
            # task-granular map re-executes the lost work itself; its
            # outputs end up fully recovered, so no degraded shuffle
            job.tasks.crash(servers)
            return
        job.failed = tuple(sorted(set(job.failed) | set(servers)))
        if ph in ("map", "pack", "remap"):
            return      # loss recorded; recovery (re)starts after the barrier
        is_shuffle = ph.startswith("shuffle:")
        if is_shuffle:
            n = self.network.cancel_flows(
                lambda tag: tag[0] == job.job_id, reason="crash")
            job.open_flows = 0
            self._trace("flows_cancelled", (job.job_id, n))
        # void the job's pending completions (stage latency / phase barrier)
        self.queue.cancel_where(
            lambda ev: ev.kind in ("stage_latency", "phase_done")
            and bool(ev.data) and ev.data[0] == job.job_id)
        if is_shuffle or ph == "reduce":
            # the voided phase's elapsed time is pure crash waste: it never
            # reaches phase_times, so the exactness law needs it here
            job.wasted_s += self.now - job.phase_start
            job.pending_ideal = 0.0
            self._begin_recovery(job)

    def _begin_recovery(self, job: _SimJob) -> None:
        """Replace the job's remaining shuffle schedule with the degraded
        one (exact loads from the degraded plan where the instance is
        compilable) and run the re-map phase first if subfiles lost all
        their owners."""
        job.n_recoveries += 1
        stages, n_remap = degraded_stage_traffic(job.params, job.scheme,
                                                 job.failed)
        job.stages = list(stages)
        job.stage_idx = 0
        job.recovered_for = job.failed
        job.remap_subfiles += n_remap
        obs_metrics.counter(
            "sim_recoveries_total",
            "degraded-recovery passes run").inc(scheme=job.scheme)
        if n_remap:
            obs_metrics.counter(
                "sim_remapped_subfiles_total",
                "subfiles re-mapped after losing all r owners").inc(
                    n_remap, scheme=job.scheme)
        self._trace("recovery", (job.job_id, job.failed, n_remap))
        if n_remap > 0:
            self._begin_remap(job, n_remap)
        elif job.stages:
            self._begin_shuffle_stage(job)
        else:
            self._begin_compute(job, "reduce")

    def _begin_remap(self, job: _SimJob, n_remap: int) -> None:
        """Re-map the orphaned subfiles, spread across the survivors;
        barrier at the slowest surviving server (fresh straggler draw)."""
        job.phase = "remap"
        job.phase_start = self.now
        coeffs = self.cost_model.phase_coeffs("map")
        work = float(n_remap) * job.spec.Q * job.spec.d
        factors = self.stragglers.factors(self.rng, self.K, self.topology.P)
        dead = set(job.failed)
        alive = [s for s in range(self.K) if s not in dead]
        n_alive = max(len(alive), 1)
        f = max((float(factors[s]) for s in alive), default=1.0)
        dur = f * coeffs.seconds(work / n_alive)
        self.queue.push(self.now + dur, "phase_done", (job.job_id, "remap"),
                        lambda: self._phase_done(job, "remap"))

    def _flow_done(self, tag: Tuple, units: float = 0.0) -> None:
        job = self._jobs[tag[0]]
        kind = tag[1] if len(tag) > 1 else ""
        # rack-level byte accounting: completed value-units by tier
        if kind == "cross":
            job.bytes_cross += units
        elif kind == "intra":
            job.bytes_intra += units
        elif kind in ("fetch_cross", "fetch_intra", "spec_fetch"):
            job.bytes_fetch += units
        if kind == "spec_fetch":
            if job.tasks is not None:
                job.tasks.fetch_done(tag[2])
            return
        job.open_flows -= 1
        if job.open_flows == 0:
            if job.phase == "fetch":
                latency = self.topology.latency("fetch")
                done = lambda: self._fetch_done(job)      # noqa: E731
            else:
                latency = self.topology.latency(
                    job.stages[job.stage_idx].stage)
                done = lambda: self._stage_done(job)      # noqa: E731
            if latency > 0:
                self.queue.push(self.now + latency, "stage_latency",
                                (job.job_id,), done)
            else:
                done()

    def _fetch_done(self, job: _SimJob) -> None:
        job.phase_times["fetch"] = self.now - job.phase_start
        job.ideal_times["fetch"] = (job.ideal_times.get("fetch", 0.0)
                                    + job.pending_ideal)
        job.pending_ideal = 0.0
        self._trace_phase_span(job, "fetch")
        self._begin_compute(job, "map")

    def _stage_done(self, job: _SimJob) -> None:
        key = f"shuffle:{job.stages[job.stage_idx].stage}"
        # accumulate (not assign): recovery re-runs stages after a crash
        job.phase_times[key] = (job.phase_times.get(key, 0.0)
                                + self.now - job.phase_start)
        # commit the as-run zero-contention ideal of the COMPLETED stage
        # run (crash-voided runs discard theirs into wasted_s instead)
        job.ideal_times[key] = (job.ideal_times.get(key, 0.0)
                                + job.pending_ideal)
        job.pending_ideal = 0.0
        self._trace_phase_span(job, key)
        job.stage_idx += 1
        if job.stage_idx < len(job.stages):
            self._begin_shuffle_stage(job)
        else:
            self._begin_compute(job, "reduce")

    def _phase_done(self, job: _SimJob, phase: str) -> None:
        job.phase_times[phase] = (job.phase_times.get(phase, 0.0)
                                  + self.now - job.phase_start)
        self._trace_phase_span(job, phase)
        if phase == "plan_compile":
            self._begin_fetch(job)
        elif phase == "map":
            self._begin_compute(job, "pack")
        elif phase == "pack":
            job.stage_idx = 0
            if job.failed != job.recovered_for:
                # a crash landed during the map/pack barriers: shuffle (and
                # possibly re-map) under the degraded schedule instead
                self._begin_recovery(job)
            elif job.stages:
                self._begin_shuffle_stage(job)
            else:
                self._begin_compute(job, "reduce")
        elif phase == "remap":
            if job.failed != job.recovered_for:
                self._begin_recovery(job)      # cascading crash during remap
            elif job.stages:
                self._begin_shuffle_stage(job)
            else:
                self._begin_compute(job, "reduce")
        elif phase == "reduce":
            job.phase = "done"
            # blame decomposition in canonical component order (exactness
            # law: components sum to jct — see repro.obs.blame)
            blame = obs_blame.decompose(
                jct=self.now - job.spec.arrival,
                queueing=job.submit_time - job.spec.arrival,
                phase_times=job.phase_times,
                ideal_times=job.ideal_times,
                ff_shuffle_ideal=job.ff_ideal,
                wasted_s=job.wasted_s)
            stats = JobStats(job.job_id, job.spec.name, job.scheme,
                             job.params.r, job.spec.arrival, job.submit_time,
                             self.now, dict(job.phase_times),
                             speculation=(getattr(job.speculation, "name",
                                                  "custom")
                                          if job.speculation is not None
                                          else None),
                             n_backups=job.n_backups,
                             n_backup_wins=job.n_backup_wins,
                             map_waves=job.map_waves,
                             crashes=job.n_crashes,
                             remapped_subfiles=job.remap_subfiles,
                             recoveries=job.n_recoveries,
                             intra_rack_bytes=job.bytes_intra,
                             cross_rack_bytes=job.bytes_cross,
                             fetch_bytes=job.bytes_fetch,
                             blame=blame,
                             ideal_times=dict(job.ideal_times),
                             ff_shuffle_ideal=dict(job.ff_ideal),
                             wasted_s=job.wasted_s)
            self.stats.append(stats)
            tot = obs_metrics.counter(
                "shuffle_bytes_total", "shuffle value-units moved, by tier")
            fam = {"hybrid": "binomial",
                   "hybrid_resolvable": "resolvable"}.get(job.scheme, "")
            tot.inc(job.bytes_intra, tier="intra", scheme=job.scheme,
                    family=fam, layer="sim")
            tot.inc(job.bytes_cross, tier="cross", scheme=job.scheme,
                    family=fam, layer="sim")
            # cache gauges stay current in snapshots without a manual pull
            obs_metrics.refresh_cache_metrics()
            self._trace("job_done", (job.job_id, job.scheme, job.params.r))
            if self.on_job_done is not None:
                self.on_job_done(stats)


def simulate_single_job(spec: JobSpec, topology: RackTopology, K: int,
                        scheme: str, r: int,
                        cost_model: CostModel = ZERO_COST,
                        stragglers: StragglerModel | None = None,
                        seed: int = 0, check: bool = True,
                        speculation: object | None = None) -> JobStats:
    """One job, empty cluster — the zero-contention special case whose JCT
    must equal ``CommCost.weighted_time`` when compute costs are zero.
    ``speculation`` switches the map phase to the task-granular speculative
    engine (see :class:`TaskMapPhase`)."""
    sim = ClusterSim(topology, K, cost_model, stragglers, seed)
    sim.submit(spec, scheme, r, time=spec.arrival, check=check,
               speculation=speculation)
    (stats,) = sim.run()
    return stats
