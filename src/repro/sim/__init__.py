"""repro.sim — rack-level cluster simulator + multi-job scheduler.

Answers the question the closed forms cannot: what is job completion TIME
under link contention, stragglers, skewed bandwidth, crashes, or a stream
of concurrent jobs?  See docs/simulator.md for the event model, calibration
recipe, scheduler policies and scenario catalog, and docs/faults.md for
seeded crash injection (:meth:`ClusterSim.inject_crash` /
:class:`repro.resilience.FaultInjector`) and recovery pricing.
"""
from .calibration import (ConformanceModel, calibrate_with_residuals,
                          conformance_report, fit_conformance,
                          load_cost_model, load_default_cost_model,
                          measurement_row_from_stats, save_cost_model)
from .cluster import (ClusterSim, CostModel, DeterministicSlowdown,
                      ExponentialTail, JobStats, MapTask, MapTaskAttempt,
                      NoStragglers, PhaseCoeffs, RackCorrelated,
                      StragglerModel, TaskMapPhase, calibrate,
                      measurements_from_pipeline_bench, phase_work,
                      simulate_single_job)
from .network import (ROOT, FlowRecord, FluidNetwork, NetworkTelemetry,
                      RackTopology, resource_key, tor)
from .scheduler import (Decision, MultiJobScheduler, POLICIES, SchemeChooser,
                        run_scheduled)
from .workload import (BurstyWorkload, DiurnalWorkload, JOB_ZOO, JobSpec,
                       PoissonWorkload, Workload, default_catalog,
                       valid_subfile_counts)

__all__ = [
    "ConformanceModel", "calibrate_with_residuals", "conformance_report",
    "fit_conformance", "load_cost_model", "load_default_cost_model",
    "measurement_row_from_stats", "save_cost_model",
    "ClusterSim", "CostModel", "DeterministicSlowdown", "ExponentialTail",
    "JobStats", "MapTask", "MapTaskAttempt", "NoStragglers", "PhaseCoeffs",
    "RackCorrelated", "StragglerModel", "TaskMapPhase", "calibrate",
    "measurements_from_pipeline_bench", "phase_work", "simulate_single_job",
    "ROOT", "FlowRecord", "FluidNetwork", "NetworkTelemetry",
    "RackTopology", "resource_key", "tor",
    "Decision", "MultiJobScheduler", "POLICIES", "SchemeChooser",
    "run_scheduled",
    "BurstyWorkload", "DiurnalWorkload", "JOB_ZOO", "JobSpec",
    "PoissonWorkload", "Workload", "default_catalog", "valid_subfile_counts",
]
