"""Online multi-job scheduler over the cluster simulator.

Two separable decisions, both made ONLINE as jobs arrive:

  * **scheme choice** (:class:`SchemeChooser`): for each admitted job, pick
    (scheme, r) ∈ {uncoded} ∪ {coded, hybrid} x rs minimizing the job's
    estimated completion time under the CURRENT cluster load — estimated
    with the same cost model and stage-traffic closed forms the simulator
    itself uses, plus the observed backlog on the root/ToR switches and a
    plan-compile charge when the hybrid plan is not in the REAL LRU plan
    cache (:func:`repro.core.coded_collectives.plan_cache_info`);
  * **admission order** (:class:`MultiJobScheduler`): at most
    ``max_concurrent`` jobs share the network at once; the queue drains in
    FIFO, SRPT (shortest estimated completion first) or FAIR
    (least-attained-service per job kind) order.

A fixed-scheme chooser (``adaptive=False``) is the baseline the benchmarks
compare against: same workload, same admission policy, every job forced to
one (scheme, r).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.coded_collectives import compile_hybrid_plan, plan_cache_info
from ..core.params import SchemeParams
from ..core.plan_registry import family_of_scheme
from ..core.shuffle_plan import scheme_stage_traffic
from ..obs import blame as obs_blame
from ..obs import metrics as obs_metrics
from ..obs.drift import (DriftMonitor, record_blame,
                         record_component_errors)
from .cluster import ClusterSim, CostModel, JobStats, calibrate, phase_work
from .network import ROOT, tor
from .workload import JobSpec

POLICIES = ("fifo", "srpt", "fair")


@dataclasses.dataclass(frozen=True)
class Decision:
    scheme: str
    r: int
    est_jct: float
    compile_s: float            # plan-compile charge (0 on cache hit)
    cache_hit: bool
    # placement bridge of this admission (None unless the chooser runs a
    # placement solver and the job went hybrid): fetch traffic + map factors
    # handed to ClusterSim.submit, plus its achieved localities
    placement: Optional[object] = None
    # speculation policy handed to ClusterSim.submit (None = barrier map)
    speculation: Optional[object] = None
    # component-wise view of est_jct (repro.obs.blame COMPONENTS keys),
    # priced by SchemeChooser.estimate_components for the WINNING candidate;
    # reconciled per-component against the job's actual blame at completion
    est_components: Optional[Dict[str, float]] = None


class SchemeChooser:
    """Greedy myopic (scheme, r) choice by minimum estimated JCT.

    The estimate mirrors the simulator's own model: per-phase affine compute
    costs (optionally inflated by ``expected_straggler`` — e.g. 1 + scale
    for an exponential tail, a quantity operators calibrate from history),
    sequential shuffle stages where each stage drains behind the resource's
    current backlog, and a plan-compile charge on hybrid plan-cache misses.
    It deliberately ignores FUTURE arrivals (online setting).
    """

    def __init__(self, K: int, cost_model: CostModel = CostModel(),
                 rs: Sequence[int] = (1, 2, 3),
                 schemes: Sequence[str] = ("uncoded", "coded", "hybrid",
                                           "hybrid_resolvable"),
                 adaptive: bool = True,
                 fixed: Tuple[str, int] = ("coded", 2),
                 expected_straggler: float = 1.0,
                 compile_real_plans: bool = True,
                 placement_solver: Optional[str] = None,
                 placement_r_f: int = 3,
                 placement_policy: str = "uniform",
                 placement_lam: float = 0.8,
                 placement_remote_penalty: float = 0.5,
                 placement_seed: int = 0,
                 speculation: Optional[object] = None,
                 r_policy: Optional[object] = None,
                 crash_prob: float = 0.0) -> None:
        """``placement_solver`` turns on locality-aware placement for every
        hybrid admission: a registered :mod:`repro.placement` solver name
        ('random', 'greedy', 'flow', 'local_search', 'anneal_jax').  Each
        admitted hybrid job draws a random replica placement under
        ``placement_policy`` ('uniform' — the paper's model — or 'hdfs',
        Hadoop's rack-spread rule) with ``placement_r_f`` replicas,
        deterministic in ``placement_seed`` and the admission sequence,
        then solves the Section-IV assignment; the resulting fetch traffic
        + map-phase imbalance ride into the sim via
        :class:`Decision.placement` — and since the estimate prices that
        fetch traffic per candidate, a placement-heavy hybrid can LOSE an
        admission it would have won blind.  ``None`` (default) keeps the
        legacy locality-blind behavior.

        ``speculation`` (a :mod:`repro.resilience.speculation` policy)
        rides into every admission's ``ClusterSim.submit`` — the map phase
        turns task-granular with speculative backups.

        ``r_policy`` (e.g. :class:`repro.resilience.replication
        .HedgedRPolicy`) makes the chooser straggler-aware: candidate
        compute phases are inflated by ``r_policy.compute_inflation(scheme,
        r)`` instead of the static ``expected_straggler`` guess, and hybrid
        admissions take ``r_policy.placement_for(p)`` — a deterministic
        rack-hedged structured placement — over the random draw.  The
        :class:`MultiJobScheduler` feeds every completion back via
        ``r_policy.observe`` so the fit tracks the live cluster.

        ``crash_prob`` is the availability term: the operator's estimate of
        the probability that one server crashes during the job.  Each
        candidate is charged ``crash_prob`` times its expected recovery
        cost — the degraded re-shuffle draining behind the current
        backlogs, plus the re-map of orphaned subfiles where the candidate
        cannot decode around a single failure (r = 1 / uncoded re-run the
        dead server's whole map partition; r >= 2 re-map NOTHING for
        f <= r-1) — so replication r is priced as a failure-tolerance knob,
        not only a communication one.  0.0 (default) keeps the chooser
        availability-blind."""
        self.K = K
        self.cost_model = cost_model
        self.rs = tuple(rs)
        self.schemes = tuple(schemes)
        self.adaptive = adaptive
        self.fixed = fixed
        self.expected_straggler = float(expected_straggler)
        self.compile_real_plans = compile_real_plans
        self.placement_solver = placement_solver
        self.placement_r_f = int(placement_r_f)
        self.placement_policy = placement_policy
        self.placement_lam = float(placement_lam)
        self.placement_remote_penalty = float(placement_remote_penalty)
        self.placement_seed = int(placement_seed)
        self.speculation = speculation
        self.r_policy = r_policy
        self.crash_prob = float(crash_prob)
        self._placement_seq = 0
        self._admission_replicas: Optional[np.ndarray] = None

    def candidates(self) -> List[Tuple[str, int]]:
        """(scheme, r) grid: hybrid admits r = 1 (degenerates to uncoded
        layers); coded and hybrid_resolvable need r >= 2.  The chooser now
        prices binomial vs resolvable hybrids per admission — inadmissible
        combinations are dropped by :meth:`estimate` returning None."""
        out: List[Tuple[str, int]] = []
        if "uncoded" in self.schemes:
            out.append(("uncoded", 1))
        for scheme in ("coded", "hybrid", "hybrid_resolvable"):
            if scheme in self.schemes:
                out.extend((scheme, r) for r in self.rs if r >= 2 or
                           scheme == "hybrid")
        return out

    def _phase_inflation(self, scheme: str, r: int) -> float:
        """Per-candidate expected straggler inflation of compute phases:
        the fitted barrier factor when an ``r_policy`` is attached (so
        map-heavy high-r candidates pay their true exposure), else the
        static ``expected_straggler`` guess."""
        if self.r_policy is not None:
            return float(self.r_policy.compute_inflation(scheme, r))
        return self.expected_straggler

    def estimate(self, spec: JobSpec, scheme: str, r: int,
                 cluster: ClusterSim,
                 placement: Optional[object] = None) -> Optional[float]:
        """Estimated completion seconds for one candidate; None if the
        scheme's divisibility hypotheses reject (N, Q, r).

        ``placement`` (a ``PlacementTraffic``) makes the estimate
        FETCH-AWARE: the pre-map fetch drains behind the current root/ToR
        backlogs and the map phase is skewed by the placement's worst
        map-work factor — pricing a placement BEFORE choosing, not after.
        """
        try:
            p = SchemeParams(K=self.K, P=cluster.topology.P,
                             Q=spec.Q, N=spec.N, r=r)
            stages = scheme_stage_traffic(p, scheme, check=True)
        except ValueError:
            return None
        est = self._compile_charge(p, scheme, probe=False)[0]
        topo = cluster.topology
        if placement is not None and placement.total_units > 0:
            times = [0.0]
            if placement.cross_units > 0:
                load = placement.cross_units + cluster.network.backlog(ROOT)
                times.append(load / topo.capacity(ROOT))
            for rack, units in enumerate(placement.intra_units_per_rack):
                if units > 0:
                    load = units + cluster.network.backlog(tor(rack))
                    times.append(load / topo.capacity(tor(rack)))
            est += max(times) + topo.latency("fetch")
        map_skew = (max(placement.map_factors)
                    if placement is not None else 1.0)
        infl = self._phase_inflation(scheme, r)
        work = phase_work(p, scheme, spec.d)
        for phase in ("map", "pack", "reduce"):
            secs = self.cost_model.phase_coeffs(phase).seconds(work[phase])
            if phase == "map":
                secs *= map_skew
            est += infl * secs
        for stage in stages:
            times = [0.0]
            if stage.cross_pairs > 0:
                load = stage.cross_pairs * spec.d + cluster.network.backlog(ROOT)
                times.append(load / topo.capacity(ROOT))
            for rack, pairs in enumerate(stage.intra_pairs_per_rack):
                if pairs > 0:
                    load = pairs * spec.d + cluster.network.backlog(tor(rack))
                    times.append(load / topo.capacity(tor(rack)))
            est += max(times) + topo.latency(stage.stage)
        if self.crash_prob > 0.0:
            est += self.crash_prob * self._recovery_charge(p, scheme, spec,
                                                           cluster)
        return est

    def estimate_components(self, spec: JobSpec, scheme: str, r: int,
                            cluster: ClusterSim,
                            placement: Optional[object] = None
                            ) -> Optional[Dict[str, float]]:
        """Component-wise view of :meth:`estimate`, keyed like
        :data:`repro.obs.blame.COMPONENTS`: the same pieces the estimate
        sums, attributed the same way the simulator attributes the actuals
        — zero-contention stage ideals under ``fetch`` / ``shuffle_*``,
        backlog-induced excess under ``contention``, straggler inflation of
        the map barrier under ``map_straggle``, and the availability charge
        under ``recovery``.  Components sum to :meth:`estimate` up to float
        round-off (``estimate`` itself is untouched — admission decisions
        are bit-identical with or without this view).  ``queueing`` is 0:
        the estimate is priced AT admission and predicts finish - submit.
        """
        try:
            p = SchemeParams(K=self.K, P=cluster.topology.P,
                             Q=spec.Q, N=spec.N, r=r)
            stages = scheme_stage_traffic(p, scheme, check=True)
        except ValueError:
            return None
        comps = {k: 0.0 for k in obs_blame.COMPONENTS}
        comps["plan_compile"] = self._compile_charge(p, scheme,
                                                     probe=False)[0]
        topo = cluster.topology
        if placement is not None and placement.total_units > 0:
            ideal = [0.0]
            loaded = [0.0]
            if placement.cross_units > 0:
                cap = topo.capacity(ROOT)
                ideal.append(placement.cross_units / cap)
                loaded.append((placement.cross_units
                               + cluster.network.backlog(ROOT)) / cap)
            for rack, units in enumerate(placement.intra_units_per_rack):
                if units > 0:
                    cap = topo.capacity(tor(rack))
                    ideal.append(units / cap)
                    loaded.append((units
                                   + cluster.network.backlog(tor(rack)))
                                  / cap)
            comps["fetch"] = max(ideal) + topo.latency("fetch")
            comps["contention"] += max(loaded) - max(ideal)
        map_skew = (max(placement.map_factors)
                    if placement is not None else 1.0)
        infl = self._phase_inflation(scheme, r)
        work = phase_work(p, scheme, spec.d)
        for phase in ("map", "pack", "reduce"):
            secs = self.cost_model.phase_coeffs(phase).seconds(work[phase])
            if phase == "map":
                comps["map"] = secs * map_skew
                comps["map_straggle"] = (infl - 1.0) * secs * map_skew
            else:
                comps[phase] = infl * secs
        for stage in stages:
            ideal = [0.0]
            loaded = [0.0]
            if stage.cross_pairs > 0:
                cap = topo.capacity(ROOT)
                ideal.append(stage.cross_pairs * spec.d / cap)
                loaded.append((stage.cross_pairs * spec.d
                               + cluster.network.backlog(ROOT)) / cap)
            for rack, pairs in enumerate(stage.intra_pairs_per_rack):
                if pairs > 0:
                    cap = topo.capacity(tor(rack))
                    ideal.append(pairs * spec.d / cap)
                    loaded.append((pairs * spec.d
                                   + cluster.network.backlog(tor(rack)))
                                  / cap)
            comps[f"shuffle_{stage.stage}"] += (max(ideal)
                                                + topo.latency(stage.stage))
            comps["contention"] += max(loaded) - max(ideal)
        if self.crash_prob > 0.0:
            comps["recovery"] = self.crash_prob * self._recovery_charge(
                p, scheme, spec, cluster)
        return comps

    def _recovery_charge(self, p: SchemeParams, scheme: str, spec: JobSpec,
                         cluster: ClusterSim) -> float:
        """Expected seconds to recover from ONE server crash mid-shuffle
        (the availability term): the candidate's degraded re-shuffle
        draining behind the current backlogs, plus — where a single failure
        orphans subfiles (r = 1 / uncoded) — a conservative serial re-map
        of the dead server's partition.  r >= 2 candidates re-map nothing,
        so a rising ``crash_prob`` shifts choices toward replication."""
        from ..core.degraded import degraded_stage_traffic
        topo = cluster.topology
        stages, n_remap = degraded_stage_traffic(p, scheme, (0,))
        t = 0.0
        if n_remap:
            t += self._phase_inflation(scheme, p.r) * \
                self.cost_model.map.seconds(float(n_remap) * spec.Q * spec.d)
        for stage in stages:
            times = [0.0]
            if stage.cross_pairs > 0:
                load = (stage.cross_pairs * spec.d
                        + cluster.network.backlog(ROOT))
                times.append(load / topo.capacity(ROOT))
            for rack, pairs in enumerate(stage.intra_pairs_per_rack):
                if pairs > 0:
                    load = pairs * spec.d + cluster.network.backlog(tor(rack))
                    times.append(load / topo.capacity(tor(rack)))
            t += max(times) + topo.latency(stage.stage)
        return t

    def _compile_charge(self, p: SchemeParams, scheme: str,
                        probe: bool) -> Tuple[float, bool]:
        """(compile seconds, cache_hit).  With ``probe``, actually compiles
        the scheme family's plan through the LRU cache and reads the
        PER-FAMILY hit/miss delta from :func:`plan_cache_info` — the cache
        keys on (params, perm, family), so probing a binomial candidate
        never counterfeits a hit for its resolvable sibling."""
        family = family_of_scheme(scheme)
        if family is None or not self.compile_real_plans:
            return 0.0, True
        if probe:
            before = plan_cache_info().families.get(family)
            try:
                compile_hybrid_plan(p, family=family)
                now = plan_cache_info().families[family]
                hit = now.hits > (before.hits if before else 0)
            except ValueError:
                # closed-form-admissible but not executable (r | M fails):
                # nothing cacheable — charge a fresh compile every time
                hit = False
        else:
            hit = False                      # pessimistic while estimating
        if hit:
            return 0.0, True
        return self.cost_model.plan_compile.seconds(p.N), False

    def choose(self, spec: JobSpec, cluster: ClusterSim) -> Decision:
        self._placement_seq += 1          # one replica draw per admission
        self._admission_replicas = None
        if self.adaptive:
            best: Optional[Tuple[float, str, int, Optional[object]]] = None
            for scheme, r in self.candidates():
                est = self.estimate(spec, scheme, r, cluster)
                if est is None:
                    continue                       # inadmissible candidate
                tr = self._candidate_placement(spec, scheme, r, cluster)
                if tr is not None:                 # price the fetch traffic
                    est = self.estimate(spec, scheme, r, cluster,
                                        placement=tr)
                if best is None or est < best[0]:
                    best = (est, scheme, r, tr)
            if best is None:
                raise ValueError(f"no admissible (scheme, r) for {spec}")
            est, scheme, r, placement = best
        else:
            scheme, r = self.fixed
            est = self.estimate(spec, scheme, r, cluster)
            if est is None:
                raise ValueError(
                    f"fixed (scheme, r)={self.fixed} is inadmissible for "
                    f"{spec}; build the workload catalog with "
                    f"valid_subfile_counts so baselines cover the stream")
            placement = self._candidate_placement(spec, scheme, r, cluster)
            if placement is not None:
                est = self.estimate(spec, scheme, r, cluster,
                                    placement=placement)
        p = SchemeParams(K=self.K, P=cluster.topology.P,
                         Q=spec.Q, N=spec.N, r=r, r_f=self.placement_r_f)
        compile_s, hit = self._compile_charge(p, scheme, probe=True)
        obs_metrics.counter(
            "chooser_decisions_total",
            "scheme decisions by (scheme, r, family)").inc(
                scheme=scheme, r=r, family=family_of_scheme(scheme) or "none")
        est_components = self.estimate_components(spec, scheme, r, cluster,
                                                  placement=placement)
        return Decision(scheme, r, est, compile_s, hit, placement,
                        self.speculation, est_components)

    def _candidate_placement(self, spec: JobSpec, scheme: str, r: int,
                             cluster: ClusterSim) -> Optional[object]:
        """Placement traffic of one (admissible) hybrid candidate: the
        r_policy's rack-hedged structured placement when attached, else the
        admission's random replica draw (shared across the candidate rs —
        replicas are r-invariant) solved per r.  None when both knobs are
        off or the instance is structurally rejected.  Imported lazily: the
        sim stays usable without repro.placement.  Resolvable hybrids stay
        placement-blind for now: the Section-IV solver suite reasons over
        the binomial family's rack r-subsets."""
        if scheme != "hybrid":
            return None
        p = SchemeParams(K=self.K, P=cluster.topology.P,
                         Q=spec.Q, N=spec.N, r=r, r_f=self.placement_r_f)
        if self.r_policy is not None:
            tr = self.r_policy.placement_for(p, spec.d)
            if tr is not None:
                return tr
        if self.placement_solver is None:
            return None
        from ..placement import place_replicas, solve, traffic_for_result
        if self._admission_replicas is None:
            rng = np.random.default_rng(
                (self.placement_seed, self._placement_seq))
            self._admission_replicas = place_replicas(
                p, rng, self.placement_policy)
        try:
            result = solve(p, self._admission_replicas,
                           self.placement_solver, self.placement_lam,
                           rng=np.random.default_rng(
                               (self.placement_seed, self._placement_seq,
                                r)))
        except ValueError:
            return None
        return traffic_for_result(result, spec.d,
                                  self.placement_remote_penalty)


class MultiJobScheduler:
    """Admits an arrival stream into a :class:`ClusterSim` under a queueing
    policy, consulting a :class:`SchemeChooser` per admission (decisions see
    the cluster state AT ADMISSION, so queued jobs are re-priced when
    capacity frees up)."""

    def __init__(self, chooser: SchemeChooser, policy: str = "fifo",
                 max_concurrent: int = 4,
                 drift: Optional[DriftMonitor] = None,
                 recalibrate: bool = False, refit_window: int = 16,
                 refit_min_rows: int = 4) -> None:
        """Every admission's predicted JCT (:class:`Decision.est_jct`) is
        reconciled against the completed job's actual JCT through
        ``drift`` (a :class:`repro.obs.DriftMonitor`; a default
        ``layer='sim'`` monitor is built when None) — the registry's
        ``jct_*`` histograms/gauges always see the stream.

        ``recalibrate=True`` closes the loop online: completed jobs'
        barrier phase times are kept as calibration rows (the last
        ``refit_window`` of them), and when the monitor's EWMA crosses its
        drift threshold the chooser's cost model is refitted from that
        live stream via :func:`repro.sim.calibrate` (straggler inflation
        is absorbed into the refitted betas).  The stale model's regret is
        banked by the monitor at each refit.  Default False: no behavior
        change, telemetry only."""
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        self.chooser = chooser
        self.policy = policy
        self.max_concurrent = max_concurrent
        self.drift = drift if drift is not None else DriftMonitor()
        self.recalibrate = recalibrate
        self.refit_min_rows = int(refit_min_rows)
        self.decisions: Dict[int, Decision] = {}
        self._queue: List[Tuple[int, JobSpec]] = []
        self._running = 0
        self._seq = 0
        self._service_by_kind: Dict[str, float] = {}
        self._expected_map: Dict[int, float] = {}
        self._specs: Dict[int, JobSpec] = {}
        self._rows: Deque[Dict] = deque(maxlen=int(refit_window))

    # ---- policy ordering ---------------------------------------------------

    def _pop_next(self, cluster: ClusterSim) -> Tuple[int, JobSpec]:
        if self.policy == "fifo":
            idx = 0
        elif self.policy == "srpt":
            ests = [min((e for e in (self.chooser.estimate(s, sch, r, cluster)
                                     for sch, r in self.chooser.candidates())
                         if e is not None), default=float("inf"))
                    for _, s in self._queue]
            idx = int(np.argmin(ests))
        else:                                   # fair: least attained service
            attained = [self._service_by_kind.get(s.name, 0.0)
                        for _, s in self._queue]
            idx = int(np.argmin(attained))
        return self._queue.pop(idx)

    # ---- driving the sim ---------------------------------------------------

    def run(self, jobs: Sequence[JobSpec],
            cluster: ClusterSim) -> List[JobStats]:
        cluster.on_job_done = lambda stats: self._job_done(stats, cluster)
        for spec in sorted(jobs, key=lambda s: s.arrival):
            cluster.at(spec.arrival,
                       lambda s=spec: self._arrive(s, cluster), "arrival")
        return cluster.run()

    def _arrive(self, spec: JobSpec, cluster: ClusterSim) -> None:
        self._queue.append((self._seq, spec))
        self._seq += 1
        cluster.tracer.event("sched_arrival",
                             data=(spec.name, len(self._queue)),
                             policy=self.policy)
        self._drain(cluster)

    def _job_done(self, stats: JobStats, cluster: ClusterSim) -> None:
        self._running -= 1
        rp = self.chooser.r_policy
        if rp is not None:
            # feed the observed map slowdown back into the straggler fit
            rp.observe(stats, self._expected_map.pop(stats.job_id, 0.0))
        self._reconcile(stats, cluster)
        cluster.tracer.event("sched_drain", job_id=stats.job_id,
                             data=(self._running, len(self._queue)),
                             policy=self.policy)
        self._drain(cluster)

    def _reconcile(self, stats: JobStats, cluster: ClusterSim) -> None:
        """Predicted-vs-actual JCT for one completion; refit on drift."""
        d = self.decisions.get(stats.job_id)
        spec = self._specs.pop(stats.job_id, None)
        if d is None:
            return
        # est_jct was priced AT ADMISSION (= submit time), so the actual
        # it predicts is finish - submit, not the arrival-based stats.jct
        fired = self.drift.observe(d.est_jct, stats.finish - stats.submit,
                                   scheme=d.scheme)
        if stats.blame is not None:
            # per-admission blame: fold the job's decomposition into the
            # fleet gauges, and break the chooser's miss down by component
            # (queueing is outside the estimate's scope — see
            # estimate_components — so it is excluded from the comparison)
            record_blame(stats.blame, layer="sim", scheme=d.scheme)
            if d.est_components is not None:
                actual = dict(stats.blame)
                actual["queueing"] = 0.0
                record_component_errors(d.est_components, actual,
                                        layer="sim", scheme=d.scheme)
        if not self.recalibrate or spec is None:
            return
        from .calibration import measurement_row_from_stats
        p = SchemeParams(K=self.chooser.K, P=cluster.topology.P,
                         Q=spec.Q, N=spec.N, r=d.r)
        self._rows.append(
            measurement_row_from_stats(stats, p, d.scheme, spec.d))
        if fired and len(self._rows) >= self.refit_min_rows:
            self.chooser.cost_model = calibrate(list(self._rows))
            self.drift.refitted()
            cluster.tracer.event("sched_refit", job_id=stats.job_id,
                                 data=(len(self._rows),),
                                 policy=self.policy)

    def _drain(self, cluster: ClusterSim) -> None:
        while self._queue and self._running < self.max_concurrent:
            _, spec = self._pop_next(cluster)
            d = self.chooser.choose(spec, cluster)
            job_id = cluster.submit(spec, d.scheme, d.r,
                                    compile_s=d.compile_s,
                                    placement=d.placement,
                                    speculation=d.speculation)
            self.decisions[job_id] = d
            self._specs[job_id] = spec
            # no cache_hit label: it reflects process-global plan-cache
            # state, which would break per-seed bit-identical traces
            cluster.tracer.event("sched_admit", job_id=job_id,
                                 data=(spec.name, d.scheme, d.r),
                                 scheme=d.scheme, r=d.r, policy=self.policy)
            if self.chooser.r_policy is not None:
                p = SchemeParams(K=self.chooser.K, P=cluster.topology.P,
                                 Q=spec.Q, N=spec.N, r=d.r)
                exp = self.chooser.cost_model.map.seconds(
                    phase_work(p, d.scheme, spec.d)["map"])
                if d.placement is not None:      # locality skew is expected,
                    exp *= max(d.placement.map_factors)  # not straggling
                self._expected_map[job_id] = exp
            self._service_by_kind[spec.name] = (
                self._service_by_kind.get(spec.name, 0.0) + d.est_jct)
            self._running += 1


def run_scheduled(jobs: Sequence[JobSpec], cluster: ClusterSim,
                  chooser: SchemeChooser, policy: str = "fifo",
                  max_concurrent: int = 4
                  ) -> Tuple[List[JobStats], MultiJobScheduler]:
    """Convenience wrapper: schedule ``jobs`` on ``cluster``; returns
    (per-job stats, the scheduler with its per-job decisions)."""
    sched = MultiJobScheduler(chooser, policy, max_concurrent)
    stats = sched.run(jobs, cluster)
    return stats, sched
