"""Two-tier server-rack network: ToR switches + root switch, fluid fair share.

Topology (the paper's Fig. 1): K servers in P racks; every server hangs off
its rack's Top-of-Rack switch, and the P ToR switches hang off one root
switch.  Intra-rack transfers traverse only the sender's ToR; cross-rack
transfers traverse the root (a coded multicast counted ONCE — the paper
metric).

The contention model is processor-sharing fluid flow: each resource (the
root switch, or one ToR switch) divides its capacity EQUALLY among its
active flows.  The simulator aggregates one flow per (job, stage, resource),
so the equal split is per-JOB fairness — the standard abstraction for
datacenter flow-level simulation (cf. flow-level models in coflow/Varys
literature).

Calibration identity: with one job, no stragglers, and a uniform topology,
the hybrid shuffle drains its cross stage in ``cross_pairs / cross_bw``
(single flow on the root) and its intra stage in ``intra_total / intra_bw``
(P parallel per-rack flows of ``intra_total / P`` each on ToR capacity
``intra_bw / P``) — exactly :meth:`repro.core.costs.CommCost.weighted_time`.
That equality on the full Table I grid is asserted by
``benchmarks/sim_bench.py`` and ``tests/test_table1_regression.py``.

Telemetry: an optional :class:`NetworkTelemetry` observer (sampled on the
sim clock, see :class:`repro.sim.ClusterSim`) records per-resource
utilization / active-flow / backlog time series and per-flow lifecycle
records including the full contention-share (rate) history.  It is OFF by
default and records on the same event boundaries the simulator already
processes, so enabling it never changes event order — seeded traces stay
bit-identical with telemetry on or off, and the telemetry itself is
byte-identical per seed (pinned by ``benchmarks/blame_bench.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

from ..obs import metrics as obs_metrics

Resource = Union[str, Tuple[str, int]]          # 'root' | ('tor', rack)

ROOT: Resource = "root"


def tor(rack: int) -> Resource:
    return ("tor", rack)


def resource_key(res: Resource) -> str:
    """Stable string key for a resource ('root' or 'tor:<rack>') — used as
    the JSON-safe identifier in telemetry exports and report tables."""
    if res == ROOT:
        return "root"
    _, rack = res
    return f"tor:{rack}"


@dataclasses.dataclass(frozen=True)
class RackTopology:
    """Bandwidths are in value-units/s (pairs x payload width d).

    ``cross_bw`` is the root-switch capacity; ``intra_bw`` is the AGGREGATE
    intra tier capacity, split evenly over the P ToR switches (so one rack's
    ToR runs at ``intra_bw / P``) — the convention under which zero-contention
    simulated shuffle time equals ``CommCost.weighted_time(intra_bw,
    cross_bw)``.  ``rack_bw_scale`` skews individual ToR switches (straggling
    racks / heterogeneous hardware); ``cross_latency`` / ``intra_latency``
    add a fixed per-stage latency floor, and ``fetch_latency`` the floor of
    the pre-map input-fetch stage a locality-aware placement generates
    (see :mod:`repro.placement.sim_bridge`).
    """
    P: int
    cross_bw: float = 1.0
    intra_bw: float = 10.0
    rack_bw_scale: Tuple[float, ...] | None = None
    cross_latency: float = 0.0
    intra_latency: float = 0.0
    fetch_latency: float = 0.0

    def __post_init__(self) -> None:
        if self.P < 1 or self.cross_bw <= 0 or self.intra_bw <= 0:
            raise ValueError("need P >= 1 and positive bandwidths")
        if self.rack_bw_scale is not None:
            if len(self.rack_bw_scale) != self.P:
                raise ValueError(f"rack_bw_scale must have P={self.P} entries")
            if any(s <= 0 for s in self.rack_bw_scale):
                raise ValueError("rack_bw_scale entries must be positive")

    def capacity(self, res: Resource) -> float:
        if res == ROOT:
            return self.cross_bw
        _, rack = res
        scale = self.rack_bw_scale[rack] if self.rack_bw_scale else 1.0
        return self.intra_bw / self.P * scale

    def latency(self, stage: str) -> float:
        if stage == "fetch":
            return self.fetch_latency
        return self.cross_latency if stage == "cross" else self.intra_latency

    def resources(self) -> List[Resource]:
        return [ROOT] + [tor(r) for r in range(self.P)]


@dataclasses.dataclass
class Flow:
    flow_id: int
    resource: Resource
    remaining: float                 # value-units left to move
    tag: Tuple                       # (job_id, phase, ...) — for the trace
    size: float = 0.0                # original value-units (byte accounting)


def _tag_stage(tag: Tuple) -> str:
    """Stage label of a flow tag — tags are (job_id, stage, ...) tuples
    ('cross' | 'intra' | 'fetch_cross' | 'fetch_intra' | 'spec_fetch')."""
    return str(tag[1]) if len(tag) > 1 else "unknown"


@dataclasses.dataclass
class FlowRecord:
    """Lifecycle record of one flow: identity, start/end on the sim clock,
    terminal state, bytes drained, and the contention-share history — one
    ``(t, rate)`` entry per rate change (equal share changes exactly when
    the resource's active-flow set changes)."""
    flow_id: int
    resource: str                    # resource_key form
    tag: Tuple
    size: float
    start: float
    end: float = -1.0
    state: str = "active"            # -> 'done' | 'cancelled'
    drained: float = 0.0
    reason: str = ""                 # cancellation reason, '' otherwise
    rates: List[Tuple[float, float]] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, object]:
        return {"flow_id": self.flow_id, "resource": self.resource,
                "tag": list(self.tag), "size": self.size,
                "start": self.start, "end": self.end, "state": self.state,
                "drained": self.drained, "reason": self.reason,
                "rates": [list(rc) for rc in self.rates]}


class NetworkTelemetry:
    """Deterministic observer of a :class:`FluidNetwork`.

    Sampled on the injected sim clock at every flow-set change (start /
    finish / cancel) — the exact instants at which equal-share rates can
    change — so the series are lossless for a fluid network while staying
    O(#flow events) in size.  Per resource it keeps ``(t, active_flows,
    backlog)`` samples; per flow a :class:`FlowRecord` with the full rate
    history.  Purely observational: it never mutates the network and emits
    no trace events, so golden traces are untouched.
    """

    def __init__(self, topology: RackTopology,
                 clock: Callable[[], float]) -> None:
        self.topology = topology
        self.clock = clock
        self.flows: Dict[int, FlowRecord] = {}
        self.samples: Dict[str, List[Tuple[float, int, float]]] = {
            resource_key(res): [] for res in topology.resources()}

    # -- lifecycle hooks (driven by FluidNetwork) ---------------------------
    def flow_started(self, flow: Flow) -> None:
        self.flows[flow.flow_id] = FlowRecord(
            flow.flow_id, resource_key(flow.resource), flow.tag,
            flow.size, self.clock())

    def flow_finished(self, flow: Flow) -> None:
        rec = self.flows.get(flow.flow_id)
        if rec is not None:
            rec.end = self.clock()
            rec.state = "done"
            rec.drained = flow.size

    def flow_cancelled(self, flow: Flow, reason: str) -> None:
        rec = self.flows.get(flow.flow_id)
        if rec is not None:
            rec.end = self.clock()
            rec.state = "cancelled"
            rec.drained = max(flow.size - flow.remaining, 0.0)
            rec.reason = reason

    def sample(self, net: "FluidNetwork") -> None:
        """Record one sample per resource (and refresh per-flow rates)."""
        t = self.clock()
        rates = net.rates() if net.flows else {}
        counts: Dict[str, int] = {}
        backlogs: Dict[str, float] = {}
        for f in net.flows.values():
            key = resource_key(f.resource)
            counts[key] = counts.get(key, 0) + 1
            backlogs[key] = backlogs.get(key, 0.0) + f.remaining
        for key, series in self.samples.items():
            row = (t, counts.get(key, 0), backlogs.get(key, 0.0))
            if series and series[-1][0] == t:
                series[-1] = row        # coalesce same-instant events
            elif not series or series[-1][1:] != row[1:]:
                series.append(row)
        for fid in sorted(rates):
            rec = self.flows.get(fid)
            if rec is None:
                continue
            rate = rates[fid]
            if rec.rates and rec.rates[-1][0] == t:
                rec.rates[-1] = (t, rate)
            elif not rec.rates or rec.rates[-1][1] != rate:
                rec.rates.append((t, rate))

    # -- summaries ----------------------------------------------------------
    def utilization(self, until: Optional[float] = None) -> Dict[str, Dict[str, float]]:
        """Per-resource rollup over [first sample, ``until``]: busy seconds
        (>=1 active flow), utilization fraction, time-weighted mean active
        flows, peak backlog, and flow outcome counts."""
        horizon = self.clock() if until is None else float(until)
        out: Dict[str, Dict[str, float]] = {}
        for key, series in self.samples.items():
            busy = 0.0
            flow_time = 0.0
            peak_backlog = 0.0
            span = 0.0
            for i, (t, active, backlog) in enumerate(series):
                t_next = series[i + 1][0] if i + 1 < len(series) else horizon
                dt = max(t_next - t, 0.0)
                span += dt
                if active > 0:
                    busy += dt
                    flow_time += active * dt
                peak_backlog = max(peak_backlog, backlog)
            done = cancelled = 0
            for rec in self.flows.values():
                if rec.resource != key:
                    continue
                if rec.state == "done":
                    done += 1
                elif rec.state == "cancelled":
                    cancelled += 1
            out[key] = {"busy_s": busy,
                        "util": busy / span if span > 0 else 0.0,
                        "mean_active_flows": flow_time / span if span > 0 else 0.0,
                        "peak_backlog": peak_backlog,
                        "flows_done": float(done),
                        "flows_cancelled": float(cancelled)}
        return out

    def cancelled_units(self) -> Dict[str, float]:
        """Partially-drained value-units of cancelled flows, by stage label
        (the telemetry-side mirror of ``flow_cancelled_bytes_total``)."""
        out: Dict[str, float] = {}
        for fid in sorted(self.flows):
            rec = self.flows[fid]
            if rec.state == "cancelled":
                stage = _tag_stage(rec.tag)
                out[stage] = out.get(stage, 0.0) + rec.drained
        return out

    def to_dict(self) -> Dict[str, object]:
        """Deterministic JSON-able dump — byte-identical per seed (pinned
        by ``benchmarks/blame_bench.py`` via its sha256)."""
        return {"samples": {k: [list(s) for s in self.samples[k]]
                            for k in sorted(self.samples)},
                "flows": [self.flows[fid].to_dict()
                          for fid in sorted(self.flows)]}


class FluidNetwork:
    """Set of active flows advancing under per-resource equal share."""

    def __init__(self, topology: RackTopology,
                 telemetry: Optional[NetworkTelemetry] = None) -> None:
        self.topology = topology
        self.flows: Dict[int, Flow] = {}
        self.telemetry = telemetry
        self._next_id = 0

    def start_flow(self, resource: Resource, size: float, tag: Tuple) -> int:
        fid = self._next_id
        self._next_id += 1
        sz = max(float(size), 0.0)
        self.flows[fid] = Flow(fid, resource, sz, tag, sz)
        if self.telemetry is not None:
            self.telemetry.flow_started(self.flows[fid])
            self.telemetry.sample(self)
        return fid

    def _counts(self) -> Dict[Resource, int]:
        counts: Dict[Resource, int] = {}
        for f in self.flows.values():
            counts[f.resource] = counts.get(f.resource, 0) + 1
        return counts

    def rates(self) -> Dict[int, float]:
        """Current drain rate of every active flow (equal share)."""
        counts = self._counts()
        return {fid: self.topology.capacity(f.resource) / counts[f.resource]
                for fid, f in self.flows.items()}

    def _account_cancel(self, flow: Flow, reason: str) -> None:
        """Wasted-work accounting: a cancelled flow's partially-drained
        units were moved and then thrown away (speculation losers, crash-
        voided stages) — count them instead of dropping them silently."""
        drained = max(flow.size - flow.remaining, 0.0)
        if drained > 0:
            obs_metrics.counter(
                "flow_cancelled_bytes_total",
                "Partially-drained value-units of cancelled flows "
                "(wasted work: speculation losers, crash-voided stages)"
            ).inc(drained, stage=_tag_stage(flow.tag), reason=reason)
        if self.telemetry is not None:
            self.telemetry.flow_cancelled(flow, reason)

    def cancel_flow(self, flow_id: int, reason: str = "cancelled") -> None:
        """Abort an active flow (first-finisher-wins speculation kills the
        losing attempt's input fetch); freed capacity is re-shared among the
        survivors from the next advance.  Unknown/finished ids are no-ops.
        Partially-drained units are counted into
        ``flow_cancelled_bytes_total{stage,reason}``."""
        flow = self.flows.pop(flow_id, None)
        if flow is not None:
            self._account_cancel(flow, reason)
            if self.telemetry is not None:
                self.telemetry.sample(self)

    def cancel_flows(self, match, reason: str = "cancelled") -> int:
        """Abort every active flow whose ``tag`` matches the predicate, in
        deterministic (flow_id) order; returns the number cancelled.  A
        server crash mid-shuffle voids the job's whole in-flight stage —
        ``cancel_flows(lambda tag: tag[0] == job_id)`` guarantees no orphan
        flows keep draining a dead job's bytes (asserted in tests).
        Partially-drained units are counted like :meth:`cancel_flow`."""
        doomed = [fid for fid in sorted(self.flows)
                  if match(self.flows[fid].tag)]
        for fid in doomed:
            self._account_cancel(self.flows[fid], reason)
            del self.flows[fid]
        if doomed and self.telemetry is not None:
            self.telemetry.sample(self)
        return len(doomed)

    def backlog(self, resource: Resource) -> float:
        """Total value-units queued on a resource (scheduler load signal)."""
        return sum(f.remaining for f in self.flows.values()
                   if f.resource == resource)

    def time_to_next_completion(self) -> float:
        """Time until the earliest active flow drains at current rates
        (inf when no flows are active)."""
        rates = self.rates()
        dt = float("inf")
        for fid, f in sorted(self.flows.items()):
            dt = min(dt, f.remaining / rates[fid])
        return dt

    def advance(self, dt: float) -> List[Flow]:
        """Drain all flows for ``dt`` seconds; return completed flows in
        deterministic (flow_id) order.  A flow whose residue would drain in
        under a nanosecond at its current rate completes now — the guard
        that keeps float round-off from stranding un-advanceable slivers."""
        if not self.flows:
            return []
        rates = self.rates()
        done: List[Flow] = []
        for fid in sorted(self.flows):
            f = self.flows[fid]
            f.remaining -= rates[fid] * dt
            if f.remaining <= rates[fid] * 1e-9:
                f.remaining = 0.0
                done.append(f)
        for f in done:
            del self.flows[f.flow_id]
        if done and self.telemetry is not None:
            for f in done:
                self.telemetry.flow_finished(f)
            self.telemetry.sample(self)
        return done
