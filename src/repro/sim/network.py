"""Two-tier server-rack network: ToR switches + root switch, fluid fair share.

Topology (the paper's Fig. 1): K servers in P racks; every server hangs off
its rack's Top-of-Rack switch, and the P ToR switches hang off one root
switch.  Intra-rack transfers traverse only the sender's ToR; cross-rack
transfers traverse the root (a coded multicast counted ONCE — the paper
metric).

The contention model is processor-sharing fluid flow: each resource (the
root switch, or one ToR switch) divides its capacity EQUALLY among its
active flows.  The simulator aggregates one flow per (job, stage, resource),
so the equal split is per-JOB fairness — the standard abstraction for
datacenter flow-level simulation (cf. flow-level models in coflow/Varys
literature).

Calibration identity: with one job, no stragglers, and a uniform topology,
the hybrid shuffle drains its cross stage in ``cross_pairs / cross_bw``
(single flow on the root) and its intra stage in ``intra_total / intra_bw``
(P parallel per-rack flows of ``intra_total / P`` each on ToR capacity
``intra_bw / P``) — exactly :meth:`repro.core.costs.CommCost.weighted_time`.
That equality on the full Table I grid is asserted by
``benchmarks/sim_bench.py`` and ``tests/test_table1_regression.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple, Union

Resource = Union[str, Tuple[str, int]]          # 'root' | ('tor', rack)

ROOT: Resource = "root"


def tor(rack: int) -> Resource:
    return ("tor", rack)


@dataclasses.dataclass(frozen=True)
class RackTopology:
    """Bandwidths are in value-units/s (pairs x payload width d).

    ``cross_bw`` is the root-switch capacity; ``intra_bw`` is the AGGREGATE
    intra tier capacity, split evenly over the P ToR switches (so one rack's
    ToR runs at ``intra_bw / P``) — the convention under which zero-contention
    simulated shuffle time equals ``CommCost.weighted_time(intra_bw,
    cross_bw)``.  ``rack_bw_scale`` skews individual ToR switches (straggling
    racks / heterogeneous hardware); ``cross_latency`` / ``intra_latency``
    add a fixed per-stage latency floor, and ``fetch_latency`` the floor of
    the pre-map input-fetch stage a locality-aware placement generates
    (see :mod:`repro.placement.sim_bridge`).
    """
    P: int
    cross_bw: float = 1.0
    intra_bw: float = 10.0
    rack_bw_scale: Tuple[float, ...] | None = None
    cross_latency: float = 0.0
    intra_latency: float = 0.0
    fetch_latency: float = 0.0

    def __post_init__(self) -> None:
        if self.P < 1 or self.cross_bw <= 0 or self.intra_bw <= 0:
            raise ValueError("need P >= 1 and positive bandwidths")
        if self.rack_bw_scale is not None:
            if len(self.rack_bw_scale) != self.P:
                raise ValueError(f"rack_bw_scale must have P={self.P} entries")
            if any(s <= 0 for s in self.rack_bw_scale):
                raise ValueError("rack_bw_scale entries must be positive")

    def capacity(self, res: Resource) -> float:
        if res == ROOT:
            return self.cross_bw
        _, rack = res
        scale = self.rack_bw_scale[rack] if self.rack_bw_scale else 1.0
        return self.intra_bw / self.P * scale

    def latency(self, stage: str) -> float:
        if stage == "fetch":
            return self.fetch_latency
        return self.cross_latency if stage == "cross" else self.intra_latency


@dataclasses.dataclass
class Flow:
    flow_id: int
    resource: Resource
    remaining: float                 # value-units left to move
    tag: Tuple                       # (job_id, phase, ...) — for the trace
    size: float = 0.0                # original value-units (byte accounting)


class FluidNetwork:
    """Set of active flows advancing under per-resource equal share."""

    def __init__(self, topology: RackTopology) -> None:
        self.topology = topology
        self.flows: Dict[int, Flow] = {}
        self._next_id = 0

    def start_flow(self, resource: Resource, size: float, tag: Tuple) -> int:
        fid = self._next_id
        self._next_id += 1
        sz = max(float(size), 0.0)
        self.flows[fid] = Flow(fid, resource, sz, tag, sz)
        return fid

    def _counts(self) -> Dict[Resource, int]:
        counts: Dict[Resource, int] = {}
        for f in self.flows.values():
            counts[f.resource] = counts.get(f.resource, 0) + 1
        return counts

    def rates(self) -> Dict[int, float]:
        """Current drain rate of every active flow (equal share)."""
        counts = self._counts()
        return {fid: self.topology.capacity(f.resource) / counts[f.resource]
                for fid, f in self.flows.items()}

    def cancel_flow(self, flow_id: int) -> None:
        """Abort an active flow (first-finisher-wins speculation kills the
        losing attempt's input fetch); freed capacity is re-shared among the
        survivors from the next advance.  Unknown/finished ids are no-ops."""
        self.flows.pop(flow_id, None)

    def cancel_flows(self, match) -> int:
        """Abort every active flow whose ``tag`` matches the predicate, in
        deterministic (flow_id) order; returns the number cancelled.  A
        server crash mid-shuffle voids the job's whole in-flight stage —
        ``cancel_flows(lambda tag: tag[0] == job_id)`` guarantees no orphan
        flows keep draining a dead job's bytes (asserted in tests)."""
        doomed = [fid for fid in sorted(self.flows)
                  if match(self.flows[fid].tag)]
        for fid in doomed:
            del self.flows[fid]
        return len(doomed)

    def backlog(self, resource: Resource) -> float:
        """Total value-units queued on a resource (scheduler load signal)."""
        return sum(f.remaining for f in self.flows.values()
                   if f.resource == resource)

    def time_to_next_completion(self) -> float:
        """Time until the earliest active flow drains at current rates
        (inf when no flows are active)."""
        rates = self.rates()
        dt = float("inf")
        for fid, f in sorted(self.flows.items()):
            dt = min(dt, f.remaining / rates[fid])
        return dt

    def advance(self, dt: float) -> List[Flow]:
        """Drain all flows for ``dt`` seconds; return completed flows in
        deterministic (flow_id) order.  A flow whose residue would drain in
        under a nanosecond at its current rate completes now — the guard
        that keeps float round-off from stranding un-advanceable slivers."""
        if not self.flows:
            return []
        rates = self.rates()
        done: List[Flow] = []
        for fid in sorted(self.flows):
            f = self.flows[fid]
            f.remaining -= rates[fid] * dt
            if f.remaining <= rates[fid] * 1e-9:
                f.remaining = 0.0
                done.append(f)
        for f in done:
            del self.flows[f.flow_id]
        return done
