"""Batched KV-cache serving engine: prefill + decode with request slots.

Two layers:

  * :func:`make_serve_step` — the jitted single-token decode step the
    dry-run lowers for the ``decode_32k`` / ``long_500k`` shapes: one new
    token for every sequence in the batch against a seq_len-deep cache.
  * :class:`ServeEngine` — slot-based batching: requests occupy fixed
    batch slots, prefill fills a slot's cache region, decode advances all
    live slots together, finished slots are refilled from the queue
    (continuous batching at step granularity).

Sampling: greedy or temperature; deterministic per (seed, slot, pos).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import lm


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # [len] int32
    max_new_tokens: int
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def make_serve_step(cfg: ArchConfig, *, scan_layers: bool = True,
                    dense_moe: bool = False) -> Callable:
    """step(params, cache, token [B], pos []) -> (logits [B, V], cache)."""
    def step(params, cache, token, pos):
        return lm.decode_step(params, cfg, token, cache, pos,
                              scan_layers=scan_layers, dense_moe=dense_moe)
    return step


def sample_token(logits: jax.Array, key: jax.Array,
                 temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


class ServeEngine:
    """Fixed-slot batched engine (single uniform position per step).

    Uniform-position slots keep every cache write a single
    dynamic_update_slice (TPU-friendly); a production engine would add
    per-slot positions — the cache layout here already supports it (the
    ring/window caches mask by kpos, and dense caches by valid length).
    """

    def __init__(self, cfg: ArchConfig, params, batch_slots: int,
                 max_seq: int, dtype=jnp.float32, *,
                 dense_moe: bool = False, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.dtype = dtype
        self.dense_moe = dense_moe
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(make_serve_step(cfg, dense_moe=dense_moe))

    # -- batched generation (uniform prompts) -------------------------------
    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 temperature: float = 0.0,
                 enc_frames: Optional[jax.Array] = None,
                 prefix_embeds: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: [B, L] (uniform length).  Returns [B, max_new_tokens]."""
        B, L = prompts.shape
        assert B == self.B
        cache = lm.init_cache(self.cfg, B, self.max_seq, self.dtype)
        logits, cache = lm.prefill(
            self.params, self.cfg, jnp.asarray(prompts), cache,
            enc_frames=enc_frames, prefix_embeds=prefix_embeds,
            dense_moe=self.dense_moe)
        n_front = (prefix_embeds.shape[1] if prefix_embeds is not None
                   else 0)
        pos = L + n_front
        out = np.zeros((B, max_new_tokens), np.int32)
        tok = sample_token(logits, jax.random.fold_in(self.key, pos),
                           temperature)
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok)
            if t == max_new_tokens - 1:
                break
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.asarray(pos, jnp.int32))
            pos += 1
            tok = sample_token(logits, jax.random.fold_in(self.key, pos),
                               temperature)
        return out

    # -- slot-based continuous batching --------------------------------------
    def serve(self, requests: List[Request]) -> List[Request]:
        """Run a request list to completion with slot reuse.  Prompts are
        left-aligned per wave; slots join at wave boundaries (step-level
        continuous batching)."""
        queue = list(requests)
        while queue:
            wave = queue[: self.B]
            queue = queue[len(wave):]
            L = max(len(r.prompt) for r in wave)
            prompts = np.zeros((self.B, L), np.int32)
            for i, r in enumerate(wave):
                prompts[i, L - len(r.prompt):] = r.prompt   # left-pad
            steps = max(r.max_new_tokens for r in wave)
            toks = self.generate(prompts, steps,
                                 temperature=wave[0].temperature)
            for i, r in enumerate(wave):
                r.out_tokens = list(map(int, toks[i, : r.max_new_tokens]))
                r.done = True
        return requests
