"""JCT blame decomposition: WHY a job took as long as it did.

The paper's argument is an attribution claim — JCT is dominated by where
bytes flow (cross-rack vs intra-rack) and hybrid coding wins by moving
blame between tiers.  This module turns a completed job into a
:class:`BlameReport` decomposing its JCT into named components under an
**exactness law**: the components sum to the measured JCT (zero residual
up to float round-off; the simulator pins ``<= 1e-9`` relative on the full
Table I grid via ``benchmarks/blame_bench.py``).

Components (``COMPONENTS`` order)::

    queueing       admission wait: submit - arrival
    plan_compile   plan-compilation phase seconds
    fetch          zero-contention ideal of the pre-map input-fetch stage
    map            straggler-free ideal of the map barrier (placement
                   map_factors included — locality imbalance is map blame,
                   not straggle)
    map_straggle   actual map - ideal map (straggler inflation; can be
                   NEGATIVE when speculative backups beat the home server's
                   serial ideal)
    pack           pack barrier seconds (as measured)
    shuffle_cross  failure-free zero-contention ideal of the cross-rack
                   shuffle stages (root-switch drain + latency)
    shuffle_intra  same for the intra-rack stages (bottleneck ToR drain)
    contention     network sharing: sum over completed fetch/shuffle stage
                   runs of (actual - zero-contention ideal of that run)
    reduce         reduce barrier seconds (as measured)
    recovery       crash cost: wasted (crash-voided partial phases) + re-map
                   seconds + (degraded as-run shuffle ideal - failure-free
                   shuffle ideal)

Exactness follows by telescoping: ideal terms cancel against their
(actual - ideal) partners, leaving queueing + every recorded phase second +
crash-voided seconds = finish - arrival.

Two independent paths produce the same report: :func:`decompose` from a
job's bookkeeping (the simulator computes this at job completion and
stores it on ``JobStats.blame``), and :func:`extract_blame`, a
critical-path extractor that walks the ``phase_span`` events of the
structured trace (every sim phase is a barrier, so a single job's phase
chain IS its critical path), recovers crash-voided time from span gaps,
and cross-checks the stored decomposition.  ``benchmarks/blame_bench.py``
pins their agreement.

This module is deliberately sim-free (duck-typed ``JobStats``) so
``repro.sim`` can import it without a cycle.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

COMPONENTS: Tuple[str, ...] = (
    "queueing", "plan_compile", "fetch", "map", "map_straggle", "pack",
    "shuffle_cross", "shuffle_intra", "contention", "reduce", "recovery")

_SHUFFLE_TIERS = ("cross", "intra")


def decompose(jct: float, queueing: float, phase_times: Dict[str, float],
              ideal_times: Optional[Dict[str, float]] = None,
              ff_shuffle_ideal: Optional[Dict[str, float]] = None,
              wasted_s: float = 0.0) -> Dict[str, float]:
    """Blame components from a job's bookkeeping (see module docstring).

    ``phase_times`` are the measured phase seconds (``plan_compile``,
    ``fetch``, ``map``, ``pack``, ``shuffle:cross``, ``shuffle:intra``,
    ``remap``, ``reduce``); ``ideal_times`` the zero-contention /
    straggler-free ideals of the completed fetch, map, and (as-run) shuffle
    stage runs; ``ff_shuffle_ideal`` the failure-free shuffle ideals by
    tier; ``wasted_s`` the crash-voided partial-phase seconds.  Missing
    ideals default to the actuals (components degrade gracefully to the
    raw phase decomposition — the sum law holds regardless).
    """
    pt = phase_times
    it = ideal_times or {}
    map_act = pt.get("map", 0.0)
    map_ideal = it.get("map", map_act)
    fetch_act = pt.get("fetch", 0.0)
    fetch_ideal = it.get("fetch", fetch_act)
    sh_act = {k: pt.get(f"shuffle:{k}", 0.0) for k in _SHUFFLE_TIERS}
    sh_ideal = {k: it.get(f"shuffle:{k}", sh_act[k])
                for k in _SHUFFLE_TIERS}
    ff_src = ff_shuffle_ideal or {}
    ff = {k: ff_src.get(k, sh_ideal[k]) for k in _SHUFFLE_TIERS}
    return {
        "queueing": queueing,
        "plan_compile": pt.get("plan_compile", 0.0),
        "fetch": fetch_ideal,
        "map": map_ideal,
        "map_straggle": map_act - map_ideal,
        "pack": pt.get("pack", 0.0),
        "shuffle_cross": ff["cross"],
        "shuffle_intra": ff["intra"],
        "contention": ((fetch_act - fetch_ideal)
                       + sum(sh_act[k] - sh_ideal[k]
                             for k in _SHUFFLE_TIERS)),
        "reduce": pt.get("reduce", 0.0),
        "recovery": (wasted_s + pt.get("remap", 0.0)
                     + sum(sh_ideal[k] - ff[k] for k in _SHUFFLE_TIERS)),
    }


@dataclasses.dataclass(frozen=True)
class BlameReport:
    """One job's JCT decomposition.  ``components`` is keyed in
    ``COMPONENTS`` order (engine-side reports may carry extra fused keys
    like ``map_shuffle_reduce``); ``residual`` is the exactness-law check
    — the simulator keeps it at float round-off."""
    job_id: int
    name: str
    scheme: str
    r: int
    jct: float
    components: Dict[str, float]

    @property
    def residual(self) -> float:
        return self.jct - math.fsum(self.components.values())

    def dominant(self) -> str:
        """Component with the largest blame share."""
        return max(self.components, key=lambda k: (self.components[k], k))

    def share(self, component: str) -> float:
        return (self.components.get(component, 0.0) / self.jct
                if self.jct > 0 else 0.0)

    def to_dict(self) -> Dict[str, object]:
        return {"job_id": self.job_id, "name": self.name,
                "scheme": self.scheme, "r": self.r, "jct": self.jct,
                "components": dict(self.components),
                "residual": self.residual, "dominant": self.dominant()}


def blame_report(stats: object) -> BlameReport:
    """Build a :class:`BlameReport` from a completed job's ``JobStats``
    (duck-typed).  Uses the sim-computed ``stats.blame`` when present,
    else re-derives it from the raw bookkeeping fields."""
    comps = getattr(stats, "blame", None)
    if comps is None:
        comps = decompose(
            stats.finish - stats.arrival, stats.submit - stats.arrival,
            stats.phase_times, getattr(stats, "ideal_times", None),
            getattr(stats, "ff_shuffle_ideal", None),
            getattr(stats, "wasted_s", 0.0))
    return BlameReport(stats.job_id, stats.name, stats.scheme, stats.r,
                       stats.finish - stats.arrival, dict(comps))


# ---------------------------------------------------------------------------
# Critical-path extraction from the structured trace
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PathSegment:
    """One segment of a job's critical path: a phase span, or a ``__void__``
    gap where a crash discarded in-flight work (the span for that phase was
    never recorded because the phase never completed)."""
    phase: str
    start: float
    end: float

    @property
    def dur(self) -> float:
        return self.end - self.start


def critical_path(events: Iterable[object], job_id: int) -> List[PathSegment]:
    """Walk the ``phase_span`` events of one job into its critical path.

    Every sim phase is a BARRIER (map/pack/reduce end at the slowest
    server, a shuffle stage at its last flow + latency), so the phase chain
    of a single job is exactly its critical path: each span's end is the
    dependency that releases the next.  Gaps between consecutive spans are
    crash-voided work (a phase that was interrupted mid-flight leaves no
    span) and surface as ``__void__`` segments — their total equals the
    job's ``wasted_s``.
    """
    spans = sorted(
        (e for e in events
         if getattr(e, "kind", None) == "phase_span"
         and getattr(e, "job_id", None) == job_id
         and getattr(e, "dur", None) is not None),
        key=lambda e: (e.ts, e.ts + e.dur))
    path: List[PathSegment] = []
    for e in spans:
        if path and e.ts > path[-1].end + 1e-12 * max(1.0, abs(e.ts)):
            path.append(PathSegment("__void__", path[-1].end, e.ts))
        path.append(PathSegment(str(e.phase), e.ts, e.ts + e.dur))
    return path


def extract_blame(events: Iterable[object], stats: object,
                  check: bool = True, tol: float = 1e-9) -> BlameReport:
    """Critical-path extractor: rebuild a job's blame decomposition from
    the ``TraceEvent`` stream instead of trusting its recorded
    ``phase_times``.

    Actual phase seconds come from :func:`critical_path` (span durations,
    accumulated per phase; re-run shuffle stages accumulate like the sim
    does), crash-voided seconds from the ``__void__`` gaps, and queueing
    from (first span start - arrival).  Ideal-side inputs still come from
    ``stats`` (they are model quantities, not observable from the trace).
    With ``check=True`` the result is verified against the sim-computed
    ``stats.blame`` to ``tol`` relative — the two independent paths must
    agree (pinned by ``benchmarks/blame_bench.py``).
    """
    path = critical_path(events, stats.job_id)
    if not path:
        raise ValueError(f"no phase_span events for job {stats.job_id}")
    actual: Dict[str, float] = {}
    wasted = 0.0
    for seg in path:
        if seg.phase == "__void__":
            wasted += seg.dur
        else:
            actual[seg.phase] = actual.get(seg.phase, 0.0) + seg.dur
    jct = stats.finish - stats.arrival
    comps = decompose(jct, path[0].start - stats.arrival, actual,
                      getattr(stats, "ideal_times", None),
                      getattr(stats, "ff_shuffle_ideal", None), wasted)
    stored = getattr(stats, "blame", None)
    if check and stored is not None:
        scale = max(1.0, abs(jct))
        for key in set(comps) | set(stored):
            diff = abs(comps.get(key, 0.0) - stored.get(key, 0.0))
            if diff > tol * scale:
                raise ValueError(
                    f"trace-extracted blame disagrees with recorded blame "
                    f"for job {stats.job_id}: {key} differs by {diff:g}")
    return BlameReport(stats.job_id, stats.name, stats.scheme, stats.r,
                       jct, comps)


# ---------------------------------------------------------------------------
# Fleet rollup
# ---------------------------------------------------------------------------

def _quantile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile (numpy 'linear' method) — local so the
    module stays dependency-free and deterministic."""
    xs = sorted(values)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    pos = q * (len(xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (pos - lo) * (xs[hi] - xs[lo])


def fleet_blame(reports: Sequence[BlameReport],
                q: float = 0.99) -> Dict[str, object]:
    """Fleet-level rollup: per-component mean / share / per-job quantile,
    plus the decomposition of the JCT TAIL — the mean blame of jobs at or
    above the ``q`` JCT quantile (what is making the p99 slow is the
    question coflow scheduling is judged on)."""
    n = len(reports)
    if n == 0:
        return {"n": 0, "q": q, "jct_mean": 0.0, "jct_q": 0.0,
                "mean": {}, "quantile": {}, "tail_mean": {},
                "tail_share": {}, "max_abs_residual": 0.0}
    keys = sorted({k for rep in reports for k in rep.components})
    jcts = [rep.jct for rep in reports]
    jct_q = _quantile(jcts, q)
    tail = [rep for rep in reports if rep.jct >= jct_q] or list(reports)
    mean = {k: math.fsum(rep.components.get(k, 0.0)
                         for rep in reports) / n for k in keys}
    tail_mean = {k: math.fsum(rep.components.get(k, 0.0)
                              for rep in tail) / len(tail) for k in keys}
    tail_jct = math.fsum(rep.jct for rep in tail)
    return {
        "n": n, "q": q,
        "jct_mean": math.fsum(jcts) / n,
        "jct_q": jct_q,
        "mean": mean,
        "quantile": {k: _quantile([rep.components.get(k, 0.0)
                                   for rep in reports], q) for k in keys},
        "tail_mean": tail_mean,
        "tail_share": {k: (tail_mean[k] * len(tail) / tail_jct
                           if tail_jct > 0 else 0.0) for k in keys},
        "max_abs_residual": max(abs(rep.residual) for rep in reports),
    }


# ---------------------------------------------------------------------------
# Engine-side adapter (measured device/host timings)
# ---------------------------------------------------------------------------

def blame_from_phase_timings(row: Dict[str, object],
                             intra_bw: Optional[float] = None,
                             cross_bw: Optional[float] = None
                             ) -> Dict[str, float]:
    """Blame components from a :func:`repro.mapreduce.engine
    .measure_phase_timings` row (measured per-phase wall clock).

    Host phases map directly; the measured shuffle wall
    (``meta['shuffle_s']``) is split into ``shuffle_cross`` /
    ``shuffle_intra`` by the scheme's closed-form byte ratio — weighted by
    per-tier bandwidths when given, by raw value-units otherwise.  No
    queueing/straggle/contention terms exist in a solo measured run, so the
    exactness law here reduces to: components sum to the measured phase
    seconds plus the measured shuffle wall.
    """
    from ..core.costs import hybrid_cost
    from ..core.params import SchemeParams

    seconds: Dict[str, float] = dict(row.get("seconds", {}))  # type: ignore
    meta: Dict[str, object] = dict(row.get("meta", {}))       # type: ignore
    comps = {
        "plan_compile": float(seconds.get("plan_compile", 0.0)),
        "map": float(seconds.get("map", 0.0)),
        "pack": float(seconds.get("pack", 0.0)),
        "reduce": float(seconds.get("reduce", 0.0)),
    }
    shuffle_s = float(meta.get("shuffle_s", seconds.get("shuffle", 0.0)))
    if shuffle_s > 0:
        try:
            p = SchemeParams(K=int(meta["K"]), P=int(meta["P"]),
                             Q=int(meta["Q"]), N=int(meta["N"]),
                             r=int(meta["r"]))
            c = hybrid_cost(p, check=False)
            intra_w = c.intra / (intra_bw or 1.0)
            cross_w = c.cross / (cross_bw or 1.0)
        except (KeyError, ValueError, TypeError):
            intra_w = cross_w = 1.0
        tot = intra_w + cross_w
        cross_frac = cross_w / tot if tot > 0 else 0.5
        comps["shuffle_cross"] = shuffle_s * cross_frac
        comps["shuffle_intra"] = shuffle_s * (1.0 - cross_frac)
    return comps
