"""Prediction-drift monitoring: does the model of the machine still match
the machine?

The scheduler admits every job with a predicted JCT (``Decision.est_jct``,
priced from the chooser's :class:`repro.sim.CostModel` and the closed-form
stage traffic).  This module closes the loop on that prediction:

  * :func:`record_prediction` reconciles one (predicted, actual) pair into
    the registry — absolute- and relative-error histograms plus a running
    prediction counter — under a ``layer`` label (``sim`` for scheduler
    admissions, ``engine`` for measured-wall-clock conformance cells);
  * :class:`DriftMonitor` additionally maintains an EWMA of the relative
    error and the cumulative REGRET of the stale model (seconds of
    |predicted - actual| accumulated since the last refit).  When the EWMA
    crosses the configured threshold the monitor reports drift, the caller
    refits (``repro.sim.calibrate`` over the live measurement stream — see
    ``MultiJobScheduler(recalibrate=True)``) and acknowledges via
    :meth:`DriftMonitor.refitted`, which banks the stale model's regret
    into ``stale_model_regret_seconds_total`` and restarts the EWMA
    warm-up for the fresh model.

Everything here is deterministic given a deterministic observation stream:
the histograms, EWMA and regret are pure folds over (predicted, actual)
pairs, so two same-seed sim runs produce byte-identical ``jct_*`` metric
snapshots — pinned by the calibration bench's determinism section.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from . import metrics as _metrics

# Relative-error histogram buckets: 1% .. 2x, then +inf.  Chosen so a
# well-calibrated model concentrates in the first few buckets and a
# regime shift (e.g. 3x straggler inflation) lands visibly in the tail.
REL_ERR_BUCKETS = (0.01, 0.02, 0.05, 0.1, 0.2, 0.35, 0.5, 1.0, 2.0,
                   float("inf"))


def record_prediction(predicted: float, actual: float, layer: str = "sim",
                      reg: Optional[_metrics.MetricsRegistry] = None,
                      **labels: object) -> float:
    """Reconcile one predicted-vs-actual JCT pair into the registry.

    Records ``jct_predictions_total{layer}``,
    ``jct_prediction_error_seconds{layer}`` (absolute) and
    ``jct_prediction_relative_error{layer}``; returns the relative error
    |predicted - actual| / max(actual, eps) so callers can fold it further
    (the :class:`DriftMonitor` EWMA does).  Extra ``labels`` ride onto all
    three metrics — keep them low-cardinality (scheme, not job id).
    """
    reg = reg if reg is not None else _metrics.registry()
    err = abs(float(predicted) - float(actual))
    rel = err / max(abs(float(actual)), 1e-12)
    reg.counter("jct_predictions_total",
                "predicted-vs-actual JCT reconciliations").inc(
                    layer=layer, **labels)
    reg.histogram("jct_prediction_error_seconds",
                  "absolute JCT prediction error |pred - actual| (s)"
                  ).observe(err, layer=layer, **labels)
    reg.histogram("jct_prediction_relative_error",
                  "relative JCT prediction error |pred - actual| / actual",
                  buckets=REL_ERR_BUCKETS).observe(rel, layer=layer,
                                                   **labels)
    return rel


def record_blame(components: Dict[str, float], layer: str = "sim",
                 reg: Optional[_metrics.MetricsRegistry] = None,
                 **labels: object) -> None:
    """Fold one job's blame decomposition (:func:`repro.obs.blame
    .decompose`) into the registry: ``jct_blame_seconds{component,layer}``
    accumulates per-component seconds across completions.  A gauge (via
    ``add``), not a counter, because ``map_straggle`` can go negative when
    speculative backups beat the home server's serial ideal."""
    reg = reg if reg is not None else _metrics.registry()
    g = reg.gauge("jct_blame_seconds",
                  "accumulated JCT blame seconds by component "
                  "(repro.obs.blame exactness-law decomposition)")
    jobs = reg.counter("jct_blame_jobs_total",
                       "jobs folded into jct_blame_seconds")
    for comp in sorted(components):
        g.add(float(components[comp]), component=comp, layer=layer, **labels)
    jobs.inc(layer=layer, **labels)


def record_component_errors(estimated: Dict[str, float],
                            actual: Dict[str, float], layer: str = "sim",
                            reg: Optional[_metrics.MetricsRegistry] = None,
                            **labels: object) -> Dict[str, float]:
    """Per-component prediction-error breakdown: what the chooser's
    estimate missed, component by component (the drift layer's refinement
    of the scalar ``jct_prediction_*`` stream).

    Records ``jct_component_error_seconds{component,layer}`` (absolute
    error histogram) and ``jct_component_bias_seconds{component,layer}``
    (signed actual - estimated, accumulated — positive bias on
    ``contention`` means the chooser systematically under-prices network
    sharing).  Returns the signed errors for callers that fold further.
    """
    reg = reg if reg is not None else _metrics.registry()
    hist = reg.histogram("jct_component_error_seconds",
                         "absolute per-component JCT prediction error (s)")
    bias = reg.gauge("jct_component_bias_seconds",
                     "accumulated signed per-component prediction error "
                     "(actual - estimated, s)")
    out: Dict[str, float] = {}
    for comp in sorted(set(estimated) | set(actual)):
        err = float(actual.get(comp, 0.0)) - float(estimated.get(comp, 0.0))
        out[comp] = err
        hist.observe(abs(err), component=comp, layer=layer, **labels)
        bias.add(err, component=comp, layer=layer, **labels)
    return out


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Knobs of the EWMA drift detector.

    ``ewma_alpha`` weights the newest observation; ``threshold`` is the
    EWMA relative error above which drift fires; ``min_observations``
    gates firing until the EWMA has warmed up (and again after every
    refit, so a fresh model gets the same grace period).
    """
    ewma_alpha: float = 0.3
    threshold: float = 0.25
    min_observations: int = 5


class DriftMonitor:
    """EWMA drift detector + regret accountant over a prediction stream.

    One monitor watches one model (one scheduler / one layer).  Feed every
    completion through :meth:`observe`; when it returns True the model has
    drifted — refit it, then call :meth:`refitted`.  The monitor never
    refits by itself: the refit needs the measurement stream, which the
    caller owns (see ``MultiJobScheduler._job_done``).
    """

    def __init__(self, config: DriftConfig = DriftConfig(),
                 layer: str = "sim",
                 reg: Optional[_metrics.MetricsRegistry] = None) -> None:
        self.config = config
        self.layer = layer
        self.reg = reg if reg is not None else _metrics.registry()
        self.ewma: Optional[float] = None
        self.observations = 0            # since last refit
        self.total_observations = 0
        self.refits = 0
        self.drift_events = 0
        self.regret_s = 0.0              # |pred - actual| since last refit

    def observe(self, predicted: float, actual: float,
                **labels: object) -> bool:
        """Fold one completion into the detector; True = drift fired."""
        rel = record_prediction(predicted, actual, layer=self.layer,
                                reg=self.reg, **labels)
        self.regret_s += abs(float(predicted) - float(actual))
        self.observations += 1
        self.total_observations += 1
        a = self.config.ewma_alpha
        self.ewma = rel if self.ewma is None else a * rel + (1 - a) * self.ewma
        g = self.reg.gauge("jct_drift_ewma",
                           "EWMA of relative JCT prediction error")
        g.set(self.ewma, layer=self.layer)
        self.reg.gauge("jct_model_regret_seconds",
                       "cumulative |pred - actual| since last refit"
                       ).set(self.regret_s, layer=self.layer)
        fired = (self.observations >= self.config.min_observations
                 and self.ewma > self.config.threshold)
        if fired:
            self.drift_events += 1
            self.reg.counter("jct_drift_events_total",
                             "EWMA drift-threshold crossings").inc(
                                 layer=self.layer)
        return fired

    def refitted(self) -> None:
        """Acknowledge a model refit: bank the stale model's regret, count
        the refit, and restart the EWMA warm-up for the fresh model."""
        self.reg.counter("jct_model_refits_total",
                         "cost-model refits triggered by drift").inc(
                             layer=self.layer)
        self.reg.counter("stale_model_regret_seconds_total",
                         "regret (s) accumulated by stale models before "
                         "their refit").inc(self.regret_s, layer=self.layer)
        self.refits += 1
        self.regret_s = 0.0
        self.observations = 0
        self.ewma = None
        self.reg.gauge("jct_model_regret_seconds",
                       "cumulative |pred - actual| since last refit"
                       ).set(0.0, layer=self.layer)

    def state(self) -> Dict[str, object]:
        """JSON-ready view (bench reports, debugging)."""
        return {"layer": self.layer, "ewma": self.ewma,
                "observations": self.observations,
                "total_observations": self.total_observations,
                "refits": self.refits, "drift_events": self.drift_events,
                "regret_s": self.regret_s,
                "threshold": self.config.threshold}


__all__ = ["DriftConfig", "DriftMonitor", "record_blame",
           "record_component_errors", "record_prediction",
           "REL_ERR_BUCKETS"]
