"""Structured span/event tracing with a stable schema + exporters.

One event type serves all three layers:

  * the **simulator** replaces its bare ``(now, kind, tuple)`` trace entries
    with :class:`TraceEvent` (a compatibility shim on
    :class:`repro.sim.ClusterSim` keeps the legacy tuple view alive);
  * the **engine** wraps its host-side phases (plan compile, host pack, the
    jitted fused program) in spans via the process-global tracer, and
    :func:`spans_from_phase_timings` converts the calibrated per-phase
    device timings of ``measure_phase_timings`` into spans;
  * the **scheduler** emits admission / decision / drain events into the
    cluster tracer it runs on.

Timestamps are EXACT where recorded (the simulator trace must compare
bit-identically across seeded reruns, and consumers like the resume test
need exact event times); rounding happens only in the exporters, so
committed artifacts (golden files, BENCH JSON) stay stable without
perturbing live consumers.

Exporters:

  * :func:`to_jsonl` — one JSON object per line, sorted keys;
  * :func:`to_chrome_trace` — Chrome/Perfetto ``trace_event`` format
    (``{"traceEvents": [...]}``).  Open the file at ``chrome://tracing`` or
    https://ui.perfetto.dev: spans render as nested bars per (pid=job,
    tid=phase lane), instants as marks.  Sim time is seconds and is scaled
    to microseconds on export; engine spans use wall-clock seconds, same
    scaling.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import (Any, Callable, Dict, Iterable, List, Optional, Tuple,
                    Union)

TS_NDIGITS = 12          # exporter-side rounding (float-stable artifacts)


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One structured trace record — the stable schema of the whole system.

    ``ts`` is seconds (sim clock or wall clock, per tracer); ``kind`` is the
    event type (the simulator's event kinds, ``"span"`` for timed spans,
    scheduler ``"sched_*"`` kinds...); ``job_id``/``phase`` are filled where
    the producer knows them; ``labels`` is a sorted tuple of (key, str)
    pairs so events stay hashable and compare deterministically; ``dur`` is
    span duration in seconds (None for instants); ``data`` carries the
    legacy positional payload of the simulator's tuple trace.
    """
    ts: float
    kind: str
    job_id: Optional[int] = None
    phase: Optional[str] = None
    labels: Tuple[Tuple[str, str], ...] = ()
    dur: Optional[float] = None
    data: Tuple[Any, ...] = ()

    def to_dict(self, ndigits: Optional[int] = TS_NDIGITS) -> Dict[str, Any]:
        rnd = (lambda x: x) if ndigits is None else \
            (lambda x: round(float(x), ndigits))
        out: Dict[str, Any] = {"ts": rnd(self.ts), "kind": self.kind}
        if self.job_id is not None:
            out["job_id"] = self.job_id
        if self.phase is not None:
            out["phase"] = self.phase
        if self.labels:
            out["labels"] = dict(self.labels)
        if self.dur is not None:
            out["dur"] = rnd(self.dur)
        if self.data:
            out["data"] = _jsonable(self.data)
        return out


def _jsonable(x: Any) -> Any:
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in sorted(x.items())}
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if hasattr(x, "item"):                    # numpy scalar
        return x.item()
    return str(x)


def _labels_of(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Tracer:
    """Append-only event collector with an injectable clock.

    ``enabled=False`` turns every record call into a near-no-op (one
    attribute check), so instrumented hot paths cost nothing when tracing
    is off — the engine's process-global tracer ships disabled and is
    switched on per run/bench via :func:`enable_tracing`.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 enabled: bool = True) -> None:
        self.clock = clock if clock is not None else time.perf_counter
        self.enabled = enabled
        self.events: List[TraceEvent] = []

    def event(self, kind: str, job_id: Optional[int] = None,
              phase: Optional[str] = None, data: Tuple[Any, ...] = (),
              ts: Optional[float] = None, **labels: Any) -> None:
        """Record an instant event (no-op when disabled)."""
        if not self.enabled:
            return
        self.events.append(TraceEvent(
            self.clock() if ts is None else float(ts), kind, job_id, phase,
            _labels_of(labels), None, tuple(data)))

    def span_at(self, start: float, end: float, kind: str = "span",
                job_id: Optional[int] = None, phase: Optional[str] = None,
                data: Tuple[Any, ...] = (), **labels: Any) -> None:
        """Record a completed span with explicit bounds (the simulator knows
        its phase start/end times; no wall clock involved)."""
        if not self.enabled:
            return
        self.events.append(TraceEvent(
            float(start), kind, job_id, phase, _labels_of(labels),
            float(end) - float(start), tuple(data)))

    @contextlib.contextmanager
    def span(self, phase: str, job_id: Optional[int] = None,
             kind: str = "span", **labels: Any):
        """Context manager measuring a wall-clock span around its body."""
        if not self.enabled:
            yield self
            return
        t0 = self.clock()
        try:
            yield self
        finally:
            self.span_at(t0, self.clock(), kind, job_id, phase, **labels)

    def clear(self) -> None:
        self.events.clear()


# ---------------------------------------------------------------------------
# Process-global tracer (engine + anything without its own clock)
# ---------------------------------------------------------------------------

_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-global tracer.  Disabled by default: enabling it is the
    observability switch for the engine's host-side spans."""
    return _TRACER


def enable_tracing(enabled: bool = True) -> Tracer:
    """Toggle the global tracer; returns it (cleared on enable so a fresh
    run starts with an empty buffer)."""
    _TRACER.enabled = enabled
    if enabled:
        _TRACER.clear()
    return _TRACER


# ---------------------------------------------------------------------------
# Span adapters
# ---------------------------------------------------------------------------

def spans_from_phase_timings(row: Dict[str, Any],
                             tracer: Optional[Tracer] = None,
                             job_id: Optional[int] = None) -> List[TraceEvent]:
    """Convert one ``measure_phase_timings`` row (the calibration feed of
    :func:`repro.mapreduce.engine.measure_phase_timings`) into consecutive
    per-phase device-timing spans, recorded on ``tracer`` (default: the
    global one) and returned.

    The row's phases are laid end to end from t=0 — these are best-of
    per-phase device timings, not one wall-clock run, so the produced
    timeline is the *idealized* pipeline the calibration fit consumes (and
    exactly what the simulator's cost model reproduces)."""
    tracer = tracer if tracer is not None else _TRACER
    meta = {str(k): v for k, v in row.get("meta", {}).items()}
    t = 0.0
    out: List[TraceEvent] = []
    phases = dict(row["seconds"])
    if "shuffle_s" in meta:                  # measured but reported in meta
        phases["shuffle"] = float(meta["shuffle_s"])
    for phase in ("plan_compile", "map", "pack", "shuffle", "reduce"):
        if phase not in phases:
            continue
        dur = float(phases[phase])
        ev = TraceEvent(t, "device_phase", job_id, phase,
                        _labels_of({"job": meta.get("job", ""),
                                    "backend": meta.get("backend", "")}),
                        dur)
        out.append(ev)
        t += dur
    if tracer.enabled:
        tracer.events.extend(out)
    return out


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def to_jsonl(events: Iterable[TraceEvent], path: Optional[str] = None,
             ndigits: Optional[int] = TS_NDIGITS) -> str:
    """JSONL export (one event per line, sorted keys, timestamps rounded to
    ``ndigits`` — rounding lives HERE, not in the producers, so committed
    artifacts are stable while live consumers see exact times)."""
    lines = [json.dumps(e.to_dict(ndigits), sort_keys=True) for e in events]
    text = "\n".join(lines) + ("\n" if lines else "")
    if path is not None:
        with open(path, "w") as f:
            f.write(text)
    return text


def to_chrome_trace(events: Iterable[TraceEvent],
                    path: Optional[str] = None,
                    time_scale: float = 1e6) -> Dict[str, Any]:
    """Chrome/Perfetto ``trace_event`` export.

    Spans (``dur`` set) become complete events (``ph="X"``), instants become
    ``ph="i"`` with thread scope.  ``pid`` is the job id (-1 for cluster-
    scope events), ``tid`` the phase lane (falling back to the kind), and
    timestamps are scaled seconds -> microseconds (``time_scale``).  Load
    the written file in ``chrome://tracing`` or https://ui.perfetto.dev.
    """
    te: List[Dict[str, Any]] = []
    for e in events:
        pid = -1 if e.job_id is None else int(e.job_id)
        tid = e.phase if e.phase is not None else e.kind
        args = dict(e.labels)
        if e.data:
            args["data"] = json.dumps(_jsonable(e.data))
        rec: Dict[str, Any] = {
            "name": e.kind if e.phase is None else f"{e.kind}:{e.phase}",
            "cat": e.kind,
            "ts": round(e.ts * time_scale, 3),
            "pid": pid,
            "tid": tid,
            "args": args,
        }
        if e.dur is not None:
            rec["ph"] = "X"
            rec["dur"] = round(e.dur * time_scale, 3)
        else:
            rec["ph"] = "i"
            rec["s"] = "t"
        te.append(rec)
    doc = {"traceEvents": te, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f, sort_keys=True)
    return doc


def validate_chrome_trace(doc: Union[Dict[str, Any], str]) -> int:
    """Sanity-check a ``trace_event`` document (dict or JSON text): required
    keys present, numeric timestamps, known phase codes.  Returns the event
    count; raises ``ValueError`` on malformed input.  Used by the bench to
    assert exported traces really load."""
    if isinstance(doc, str):
        doc = json.loads(doc)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("missing traceEvents list")
    for i, e in enumerate(events):
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in e:
                raise ValueError(f"traceEvents[{i}] missing {k!r}")
        if not isinstance(e["ts"], (int, float)):
            raise ValueError(f"traceEvents[{i}].ts not numeric")
        if e["ph"] not in ("X", "i", "B", "E", "M"):
            raise ValueError(f"traceEvents[{i}].ph unknown: {e['ph']!r}")
        if e["ph"] == "X" and not isinstance(e.get("dur"), (int, float)):
            raise ValueError(f"traceEvents[{i}] span without numeric dur")
    return len(events)


__all__ = [
    "TraceEvent", "Tracer", "get_tracer", "enable_tracing",
    "spans_from_phase_timings", "to_jsonl", "to_chrome_trace",
    "validate_chrome_trace", "TS_NDIGITS",
]
