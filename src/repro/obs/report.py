"""Standalone observatory report: one page that answers "what did the
system just do, and does its model of the machine still hold?".

:func:`build_report` folds a metrics snapshot + trace events into a plain
structured dict; :func:`render_markdown` / :func:`render_html` turn that
into a committed-artifact-friendly page with four sections:

  * **metrics snapshot** — every counter/gauge series, histograms
    summarized as count/sum/mean;
  * **prediction-error distributions** — the ``jct_prediction_*``
    histograms (absolute seconds and relative error) per layer, rendered
    as cumulative bucket tables, plus the drift gauges
    (``jct_drift_ewma``, ``jct_model_regret_seconds``);
  * **per-rack byte matrices** — ``rack_pair_bytes_total`` re-assembled
    into the [P, P] cross-rack matrix per layer (the paper's central
    quantity, as actually moved);
  * **link utilization** — per-resource (root / ToR uplinks) busy time,
    utilization fraction, mean active flows and a binned activity
    timeline, from :class:`repro.sim.NetworkTelemetry`;
  * **JCT blame** — per-job blame decomposition table
    (:mod:`repro.obs.blame`, components sum to measured JCT) plus the
    fleet-level p99 rollup — what is making the tail slow;
  * **wasted work** — ``flow_cancelled_bytes_total`` by (stage, reason):
    partially-drained value-units of cancelled flows (speculation
    losers, crash-voided stages);
  * **trace summary** — event counts by kind and total span seconds per
    (kind, phase) lane.

``python -m repro.obs.report`` (``make obs-report``) runs a small seeded
scheduled-sim demo to populate the registry and writes
``bench_out/obs_report.md`` + ``.html``; pass ``--no-demo`` to render
whatever the process registry already holds (e.g. from a bench that
imports this module at exit).  Zero dependencies beyond the stdlib.
"""
from __future__ import annotations

import html as _html
import json
from typing import Dict, List, Optional, Sequence

from . import metrics as _metrics


# ---------------------------------------------------------------------------
# Fold snapshot + events into one structured report dict
# ---------------------------------------------------------------------------

def _series(snap: Dict, name: str) -> Dict[str, object]:
    return snap.get(name, {}).get("samples", {})


def _resource_order(key: str):
    # "root" first, then ToR uplinks in rack order
    if key == "root":
        return (0, 0)
    if key.startswith("tor:"):
        return (1, int(key.split(":", 1)[1]))
    return (2, 0)


_SPARK = " ▁▂▃▄▅▆▇█"


def _activity_timeline(series: Sequence[Sequence[float]], horizon: float,
                       bins: int = 32) -> str:
    """Binned sparkline of time-weighted mean active flows over the run —
    the compact "when was this link busy" view of a sample series."""
    if not series or horizon <= series[0][0]:
        return ""
    t0 = series[0][0]
    width = (horizon - t0) / bins
    weighted = [0.0] * bins
    for i, row in enumerate(series):
        t, active = float(row[0]), float(row[1])
        t_next = float(series[i + 1][0]) if i + 1 < len(series) else horizon
        lo, hi = max(t, t0), min(t_next, horizon)
        if hi <= lo or active <= 0:
            continue
        b0 = min(int((lo - t0) / width), bins - 1)
        b1 = min(int((hi - t0) / width - 1e-12), bins - 1)
        for b in range(b0, b1 + 1):
            seg = min(hi, t0 + (b + 1) * width) - max(lo, t0 + b * width)
            weighted[b] += active * max(seg, 0.0)
    peak = max(weighted)
    if peak <= 0:
        return _SPARK[0] * bins
    return "".join(
        _SPARK[min(int(w / peak * (len(_SPARK) - 1) + 0.5),
                   len(_SPARK) - 1)] for w in weighted)


def _utilization_section(telemetry) -> List[Dict[str, object]]:
    """Per-resource rollup rows from a :class:`repro.sim.NetworkTelemetry`
    (or any object with the same ``utilization()``/``samples`` shape)."""
    if telemetry is None:
        return []
    util = telemetry.utilization()
    samples = getattr(telemetry, "samples", {})
    horizon = max((s[-1][0] for s in samples.values() if s), default=0.0)
    rows = []
    for key in sorted(util, key=_resource_order):
        u = util[key]
        rows.append({"resource": key, **u,
                     "timeline": _activity_timeline(samples.get(key, ()),
                                                    horizon)})
    return rows


def _blame_section(stats: Optional[Sequence]) -> Dict[str, object]:
    """Per-job blame table + fleet rollup from completed-job stats (any
    objects accepted by :func:`repro.obs.blame.blame_report`, or
    ready-made :class:`BlameReport` instances).  Jobs without a blame
    decomposition (e.g. crashed before finishing) are skipped."""
    from . import blame as _blame
    reports = []
    for s in stats or ():
        if isinstance(s, _blame.BlameReport):
            reports.append(s)
        elif getattr(s, "blame", None) is not None:
            reports.append(_blame.blame_report(s))
    if not reports:
        return {}
    # only show components that matter somewhere in the fleet
    active = [c for c in _blame.COMPONENTS
              if any(abs(r.components.get(c, 0.0)) > 0 for r in reports)]
    jobs = [{"job_id": r.job_id, "name": r.name, "scheme": r.scheme,
             "r": r.r, "jct": r.jct, "dominant": r.dominant(),
             "residual": r.residual,
             "components": {c: r.components.get(c, 0.0) for c in active}}
            for r in sorted(reports, key=lambda r: r.job_id)]
    return {"components": active, "jobs": jobs,
            "fleet": _blame.fleet_blame(reports)}


def _wasted_section(snap: Dict) -> List[Dict[str, object]]:
    rows = []
    for labels_json, v in sorted(
            _series(snap, "flow_cancelled_bytes_total").items()):
        lb = json.loads(labels_json)
        rows.append({"stage": lb.get("stage", ""),
                     "reason": lb.get("reason", ""), "units": float(v)})
    return rows


def build_report(snapshot: Optional[Dict] = None,
                 events: Optional[Sequence] = None,
                 title: str = "Observatory report",
                 telemetry=None,
                 stats: Optional[Sequence] = None) -> Dict[str, object]:
    """Structured report from a registry ``snapshot`` (default registry's
    if None) and optional :class:`repro.obs.TraceEvent` sequence.

    ``telemetry`` (a :class:`repro.sim.NetworkTelemetry`) adds the
    link-utilization section; ``stats`` (completed-job stats or
    :class:`BlameReport` instances) adds the per-job blame table and the
    fleet p99 rollup.  Both default to empty sections when absent, so the
    report renders from a bare registry too."""
    snap = snapshot if snapshot is not None else _metrics.snapshot()
    scalars: List[Dict[str, object]] = []
    hist_summary: List[Dict[str, object]] = []
    pred_hists: List[Dict[str, object]] = []
    for name in sorted(snap):
        meta = snap[name]
        for labels_json, val in meta.get("samples", {}).items():
            if meta.get("type") == "histogram":
                row = {"name": name, "labels": labels_json,
                       "count": val["count"], "sum": val["sum"],
                       "mean": (val["sum"] / val["count"]
                                if val["count"] else 0.0)}
                hist_summary.append(row)
                if name.startswith("jct_prediction"):
                    pred_hists.append({**row, "buckets": val["buckets"],
                                       "counts": val["counts"]})
            else:
                scalars.append({"name": name, "kind": meta.get("type"),
                                "labels": labels_json, "value": val})
    drift_gauges = [s for s in scalars
                    if s["name"] in ("jct_drift_ewma",
                                     "jct_model_regret_seconds")]

    # rack matrices: {"src": i, "dst": j, "layer": l} -> [P, P] per layer
    matrices: Dict[str, Dict] = {}
    for labels_json, v in _series(snap, "rack_pair_bytes_total").items():
        lb = json.loads(labels_json)
        layer = lb.get("layer", "")
        m = matrices.setdefault(layer, {})
        m[(int(lb["src"]), int(lb["dst"]))] = float(v)
    rack_matrices = {}
    for layer, cells in sorted(matrices.items()):
        P = 1 + max(max(s, t) for s, t in cells)
        mat = [[cells.get((s, t), 0.0) for t in range(P)] for s in range(P)]
        rack_matrices[layer] = mat

    trace: Dict[str, object] = {}
    if events:
        by_kind: Dict[str, int] = {}
        span_s: Dict[str, float] = {}
        for ev in events:
            by_kind[ev.kind] = by_kind.get(ev.kind, 0) + 1
            if ev.dur is not None:
                lane = f"{ev.kind}:{ev.phase}" if ev.phase else ev.kind
                span_s[lane] = span_s.get(lane, 0.0) + float(ev.dur)
        trace = {"n_events": len(events),
                 "by_kind": dict(sorted(by_kind.items())),
                 "span_seconds": {k: span_s[k] for k in sorted(span_s)}}

    return {"title": title, "scalars": scalars,
            "histograms": hist_summary, "prediction_hists": pred_hists,
            "drift_gauges": drift_gauges, "rack_matrices": rack_matrices,
            "link_utilization": _utilization_section(telemetry),
            "blame": _blame_section(stats),
            "wasted": _wasted_section(snap),
            "trace": trace}


# ---------------------------------------------------------------------------
# Renderers (markdown + standalone HTML from the same structure)
# ---------------------------------------------------------------------------

def _md_table(headers: Sequence[str], rows: Sequence[Sequence]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)


def _fmt(v: object) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def render_markdown(report: Dict[str, object]) -> str:
    lines = [f"# {report['title']}", ""]
    lines += ["## Metrics snapshot", ""]
    if report["scalars"]:
        lines.append(_md_table(
            ("metric", "kind", "labels", "value"),
            [(s["name"], s["kind"], f"`{s['labels']}`", _fmt(s["value"]))
             for s in report["scalars"]]))
    else:
        lines.append("_registry is empty_")
    if report["histograms"]:
        lines += ["", _md_table(
            ("histogram", "labels", "count", "sum", "mean"),
            [(h["name"], f"`{h['labels']}`", h["count"], _fmt(h["sum"]),
              _fmt(h["mean"])) for h in report["histograms"]])]

    lines += ["", "## Prediction-error distributions", ""]
    if report["prediction_hists"]:
        for h in report["prediction_hists"]:
            lines += [f"### `{h['name']}` {h['labels']}", "",
                      f"n={h['count']}  sum={_fmt(h['sum'])}  "
                      f"mean={_fmt(h['mean'])}", "",
                      _md_table(("bucket &le;", "cumulative count"),
                                list(zip(map(str, h["buckets"]),
                                         h["counts"]))), ""]
        if report["drift_gauges"]:
            lines += [_md_table(
                ("drift gauge", "labels", "value"),
                [(g["name"], f"`{g['labels']}`", _fmt(g["value"]))
                 for g in report["drift_gauges"]]), ""]
    else:
        lines += ["_no predictions recorded_", ""]

    lines += ["## Per-rack byte matrices (cross-rack value-units)", ""]
    if report["rack_matrices"]:
        for layer, mat in report["rack_matrices"].items():
            P = len(mat)
            lines += [f"### layer `{layer or '(none)'}`", "",
                      _md_table(["src\\dst"] + [str(j) for j in range(P)],
                                [[str(i)] + [_fmt(v) for v in row]
                                 for i, row in enumerate(mat)]), ""]
    else:
        lines += ["_no rack-level bytes recorded_", ""]

    lines += ["## Link utilization", ""]
    util_rows = report.get("link_utilization") or []
    if util_rows:
        lines += [_md_table(
            ("resource", "busy s", "util", "mean active", "peak backlog",
             "done", "cancelled", "activity timeline"),
            [(u["resource"], _fmt(u["busy_s"]), _fmt(u["util"]),
              _fmt(u["mean_active_flows"]), _fmt(u["peak_backlog"]),
              u["flows_done"], u["flows_cancelled"],
              f"`{u['timeline']}`" if u["timeline"] else "")
             for u in util_rows]), ""]
    else:
        lines += ["_no network telemetry provided_", ""]

    lines += ["## JCT blame decomposition", ""]
    bl = report.get("blame") or {}
    if bl:
        comps = bl["components"]
        lines += [_md_table(
            ["job", "name", "scheme", "r", "JCT", "dominant"] + comps,
            [[j["job_id"], j["name"], j["scheme"], j["r"], _fmt(j["jct"]),
              j["dominant"]] + [_fmt(j["components"][c]) for c in comps]
             for j in bl["jobs"]]), ""]
        fl = bl["fleet"]
        lines += [f"fleet rollup over n={fl['n']} jobs "
                  f"(q={fl['q']:g}): mean JCT {_fmt(fl['jct_mean'])} s, "
                  f"p{int(fl['q'] * 100)} JCT {_fmt(fl['jct_q'])} s, "
                  f"max |residual| {_fmt(fl['max_abs_residual'])} s", "",
                  _md_table(
                      ("component", "fleet mean s", f"p{int(fl['q'] * 100)} s",
                       "tail mean s", "tail share"),
                      [(c, _fmt(fl["mean"][c]), _fmt(fl["quantile"][c]),
                        _fmt(fl["tail_mean"][c]), _fmt(fl["tail_share"][c]))
                       for c in comps if c in fl["mean"]]), ""]
    else:
        lines += ["_no completed-job blame provided_", ""]

    lines += ["## Wasted work (cancelled flows)", ""]
    wasted = report.get("wasted") or []
    if wasted:
        lines += [_md_table(
            ("stage", "reason", "drained value-units"),
            [(w["stage"], w["reason"], _fmt(w["units"]))
             for w in wasted]), ""]
    else:
        lines += ["_no cancelled-flow bytes recorded_", ""]

    lines += ["## Trace summary", ""]
    tr = report["trace"]
    if tr:
        lines.append(f"{tr['n_events']} events")
        lines += ["", _md_table(("event kind", "count"),
                                sorted(tr["by_kind"].items()))]
        if tr["span_seconds"]:
            lines += ["", _md_table(
                ("span lane", "total seconds"),
                [(k, _fmt(v)) for k, v in tr["span_seconds"].items()])]
    else:
        lines.append("_no trace events provided_")
    return "\n".join(lines) + "\n"


_HTML_STYLE = """
body { font-family: system-ui, sans-serif; margin: 2rem; color: #222; }
table { border-collapse: collapse; margin: 0.5rem 0 1.25rem; }
th, td { border: 1px solid #ccc; padding: 0.25rem 0.6rem;
         font-size: 0.85rem; text-align: right; }
th { background: #f0f0f3; }
td:first-child, th:first-child { text-align: left; }
code { background: #f5f5f7; padding: 0 0.2rem; }
h2 { border-bottom: 2px solid #e0e0e6; padding-bottom: 0.2rem; }
"""


def _html_table(headers: Sequence[str], rows: Sequence[Sequence]) -> str:
    head = "".join(f"<th>{_html.escape(str(h))}</th>" for h in headers)
    body = "".join(
        "<tr>" + "".join(f"<td>{_html.escape(str(c))}</td>" for c in r)
        + "</tr>" for r in rows)
    return f"<table><tr>{head}</tr>{body}</table>"


def render_html(report: Dict[str, object]) -> str:
    h: List[str] = ["<!doctype html><html><head><meta charset='utf-8'>",
                    f"<title>{_html.escape(str(report['title']))}</title>",
                    f"<style>{_HTML_STYLE}</style></head><body>",
                    f"<h1>{_html.escape(str(report['title']))}</h1>"]
    h.append("<h2>Metrics snapshot</h2>")
    if report["scalars"]:
        h.append(_html_table(
            ("metric", "kind", "labels", "value"),
            [(s["name"], s["kind"], s["labels"], _fmt(s["value"]))
             for s in report["scalars"]]))
    if report["histograms"]:
        h.append(_html_table(
            ("histogram", "labels", "count", "sum", "mean"),
            [(x["name"], x["labels"], x["count"], _fmt(x["sum"]),
              _fmt(x["mean"])) for x in report["histograms"]]))

    h.append("<h2>Prediction-error distributions</h2>")
    if report["prediction_hists"]:
        for x in report["prediction_hists"]:
            h.append(f"<h3><code>{_html.escape(x['name'])}</code> "
                     f"{_html.escape(x['labels'])}</h3>")
            h.append(f"<p>n={x['count']} sum={_fmt(x['sum'])} "
                     f"mean={_fmt(x['mean'])}</p>")
            h.append(_html_table(("bucket ≤", "cumulative count"),
                                 list(zip(map(str, x["buckets"]),
                                          x["counts"]))))
        if report["drift_gauges"]:
            h.append(_html_table(
                ("drift gauge", "labels", "value"),
                [(g["name"], g["labels"], _fmt(g["value"]))
                 for g in report["drift_gauges"]]))
    else:
        h.append("<p><em>no predictions recorded</em></p>")

    h.append("<h2>Per-rack byte matrices</h2>")
    for layer, mat in report["rack_matrices"].items():
        P = len(mat)
        h.append(f"<h3>layer <code>{_html.escape(layer or '(none)')}"
                 f"</code></h3>")
        h.append(_html_table(
            ["src\\dst"] + [str(j) for j in range(P)],
            [[str(i)] + [_fmt(v) for v in row]
             for i, row in enumerate(mat)]))

    h.append("<h2>Link utilization</h2>")
    util_rows = report.get("link_utilization") or []
    if util_rows:
        h.append(_html_table(
            ("resource", "busy s", "util", "mean active", "peak backlog",
             "done", "cancelled", "activity timeline"),
            [(u["resource"], _fmt(u["busy_s"]), _fmt(u["util"]),
              _fmt(u["mean_active_flows"]), _fmt(u["peak_backlog"]),
              u["flows_done"], u["flows_cancelled"], u["timeline"])
             for u in util_rows]))
    else:
        h.append("<p><em>no network telemetry provided</em></p>")

    h.append("<h2>JCT blame decomposition</h2>")
    bl = report.get("blame") or {}
    if bl:
        comps = bl["components"]
        h.append(_html_table(
            ["job", "name", "scheme", "r", "JCT", "dominant"] + comps,
            [[j["job_id"], j["name"], j["scheme"], j["r"], _fmt(j["jct"]),
              j["dominant"]] + [_fmt(j["components"][c]) for c in comps]
             for j in bl["jobs"]]))
        fl = bl["fleet"]
        h.append(f"<p>fleet rollup over n={fl['n']} jobs "
                 f"(q={fl['q']:g}): mean JCT {_fmt(fl['jct_mean'])} s, "
                 f"p{int(fl['q'] * 100)} JCT {_fmt(fl['jct_q'])} s, "
                 f"max |residual| {_fmt(fl['max_abs_residual'])} s</p>")
        h.append(_html_table(
            ("component", "fleet mean s", f"p{int(fl['q'] * 100)} s",
             "tail mean s", "tail share"),
            [(c, _fmt(fl["mean"][c]), _fmt(fl["quantile"][c]),
              _fmt(fl["tail_mean"][c]), _fmt(fl["tail_share"][c]))
             for c in comps if c in fl["mean"]]))
    else:
        h.append("<p><em>no completed-job blame provided</em></p>")

    h.append("<h2>Wasted work (cancelled flows)</h2>")
    wasted = report.get("wasted") or []
    if wasted:
        h.append(_html_table(
            ("stage", "reason", "drained value-units"),
            [(w["stage"], w["reason"], _fmt(w["units"])) for w in wasted]))
    else:
        h.append("<p><em>no cancelled-flow bytes recorded</em></p>")

    h.append("<h2>Trace summary</h2>")
    tr = report["trace"]
    if tr:
        h.append(f"<p>{tr['n_events']} events</p>")
        h.append(_html_table(("event kind", "count"),
                             sorted(tr["by_kind"].items())))
        if tr["span_seconds"]:
            h.append(_html_table(
                ("span lane", "total seconds"),
                [(k, _fmt(v)) for k, v in tr["span_seconds"].items()]))
    else:
        h.append("<p><em>no trace events provided</em></p>")
    h.append("</body></html>")
    return "".join(h)


def write_report(path: str, report: Optional[Dict] = None,
                 events: Optional[Sequence] = None,
                 title: str = "Observatory report",
                 telemetry=None, stats: Optional[Sequence] = None) -> str:
    """Render ``report`` (built from the default registry when None) to
    ``path``; the extension picks the format (.html -> HTML, else
    markdown).  Returns the path."""
    rep = report if report is not None else build_report(
        events=events, title=title, telemetry=telemetry, stats=stats)
    text = (render_html(rep) if path.endswith((".html", ".htm"))
            else render_markdown(rep))
    with open(path, "w") as f:
        f.write(text)
    return path


# ---------------------------------------------------------------------------
# Demo CLI: populate the registry with a seeded scheduled-sim run, render
# ---------------------------------------------------------------------------

def _demo_populate(seed: int = 0):
    """Seeded scheduled workload through the simulator so every section of
    the report has real content; returns (trace events, network telemetry,
    per-job stats)."""
    from ..sim import (ClusterSim, MultiJobScheduler, PoissonWorkload,
                      RackTopology, SchemeChooser, default_catalog)
    from ..sim.cluster import CostModel, PhaseCoeffs
    _metrics.reset()
    topo = RackTopology(P=4, cross_bw=2e4, intra_bw=2e5)
    cluster = ClusterSim(topo, K=8, seed=seed, telemetry=True)
    cm = CostModel(map=PhaseCoeffs(1e-3, 2e-7),
                   pack=PhaseCoeffs(5e-4, 1e-7),
                   reduce=PhaseCoeffs(1e-3, 2e-7))
    chooser = SchemeChooser(8, cost_model=cm, compile_real_plans=False)
    wl = PoissonWorkload(default_catalog(8, 4), n_jobs=24, rate=2.0)
    sched = MultiJobScheduler(chooser, policy="srpt", max_concurrent=4)
    stats = sched.run(wl.generate(seed), cluster)
    _metrics.refresh_cache_metrics()
    return list(cluster.tracer.events), cluster.telemetry, stats


def main(argv: Optional[List[str]] = None) -> None:
    import argparse
    import os
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default="bench_out",
                    help="directory for obs_report.md / obs_report.html")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-demo", action="store_true",
                    help="render the current process registry instead of "
                         "running the seeded demo workload")
    args = ap.parse_args(argv)
    events: Optional[List] = None
    telemetry = stats = None
    if not args.no_demo:
        events, telemetry, stats = _demo_populate(args.seed)
    os.makedirs(args.out_dir, exist_ok=True)
    rep = build_report(events=events, telemetry=telemetry, stats=stats)
    for name in ("obs_report.md", "obs_report.html"):
        path = write_report(os.path.join(args.out_dir, name), rep)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()


__all__ = ["build_report", "render_markdown", "render_html",
           "write_report", "main"]
