"""Rack-level byte accounting as a first-class instrument.

The paper's central quantity is the split of shuffle traffic into
intra-rack and cross-rack <key, value> pairs.  This module derives the
per-(src_rack, dst_rack) transfer matrix of the ACTUAL compiled plan
(:func:`repro.core.coded_collectives.plan_transfer_matrices`, which also
handles degraded plans), scales it to value-units (pairs x payload width
``d`` — the unit the fluid network and cost model share), records it into
the metrics registry, and asserts the measured schedule reconciles with the
``CommCost`` closed forms (Props 1-2 / Thm III.1 / the resolvable family's
closed form).

Three counting conventions appear; keep them straight:

  * **paper metric** (``multicast='coded'``): a coded multicast packet
    traverses the root ONCE — this is what ``CommCost`` closed forms count
    and what ``intra_rack_bytes`` / ``cross_rack_bytes`` on ``JobResult``
    and ``JobStats`` report, so engine and sim agree by construction;
  * **wire format** (``multicast='unicast'``): each destination stream is a
    separate copy — what a unicast realization actually moves;
  * **degraded**: recovery runs unicast (the multicast gain is forfeited),
    so its matrix comes straight from the degraded plan's 4-dim
    ``cross_valid`` routing, plus one per-rack redistribution of each
    re-mapped orphan subfile (``n_remap * Q`` pairs — the same term
    :func:`repro.core.degraded.degraded_stage_traffic` prices).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from . import metrics as _metrics


class ByteReconciliationError(AssertionError):
    """Measured schedule bytes do not match the closed-form ``CommCost`` —
    either the plan compiler and the cost theorems disagree (a real bug) or
    the caller mixed counting conventions (see module docstring)."""


@dataclasses.dataclass(frozen=True)
class RackBytes:
    """Value-unit transfer accounting of one shuffle schedule.

    ``cross_matrix[src, dst]`` is stage-1 root-switch value-units from rack
    src to rack dst; ``intra_per_rack[rack]`` stage-2 units through that
    rack's ToR.  ``d`` is the payload width the pair counts were scaled by.
    """
    cross_matrix: np.ndarray          # [P, P]
    intra_per_rack: np.ndarray        # [P]
    d: int = 1

    @property
    def cross_total(self) -> float:
        return float(self.cross_matrix.sum())

    @property
    def intra_total(self) -> float:
        return float(self.intra_per_rack.sum())

    def to_dict(self) -> Dict[str, object]:
        return {"cross_matrix": self.cross_matrix.tolist(),
                "intra_per_rack": self.intra_per_rack.tolist(),
                "cross_total": self.cross_total,
                "intra_total": self.intra_total, "d": int(self.d)}


def plan_rack_bytes(plan, multicast: str = "coded", d: int = 1) -> RackBytes:
    """Rack-level value-units of a compiled plan (failure-free OR degraded —
    ``plan_transfer_matrices`` dispatches on the ``cross_valid`` schema).

    ``multicast='coded'`` counts the paper metric; ``'unicast'`` the wire
    format of a unicast realization.  Accepts a ``HybridShufflePlan`` or a
    :class:`repro.core.degraded.DegradedPlan` (its re-routed plan is used).
    """
    from ..core.coded_collectives import plan_transfer_matrices
    inner = getattr(plan, "plan", plan)       # DegradedPlan -> its tables
    tm = plan_transfer_matrices(inner, multicast=multicast)
    return RackBytes(np.asarray(tm["cross_rack_matrix"], dtype=float) * d,
                     np.asarray(tm["intra_per_rack"], dtype=float) * d, d)


def degraded_rack_bytes(dplan, d: int = 1) -> RackBytes:
    """Value-units of a degraded recovery schedule: the unicast degraded
    routing plus the orphan-redistribution term (each re-mapped subfile's
    [Q, d] values reach every rack once — priced identically by the sim's
    crash recovery).  The redistribution has no single (src, dst) pair, so
    it is spread uniformly over off-diagonal entries to keep the matrix
    total exact."""
    rb = plan_rack_bytes(dplan, multicast="unicast", d=d)
    n_remap = int(dplan.orphan_subfiles.size)
    if n_remap == 0:
        return rb
    p = dplan.params
    extra = float(n_remap * p.Q * d)
    cross = rb.cross_matrix.copy()
    off = p.P * (p.P - 1)
    if off > 0:
        add = np.full((p.P, p.P), extra / off)
        np.fill_diagonal(add, 0.0)
        cross = cross + add
    return RackBytes(cross, rb.intra_per_rack, d)


def closed_form_bytes(p, scheme: str, d: int = 1,
                      check: bool = False) -> Dict[str, float]:
    """``CommCost`` closed form of ``scheme`` scaled to value-units:
    {'intra', 'cross', 'total'}.  ``check=False`` (default) evaluates the
    formula even on divisibility-violating Table I rows, as the paper did.
    """
    from ..core.costs import (coded_cost, hybrid_cost,
                              hybrid_resolvable_cost, uncoded_cost)
    fn = {"uncoded": uncoded_cost, "coded": coded_cost,
          "hybrid": hybrid_cost,
          "hybrid_resolvable": hybrid_resolvable_cost}[scheme]
    c = fn(p, check=check)
    return {"intra": c.intra * d, "cross": c.cross * d,
            "total": c.total * d}


def reconcile(measured_intra: float, measured_cross: float, p, scheme: str,
              d: int = 1, rtol: float = 1e-9, atol: float = 1e-6,
              check: bool = False) -> Dict[str, float]:
    """Assert measured schedule bytes equal the closed form; returns the
    comparison report.  Raises :class:`ByteReconciliationError` with both
    sides on mismatch — the invariant every instrumented job run re-checks
    (the simulated/executed traffic IS the schedule, not a formula, so this
    equality is a theorem being re-proven per job)."""
    cf = closed_form_bytes(p, scheme, d=d, check=check)
    report = {"measured_intra": float(measured_intra),
              "measured_cross": float(measured_cross),
              "closed_intra": cf["intra"], "closed_cross": cf["cross"]}
    for tier in ("intra", "cross"):
        m, c = report[f"measured_{tier}"], report[f"closed_{tier}"]
        if abs(m - c) > atol + rtol * max(abs(m), abs(c)):
            raise ByteReconciliationError(
                f"{tier}-rack bytes do not reconcile for scheme={scheme!r} "
                f"{p}: measured {m!r} != closed-form {c!r}")
    return report


def record_rack_bytes(rb: RackBytes, scheme: str, family: str = "",
                      layer: str = "engine",
                      reg: Optional[_metrics.MetricsRegistry] = None
                      ) -> RackBytes:
    """Record a schedule's rack-level bytes into the metrics registry:

      * ``shuffle_bytes_total{tier=intra|cross, scheme, family, layer}`` —
        the paper's headline split, cumulative across jobs;
      * ``rack_pair_bytes_total{src, dst, layer}`` — the [P, P] matrix
        (bounded cardinality: P^2 label sets for the cluster's fixed P).

    Returns ``rb`` unchanged so call sites can thread it through."""
    reg = reg if reg is not None else _metrics.registry()
    tot = reg.counter("shuffle_bytes_total",
                      "shuffle value-units moved, by tier")
    tot.inc(rb.intra_total, tier="intra", scheme=scheme, family=family,
            layer=layer)
    tot.inc(rb.cross_total, tier="cross", scheme=scheme, family=family,
            layer=layer)
    pair = reg.counter("rack_pair_bytes_total",
                       "cross-rack value-units per (src, dst) rack pair")
    P = rb.cross_matrix.shape[0]
    for src in range(P):
        for dst in range(P):
            v = float(rb.cross_matrix[src, dst])
            if v > 0:
                pair.inc(v, src=src, dst=dst, layer=layer)
    return rb


__all__ = [
    "RackBytes", "ByteReconciliationError", "plan_rack_bytes",
    "degraded_rack_bytes", "closed_form_bytes", "reconcile",
    "record_rack_bytes",
]
