"""Process-local metrics registry: counters, gauges, histograms with labels.

Zero-dependency (stdlib + nothing), deliberately tiny: the point is ONE
shared schema for every counter the system grew ad hoc — plan-cache and
degraded-cache hit/miss/eviction, chooser decisions per (scheme, r, family),
recovery-ladder rungs, restart-budget consumption, sim crash/remap counts,
and the rack-level byte accounting of :mod:`repro.obs.bytes` — instead of
one bespoke NamedTuple per subsystem.

Usage::

    from repro.obs import metrics
    metrics.counter("chooser_decisions_total").inc(
        scheme="hybrid", r="2", family="binomial")
    snap = metrics.snapshot()          # plain nested dict, JSON-ready
    metrics.reset()                    # zero everything (tests, benches)

Design constraints (all load-bearing):

  * **Deterministic snapshots** — label sets and metric names are emitted
    sorted, so two identical runs produce byte-identical ``snapshot()``
    JSON (the same bit-reproducibility contract the simulator trace keeps).
  * **Bounded label cardinality** — each metric refuses more than
    ``max_label_sets`` distinct label combinations (a runaway label like a
    raw job id cannot OOM the registry); the cap is per-metric and
    configurable at declaration.
  * **Cheap when idle** — recording is a dict upsert; there is no I/O, no
    locking beyond the GIL, no background thread.  The < 5 % instrumented
    overhead bound on the smoke pipeline is pinned in ``BENCH_obs.json``.

The existing cache introspection stays where it is
(:func:`repro.core.coded_collectives.plan_cache_info`,
:func:`repro.core.degraded.degraded_cache_info` — core must stay importable
without obs); :func:`collect_cache_metrics` pulls both into the registry
under the unified schema on demand.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

DEFAULT_MAX_LABEL_SETS = 4096

DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0,
                   float("inf"))


class LabelCardinalityError(RuntimeError):
    """A metric exceeded its ``max_label_sets`` bound — almost always a
    label that should not be a label (a job id, a timestamp, raw bytes)."""


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    """Shared label bookkeeping of all three metric kinds."""

    kind = "abstract"

    def __init__(self, name: str, help: str = "",
                 max_label_sets: int = DEFAULT_MAX_LABEL_SETS) -> None:
        self.name = name
        self.help = help
        self.max_label_sets = int(max_label_sets)
        self._series: Dict[LabelKey, object] = {}

    def _slot(self, labels: Dict[str, object], default) -> LabelKey:
        key = _label_key(labels)
        if key not in self._series:
            if len(self._series) >= self.max_label_sets:
                raise LabelCardinalityError(
                    f"metric {self.name!r} exceeded max_label_sets="
                    f"{self.max_label_sets}; offending labels: "
                    f"{dict(key)!r}")
            self._series[key] = default
        return key

    def reset(self) -> None:
        self._series.clear()

    def snapshot(self) -> Dict[str, object]:
        samples = {json.dumps(dict(k), sort_keys=True): self._export(v)
                   for k, v in sorted(self._series.items())}
        return {"type": self.kind, "help": self.help, "samples": samples}

    def _export(self, value: object) -> object:
        return value


class Counter(_Metric):
    """Monotonically increasing per-label-set float."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels: object) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        key = self._slot(labels, 0.0)
        self._series[key] = float(self._series[key]) + float(value)

    def value(self, **labels: object) -> float:
        return float(self._series.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """Set-to-current-value per label set (cache sizes, backlog, clock)."""

    kind = "gauge"

    def set(self, value: float, **labels: object) -> None:
        key = self._slot(labels, 0.0)
        self._series[key] = float(value)

    def add(self, value: float, **labels: object) -> None:
        key = self._slot(labels, 0.0)
        self._series[key] = float(self._series[key]) + float(value)

    def value(self, **labels: object) -> float:
        return float(self._series.get(_label_key(labels), 0.0))


@dataclasses.dataclass
class _HistState:
    counts: List[int]
    total: float = 0.0
    n: int = 0


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus convention: ``counts[i]``
    observations <= ``buckets[i]``; the last bucket is +inf)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS,
                 max_label_sets: int = DEFAULT_MAX_LABEL_SETS) -> None:
        super().__init__(name, help, max_label_sets)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs or bs[-1] != float("inf"):
            bs = bs + (float("inf"),)
        self.buckets = bs

    def observe(self, value: float, **labels: object) -> None:
        key = self._slot(labels, None)
        st = self._series[key]
        if st is None:
            st = _HistState(counts=[0] * len(self.buckets))
            self._series[key] = st
        for i, b in enumerate(self.buckets):
            if value <= b:
                st.counts[i] += 1
        st.total += float(value)
        st.n += 1

    def _export(self, st: _HistState) -> Dict[str, object]:
        return {"buckets": [b if b != float("inf") else "inf"
                            for b in self.buckets],
                "counts": list(st.counts), "sum": st.total, "count": st.n}


# ---------------------------------------------------------------------------
# Prometheus text exposition helpers
# ---------------------------------------------------------------------------

def _prom_metric_name(name: str) -> str:
    """Sanitize to the Prometheus metric-name charset
    ``[a-zA-Z_:][a-zA-Z0-9_:]*`` (invalid characters become ``_``)."""
    out = [c if (c.isascii() and (c.isalnum() or c in "_:")) else "_"
           for c in name]
    if not out:
        return "_"
    if out[0].isdigit():
        out.insert(0, "_")
    return "".join(out)


def _prom_label_name(name: str) -> str:
    """Label names allow ``[a-zA-Z_][a-zA-Z0-9_]*`` (no colon)."""
    out = [c if (c.isascii() and (c.isalnum() or c == "_")) else "_"
           for c in name]
    if not out:
        return "_"
    if out[0].isdigit():
        out.insert(0, "_")
    return "".join(out)


def _prom_label_value(value: str) -> str:
    """Escape per the exposition format: backslash, double-quote, newline."""
    return (value.replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _prom_labels(pairs: List[Tuple[str, str]]) -> str:
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def _prom_number(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    if v != v:
        return "NaN"
    return repr(float(v))


class MetricsRegistry:
    """Name -> metric map with declare-on-first-use semantics.

    Re-declaring a name returns the SAME metric object (so call sites never
    need to share handles), but re-declaring with a different kind raises —
    a counter silently becoming a gauge is a bug, not a feature.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}

    def _declare(self, cls, name: str, help: str, **kwargs) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already declared as {m.kind}, "
                    f"cannot redeclare as {cls.kind}")
            return m
        m = cls(name, help, **kwargs)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                max_label_sets: int = DEFAULT_MAX_LABEL_SETS) -> Counter:
        return self._declare(Counter, name, help,
                             max_label_sets=max_label_sets)

    def gauge(self, name: str, help: str = "",
              max_label_sets: int = DEFAULT_MAX_LABEL_SETS) -> Gauge:
        return self._declare(Gauge, name, help,
                             max_label_sets=max_label_sets)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS,
                  max_label_sets: int = DEFAULT_MAX_LABEL_SETS) -> Histogram:
        return self._declare(Histogram, name, help, buckets=buckets,
                             max_label_sets=max_label_sets)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Plain nested dict (sorted, JSON-ready, deterministic)."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}

    def snapshot_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def reset(self) -> None:
        """Zero every series but keep the declarations (helps and bucket
        layouts survive — tests and benches reset between sections)."""
        for m in self._metrics.values():
            m.reset()

    def clear(self) -> None:
        """Drop the declarations too (a fully fresh registry)."""
        self._metrics.clear()

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition (format version 0.0.4) of every
        series: ``# HELP`` / ``# TYPE`` headers, sanitized metric and label
        names, escaped label values, and the histogram ``_bucket`` (with
        cumulative counts and an ``le="+Inf"`` terminal) / ``_sum`` /
        ``_count`` convention.  Output is deterministic: metrics sorted by
        name, series by label key — same contract as :meth:`snapshot`.
        """
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pname = _prom_metric_name(name)
            if m.help:
                esc = m.help.replace("\\", r"\\").replace("\n", r"\n")
                lines.append(f"# HELP {pname} {esc}")
            lines.append(f"# TYPE {pname} {m.kind}")
            for key in sorted(m._series):
                val = m._series[key]
                pairs = [(_prom_label_name(k), _prom_label_value(v))
                         for k, v in key]
                if isinstance(m, Histogram):
                    # stored counts are already cumulative (Prometheus
                    # convention) — emit as-is
                    for b, c in zip(m.buckets, val.counts):
                        le = _prom_number(b)
                        lbl = _prom_labels(pairs + [("le", le)])
                        lines.append(f"{pname}_bucket{lbl} {c}")
                    lbl = _prom_labels(pairs)
                    lines.append(f"{pname}_sum{lbl} "
                                 f"{_prom_number(val.total)}")
                    lines.append(f"{pname}_count{lbl} {val.n}")
                else:
                    lines.append(f"{pname}{_prom_labels(pairs)} "
                                 f"{_prom_number(float(val))}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Default process-local registry + module-level conveniences
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-local default registry every instrumented call site
    records into (engine, sim, scheduler, recovery, byte accounting)."""
    return _REGISTRY


def counter(name: str, help: str = "", **kwargs) -> Counter:
    return _REGISTRY.counter(name, help, **kwargs)


def gauge(name: str, help: str = "", **kwargs) -> Gauge:
    return _REGISTRY.gauge(name, help, **kwargs)


def histogram(name: str, help: str = "", **kwargs) -> Histogram:
    return _REGISTRY.histogram(name, help, **kwargs)


def snapshot() -> Dict[str, Dict[str, object]]:
    return _REGISTRY.snapshot()


def to_prometheus_text() -> str:
    return _REGISTRY.to_prometheus_text()


def reset() -> None:
    _REGISTRY.reset()


# ---------------------------------------------------------------------------
# Cache collectors: pull the existing one-off counters into the registry
# ---------------------------------------------------------------------------

def refresh_cache_metrics(reg: Optional[MetricsRegistry] = None) -> None:
    """Mirror the plan-cache and degraded-cache introspection counters into
    ``reg`` (default registry) under the unified schema.

    Gauges (they mirror cumulative upstream state, they do not own it):

      * ``plan_cache{event=hit|miss, family=<all|family>}`` — overall and
        per-family counters of :func:`repro.core.coded_collectives
        .plan_cache_info`;
      * ``plan_cache_size{kind=current|max}``;
      * ``degraded_cache{event=hit|miss|eviction}`` and
        ``degraded_cache_size{kind=current|max}`` — the bounded side LRU of
        :func:`repro.core.degraded.degraded_cache_info`.

    Called automatically at every engine ``JobResult`` emission and sim job
    completion, so snapshots carry current cache state without callers
    pulling it by hand; call it directly to refresh outside a job boundary.
    Imported lazily so :mod:`repro.obs.metrics` itself stays dependency-free
    (and importable before jax is available).
    """
    from ..core.coded_collectives import plan_cache_info
    from ..core.degraded import degraded_cache_info

    reg = reg if reg is not None else _REGISTRY
    info = plan_cache_info()
    pc = reg.gauge("plan_cache", "LRU plan-cache events (mirrored)")
    pc.set(info.hits, event="hit", family="all")
    pc.set(info.misses, event="miss", family="all")
    for fam, st in info.families.items():
        pc.set(st.hits, event="hit", family=fam)
        pc.set(st.misses, event="miss", family=fam)
    size = reg.gauge("plan_cache_size", "LRU plan-cache occupancy")
    size.set(info.currsize, kind="current")
    size.set(-1 if info.maxsize is None else info.maxsize, kind="max")

    dinfo = degraded_cache_info()
    dc = reg.gauge("degraded_cache",
                   "degraded-plan side-cache events (mirrored)")
    dc.set(dinfo.hits, event="hit")
    dc.set(dinfo.misses, event="miss")
    dc.set(dinfo.evictions, event="eviction")
    dsize = reg.gauge("degraded_cache_size",
                      "degraded-plan side-cache occupancy")
    dsize.set(dinfo.currsize, kind="current")
    dsize.set(-1 if dinfo.maxsize is None else dinfo.maxsize, kind="max")


def collect_cache_metrics(reg: Optional[MetricsRegistry] = None
                          ) -> Dict[str, Dict[str, object]]:
    """:func:`refresh_cache_metrics` plus the refreshed registry snapshot
    (the original pull-style entry point, kept for callers that want the
    snapshot in one call)."""
    reg = reg if reg is not None else _REGISTRY
    refresh_cache_metrics(reg)
    return reg.snapshot()


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LabelCardinalityError", "DEFAULT_BUCKETS", "DEFAULT_MAX_LABEL_SETS",
    "registry", "counter", "gauge", "histogram", "snapshot", "reset",
    "refresh_cache_metrics", "collect_cache_metrics",
]
