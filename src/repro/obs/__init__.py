"""repro.obs — unified telemetry across engine, sim, and scheduler.

Three instruments with one schema (see docs/observability.md):

  * :mod:`repro.obs.metrics` — process-local counters/gauges/histograms
    with labels (snapshot/reset, bounded cardinality, deterministic JSON);
  * :mod:`repro.obs.tracing` — structured :class:`TraceEvent` spans and
    instants with JSONL and Chrome/Perfetto ``trace_event`` exporters;
  * :mod:`repro.obs.bytes` — rack-level byte accounting from compiled
    plans, reconciled against the ``CommCost`` closed forms per job;
  * :mod:`repro.obs.blame` — per-job JCT blame decomposition under an
    exactness law (components sum to measured JCT) plus the critical-path
    extractor over the trace stream and fleet-level p99 rollups;
  * :mod:`repro.obs.drift` — predicted-vs-actual reconciliation, EWMA
    drift detection, and the per-component error breakdown.

Import discipline: ``repro.core`` never imports ``repro.obs`` (obs.bytes
reaches into core, so the reverse edge would cycle); the engine, sim and
scheduler import obs directly, and core's cache counters are pulled in
lazily via :func:`repro.obs.metrics.collect_cache_metrics`.
"""
from . import bytes  # noqa: A004 - module name mirrors the instrument
from . import blame, drift, metrics, report, tracing
from .blame import (COMPONENTS, BlameReport, blame_from_phase_timings,
                    blame_report, critical_path, decompose, extract_blame,
                    fleet_blame)
from .bytes import (ByteReconciliationError, RackBytes, closed_form_bytes,
                    degraded_rack_bytes, plan_rack_bytes, reconcile,
                    record_rack_bytes)
from .drift import (DriftConfig, DriftMonitor, record_blame,
                    record_component_errors, record_prediction)
from .metrics import (Counter, Gauge, Histogram, LabelCardinalityError,
                      MetricsRegistry, collect_cache_metrics,
                      refresh_cache_metrics)
from .report import build_report, render_html, render_markdown, write_report
from .tracing import (TraceEvent, Tracer, enable_tracing, get_tracer,
                      spans_from_phase_timings, to_chrome_trace, to_jsonl,
                      validate_chrome_trace)

__all__ = [
    "metrics", "tracing", "bytes", "drift", "report", "blame",
    "COMPONENTS", "BlameReport", "blame_from_phase_timings", "blame_report",
    "critical_path", "decompose", "extract_blame", "fleet_blame",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LabelCardinalityError", "collect_cache_metrics",
    "refresh_cache_metrics",
    "DriftConfig", "DriftMonitor", "record_blame",
    "record_component_errors", "record_prediction",
    "build_report", "render_markdown", "render_html", "write_report",
    "TraceEvent", "Tracer", "get_tracer", "enable_tracing",
    "spans_from_phase_timings", "to_jsonl", "to_chrome_trace",
    "validate_chrome_trace",
    "RackBytes", "ByteReconciliationError", "plan_rack_bytes",
    "degraded_rack_bytes", "closed_form_bytes", "reconcile",
    "record_rack_bytes",
]
