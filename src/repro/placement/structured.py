"""Deterministic structured replica placements (resolvable-design style).

The paper models storage as HDFS-style RANDOM replica placement and then
optimizes the Map-task assignment around it.  Resolvable-design
constructions (cf. Konstantinidis & Ramamoorthy, arXiv:1908.05666) invert
that: place replicas so the storage layout is ALIGNED with the structure
the assignment needs, and random-vs-optimized stops mattering.

Two constructions, both deterministic (no rng).  ``resolvable`` is
perfectly storage-balanced whenever K | N; ``aligned`` is perfectly
balanced for r_f <= r (the aligned replicas inherit the hybrid design's
exact per-server symmetry; extras beyond r skew toward low-rack servers):

  * ``resolvable`` — replica layer c is a parallel class: subfile i's c-th
    replica lives at rack (rack0(i) + c) mod P, slot (slot0(i) + c // P)
    mod Kr.  Each layer is a bijection of the base layout, so every server
    stores exactly N * r_f / K subfiles and the first min(r_f, P) replicas
    of every subfile sit in DISTINCT racks (HDFS's spread goal, made
    exact).
  * ``aligned`` — replicas sit on the servers that the canonical (identity
    permutation) hybrid assignment will map the slot's subfile from; spare
    replicas (r_f > r) continue in resolvable fashion.  With r_f >= r this
    achieves node locality 1.0 with NO optimization — the upper bound the
    solvers chase, useful as an oracle and for sizing how much locality a
    placement-aware storage tier buys.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..core.assignment import hybrid_group_of_slot
from ..core.params import SchemeParams
from ..core.resolvable import cyclic_replica_server
from .objectives import group_servers

STRUCTURED_POLICIES = ("resolvable", "aligned")


def _resolvable_server(p: SchemeParams, base: np.ndarray,
                       c: int) -> np.ndarray:
    """Server of replica shift c from per-subfile base servers — the
    parallel-class shift now shared with the resolvable plan compiler
    (:func:`repro.core.resolvable.cyclic_replica_server`): rotate the rack
    by c and the in-rack slot by c // P (distinct for c < K)."""
    return cyclic_replica_server(p, base, c)


def structured_replicas(p: SchemeParams,
                        policy: str = "resolvable") -> np.ndarray:
    """Deterministic [N, r_f] replica placement (see module docstring).

    Requires r_f <= K (cannot place r_f distinct replicas otherwise).
    """
    if policy not in STRUCTURED_POLICIES:
        raise ValueError(
            f"policy must be one of {STRUCTURED_POLICIES}, got {policy!r}")
    if p.r_f > p.K:
        raise ValueError(f"need r_f <= K for distinct replicas; "
                         f"r_f={p.r_f} K={p.K}")
    out = np.empty((p.N, p.r_f), dtype=np.int64)
    if policy == "resolvable":
        base = np.arange(p.N, dtype=np.int64) % p.K
        for c in range(p.r_f):
            out[:, c] = _resolvable_server(p, base, c)
        return out

    # aligned: slot s of the canonical hybrid assignment is mapped at
    # group_servers[group(s)]; give subfile s (identity perm) its first
    # min(r_f, r) replicas there, then continue resolvably off the first.
    groups = np.asarray(group_servers(p), dtype=np.int64)       # [G, r]
    srvs = groups[hybrid_group_of_slot(p)]                      # [N, r]
    k = min(p.r_f, p.r)
    out[:, :k] = srvs[:, :k]
    for c in range(k, p.r_f):
        # The r aligned servers sit in distinct racks at the SAME layer, so
        # rack rotations of srvs[:, 0] could collide with srvs[:, 1:k] —
        # advance the in-rack slot instead (a shift that is a multiple of P
        # rotates only the slot): distinct while r_f - k < Kr; anything
        # beyond is rejected by the collision check below.
        out[:, c] = _resolvable_server(p, srvs[:, 0], (c - k + 1) * p.P)
    _check_distinct(out)
    return out


def _check_distinct(replicas: np.ndarray) -> None:
    srt = np.sort(replicas, axis=1)
    if (srt[:, 1:] == srt[:, :-1]).any():
        bad = int(np.nonzero((srt[:, 1:] == srt[:, :-1]).any(axis=1))[0][0])
        raise ValueError(f"replica collision for subfile {bad}: "
                         f"{replicas[bad].tolist()}")


def replica_load(replicas: np.ndarray, K: int) -> np.ndarray:
    """[K] subfiles stored per server — the storage-balance check: uniform
    (== N * r_f / K everywhere) for both structured policies when K | N."""
    return np.bincount(np.asarray(replicas).ravel(), minlength=K)


def storage_balance(replicas: np.ndarray, K: int) -> Tuple[int, int]:
    """(min, max) per-server storage load; equal iff perfectly balanced."""
    load = replica_load(replicas, K)
    return int(load.min()), int(load.max())
