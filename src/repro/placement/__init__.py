"""repro.placement — locality-aware placement engine (paper Section IV).

The layer between the closed-form core and the simulator: general-r
locality objectives over incidence matrices (:mod:`.objectives`), a
registry of assignment solvers from the random baseline to an exact
min-cost-flow and a JAX-batched annealer (:mod:`.solvers`),
resolvable-design structured replica placements (:mod:`.structured`),
alternating joint optimization of replicas and assignment (:mod:`.joint`),
multi-trial Table II drivers (:mod:`.experiments`), and the bridge that
feeds any solved placement into :class:`repro.sim.ClusterSim` as fetch
traffic + map-phase imbalance (:mod:`.sim_bridge`).  See docs/locality.md.
"""
from .objectives import (NonLocalLoad, group_servers, locality_incidence,
                         locality_matrix, locality_of_perm,
                         map_load_imbalance, map_work_factors, n_groups,
                         nonlocal_load, perm_objective, place_replicas,
                         replica_incidence)
from .solvers import (SOLVERS, PlacementResult, anneal_perm, flow_perm,
                      get_solver, greedy_perm, groups_to_perm,
                      local_search_perm, random_perm, register_solver,
                      solve, solve_all, solver_rng)
from .structured import (STRUCTURED_POLICIES, replica_load, storage_balance,
                         structured_replicas)
from .joint import JointResult, joint_optimize, replicate_for_assignment
from .experiments import (DEFAULT_SOLVERS, LocalityResult, SolverTrialStats,
                          Table2Trials, table2_experiment, table2_trials)
from .sim_bridge import (PlacementTraffic, jct_gap, placement_traffic,
                         simulate_placement, traffic_for_result)

__all__ = [
    "NonLocalLoad", "group_servers", "locality_incidence", "locality_matrix",
    "locality_of_perm", "map_load_imbalance", "map_work_factors", "n_groups",
    "nonlocal_load", "perm_objective", "place_replicas", "replica_incidence",
    "SOLVERS", "PlacementResult", "anneal_perm", "flow_perm", "get_solver",
    "greedy_perm", "groups_to_perm", "local_search_perm", "random_perm",
    "register_solver", "solve", "solve_all", "solver_rng",
    "STRUCTURED_POLICIES", "replica_load", "storage_balance",
    "structured_replicas",
    "JointResult", "joint_optimize", "replicate_for_assignment",
    "DEFAULT_SOLVERS", "LocalityResult", "SolverTrialStats", "Table2Trials",
    "table2_experiment", "table2_trials",
    "PlacementTraffic", "jct_gap", "placement_traffic", "simulate_placement",
    "traffic_for_result",
]
