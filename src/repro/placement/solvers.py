"""Solver suite for the Theorem IV.1 assignment problem, with a registry.

Choosing the permutation of subfiles over structural slots that maximizes
sum_slots C(perm[slot], group(slot)) subject to each group holding exactly M
subfiles is a transportation problem.  The suite covers the whole
cost/quality spectrum:

  ============  =========================  ==================================
  solver        complexity                 quality
  ============  =========================  ==================================
  random        O(N)                       Table II's 'Ran' baseline
  greedy        O(NG log(NG))              near-optimal, no backtracking
  flow          O(N * E log V), E = NG     EXACT (min-cost max-flow, SSP)
  local_search  O(moves * 1)               anytime; >= its starting point
  anneal_jax    O(steps) on device         >= greedy (warm start); batched
                                           Metropolis chains — thousands of
                                           candidate swaps evaluated per
                                           step via vectorized C-gathers
  ============  =========================  ==================================

All solvers return a permutation of range(N) (slot -> subfile), so any
result composes with :func:`repro.core.assignment.hybrid_assignment` and
satisfies Theorem IV.1's constraints BY CONSTRUCTION — swap moves permute
subfiles over slots and can never leave the feasible set.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.assignment import hybrid_group_of_slot, rack_subsets
from ..core.params import SchemeParams
from .objectives import locality_matrix, locality_of_perm, perm_objective


# ---------------------------------------------------------------------------
# Primitive solvers (perm-level API)
# ---------------------------------------------------------------------------

def random_perm(p: SchemeParams, rng: np.random.Generator) -> np.ndarray:
    """Table II's 'Ran' baseline: an arbitrary valid hybrid assignment."""
    return rng.permutation(p.N)


def greedy_perm(p: SchemeParams, C: np.ndarray) -> np.ndarray:
    """Greedy: repeatedly place the highest-scoring (subfile, group) pair
    into a free slot.  Fast, near-optimal; used as a scalable fallback."""
    G = C.shape[1]
    cap = np.full(G, p.M, dtype=np.int64)
    order = np.argsort(-C, axis=None)
    assigned = np.full(p.N, -1, dtype=np.int64)
    placed = 0
    for flat in order:
        i, g = divmod(int(flat), G)
        if assigned[i] >= 0 or cap[g] == 0:
            continue
        assigned[i] = g
        cap[g] -= 1
        placed += 1
        if placed == p.N:
            break
    return groups_to_perm(p, assigned)


def flow_perm(p: SchemeParams, C: np.ndarray) -> np.ndarray:
    """Exact solution of Theorem IV.1 via min-cost max-flow (SSP + Dijkstra
    with Johnson potentials).  Integral by flow integrality."""
    n, G = C.shape
    # node ids: 0 = source, 1..n subfiles, n+1..n+G groups, last = sink
    S, T = 0, n + G + 1
    n_nodes = T + 1
    graph: List[List[int]] = [[] for _ in range(n_nodes)]
    # edge arrays
    to: List[int] = []
    cap: List[int] = []
    cost: List[float] = []

    def add_edge(u: int, v: int, c: int, w: float) -> None:
        graph[u].append(len(to)); to.append(v); cap.append(c); cost.append(w)
        graph[v].append(len(to)); to.append(u); cap.append(0); cost.append(-w)

    cmax = float(C.max()) if C.size else 0.0
    for i in range(n):
        add_edge(S, 1 + i, 1, 0.0)
        for g in range(G):
            # shift costs so all are >= 0 for Dijkstra (maximize C == minimize
            # cmax - C); the shift is constant per unit flow, so argmin is
            # unchanged.
            add_edge(1 + i, 1 + n + g, 1, cmax - float(C[i, g]))
    for g in range(G):
        add_edge(1 + n + g, T, p.M, 0.0)

    potential = np.zeros(n_nodes)
    flow_assigned = np.full(n, -1, dtype=np.int64)
    INF = float("inf")
    for _ in range(n):  # one augmentation per subfile (unit flows)
        dist = np.full(n_nodes, INF)
        dist[S] = 0.0
        prev_edge = np.full(n_nodes, -1, dtype=np.int64)
        pq = [(0.0, S)]
        while pq:
            d, u = heapq.heappop(pq)
            if d > dist[u] + 1e-12:
                continue
            for eid in graph[u]:
                if cap[eid] <= 0:
                    continue
                v = to[eid]
                nd = d + cost[eid] + potential[u] - potential[v]
                if nd < dist[v] - 1e-12:
                    dist[v] = nd
                    prev_edge[v] = eid
                    heapq.heappush(pq, (nd, v))
        assert dist[T] < INF, "flow infeasible: check divisibility of N"
        finite = dist < INF
        potential[finite] += dist[finite]
        # augment one unit along S->T
        v = T
        while v != S:
            eid = int(prev_edge[v])
            cap[eid] -= 1
            cap[eid ^ 1] += 1
            v = to[eid ^ 1]
    # read off subfile -> group assignment
    for i in range(n):
        for eid in graph[1 + i]:
            if to[eid] != S and cap[eid ^ 1] > 0 and eid % 2 == 0:
                flow_assigned[i] = to[eid] - 1 - n
                break
    assert (flow_assigned >= 0).all()
    return groups_to_perm(p, flow_assigned)


def local_search_perm(p: SchemeParams, C: np.ndarray,
                      rng: np.random.Generator,
                      init: Optional[Sequence[int]] = None,
                      max_sweeps: int = 20,
                      batch: int = 2048) -> np.ndarray:
    """First-improvement local search over the swap neighborhood.

    A move swaps the subfiles of two slots — always another valid hybrid
    assignment.  Each sweep evaluates ``batch`` random candidate swaps at
    once (vectorized delta = C[j,ga] + C[i,gb] - C[i,ga] - C[j,gb]) and
    applies a non-conflicting improving subset; terminates when a sweep
    finds no improving move (a swap-local optimum) or after ``max_sweeps``.
    Monotone: the result's objective is >= the starting point's.
    """
    perm = np.array(greedy_perm(p, C) if init is None else init,
                    dtype=np.int64, copy=True)
    gos = hybrid_group_of_slot(p)
    for _ in range(max_sweeps):
        a = rng.integers(p.N, size=batch)
        b = rng.integers(p.N, size=batch)
        ia, ib = perm[a], perm[b]
        ga, gb = gos[a], gos[b]
        delta = (C[ib, ga] + C[ia, gb]) - (C[ia, ga] + C[ib, gb])
        improving = np.nonzero(delta > 1e-12)[0]
        if improving.size == 0:
            break            # sampled swap-local optimum: stop early
        # apply a non-conflicting subset, best deltas first (the first
        # candidate always applies: improving excludes a == b, since a
        # self-swap has delta exactly 0)
        touched = np.zeros(p.N, dtype=bool)
        for k in improving[np.argsort(-delta[improving])]:
            sa, sb = int(a[k]), int(b[k])
            if touched[sa] or touched[sb]:
                continue
            perm[sa], perm[sb] = perm[sb], perm[sa]
            touched[sa] = touched[sb] = True
    return perm


def anneal_perm(p: SchemeParams, C: np.ndarray,
                rng: np.random.Generator,
                n_chains: int = 64, n_steps: int = 1500,
                t0: float = 1.0, t1: float = 1e-3,
                init: Optional[Sequence[Sequence[int]]] = None,
                init_solvers: Sequence[str] = ("greedy",)
                ) -> np.ndarray:
    """JAX-batched parallel simulated annealing over the swap neighborhood.

    Runs ``n_chains`` independent Metropolis chains entirely on device: each
    step proposes one random slot transposition PER CHAIN and evaluates all
    the objective deltas in one vectorized gather over the C matrix — with
    the default sizes that is ~10^5 candidate permutations scored per
    ``lax.scan`` step equivalent, no host round-trips.  Temperatures follow
    a geometric schedule t0 -> t1.

    ``init`` seeds the first chains with warm-start permutations; without
    it, ``init_solvers`` names cheap solvers to warm-start from (default
    greedy; add 'flow' to polish the exact optimum).  Remaining chains
    start from random permutations.  The best objective seen by any chain
    is tracked, and a warm start is only ever REPLACED by a strictly
    better permutation — so the result's objective is >= every warm
    start's, deterministically (ties return the first warm start).
    """
    import jax
    import jax.numpy as jnp

    gos = np.asarray(hybrid_group_of_slot(p))
    warm_fns = {"greedy": greedy_perm, "flow": flow_perm}
    if init is None:
        warm = [np.asarray(warm_fns[name](p, C)) for name in init_solvers]
    else:
        warm = [np.asarray(x, dtype=np.int64) for x in init]
    n_chains = max(n_chains, len(warm))   # never silently drop a warm start
    base = np.empty((n_chains, p.N), dtype=np.int64)
    for k in range(n_chains):
        base[k] = warm[k] if k < len(warm) else rng.permutation(p.N)

    Cd = jnp.asarray(C, jnp.float32)
    gos_d = jnp.asarray(gos)
    perms0 = jnp.asarray(base)
    obj0 = Cd[perms0, gos_d[None, :]].sum(axis=1)              # [B]
    temps = jnp.asarray(
        np.geomspace(t0, t1, num=max(n_steps, 1)), jnp.float32)
    key = jax.random.PRNGKey(int(rng.integers(2 ** 31)))
    rows = jnp.arange(n_chains)

    def step(carry, t):
        perms, obj, best_perms, best_obj, key = carry
        key, ka, kb, ku = jax.random.split(key, 4)
        a = jax.random.randint(ka, (n_chains,), 0, p.N)
        b = jax.random.randint(kb, (n_chains,), 0, p.N)
        ia, ib = perms[rows, a], perms[rows, b]
        ga, gb = gos_d[a], gos_d[b]
        delta = (Cd[ib, ga] + Cd[ia, gb]) - (Cd[ia, ga] + Cd[ib, gb])
        u = jax.random.uniform(ku, (n_chains,), minval=1e-12)
        accept = (delta >= 0) | (jnp.log(u) * t < delta)
        perms = perms.at[rows, a].set(jnp.where(accept, ib, ia)) \
                     .at[rows, b].set(jnp.where(accept, ia, ib))
        obj = obj + jnp.where(accept, delta, 0.0)
        improved = obj > best_obj + 1e-6          # strictly better only
        best_obj = jnp.where(improved, obj, best_obj)
        best_perms = jnp.where(improved[:, None], perms, best_perms)
        return (perms, obj, best_perms, best_obj, key), None

    (_, _, best_perms, _, _), _ = jax.lax.scan(
        step, (perms0, obj0, perms0, obj0, key), temps)
    # Final selection is EXACT and warm-start-safe: the float32 on-device
    # objective deltas are only a Metropolis heuristic (accumulated rounding
    # could evict a warm start from a chain's tracked best), so the warm
    # starts re-enter the candidate pool here, everything is re-scored in
    # float64 by direct gather, and near-ties (summation-order roundoff) go
    # to the EARLIEST candidate — warm starts first, in caller order.  A
    # warm start is therefore only ever outranked by a meaningfully better
    # permutation, whatever the chains did.
    cand = np.concatenate([np.stack(warm), np.asarray(best_perms)], axis=0)
    finals = np.asarray([perm_objective(p, C, perm) for perm in cand])
    return cand[int(np.nonzero(finals >= finals.max() - 1e-9)[0][0])]


def groups_to_perm(p: SchemeParams, group_of_subfile: np.ndarray
                   ) -> np.ndarray:
    """Convert a subfile->group map into a slot permutation (slot_index ->
    subfile), filling each group's M slots in subfile order."""
    G = int(group_of_subfile.max()) + 1 if len(group_of_subfile) else 0
    G = max(G, p.n_layers * len(rack_subsets(p.P, p.r)))
    perm = np.full(p.N, -1, dtype=np.int64)
    next_w = np.zeros(G, dtype=np.int64)
    for i in range(p.N):
        g = int(group_of_subfile[i])
        w = int(next_w[g]); next_w[g] += 1
        assert w < p.M, "group over capacity"
        perm[g * p.M + w] = i
    assert (perm >= 0).all()
    return perm


# ---------------------------------------------------------------------------
# Registry + the PlacementResult envelope
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementResult:
    """One solved placement: the inputs that produced it and its scores.

    The envelope every downstream consumer takes: the sim bridge
    (:mod:`repro.placement.sim_bridge`), the distributed engine
    (``run_job_distributed(placement=...)``), benchmarks and the joint
    optimizer all speak PlacementResult.
    """
    params: SchemeParams
    replicas: np.ndarray           # [N, r_f] storage replica servers
    perm: np.ndarray               # [N] slot -> subfile
    solver: str
    lam: float
    objective: float               # Theorem IV.1 objective value
    node_locality: float           # Table II percentages, in [0, 1]
    rack_locality: float
    wall_s: float                  # solver wall clock (excludes C build)

    def summary(self) -> str:
        return (f"{self.solver}: node {100 * self.node_locality:.1f}% "
                f"rack {100 * self.rack_locality:.1f}% "
                f"obj {self.objective:.1f} ({self.wall_s * 1e3:.1f} ms)")


# solver signature: (params, C, rng, **kwargs) -> perm
Solver = Callable[..., np.ndarray]

SOLVERS: Dict[str, Solver] = {}


def register_solver(name: str) -> Callable[[Solver], Solver]:
    """Register a solver under ``name`` (decorator).  Third-party solvers
    (ILP backends, new metaheuristics) plug in without touching this
    module."""
    def deco(fn: Solver) -> Solver:
        SOLVERS[name] = fn
        return fn
    return deco


def get_solver(name: str) -> Solver:
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered: {sorted(SOLVERS)}"
        ) from None


register_solver("random")(lambda p, C, rng, **kw: random_perm(p, rng))
register_solver("greedy")(lambda p, C, rng, **kw: greedy_perm(p, C))
register_solver("flow")(lambda p, C, rng, **kw: flow_perm(p, C))
register_solver("local_search")(
    lambda p, C, rng, **kw: local_search_perm(p, C, rng, **kw))
register_solver("anneal_jax")(
    lambda p, C, rng, **kw: anneal_perm(p, C, rng, **kw))


def solve(p: SchemeParams, replicas: np.ndarray, solver: str = "flow",
          lam: float = 0.8, seed: int = 0,
          rng: Optional[np.random.Generator] = None,
          C: Optional[np.ndarray] = None, **kwargs) -> PlacementResult:
    """Run one registered solver end to end: build the locality matrix
    (unless a precomputed ``C`` is passed), solve, score.  ``wall_s`` times
    the solver alone."""
    fn = get_solver(solver)
    if C is None:
        C = locality_matrix(p, replicas, lam)
    if rng is None:
        rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    perm = fn(p, C, rng, **kwargs)
    wall = time.perf_counter() - t0
    node, rack = locality_of_perm(p, replicas, perm)
    return PlacementResult(p, np.asarray(replicas), np.asarray(perm), solver,
                           lam, perm_objective(p, C, perm), node, rack, wall)


def solver_rng(seed: int, name: str, trial: int = 0) -> np.random.Generator:
    """Independent per-(seed, solver, trial) generator, keyed on the solver
    NAME (stable crc32) — adding, removing or reordering solvers in a suite
    never perturbs any other solver's stream."""
    return np.random.default_rng(
        np.random.SeedSequence((seed, trial, zlib.crc32(name.encode()))))


def solve_all(p: SchemeParams, replicas: np.ndarray,
              solvers: Sequence[str] = ("random", "greedy", "flow",
                                        "local_search", "anneal_jax"),
              lam: float = 0.8, seed: int = 0,
              per_solver_kwargs: Optional[Dict[str, Dict]] = None
              ) -> Dict[str, PlacementResult]:
    """Run several solvers on the SAME (replicas, C) instance — the Table II
    comparison in one call.  Each solver gets an independent child rng keyed
    on its name (:func:`solver_rng`), so editing the suite never perturbs
    the remaining solvers."""
    C = locality_matrix(p, replicas, lam)
    kw = per_solver_kwargs or {}
    return {name: solve(p, replicas, name, lam,
                        rng=solver_rng(seed, name), C=C,
                        **kw.get(name, {}))
            for name in solvers}
