"""Section IV objectives as vectorized incidence-matrix functions, general r.

Everything here is a pure function of three one-hot incidence matrices:

  * ``has_server[i, s]`` — subfile i stores a replica on server s   ([N, K])
  * ``has_rack[i, p]``   — subfile i stores a replica in rack p     ([N, P])
  * group membership     — the servers of each (layer, rack-subset)
    structural group of the hybrid scheme                           ([G, K])

The paper's locality measure C(i, g) = lam*Node + (1-lam)*Rack, the Theorem
IV.1 objective of a permutation, Table II's node/rack locality percentages,
and the per-server non-local map-load (the quantity the simulator bridge
turns into fetch traffic and map-phase imbalance) are all one or two
[N, K] @ [K, G]-shaped products — no Python loops over subfiles or groups.
"""
from __future__ import annotations

import dataclasses
from math import comb
from typing import List, Sequence, Tuple

import numpy as np

from ..core.assignment import hybrid_group_of_slot, rack_subsets, slot_servers
from ..core.params import SchemeParams


# ---------------------------------------------------------------------------
# Storage replica placement (HDFS-style random baselines)
# ---------------------------------------------------------------------------

def place_replicas(p: SchemeParams, rng: np.random.Generator,
                   policy: str = "uniform") -> np.ndarray:
    """Replica locations, shape [N, r_f]; no two replicas share a server.

    ``uniform``: r_f distinct servers uniformly at random (the paper's model).
    ``hdfs``: first replica uniform; second in a different rack; third in the
    second's rack on a different server (Hadoop default for r_f = 3).

    Both policies draw all N subfiles' placements in batched ``rng`` calls
    (the per-subfile Python loop was the Table II setup bottleneck).
    Deterministic alternatives live in :mod:`repro.placement.structured`.
    """
    if policy == "uniform":
        # row-wise uniform random permutation of the K servers, truncated to
        # r_f: identical in distribution to ordered sampling without
        # replacement (rng.choice(K, r_f, replace=False) per row).
        return np.argsort(rng.random((p.N, p.K)), axis=1)[:, :p.r_f] \
            .astype(np.int64)
    if policy != "hdfs":
        raise ValueError(policy)

    out = np.zeros((p.N, p.r_f), dtype=np.int64)
    first = rng.integers(p.K, size=p.N)
    out[:, 0] = first
    if p.r_f >= 2:
        # uniform over the K - Kr servers outside first's rack: draw a rack
        # offset in [1, P) and a slot in [0, Kr)
        rack2 = (first // p.Kr + rng.integers(1, p.P, size=p.N)) % p.P
        out[:, 1] = rack2 * p.Kr + rng.integers(p.Kr, size=p.N)
    if p.r_f >= 3:
        # same rack as the second replica, different slot
        slot3 = (out[:, 1] % p.Kr + rng.integers(1, p.Kr, size=p.N)) % p.Kr
        out[:, 2] = (out[:, 1] // p.Kr) * p.Kr + slot3
    for c in range(3, p.r_f):
        # replicas past the Hadoop triple: uniform over the unchosen servers
        taken = np.zeros((p.N, p.K), dtype=bool)
        np.put_along_axis(taken, out[:, :c], True, axis=1)
        scores = np.where(taken, np.inf, rng.random((p.N, p.K)))
        out[:, c] = scores.argmin(axis=1)
    return out


# ---------------------------------------------------------------------------
# Structural groups and incidences
# ---------------------------------------------------------------------------

def group_servers(p: SchemeParams) -> List[Tuple[int, ...]]:
    """Server tuple of every (layer, rack-subset) group, group-major order
    matching :func:`repro.core.assignment.hybrid_slots`."""
    subsets = rack_subsets(p.P, p.r)
    out = []
    for layer in range(p.n_layers):
        for t_idx in range(len(subsets)):
            out.append(slot_servers(p, layer, t_idx))
    return out


def n_groups(p: SchemeParams) -> int:
    """Number of (layer, rack-subset) groups: Kr * C(P, r)."""
    return p.n_layers * comb(p.P, p.r)


def replica_incidence(p: SchemeParams, replicas: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """(has_server [N, K], has_rack [N, P]) 0/1 incidences of a replica
    placement."""
    replicas = np.asarray(replicas, dtype=np.int64)
    has_server = np.zeros((p.N, p.K), dtype=np.int64)
    has_server[np.arange(p.N)[:, None], replicas] = 1
    has_rack = np.zeros((p.N, p.P), dtype=np.int64)
    has_rack[np.arange(p.N)[:, None], replicas // p.Kr] = 1
    return has_server, has_rack


def locality_incidence(p: SchemeParams, replicas: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """(node[i, g], rack[i, g]) integer hit counts of assigning subfile i to
    group g: how many of g's servers host a replica of i / sit in a rack that
    hosts one.  Built as one-hot replica/rack incidence matmuls — the
    O(N*G*r) Python triple loop collapsed to two [N, K] @ [K, G] products."""
    groups = np.asarray(group_servers(p), dtype=np.int64)     # [G, r]
    G = groups.shape[0]
    has_server, has_rack = replica_incidence(p, replicas)
    # group-side incidences: server membership / per-rack server counts
    g_server = np.zeros((G, p.K), dtype=np.int64)
    g_server[np.arange(G)[:, None], groups] = 1               # distinct srvs
    g_rack = np.zeros((G, p.P), dtype=np.int64)
    np.add.at(g_rack, (np.repeat(np.arange(G), groups.shape[1]),
                       (groups // p.Kr).ravel()), 1)
    return has_server @ g_server.T, has_rack @ g_rack.T


def locality_matrix(p: SchemeParams, replicas: np.ndarray,
                    lam: float = 0.8) -> np.ndarray:
    """C[i, g] = lam*NodeLocality + (1-lam)*RackLocality of assigning subfile
    i to group g's server set (Section V's measure, general r >= 1)."""
    if not (0.5 < lam <= 1.0):
        raise ValueError("paper requires lam in (0.5, 1]")
    node, rack = locality_incidence(p, replicas)
    return lam * node + (1.0 - lam) * rack


def locality_of_perm(p: SchemeParams, replicas: np.ndarray,
                     perm: Sequence[int]) -> Tuple[float, float]:
    """(node_locality, rack_locality) in [0, 1] — Table II's percentages:
    fraction of (map-replica, server) placements that are local."""
    node, rack = locality_incidence(p, replicas)
    group_of_slot = hybrid_group_of_slot(p)
    perm = np.asarray(perm, dtype=np.int64)
    denom = p.N * p.r
    return (int(node[perm, group_of_slot].sum()) / denom,
            int(rack[perm, group_of_slot].sum()) / denom)


def perm_objective(p: SchemeParams, C: np.ndarray,
                   perm: Sequence[int]) -> float:
    """Theorem IV.1's objective value sum_slots C(perm[slot], group(slot)) —
    the quantity every solver in :mod:`repro.placement.solvers` maximizes."""
    perm = np.asarray(perm, dtype=np.int64)
    return float(C[perm, hybrid_group_of_slot(p)].sum())


# ---------------------------------------------------------------------------
# Per-server non-local map load (the simulator-facing objective)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NonLocalLoad:
    """Per-server miss counts of one (replicas, perm) placement.

    ``node_miss[s]`` — map tasks at server s whose subfile has NO replica on
    s (the input must be fetched over the network);
    ``rack_miss[s]`` — of those, the tasks with no replica anywhere in s's
    rack either (the fetch crosses the root switch).
    ``n_loc`` — the structural per-server map load M * C(P-1, r-1)
    (identical across servers in the hybrid design: imbalance comes ONLY
    from locality misses, never from task counts).
    """
    node_miss: np.ndarray          # [K] int
    rack_miss: np.ndarray          # [K] int
    n_loc: int

    @property
    def intra_fetch(self) -> np.ndarray:
        """[K] fetches served from within the rack (node miss, rack hit)."""
        return self.node_miss - self.rack_miss


def nonlocal_load(p: SchemeParams, replicas: np.ndarray,
                  perm: Sequence[int]) -> NonLocalLoad:
    """Count per-server node/rack misses of a placement, vectorized: one
    gather over the replica incidences per (slot, mapping-server) pair."""
    groups = np.asarray(group_servers(p), dtype=np.int64)       # [G, r]
    has_server, has_rack = replica_incidence(p, replicas)
    perm = np.asarray(perm, dtype=np.int64)
    srvs = groups[hybrid_group_of_slot(p)]                      # [N, r]
    sub = perm[:, None]                                         # [N, 1]
    node_hit = has_server[sub, srvs]                            # [N, r] 0/1
    rack_hit = has_rack[sub, srvs // p.Kr]                      # [N, r] 0/1
    node_miss = np.zeros(p.K, dtype=np.int64)
    rack_miss = np.zeros(p.K, dtype=np.int64)
    np.add.at(node_miss, srvs.ravel(), 1 - node_hit.ravel())
    np.add.at(rack_miss, srvs.ravel(), 1 - rack_hit.ravel())
    n_loc = p.M * comb(p.P - 1, p.r - 1)
    return NonLocalLoad(node_miss, rack_miss, n_loc)


def map_work_factors(p: SchemeParams, replicas: np.ndarray,
                     perm: Sequence[int],
                     remote_penalty: float = 0.5) -> np.ndarray:
    """[K] multiplicative map-work factors: a non-local map task costs
    (1 + remote_penalty) task-units (input read stalls behind the fetch).
    The map barrier ends at max(factors), so per-RACK locality imbalance
    shifts the simulated map phase — Table II in time units."""
    if remote_penalty < 0:
        raise ValueError("remote_penalty must be >= 0")
    load = nonlocal_load(p, replicas, perm)
    return 1.0 + remote_penalty * load.node_miss / max(load.n_loc, 1)


def map_load_imbalance(p: SchemeParams, replicas: np.ndarray,
                       perm: Sequence[int],
                       remote_penalty: float = 0.5) -> float:
    """max/mean of the per-server effective map work — 1.0 iff perfectly
    balanced.  A per-rack imbalance objective for placement solvers: the
    barrier cost of a placement is its SLOWEST server, so minimizing this
    (equivalently maximizing the minimum locality across servers) is the
    time-domain refinement of maximizing average locality."""
    f = map_work_factors(p, replicas, perm, remote_penalty)
    return float(f.max() / f.mean())
