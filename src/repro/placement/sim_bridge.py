"""Bridge from placement results into the cluster simulator.

Turns Table II's locality PERCENTAGES into JCT TIME (the ROADMAP's "Table II
in time units"): a solved placement becomes

  * **fetch traffic** — every non-local map input must be read over the
    network before the map phase: a (subfile, mapping-server) pair with a
    replica in the server's rack but not on the server costs one intra-rack
    transfer through that rack's ToR; a pair with no replica in the rack
    crosses the root switch.  These flows contend with concurrent jobs'
    shuffles in :class:`repro.sim.network.FluidNetwork` exactly like any
    other traffic (a ``fetch`` stage preceding ``map``).
  * **map-phase imbalance** — a server mapping non-local inputs runs its
    map tasks slower (reads stall behind the fetch pipe); the barrier ends
    at the SLOWEST server, so per-rack locality imbalance shifts the map
    phase time (:func:`repro.placement.objectives.map_work_factors`).

``input_units`` is the network cost of one subfile's raw input in the
fluid network's value-units.  The default ``None`` uses Q * d — the size of
one subfile's INTERMEDIATE values, i.e. a map whose output is as large as
its input; pass the real ratio to skew it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.params import SchemeParams
from ..sim.cluster import ClusterSim, CostModel, JobStats, StragglerModel
from ..sim.network import RackTopology
from ..sim.workload import JobSpec
from .objectives import locality_of_perm, map_work_factors, nonlocal_load
from .solvers import PlacementResult


@dataclasses.dataclass(frozen=True)
class PlacementTraffic:
    """What the simulator needs to know about one placement: pre-map fetch
    loads (value-units) and per-server map slowdown factors.  Consumed by
    ``ClusterSim.submit(placement=...)``."""
    cross_units: float                      # root-switch fetch load
    intra_units_per_rack: Tuple[float, ...]  # per-ToR fetch load, [P]
    map_factors: Tuple[float, ...]          # per-server map work factor, [K]
    node_locality: float
    rack_locality: float

    @property
    def total_units(self) -> float:
        return self.cross_units + sum(self.intra_units_per_rack)


def placement_traffic(p: SchemeParams, replicas: np.ndarray,
                      perm: Sequence[int],
                      input_units: Optional[float] = None,
                      remote_penalty: float = 0.5) -> PlacementTraffic:
    """Compile a (replicas, perm) placement into :class:`PlacementTraffic`.

    Fully local placements (node locality 1.0) produce zero fetch traffic
    and unit map factors — the sim job then runs exactly as an un-bridged
    submission."""
    if input_units is None:
        input_units = float(p.Q)    # one subfile's intermediate size at d=1;
        # use traffic_for_result (or pass input_units) to scale by a job's d
    load = nonlocal_load(p, replicas, perm)
    racks = np.arange(p.K) // p.Kr
    intra = np.zeros(p.P)
    np.add.at(intra, racks, load.intra_fetch * float(input_units))
    cross = float(load.rack_miss.sum()) * float(input_units)
    node, rack = locality_of_perm(p, replicas, perm)
    factors = map_work_factors(p, replicas, perm, remote_penalty)
    return PlacementTraffic(cross, tuple(intra.tolist()),
                            tuple(factors.tolist()), node, rack)


def traffic_for_result(result: PlacementResult, d: int = 1,
                       remote_penalty: float = 0.5) -> PlacementTraffic:
    """:class:`PlacementTraffic` of a solved :class:`PlacementResult`,
    scaling one subfile's input to Q * d value-units."""
    p = result.params
    return placement_traffic(p, result.replicas, result.perm,
                             input_units=float(p.Q * d),
                             remote_penalty=remote_penalty)


def simulate_placement(result: PlacementResult, topology: RackTopology,
                       spec: Optional[JobSpec] = None,
                       cost_model: CostModel = CostModel(),
                       stragglers: Optional[StragglerModel] = None,
                       seed: int = 0, d: int = 1,
                       remote_penalty: float = 0.5,
                       check: bool = True) -> JobStats:
    """Single hybrid job on an empty cluster under ``result``'s placement —
    the Table-II-in-time-units primitive.  ``spec`` defaults to a job sized
    exactly by the placement's SchemeParams."""
    p = result.params
    if spec is None:
        spec = JobSpec("placement_probe", p.N, p.Q, d)
    sim = ClusterSim(topology, p.K, cost_model, stragglers, seed)
    sim.submit(spec, "hybrid", p.r, time=spec.arrival, check=check,
               placement=traffic_for_result(result, spec.d, remote_penalty))
    (stats,) = sim.run()
    return stats


def jct_gap(opt: PlacementResult, ran: PlacementResult,
            topology: RackTopology, cost_model: CostModel = CostModel(),
            d: int = 1, remote_penalty: float = 0.5,
            seed: int = 0) -> Tuple[float, float]:
    """(jct_random, jct_optimized) of two placements of the SAME instance
    under identical simulator settings — what 64% vs 10% node locality buys
    in seconds."""
    j_ran = simulate_placement(ran, topology, cost_model=cost_model,
                               seed=seed, d=d,
                               remote_penalty=remote_penalty).jct
    j_opt = simulate_placement(opt, topology, cost_model=cost_model,
                               seed=seed, d=d,
                               remote_penalty=remote_penalty).jct
    return j_ran, j_opt
