"""Joint optimization of replica placement AND task assignment.

The paper fixes the storage replica placement (random, HDFS-style) and only
optimizes the Map-task assignment around it (Section IV).  This module
closes the loop with alternating maximization:

    repeat:
      1. assignment step — given replicas, solve Theorem IV.1 with any
         registered solver (flow = exact);
      2. replication step — given the assignment, move each subfile's
         replicas onto the servers that MAP it, subject to a per-server
         storage-capacity cap (ceil(N * r_f / K) — the balanced-storage
         constraint a real storage tier enforces).

Step 1 maximizes the objective exactly over permutations; step 2 can only
raise a subfile's own locality score (its mapping servers are where its C
contribution comes from), so the best-seen (replicas, perm) pair improves
monotonically — the returned iterate is the argmax over rounds, and the
recorded history is non-decreasing.  Convergence is typically 2-3 rounds to
node locality ~min(r_f, r)/r-capped values that no fixed-placement solver
can reach (Table II's 64% vs the joint ~100%).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from ..core.assignment import hybrid_group_of_slot
from ..core.params import SchemeParams
from .objectives import group_servers, place_replicas
from .solvers import PlacementResult, solve


@dataclasses.dataclass(frozen=True)
class JointResult:
    """Outcome of the alternating loop: the best placement found plus the
    per-round objective/locality trajectory."""
    best: PlacementResult
    history: List[PlacementResult]          # one entry per round (best-so-far)
    rounds_run: int
    converged: bool                         # stopped before the round budget


def replicate_for_assignment(p: SchemeParams, perm: Sequence[int],
                             prev_replicas: np.ndarray) -> np.ndarray:
    """Replication step: move replicas onto each subfile's mapping servers
    under the balanced-storage cap ceil(N * r_f / K) per server.

    Greedy over slots: subfile i mapped at group g gets up to r_f replicas
    on g's servers (least-loaded first, respecting the cap); remaining
    replicas keep the subfile's PREVIOUS servers where possible (they cost
    nothing to keep — no data movement) and otherwise fall to the globally
    least-loaded servers outside the subfile's racks.
    """
    perm = np.asarray(perm, dtype=np.int64)
    groups = np.asarray(group_servers(p), dtype=np.int64)       # [G, r]
    srvs_of_slot = groups[hybrid_group_of_slot(p)]              # [N, r]
    cap = -(-p.N * p.r_f // p.K)                                # ceil
    load = np.zeros(p.K, dtype=np.int64)
    out = np.full((p.N, p.r_f), -1, dtype=np.int64)
    # process slots in a load-aware order: subfiles first, so every subfile
    # gets a fair shot at its own mapping servers before caps fill
    for slot in range(p.N):
        i = int(perm[slot])
        chosen: List[int] = []
        for s in sorted(srvs_of_slot[slot].tolist(), key=lambda s: load[s]):
            if len(chosen) == p.r_f:
                break
            if load[s] < cap:
                chosen.append(int(s))
                load[s] += 1
        # keep previous replicas (free), then least-loaded fallback
        for s in prev_replicas[i]:
            if len(chosen) == p.r_f:
                break
            s = int(s)
            if s not in chosen and load[s] < cap:
                chosen.append(s)
                load[s] += 1
        if len(chosen) < p.r_f:
            for s in np.argsort(load, kind="stable"):
                if len(chosen) == p.r_f:
                    break
                s = int(s)
                if s not in chosen and load[s] < cap:
                    chosen.append(s)
                    load[s] += 1
        assert len(chosen) == p.r_f, "capacity infeasible: r_f > K?"
        out[i] = chosen
    return out


def joint_optimize(p: SchemeParams, seed: int = 0, solver: str = "flow",
                   lam: float = 0.8, rounds: int = 4,
                   init_replicas: Optional[np.ndarray] = None,
                   **solver_kwargs) -> JointResult:
    """Alternate assignment and replication steps for up to ``rounds``
    rounds, stopping early when the objective stops improving.  The
    returned ``best`` is the highest-objective (replicas, perm) pair seen
    (monotone by construction even if a replication step regresses)."""
    if p.r_f > p.K:
        raise ValueError("joint optimization needs r_f <= K")
    rng = np.random.default_rng(seed)
    replicas = (place_replicas(p, rng) if init_replicas is None
                else np.asarray(init_replicas))
    best: Optional[PlacementResult] = None
    history: List[PlacementResult] = []
    rounds_run = 0
    converged = False
    for _ in range(max(rounds, 1)):
        rounds_run += 1
        res = solve(p, replicas, solver, lam, rng=rng, **solver_kwargs)
        if best is None or res.objective > best.objective + 1e-9:
            best = res
            history.append(best)
            replicas = replicate_for_assignment(p, best.perm, best.replicas)
        else:
            history.append(best)
            converged = True             # no improvement: stop early
            break
    assert best is not None
    return JointResult(best, history, rounds_run, converged)
