"""Table II drivers: multi-trial solver comparisons with mean ± std.

The paper's Table II averages over random replica-placement instances; the
original ``table2_experiment`` drew its trials from one rng stream and
reported bare means.  :func:`table2_trials` keeps that exact draw sequence
(replicas, then the random baseline permutation, per trial — so the legacy
numbers are reproduced bit-for-bit) while running EVERY registered solver
per trial from independently spawned child rngs, and reporting mean AND
std so Table II comparisons stop being single-draw noise.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.params import SchemeParams
from .objectives import (locality_matrix, locality_of_perm, perm_objective,
                         place_replicas)
from .solvers import PlacementResult, random_perm, solve, solver_rng

DEFAULT_SOLVERS = ("random", "greedy", "flow", "local_search", "anneal_jax")


@dataclasses.dataclass(frozen=True)
class SolverTrialStats:
    """Per-solver aggregate over trials (localities in [0, 1])."""
    solver: str
    node_mean: float
    node_std: float
    rack_mean: float
    rack_std: float
    objective_mean: float
    wall_s_mean: float

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Table2Trials:
    """All trials of one Table II row: per-solver stats + raw results."""
    params: SchemeParams
    lam: float
    n_trials: int
    stats: Dict[str, SolverTrialStats]
    trials: List[Dict[str, PlacementResult]]   # [n_trials][solver]


def table2_trials(p: SchemeParams, lam: float = 0.8, seed: int = 0,
                  n_trials: int = 5, policy: str = "uniform",
                  solvers: Sequence[str] = DEFAULT_SOLVERS,
                  per_solver_kwargs: Optional[Dict[str, Dict]] = None,
                  ) -> Table2Trials:
    """Run ``n_trials`` independent replica-placement instances and every
    solver in ``solvers`` on each.

    Draw-order contract: per trial, ``place_replicas`` then one
    ``rng.permutation`` (the random baseline) are drawn from the MASTER rng
    — exactly the legacy ``table2_experiment`` sequence, so 'random',
    'greedy' and 'flow' reproduce its historical numbers exactly.  All
    other solvers consume child rngs keyed on (seed, trial, solver NAME)
    via :func:`repro.placement.solvers.solver_rng`, so adding, removing or
    reordering solvers never perturbs the rest.
    """
    rng = np.random.default_rng(seed)
    kw = per_solver_kwargs or {}
    trials: List[Dict[str, PlacementResult]] = []
    for trial in range(n_trials):
        replicas = place_replicas(p, rng, policy)
        C = locality_matrix(p, replicas, lam)
        rp = random_perm(p, rng)        # master-stream draw (legacy order)
        row: Dict[str, PlacementResult] = {}
        for name in solvers:
            if name == "random":
                t0 = time.perf_counter()
                row[name] = _scored(p, replicas, rp, "random", lam, C,
                                    time.perf_counter() - t0)
            else:
                row[name] = solve(p, replicas, name, lam,
                                  rng=solver_rng(seed, name, trial), C=C,
                                  **kw.get(name, {}))
        trials.append(row)

    stats = {}
    for name in solvers:
        rs = [t[name] for t in trials]
        stats[name] = SolverTrialStats(
            name,
            float(np.mean([r.node_locality for r in rs])),
            float(np.std([r.node_locality for r in rs])),
            float(np.mean([r.rack_locality for r in rs])),
            float(np.std([r.rack_locality for r in rs])),
            float(np.mean([r.objective for r in rs])),
            float(np.mean([r.wall_s for r in rs])))
    return Table2Trials(p, lam, n_trials, stats, trials)


def _scored(p: SchemeParams, replicas: np.ndarray, perm: np.ndarray,
            solver: str, lam: float, C: np.ndarray,
            wall: float) -> PlacementResult:
    node, rack = locality_of_perm(p, replicas, perm)
    return PlacementResult(p, replicas, np.asarray(perm), solver, lam,
                           perm_objective(p, C, perm), node, rack, wall)


# ---------------------------------------------------------------------------
# Legacy Table II driver (back-compat: repro.core.locality re-exports these)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LocalityResult:
    node_random: float
    rack_random: float
    node_opt: float
    rack_opt: float
    node_greedy: float
    rack_greedy: float
    # mean ± std upgrade: stds of the same six quantities (0.0 for trials=1)
    node_random_std: float = 0.0
    rack_random_std: float = 0.0
    node_opt_std: float = 0.0
    rack_opt_std: float = 0.0
    node_greedy_std: float = 0.0
    rack_greedy_std: float = 0.0


def table2_experiment(p: SchemeParams, lam: float = 0.8, seed: int = 0,
                      trials: int = 5, policy: str = "uniform",
                      solver: str = "optimal") -> LocalityResult:
    """Run Table II's comparison for one row, averaged over ``trials``
    random replica placements (now also reporting per-metric std).  The
    historical mean fields are bit-identical to the pre-registry
    implementation."""
    opt_name = "flow" if solver == "optimal" else "greedy"
    res = table2_trials(p, lam, seed, trials, policy,
                        solvers=("random", opt_name, "greedy")
                        if opt_name != "greedy" else ("random", "greedy"))
    s_ran = res.stats["random"]
    s_opt = res.stats[opt_name]
    s_grd = res.stats["greedy"]
    return LocalityResult(
        s_ran.node_mean, s_ran.rack_mean, s_opt.node_mean, s_opt.rack_mean,
        s_grd.node_mean, s_grd.rack_mean,
        s_ran.node_std, s_ran.rack_std, s_opt.node_std, s_opt.rack_std,
        s_grd.node_std, s_grd.rack_std)
