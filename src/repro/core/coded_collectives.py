"""Distributed realization of the Hybrid Coded MapReduce shuffle in JAX.

Two executable forms:

1. :func:`hybrid_shuffle` — a shard_map program over a ('rack', 'server')
   mesh performing the paper's two-stage shuffle with `jax.lax.all_to_all`:
   a cross-rack stage over the 'rack' axis, then an intra-rack stage over the
   'server' axis.  Works for ANY map-replication factor r in [1, P] (the
   paper's Sec. III construction; Sec. IV optimizes the r = 2 instance,
   still available as the :func:`hybrid_shuffle_r2` alias).  Each of the r
   replicas of a block sources 1/r of it, which achieves the receive-side
   optimum  QN/r * (1 - r/P) * r = QN(1 - r/P)  pair receptions per stage-1
   exchange on point-to-point links.

   Plan layout (general r): layer j's NP/K subfiles are grouped by the
   C(P, r) rack r-subsets, M = (NP/K)/C(P, r) subfiles per subset, in
   lexicographic subset order — the canonical *layer table*.  Rack i maps
   the C(P-1, r-1) subsets containing i.  For a destination rack z outside
   a subset T ∋ i, sender i contributes the share of T's M subfiles at slice
   [pos*M/r, (pos+1)*M/r) where pos = T.index(i): the r senders' shares are
   disjoint and cover T's block, so every layer-table row is received exactly
   once and `at[...].add` == `at[...].set`.

   Fidelity note (see docs/shuffle.md): the paper counts a multicast packet
   ONCE at the root switch, giving the stronger (QN/r)(1 - r/P)
   *switch-traversal* cost.  TPU ICI/DCN expose no multicast primitive, so
   the executable path realizes the receive-side optimum while the
   switch-traversal metric is reproduced bit-exactly by the schedule
   simulator (:mod:`repro.core.shuffle_plan`).  For SUM-reducible shuffles
   (gradient aggregation) the linear-combining gain *is* natively realized on
   the wire by reduce-scatter — see :mod:`repro.core.gradient_sync`.

2. :func:`plan_shuffle_reference` — a dense single-device oracle for
   validating the distributed outputs bit-exactly.

Plan compilation (:func:`compile_hybrid_plan`) builds all index tables with
vectorized NumPy construction — no per-element Python loops or
``list.index`` scans — and is memoized with an LRU cache keyed on the
(hashable, frozen) :class:`SchemeParams`, so recompiling a seen config is
O(1).  Cached plans are shared: treat their arrays as immutable.

Data model: intermediate values form V[N, Q, d] (subfile, key, payload);
reducer of key q needs q's value on ALL N subfiles.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from math import comb
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .assignment import hybrid_assignment, rack_subsets
from .params import SchemeParams
from .plan_registry import (HybridShufflePlan, get_plan_compiler,
                            plan_families, register_plan_compiler)
from ..distributed.meshes import shard_map


# ---------------------------------------------------------------------------
# Plan compilation: static index tables for the general-r hybrid shuffle
# ---------------------------------------------------------------------------
#
# The plan schema (HybridShufflePlan) and the family registry live in
# repro.core.plan_registry; this module registers the paper's binomial
# construction and hosts the family-agnostic executable paths.  The
# resolvable-design family is registered by repro.core.resolvable
# (imported at the bottom of this module).


@register_plan_compiler("binomial")
def _compile_hybrid_plan_impl(p: SchemeParams,
                              perm: Tuple[int, ...] | None = None
                              ) -> HybridShufflePlan:
    """Uncached binomial plan compilation for any r in [1, P] with r | M.

    All tables are built by vectorized index arithmetic on the structural
    (layer, subset, w) coordinates; cost is O(N + P^2 * C(P, r)).

    ``perm`` places subfile ``perm[slot]`` into each structural slot (the
    Section-IV locality degree of freedom); every positional table is
    perm-independent — only the subfile-id tables (``local_subfiles``,
    ``layer_subfiles``) change, so a locality-optimized plan shuffles
    byte-identically to the canonical one.
    """
    p.validate_hybrid()
    r = p.r
    M = p.M
    if M % r != 0:
        raise ValueError(f"executable hybrid plan needs r | M; M={M} r={r}")
    a = hybrid_assignment(p, perm=list(perm) if perm is not None else None)
    subsets = np.asarray(rack_subsets(p.P, r), dtype=np.int64)   # [n_sub, r]
    n_sub = subsets.shape[0]
    slot = np.asarray(a.meta["slot_of_subfile"], dtype=np.int64)  # [N, 3]

    share = M // r                         # rows each replica sources
    n_layer = p.subfiles_per_layer
    c_loc = comb(p.P - 1, r - 1)           # subsets containing a given rack
    c_pair = comb(p.P - 2, r - 1) if p.P >= 2 else 0   # i in T, z not in T
    n_loc = c_loc * M
    n_send = c_pair * share

    # subfile id of each structural slot: S[layer, subset, w]
    S = np.empty((p.Kr, n_sub, M), dtype=np.int64)
    S[slot[:, 0], slot[:, 1], slot[:, 2]] = np.arange(p.N)

    # rack-membership tables over subsets
    t_ids = np.repeat(np.arange(n_sub), r)
    member = np.zeros((p.P, n_sub), dtype=bool)
    member[subsets.ravel(), t_ids] = True              # member[i, t]: i in T_t
    pos_in = np.zeros((p.P, n_sub), dtype=np.int64)
    pos_in[subsets.ravel(), t_ids] = np.tile(np.arange(r), n_sub)

    # subsets containing each rack (ascending) and each subset's rank therein
    ts = np.nonzero(member)[1].reshape(p.P, c_loc)     # [P, c_loc]
    rank = np.zeros((p.P, n_sub), dtype=np.int64)
    rank[np.arange(p.P)[:, None], ts] = np.arange(c_loc)[None, :]

    # layer table is rack-independent; local tables are layer-independent:
    # store broadcast views to keep the [P, Kr, ...] interface without copies
    layer_table = np.broadcast_to(S.reshape(1, p.Kr, n_layer),
                                  (p.P, p.Kr, n_layer))
    local_subfiles = np.ascontiguousarray(
        S[:, ts, :].transpose(1, 0, 2, 3).reshape(p.P, p.Kr, n_loc))
    local_mask = np.broadcast_to(
        np.repeat(member, M, axis=1)[:, None, :], (p.P, p.Kr, n_layer))
    local_pos = np.broadcast_to(
        (ts[:, :, None] * M + np.arange(M)).reshape(p.P, 1, n_loc),
        (p.P, p.Kr, n_loc))

    cross_send_pos = np.zeros((p.P, p.Kr, p.P, n_send), dtype=np.int64)
    cross_recv_pos = np.zeros((p.P, p.Kr, p.P, n_send), dtype=np.int64)
    n_known = max(r - 1, 0)
    mcast_comp_pos = np.zeros((p.P, p.P, n_send, r), dtype=np.int64)
    mcast_comp_rack = np.zeros((p.P, p.P, n_send, r), dtype=np.int64)
    mcast_known_pos = np.zeros((p.P, p.P, n_send, n_known), dtype=np.int64)
    mcast_known_rack = np.zeros((p.P, p.P, n_send, n_known), dtype=np.int64)
    if n_send:
        subset_index = {tuple(T): t for t, T in enumerate(subsets.tolist())}
        off = np.arange(share)
        for i in range(p.P):
            for z in range(p.P):
                if z == i:
                    continue
                # i's share of every subset it maps that z does not
                t_snd = np.nonzero(member[i] & ~member[z])[0]    # [c_pair]
                cross_send_pos[i, :, z, :] = (
                    rank[i, t_snd, None] * M
                    + pos_in[i, t_snd, None] * share + off).reshape(-1)
                # where z's share of the subsets i lacks lands in the table
                t_rcv = np.nonzero(member[z] & ~member[i])[0]
                cross_recv_pos[i, :, z, :] = (
                    t_rcv[:, None] * M
                    + pos_in[z, t_rcv, None] * share + off).reshape(-1)
                # --- coded multicast component tables ----------------------
                # Packet block a of the i -> z stream realizes the multicast
                # group S = T ∪ {z} (T = t_snd[a]): component c serves
                # receiver z2 in S \ {i} with i's share of T_{z2} = S \ {z2}.
                # The components depend only on (S, w), so the packet i sends
                # every receiver of S is identical — a true multicast payload.
                for a, t in enumerate(t_snd):
                    S = tuple(sorted(subsets[t].tolist() + [z]))
                    rows = slice(a * share, (a + 1) * share)
                    for c, z2 in enumerate(x for x in S if x != i):
                        t2 = subset_index[tuple(x for x in S if x != z2)]
                        mcast_comp_pos[i, z, rows, c] = (
                            rank[i, t2] * M + pos_in[i, t2] * share + off)
                        mcast_comp_rack[i, z, rows, c] = z2
                # Receiver i decoding source s = z's stream: packet block a
                # covers T = t_rcv[a] (∋ s, ∌ i), group S = T ∪ {i}; the
                # known components are s's shares of T_{z2}, z2 in S\{s, i} —
                # all mapped locally at i since i ∈ T_{z2}.
                for a, t in enumerate(t_rcv):
                    S = tuple(sorted(subsets[t].tolist() + [i]))
                    rows = slice(a * share, (a + 1) * share)
                    for c, z2 in enumerate(x for x in S if x not in (z, i)):
                        t2 = subset_index[tuple(x for x in S if x != z2)]
                        mcast_known_pos[i, z, rows, c] = (
                            rank[i, t2] * M + pos_in[z, t2] * share + off)
                        mcast_known_rack[i, z, rows, c] = z2
    return HybridShufflePlan(p, local_subfiles, cross_send_pos, layer_table,
                             cross_recv_pos, local_mask, n_send, local_pos,
                             mcast_comp_pos, mcast_comp_rack,
                             mcast_known_pos, mcast_known_rack)


# ---------------------------------------------------------------------------
# Plan cache: configurable LRU with per-family introspection
# ---------------------------------------------------------------------------
#
# The cache maxsize is configurable (the multi-job scheduler of `repro.sim`
# charges plan-compile latency on cache miss, and sweeps want to bound or
# disable caching): set the REPRO_PLAN_CACHE_MAXSIZE env var before import,
# or call :func:`configure_plan_cache` at runtime.  Entries are keyed on
# (params, perm, family) — two families of the same (params, perm) are
# distinct plans — and hit/miss counters are kept per family so the
# scheduler's compile-charge accounting stays honest when it prices
# binomial vs resolvable candidates of one job.

PLAN_CACHE_MAXSIZE_ENV = "REPRO_PLAN_CACHE_MAXSIZE"
_PLAN_CACHE_DEFAULT_MAXSIZE = 128


class FamilyCacheInfo(NamedTuple):
    hits: int
    misses: int


class PlanCacheInfo(NamedTuple):
    """CacheInfo of the plan cache, extended with per-family counters
    (``families`` maps family name -> :class:`FamilyCacheInfo`; families
    never compiled are absent)."""
    hits: int
    misses: int
    maxsize: int | None
    currsize: int
    families: Dict[str, FamilyCacheInfo]


def _plan_cache_default_maxsize() -> int:
    raw = os.environ.get(PLAN_CACHE_MAXSIZE_ENV, "")
    try:
        return int(raw)
    except ValueError:
        return _PLAN_CACHE_DEFAULT_MAXSIZE


def _drop_device_tables() -> None:
    # device_plan_tables is defined later in the module (it needs the plan
    # type); guard for the import-time configure_plan_cache() call
    fn = globals().get("device_plan_tables")
    if fn is not None:
        fn.cache_clear()


def _compile_plan_dispatch(p: SchemeParams, perm: Tuple[int, ...] | None,
                           family: str) -> HybridShufflePlan:
    """The cached unit: registry dispatch on the full (params, perm, family)
    key."""
    return get_plan_compiler(family)(p, perm)


def configure_plan_cache(maxsize: int | None = None):
    """(Re)build the LRU plan cache with the given maxsize (``None`` -> the
    ``REPRO_PLAN_CACHE_MAXSIZE`` env var, falling back to 128).  Drops all
    cached plans (and their on-device table uploads — see
    :func:`plan_cache_clear`) and zeroes the per-family counters; returns
    the new cache wrapper."""
    global _PLAN_CACHE
    if maxsize is None:
        maxsize = _plan_cache_default_maxsize()
    _PLAN_CACHE = functools.lru_cache(maxsize=maxsize)(_compile_plan_dispatch)
    _FAMILY_STATS.clear()
    _drop_device_tables()
    return _PLAN_CACHE


_FAMILY_STATS: Dict[str, list] = {}   # family -> [hits, misses]
_PLAN_CACHE = configure_plan_cache()


def compile_hybrid_plan(p: SchemeParams,
                        perm: Sequence[int] | None = None,
                        family: str = "binomial") -> HybridShufflePlan:
    """LRU-cached plan compilation; repeated calls for a seen
    (:class:`SchemeParams`, perm, family) return the SAME plan object in
    O(1).  ``perm`` is the Section-IV slot permutation of a
    locality-optimized placement (``repro.placement``); None is the
    canonical identity layout.  ``family`` selects the registered plan
    compiler (see :mod:`repro.core.plan_registry`): ``'binomial'`` is the
    paper's Sec. III construction, ``'resolvable'`` the SPC resolvable
    design of :mod:`repro.core.resolvable`."""
    key_perm = None if perm is None else tuple(int(x) for x in perm)
    before = _PLAN_CACHE.cache_info().misses
    plan = _PLAN_CACHE(p, key_perm, family)
    missed = _PLAN_CACHE.cache_info().misses > before
    st = _FAMILY_STATS.setdefault(family, [0, 0])
    st[1 if missed else 0] += 1
    return plan


def plan_cache_info() -> PlanCacheInfo:
    """:class:`PlanCacheInfo` of the plan cache — the scheduler reads the
    per-family counters to account compile cost on miss."""
    info = _PLAN_CACHE.cache_info()
    fams = {f: FamilyCacheInfo(h, m) for f, (h, m) in
            sorted(_FAMILY_STATS.items())}
    return PlanCacheInfo(info.hits, info.misses, info.maxsize, info.currsize,
                         fams)


def plan_cache_clear() -> None:
    """Drop all cached plans AND their on-device index tables:
    :func:`device_plan_tables` keys on plan identity, so a cleared plan
    cache would otherwise pin every evicted plan (and its device arrays)
    alive inside the tables cache.  Also zeroes the per-family counters."""
    _PLAN_CACHE.cache_clear()
    _FAMILY_STATS.clear()
    _drop_device_tables()


# Back-compat: existing call sites treat compile_hybrid_plan as the
# lru_cache wrapper itself.
compile_hybrid_plan.cache_info = plan_cache_info    # type: ignore[attr-defined]
compile_hybrid_plan.cache_clear = plan_cache_clear  # type: ignore[attr-defined]


def compile_hybrid_plan_r2(p: SchemeParams) -> HybridShufflePlan:
    """Back-compat alias: the r = 2 instance of :func:`compile_hybrid_plan`
    (rejects other r, as the pre-general-r API did)."""
    if p.r != 2:
        raise ValueError("compile_hybrid_plan_r2 is the r = 2 special case; "
                         "use compile_hybrid_plan for general r")
    return compile_hybrid_plan(p)


# Back-compat name for the plan type (the r = 2 plan is just an instance).
HybridShufflePlanR2 = HybridShufflePlan


# ---------------------------------------------------------------------------
# Distributed execution (shard_map over ('rack', 'server'))
# ---------------------------------------------------------------------------

MULTICAST_MODES = ("unicast", "coded", "coded_xor")
COMBINE_IMPLS = ("xla", "pallas")


@dataclasses.dataclass(frozen=True, eq=False)
class DevicePlanTables:
    """The plan's index tables as on-device jnp constants (hoisted once per
    plan — see :func:`device_plan_tables`)."""
    send_pos: jax.Array          # [P, Kr, P, n_send]
    recv_pos: jax.Array          # [P, Kr, P, n_send]
    local_pos: jax.Array         # [P, Kr, n_loc]
    mcast_comp_pos: jax.Array    # [P, P, n_send, arity]
    mcast_comp_rack: jax.Array
    mcast_known_pos: jax.Array   # [P, P, n_send, arity-1]
    mcast_known_rack: jax.Array
    # stage-1 slot validity [P, P, n_send]; None = binomial's uniform rule
    cross_valid: Optional[jax.Array] = None


@functools.lru_cache(maxsize=128)
def device_plan_tables(plan: HybridShufflePlan) -> DevicePlanTables:
    """jnp views of a plan's index tables, transferred to device once and
    cached alongside the LRU'd plan (plans hash by identity, and
    :func:`compile_hybrid_plan` returns the same object per config, so a
    repeated shuffle never re-uploads its tables).

    The upload is forced OUTSIDE any active trace
    (``ensure_compile_time_eval``): the first call for a plan may happen
    inside a jitted caller (e.g. ``jax.jit(lambda v: hybrid_shuffle(...))``
    on a cold cache), and caching trace-scoped tracers here would leak them
    into every later caller."""
    with jax.ensure_compile_time_eval():
        return DevicePlanTables(
            jnp.asarray(plan.cross_send_pos),
            jnp.asarray(plan.cross_recv_pos),
            jnp.asarray(plan.local_pos),
            jnp.asarray(plan.mcast_comp_pos),
            jnp.asarray(plan.mcast_comp_rack),
            jnp.asarray(plan.mcast_known_pos),
            jnp.asarray(plan.mcast_known_rack),
            None if plan.cross_valid is None
            else jnp.asarray(plan.cross_valid))


def _combine(streams, multicast: str, combine_impl: str):
    """Encode r component streams (list of same-shape arrays) into one packet
    stream — the paper's f(.) (eq. (1), unit coefficients) or its GF(2)
    variant."""
    if combine_impl == "pallas":
        from ..kernels.coded_combine import ops as cc_ops
        if multicast == "coded_xor":
            return cc_ops.xor_encode(streams)
        return cc_ops.coded_encode(streams, jnp.ones(len(streams)))
    if multicast == "coded_xor":
        return functools.reduce(jnp.bitwise_xor, streams)
    return functools.reduce(jnp.add, [s.astype(jnp.float32) for s in streams]
                            ).astype(streams[0].dtype)


def _uncombine(f, known, multicast: str, combine_impl: str):
    """Recover the missing component of packet stream ``f`` from the r-1
    known components (receiver side information)."""
    if not known:
        return f
    if combine_impl == "pallas":
        from ..kernels.coded_combine import ops as cc_ops
        if multicast == "coded_xor":
            return cc_ops.xor_decode(f, known)
        return cc_ops.coded_decode(f, known, jnp.ones(len(known) + 1))
    if multicast == "coded_xor":
        return functools.reduce(jnp.bitwise_xor, known, f)
    acc = functools.reduce(jnp.add,
                           [k.astype(jnp.float32) for k in known])
    return (f.astype(jnp.float32) - acc).astype(f.dtype)


def shuffle_device_body(vals: jax.Array, plan: HybridShufflePlan,
                        tables: DevicePlanTables,
                        multicast: str = "unicast",
                        combine_impl: str = "xla",
                        patch: Optional[jax.Array] = None) -> jax.Array:
    """Per-device body of the two-stage hybrid shuffle, general r.

    Runs inside a shard_map over ('rack', 'server').  ``vals`` is THIS
    device's [n_loc, Q, d] mapped values (rows ordered as
    ``plan.local_subfiles[i, j]``); returns its [N, q_srv, d] reduce rows
    (order = :func:`reduce_ready_order`).  Shared by :func:`hybrid_shuffle`
    and the fused device-resident pipeline of :mod:`repro.mapreduce.engine`.

    ``multicast='coded'`` replaces raw stage-1 rows with the paper's coded
    multicast packets f(v_1..v_arity) (unit coefficients), decoded at
    receivers from replicated-map side information; ``'coded_xor'`` is the
    GF(2) variant (integer payloads, bit-exact).  The packet arity is the
    plan's ``mcast_arity`` (r for binomial, r - 1 for resolvable);
    single-component streams degenerate to unicast.  ``combine_impl``
    selects the encode/decode implementation: ``'xla'`` (jnp adds) or
    ``'pallas'`` (the fused single-HBM-pass kernels of
    :mod:`repro.kernels.coded_combine`, interpret-mode off TPU).

    ``patch`` is this device's [n_layer, q_rack, d] additive stage-1 table
    correction — the degraded-recovery path of :mod:`repro.core.degraded`
    injects re-mapped orphan rows through it (those rows receive nothing
    and their local fill is zero, so add == set).  ``None`` costs nothing.
    """
    if multicast not in MULTICAST_MODES:
        raise ValueError(f"multicast must be one of {MULTICAST_MODES}")
    if combine_impl not in COMBINE_IMPLS:
        raise ValueError(f"combine_impl must be one of {COMBINE_IMPLS}")
    p = plan.params
    q_rack, q_srv = p.Q // p.P, p.Q // p.K
    n_layer = p.subfiles_per_layer
    d = vals.shape[-1]
    n_send = plan.n_send
    arity = plan.mcast_arity
    coded = multicast != "unicast" and arity >= 2

    i = jax.lax.axis_index("rack")
    j = jax.lax.axis_index("server")
    my_local = tables.local_pos[i, j]                # [n_loc]
    key_starts = jnp.arange(p.P) * q_rack
    key_off = jnp.arange(q_rack)

    # ---- Stage 1: cross-rack all_to_all over 'rack' ------------------------
    table = jnp.zeros((n_layer, q_rack, d), vals.dtype)
    my_keys = jax.lax.dynamic_slice_in_dim(vals, i * q_rack, q_rack, 1)
    table = table.at[my_local].set(my_keys)          # locally mapped rows
    if n_send > 0:
        if coded:
            # encode: gather the arity components of every packet of every
            # destination stream — component c of packet m to rack z is a
            # locally mapped row restricted to rack mcast_comp_rack[...,c]'s
            # key block — then combine with f(.)
            comp_pos = tables.mcast_comp_pos[i]      # [P, n_send, arity]
            cols = (tables.mcast_comp_rack[i][..., None] * q_rack
                    + key_off)                       # [P, n_send, ar, q_rack]
            comps = vals[comp_pos[..., None], cols]  # [P, n_send, ar, qr, d]
            blocks = _combine([comps[:, :, c] for c in range(arity)],
                              multicast, combine_impl)
        else:
            my_send = tables.send_pos[i, j]          # [P, n_send]

            def build_block(z):
                rows = jnp.take(vals, my_send[z], axis=0)   # [n_send, Q, d]
                return jax.lax.dynamic_slice_in_dim(
                    rows, key_starts[z], q_rack, 1)         # [n_send, qr, d]
            blocks = jax.vmap(build_block)(jnp.arange(p.P))  # [P,n_send,qr,d]
        recvd = jax.lax.all_to_all(blocks, "rack", split_axis=0,
                                   concat_axis=0, tiled=True)
        if coded:
            # decode: subtract the arity-1 known components (rows this
            # device mapped itself — the replicated-map side information)
            recvd = recvd.reshape(p.P, n_send, q_rack, d)
            kcols = (tables.mcast_known_rack[i][..., None] * q_rack
                     + key_off)                      # [P, n_send, ar-1, qr]
            known = vals[tables.mcast_known_pos[i][..., None], kcols]
            recvd = _uncombine(recvd,
                               [known[:, :, c] for c in range(arity - 1)],
                               multicast, combine_impl)
        my_recv = tables.recv_pos[i, j]
        flat_dst = my_recv.reshape(-1)                   # [P*n_send]
        flat_src = recvd.reshape(p.P * n_send, q_rack, d)
        if tables.cross_valid is None:
            # binomial: every slot from a distinct source rack is real
            valid = (jnp.repeat(jnp.arange(p.P), n_send) != i)
        elif tables.cross_valid.ndim == 4:
            # degraded plans: per-LAYER validity (repair streams differ by
            # which servers of the layer died)
            valid = tables.cross_valid[i, j].reshape(-1)
        else:
            # families with padded streams (resolvable): per-slot mask
            valid = tables.cross_valid[i].reshape(-1)
        # the senders' shares are disjoint slices of each block, so target
        # rows are hit at most once => add == set
        table = table.at[flat_dst].add(
            jnp.where(valid[:, None, None], flat_src, 0))
    if patch is not None:
        table = table + patch

    # ---- Stage 2: intra-rack all_to_all over 'server' ----------------------
    per_srv = table.reshape(n_layer, p.Kr, q_srv, d).transpose(1, 0, 2, 3)
    gathered = jax.lax.all_to_all(per_srv, "server", split_axis=0,
                                  concat_axis=0, tiled=True)
    return gathered.reshape(p.Kr * n_layer, q_srv, d)


def hybrid_shuffle(values_local: jax.Array, plan: HybridShufflePlan,
                   mesh: Mesh, multicast: str = "unicast",
                   combine_impl: str = "xla") -> jax.Array:
    """Two-stage hybrid shuffle, general r.

    values_local: [K, n_loc, Q, d], axis 0 sharded over ('rack','server');
      row (i*Kr + j) = device (i, j)'s mapped subfile values, ordered as
      ``plan.local_subfiles[i, j]``.
    Returns [K, N, q_srv, d]: per device, values of ALL N subfiles for its own
      q_srv reduce keys, rows ordered as :func:`reduce_ready_order`.

    ``multicast`` / ``combine_impl`` select the stage-1 wire format and the
    f(.) implementation — see :func:`shuffle_device_body`.
    """
    tables = device_plan_tables(plan)

    def device_fn(vals):                             # [1, n_loc, Q, d]
        return shuffle_device_body(vals[0], plan, tables, multicast,
                                   combine_impl)[None]

    # pallas_call has no shard_map replication rule on jax 0.4.x; the body
    # is fully per-device anyway, so the check adds nothing
    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(("rack", "server")),),
                   out_specs=P(("rack", "server")),
                   check=combine_impl != "pallas")
    return fn(values_local)


def hybrid_shuffle_r2(values_local: jax.Array, plan: HybridShufflePlan,
                      mesh: Mesh) -> jax.Array:
    """Back-compat alias for :func:`hybrid_shuffle` (r = 2 plans and any
    other compiled plan run through the identical program)."""
    return hybrid_shuffle(values_local, plan, mesh)


def reduce_ready_order(plan: HybridShufflePlan) -> np.ndarray:
    """Global subfile id of each output row of :func:`hybrid_shuffle`,
    per device: [P, Kr, N] (layer-major, canonical layer-table order)."""
    p = plan.params
    flat = np.asarray(plan.layer_subfiles).reshape(p.P, p.N)
    return np.broadcast_to(flat[:, None, :], (p.P, p.Kr, p.N))


def reduce_output_keys(plan: HybridShufflePlan) -> np.ndarray:
    """Global key id of each reduce row produced by server s: [K, Q/K].

    Output assembly must place server s's row q at global key
    ``reduce_output_keys(plan)[s, q]`` — derived from the key partition
    explicitly rather than assuming the flat [K * Q/K] order IS key order
    (true only for the default contiguous partition)."""
    p = plan.params
    return np.asarray([list(p.keys_of_server(s)) for s in range(p.K)],
                      dtype=np.int64)


def pack_local_values(values: np.ndarray,
                      plan: HybridShufflePlan) -> np.ndarray:
    """Distribute dense V[N, Q, d] into the per-device layout expected by
    :func:`hybrid_shuffle`: [K, n_loc, Q, d]."""
    p = plan.params
    return values[plan.local_subfiles.reshape(p.K, -1)]


def plan_transfer_matrices(plan: HybridShufflePlan,
                           multicast: str = "coded") -> Dict[str, np.ndarray]:
    """Per-round transfer matrices of the EXECUTABLE hybrid shuffle.

    Returns the actual traffic the compiled plan moves (all layers summed),
    in <key, value> pairs:

      * ``cross_rack_matrix`` [P, P]: stage-1 pairs the root switch carries
        from rack i to rack z.  ``multicast='unicast'`` counts the wire
        format of a unicast realization (each destination stream a separate
        copy); ``'coded'`` / ``'coded_xor'`` count the paper metric — each
        coded packet serves ``mcast_arity`` destination racks and traverses
        the root ONCE, so 1/arity is attributed to each of its streams (row
        sums = per-sender root load, total = the family's closed-form cross
        cost: ``hybrid_cost(p).cross`` or
        ``hybrid_resolvable_cost(p).cross``).  Families with padded streams
        report the ACTUAL per-pair loads (padding carries no pairs), so the
        matrix is not uniform — resolvable same-class rack pairs exchange
        nothing.
      * ``intra_per_rack`` [P]: stage-2 pairs through each ToR switch
        (identical per rack by symmetry; total = the closed-form intra
        cost, the same expression for both families).

    Degraded plans (4-dim ``cross_valid`` — see :mod:`repro.core.degraded`)
    are handled too: their stage-1 routing is per-layer repair unicast, so
    the matrix is counted straight off the valid slots (the multicast gain
    is forfeited during recovery regardless of ``multicast``).

    The `repro.sim` network model consumes these loads, so simulated traffic
    is the executable schedule — not a formula (their equality with the
    closed forms is nevertheless asserted in tests).
    """
    if multicast not in MULTICAST_MODES:
        raise ValueError(f"multicast must be one of {MULTICAST_MODES}")
    p = plan.params
    q_rack, q_srv = p.Q // p.P, p.Q // p.K
    intra_rack = float(p.Kr * (p.Kr - 1) * p.subfiles_per_layer * q_srv)
    cv = plan.cross_valid
    if cv is not None and getattr(cv, "ndim", 0) == 4:
        # valid slots summed over layers and slot axis: [recv i, src z]
        counts = cv.sum(axis=(1, 3)) if cv.size else np.zeros((p.P, p.P))
        return {"cross_rack_matrix": counts.T.astype(float) * q_rack,
                "intra_per_rack": np.full((p.P,), intra_rack)}
    arity = plan.mcast_arity
    gain = arity if (multicast != "unicast" and arity >= 2) else 1
    if plan.family == "resolvable":
        from .resolvable import shared_group_counts
        sh = p.M_res // (p.r - 1)
        cross = (shared_group_counts(p).astype(float)
                 * sh * p.Kr * q_rack / gain)
    else:
        per_stream = float(p.Kr * plan.n_send * q_rack) / gain
        cross = np.full((p.P, p.P), per_stream)
        np.fill_diagonal(cross, 0.0)
    return {"cross_rack_matrix": cross,
            "intra_per_rack": np.full((p.P,), intra_rack)}


def plan_shuffle_reference(values: np.ndarray, p: SchemeParams,
                           family: str = "binomial") -> np.ndarray:
    """Oracle: [K, N, q_srv, d] that a correct shuffle must deliver, in the
    row order of :func:`reduce_ready_order`."""
    plan = compile_hybrid_plan(p, family=family)
    order = reduce_ready_order(plan)
    q_srv = p.Q // p.K
    out = np.zeros((p.K, p.N, q_srv, values.shape[-1]), values.dtype)
    for i in range(p.P):
        for j in range(p.Kr):
            s = p.server_id(i, j)
            keys = list(p.keys_of_server(s))
            out[s] = values[order[i, j]][:, keys, :]
    return out


def simulate_plan_shuffle(values: np.ndarray, plan: HybridShufflePlan,
                          multicast: str = "unicast", *,
                          failed: Sequence[int] = (),
                          patch: Optional[np.ndarray] = None) -> np.ndarray:
    """Re-execute the exact data movement of :func:`hybrid_shuffle` with
    NumPy indexing: stage-1 table fill (local rows + per-source-rack
    received blocks), then the stage-2 intra-rack key split.  Independent of
    jax and of device count, so it validates the index tables of ANY
    registered plan family in-process — the decodability oracle of the
    tests and of ``benchmarks/scale_bench.py``.

    ``multicast='coded'`` re-executes the coded wire format instead: each
    stage-1 packet is the SUM of its ``mcast_arity`` components (built from
    the sender's ``mcast_comp_*`` tables) and the receiver decodes by
    subtracting its arity-1 locally-known components (``mcast_known_*``) —
    NumPy end to end, so it proves decodability of the multicast tables
    themselves.  Plans with padded streams contribute only their
    ``cross_valid`` slots, exactly like the device body's receive mask.

    ``failed`` (flat server ids) zeroes those devices' in-memory map outputs
    before the shuffle — the crash model of :mod:`repro.core.degraded` —
    and ``patch`` adds a [K, n_layer, q_rack, d] per-device stage-1
    correction (re-mapped orphan rows) after the table fill, mirroring the
    ``patch`` argument of :func:`shuffle_device_body`.  Together they make
    this oracle re-execute a DEGRADED plan exactly as the 8-device driver
    would, still independent of jax."""
    p = plan.params
    q_rack, q_srv = p.Q // p.P, p.Q // p.K
    n_layer = p.subfiles_per_layer
    d = values.shape[-1]
    local = pack_local_values(values, plan).reshape(
        p.P, p.Kr, -1, p.Q, d)                      # [P, Kr, n_loc, Q, d]
    if failed:
        local = local.copy()
        for s in failed:
            local[int(s) // p.Kr, int(s) % p.Kr] = 0
    arity = plan.mcast_arity
    coded = multicast == "coded" and arity >= 2

    # ---- Stage 1: per-device layer table over its rack's q_rack keys ------
    table = np.zeros((p.P, p.Kr, n_layer, q_rack, d), values.dtype)
    for i in range(p.P):
        keys_i = np.arange(i * q_rack, (i + 1) * q_rack)
        for j in range(p.Kr):
            table[i, j, plan.local_pos[i, j]] = local[i, j][:, keys_i]
            if plan.n_send:
                for z in range(p.P):
                    if z == i:
                        continue
                    cv = plan.cross_valid
                    valid = (slice(None) if cv is None
                             else cv[i, j, z] if cv.ndim == 4
                             else cv[i, z])
                    dst = plan.cross_recv_pos[i, j, z][valid]
                    if not coded:
                        # what z sends to i: its share rows, i's rack keys
                        sent = local[z, j][plan.cross_send_pos[z, j, i]][
                            :, keys_i]
                        table[i, j, dst] = sent[valid]
                        continue
                    # sender z encodes packets for destination i
                    cpos = plan.mcast_comp_pos[z, i]     # [n_send, arity]
                    ckey = (plan.mcast_comp_rack[z, i][..., None] * q_rack
                            + np.arange(q_rack))         # [n_send, ar, qr]
                    f = local[z, j][cpos[..., None],
                                    ckey].sum(axis=1)    # [n_send, qr, d]
                    # receiver i decodes with its side information
                    kpos = plan.mcast_known_pos[i, z]    # [n_send, arity-1]
                    kkey = (plan.mcast_known_rack[i, z][..., None] * q_rack
                            + np.arange(q_rack))
                    side = local[i, j][kpos[..., None], kkey].sum(axis=1)
                    table[i, j, dst] = (f - side)[valid]
    if patch is not None:
        table = table + np.asarray(patch).reshape(
            p.P, p.Kr, n_layer, q_rack, d)

    # ---- Stage 2: intra-rack all_to_all == per-server key split -----------
    out = np.zeros((p.K, p.Kr * n_layer, q_srv, d), values.dtype)
    for i in range(p.P):
        for j in range(p.Kr):
            s = p.server_id(i, j)
            # device (i, j) collects key-chunk j of every layer jp's table
            out[s] = table[i, :, :, j * q_srv:(j + 1) * q_srv, :].reshape(
                p.Kr * n_layer, q_srv, d)
    return out


# Register the resolvable-design family (import side effect; kept at module
# bottom — resolvable.py needs only plan_registry/params/assignment, so no
# cycle, but its docstrings reference this module's executable paths).
from . import resolvable as _resolvable_family  # noqa: E402,F401

__all__ = [
    "HybridShufflePlan", "HybridShufflePlanR2", "register_plan_compiler",
    "get_plan_compiler", "plan_families", "compile_hybrid_plan",
    "compile_hybrid_plan_r2", "configure_plan_cache", "plan_cache_info",
    "plan_cache_clear", "PlanCacheInfo", "FamilyCacheInfo",
    "PLAN_CACHE_MAXSIZE_ENV", "MULTICAST_MODES", "COMBINE_IMPLS",
    "DevicePlanTables", "device_plan_tables", "shuffle_device_body",
    "hybrid_shuffle", "hybrid_shuffle_r2", "reduce_ready_order",
    "reduce_output_keys", "pack_local_values", "plan_transfer_matrices",
    "plan_shuffle_reference", "simulate_plan_shuffle",
]
