"""Distributed realization of the Hybrid Coded MapReduce shuffle in JAX.

Two executable forms:

1. :func:`hybrid_shuffle` — a shard_map program over a ('rack', 'server')
   mesh performing the paper's two-stage shuffle with `jax.lax.all_to_all`:
   a cross-rack stage over the 'rack' axis, then an intra-rack stage over the
   'server' axis.  Works for ANY map-replication factor r in [1, P] (the
   paper's Sec. III construction; Sec. IV optimizes the r = 2 instance,
   still available as the :func:`hybrid_shuffle_r2` alias).  Each of the r
   replicas of a block sources 1/r of it, which achieves the receive-side
   optimum  QN/r * (1 - r/P) * r = QN(1 - r/P)  pair receptions per stage-1
   exchange on point-to-point links.

   Plan layout (general r): layer j's NP/K subfiles are grouped by the
   C(P, r) rack r-subsets, M = (NP/K)/C(P, r) subfiles per subset, in
   lexicographic subset order — the canonical *layer table*.  Rack i maps
   the C(P-1, r-1) subsets containing i.  For a destination rack z outside
   a subset T ∋ i, sender i contributes the share of T's M subfiles at slice
   [pos*M/r, (pos+1)*M/r) where pos = T.index(i): the r senders' shares are
   disjoint and cover T's block, so every layer-table row is received exactly
   once and `at[...].add` == `at[...].set`.

   Fidelity note (see docs/shuffle.md): the paper counts a multicast packet
   ONCE at the root switch, giving the stronger (QN/r)(1 - r/P)
   *switch-traversal* cost.  TPU ICI/DCN expose no multicast primitive, so
   the executable path realizes the receive-side optimum while the
   switch-traversal metric is reproduced bit-exactly by the schedule
   simulator (:mod:`repro.core.shuffle_plan`).  For SUM-reducible shuffles
   (gradient aggregation) the linear-combining gain *is* natively realized on
   the wire by reduce-scatter — see :mod:`repro.core.gradient_sync`.

2. :func:`plan_shuffle_reference` — a dense single-device oracle for
   validating the distributed outputs bit-exactly.

Plan compilation (:func:`compile_hybrid_plan`) builds all index tables with
vectorized NumPy construction — no per-element Python loops or
``list.index`` scans — and is memoized with an LRU cache keyed on the
(hashable, frozen) :class:`SchemeParams`, so recompiling a seen config is
O(1).  Cached plans are shared: treat their arrays as immutable.

Data model: intermediate values form V[N, Q, d] (subfile, key, payload);
reducer of key q needs q's value on ALL N subfiles.
"""
from __future__ import annotations

import dataclasses
import functools
from math import comb

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .assignment import hybrid_assignment, rack_subsets
from .params import SchemeParams
from ..distributed.meshes import shard_map


# ---------------------------------------------------------------------------
# Plan compilation: static index tables for the general-r hybrid shuffle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class HybridShufflePlan:
    """Static index tables driving :func:`hybrid_shuffle` for any r."""
    params: SchemeParams
    # global subfile ids mapped at device (rack i, layer j): [P, Kr, n_loc]
    local_subfiles: np.ndarray
    # cross-stage: local subfile positions to send to rack z: [P, Kr, P, n_send]
    cross_send_pos: np.ndarray
    # canonical layer table (global subfile id per row): [P, Kr, n_layer]
    layer_subfiles: np.ndarray
    # positions in the layer table where rack z's block lands: [P, Kr, P, n_send]
    cross_recv_pos: np.ndarray
    # layer-table rows mapped locally: [P, Kr, n_layer] bool
    local_mask: np.ndarray
    n_send: int
    # layer-table position of each locally mapped subfile: [P, Kr, n_loc]
    local_pos: np.ndarray


@functools.lru_cache(maxsize=128)
def compile_hybrid_plan(p: SchemeParams) -> HybridShufflePlan:
    """Compile the static shuffle plan for any r in [1, P] with r | M.

    All tables are built by vectorized index arithmetic on the structural
    (layer, subset, w) coordinates; cost is O(N + P^2 * C(P, r)).
    """
    p.validate_hybrid()
    r = p.r
    M = p.M
    if M % r != 0:
        raise ValueError(f"executable hybrid plan needs r | M; M={M} r={r}")
    a = hybrid_assignment(p)
    subsets = np.asarray(rack_subsets(p.P, r), dtype=np.int64)   # [n_sub, r]
    n_sub = subsets.shape[0]
    slot = np.asarray(a.meta["slot_of_subfile"], dtype=np.int64)  # [N, 3]

    share = M // r                         # rows each replica sources
    n_layer = p.subfiles_per_layer
    c_loc = comb(p.P - 1, r - 1)           # subsets containing a given rack
    c_pair = comb(p.P - 2, r - 1) if p.P >= 2 else 0   # i in T, z not in T
    n_loc = c_loc * M
    n_send = c_pair * share

    # subfile id of each structural slot: S[layer, subset, w]
    S = np.empty((p.Kr, n_sub, M), dtype=np.int64)
    S[slot[:, 0], slot[:, 1], slot[:, 2]] = np.arange(p.N)

    # rack-membership tables over subsets
    t_ids = np.repeat(np.arange(n_sub), r)
    member = np.zeros((p.P, n_sub), dtype=bool)
    member[subsets.ravel(), t_ids] = True              # member[i, t]: i in T_t
    pos_in = np.zeros((p.P, n_sub), dtype=np.int64)
    pos_in[subsets.ravel(), t_ids] = np.tile(np.arange(r), n_sub)

    # subsets containing each rack (ascending) and each subset's rank therein
    ts = np.nonzero(member)[1].reshape(p.P, c_loc)     # [P, c_loc]
    rank = np.zeros((p.P, n_sub), dtype=np.int64)
    rank[np.arange(p.P)[:, None], ts] = np.arange(c_loc)[None, :]

    # layer table is rack-independent; local tables are layer-independent:
    # store broadcast views to keep the [P, Kr, ...] interface without copies
    layer_table = np.broadcast_to(S.reshape(1, p.Kr, n_layer),
                                  (p.P, p.Kr, n_layer))
    local_subfiles = np.ascontiguousarray(
        S[:, ts, :].transpose(1, 0, 2, 3).reshape(p.P, p.Kr, n_loc))
    local_mask = np.broadcast_to(
        np.repeat(member, M, axis=1)[:, None, :], (p.P, p.Kr, n_layer))
    local_pos = np.broadcast_to(
        (ts[:, :, None] * M + np.arange(M)).reshape(p.P, 1, n_loc),
        (p.P, p.Kr, n_loc))

    cross_send_pos = np.zeros((p.P, p.Kr, p.P, n_send), dtype=np.int64)
    cross_recv_pos = np.zeros((p.P, p.Kr, p.P, n_send), dtype=np.int64)
    if n_send:
        off = np.arange(share)
        for i in range(p.P):
            for z in range(p.P):
                if z == i:
                    continue
                # i's share of every subset it maps that z does not
                t_snd = np.nonzero(member[i] & ~member[z])[0]    # [c_pair]
                cross_send_pos[i, :, z, :] = (
                    rank[i, t_snd, None] * M
                    + pos_in[i, t_snd, None] * share + off).reshape(-1)
                # where z's share of the subsets i lacks lands in the table
                t_rcv = np.nonzero(member[z] & ~member[i])[0]
                cross_recv_pos[i, :, z, :] = (
                    t_rcv[:, None] * M
                    + pos_in[z, t_rcv, None] * share + off).reshape(-1)
    return HybridShufflePlan(p, local_subfiles, cross_send_pos, layer_table,
                             cross_recv_pos, local_mask, n_send, local_pos)


def compile_hybrid_plan_r2(p: SchemeParams) -> HybridShufflePlan:
    """Back-compat alias: the r = 2 instance of :func:`compile_hybrid_plan`
    (rejects other r, as the pre-general-r API did)."""
    if p.r != 2:
        raise ValueError("compile_hybrid_plan_r2 is the r = 2 special case; "
                         "use compile_hybrid_plan for general r")
    return compile_hybrid_plan(p)


# Back-compat name for the plan type (the r = 2 plan is just an instance).
HybridShufflePlanR2 = HybridShufflePlan


# ---------------------------------------------------------------------------
# Distributed execution (shard_map over ('rack', 'server'))
# ---------------------------------------------------------------------------

def hybrid_shuffle(values_local: jax.Array, plan: HybridShufflePlan,
                   mesh: Mesh) -> jax.Array:
    """Two-stage hybrid shuffle, general r.

    values_local: [K, n_loc, Q, d], axis 0 sharded over ('rack','server');
      row (i*Kr + j) = device (i, j)'s mapped subfile values, ordered as
      ``plan.local_subfiles[i, j]``.
    Returns [K, N, q_srv, d]: per device, values of ALL N subfiles for its own
      q_srv reduce keys, rows ordered as :func:`reduce_ready_order`.
    """
    p = plan.params
    q_rack, q_srv = p.Q // p.P, p.Q // p.K
    n_layer = p.subfiles_per_layer
    d = values_local.shape[-1]
    n_send = plan.n_send

    send_pos = jnp.asarray(plan.cross_send_pos)      # [P, Kr, P, n_send]
    recv_pos = jnp.asarray(plan.cross_recv_pos)
    local_pos = jnp.asarray(plan.local_pos)          # [P, Kr, n_loc]

    def device_fn(vals):                             # [1, n_loc, Q, d]
        vals = vals[0]
        i = jax.lax.axis_index("rack")
        j = jax.lax.axis_index("server")
        my_send = send_pos[i, j]                     # [P, n_send]
        my_recv = recv_pos[i, j]
        my_local = local_pos[i, j]                   # [n_loc]
        key_starts = jnp.arange(p.P) * q_rack

        # ---- Stage 1: cross-rack all_to_all over 'rack' --------------------
        table = jnp.zeros((n_layer, q_rack, d), vals.dtype)
        my_keys = jax.lax.dynamic_slice_in_dim(vals, i * q_rack, q_rack, 1)
        table = table.at[my_local].set(my_keys)      # locally mapped rows
        if n_send > 0:
            def build_block(z):
                rows = jnp.take(vals, my_send[z], axis=0)   # [n_send, Q, d]
                return jax.lax.dynamic_slice_in_dim(
                    rows, key_starts[z], q_rack, 1)         # [n_send, qr, d]
            blocks = jax.vmap(build_block)(jnp.arange(p.P))  # [P,n_send,qr,d]
            recvd = jax.lax.all_to_all(blocks, "rack", split_axis=0,
                                       concat_axis=0, tiled=True)
            flat_dst = my_recv.reshape(-1)                   # [P*n_send]
            flat_src = recvd.reshape(p.P * n_send, q_rack, d)
            valid = (jnp.repeat(jnp.arange(p.P), n_send) != i)
            # the r senders' shares are disjoint slices of each subset block,
            # so target rows are hit at most once => add == set
            table = table.at[flat_dst].add(
                jnp.where(valid[:, None, None], flat_src, 0))

        # ---- Stage 2: intra-rack all_to_all over 'server' ------------------
        per_srv = table.reshape(n_layer, p.Kr, q_srv, d).transpose(1, 0, 2, 3)
        gathered = jax.lax.all_to_all(per_srv, "server", split_axis=0,
                                      concat_axis=0, tiled=True)
        out = gathered.reshape(p.Kr * n_layer, q_srv, d)
        return out[None]

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(("rack", "server")),),
                   out_specs=P(("rack", "server")))
    return fn(values_local)


def hybrid_shuffle_r2(values_local: jax.Array, plan: HybridShufflePlan,
                      mesh: Mesh) -> jax.Array:
    """Back-compat alias for :func:`hybrid_shuffle` (r = 2 plans and any
    other compiled plan run through the identical program)."""
    return hybrid_shuffle(values_local, plan, mesh)


def reduce_ready_order(plan: HybridShufflePlan) -> np.ndarray:
    """Global subfile id of each output row of :func:`hybrid_shuffle`,
    per device: [P, Kr, N] (layer-major, canonical layer-table order)."""
    p = plan.params
    flat = np.asarray(plan.layer_subfiles).reshape(p.P, p.N)
    return np.broadcast_to(flat[:, None, :], (p.P, p.Kr, p.N))


def pack_local_values(values: np.ndarray,
                      plan: HybridShufflePlan) -> np.ndarray:
    """Distribute dense V[N, Q, d] into the per-device layout expected by
    :func:`hybrid_shuffle`: [K, n_loc, Q, d]."""
    p = plan.params
    return values[plan.local_subfiles.reshape(p.K, -1)]


def plan_shuffle_reference(values: np.ndarray, p: SchemeParams) -> np.ndarray:
    """Oracle: [K, N, q_srv, d] that a correct shuffle must deliver, in the
    row order of :func:`reduce_ready_order`."""
    plan = compile_hybrid_plan(p)
    order = reduce_ready_order(plan)
    q_srv = p.Q // p.K
    out = np.zeros((p.K, p.N, q_srv, values.shape[-1]), values.dtype)
    for i in range(p.P):
        for j in range(p.Kr):
            s = p.server_id(i, j)
            keys = list(p.keys_of_server(s))
            out[s] = values[order[i, j]][:, keys, :]
    return out
